"""Fig. 4(b,e) — memory overhead of each convolution algorithm on
cv1-cv12, exact (analytic, f32 bytes, batch=1 as on Mobile).  The paper's
headline: MEC ~3.2x less overhead than im2col on average.

Thin wrapper over the ``repro.bench`` registry: specs come from the
``table2`` suite; ``--format json`` emits the schema-validated report
(analytic fields only — memory numbers need no timing run).
"""
from __future__ import annotations

import json

import numpy as np

from repro.bench.harness import run_suite
from repro.bench.scenarios import CV_LAYERS, layer_spec
from repro.core.memory import ALL_OVERHEADS
from repro.launch.costmodel import pick_conv2d_algorithm


def rows(batch: int = 1):
    out = []
    for name in CV_LAYERS:
        s = layer_spec(name, batch=batch)
        mb = {alg: fn(s) * 4 / 2 ** 20 for alg, fn in ALL_OVERHEADS.items()}
        mb["ratio_im2col_mec"] = mb["im2col"] / mb["mec"]
        mb["name"] = name
        mb["auto"] = pick_conv2d_algorithm(s)   # conv2d front-end's choice
        out.append(mb)
    return out


def main(emit=print, fmt: str = "csv"):
    if fmt == "json":
        doc = run_suite("table2", with_hlo=False, with_timing=False)
        emit(json.dumps(doc, indent=2))
        return doc
    rs = rows()
    emit("table,name,us_per_call,derived")
    ratios = []
    for r in rs:
        ratios.append(r["ratio_im2col_mec"])
        emit(f"fig4b_memory,{r['name']},0,"
             f"im2col={r['im2col']:.2f}MB;mec={r['mec']:.2f}MB;"
             f"fft={r['fft']:.2f}MB;wino={r['winograd']:.2f}MB;"
             f"ratio={r['ratio_im2col_mec']:.2f}x;auto={r['auto']}")
    emit(f"fig4b_memory,geomean,0,"
         f"im2col/mec={float(np.exp(np.mean(np.log(ratios)))):.2f}x"
         f" (paper: ~3.2x avg)")
    return rs


if __name__ == "__main__":
    main()
