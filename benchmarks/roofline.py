"""Roofline table: three terms per (arch x shape) on the single-pod mesh.

Primary numbers come from the analytic cost model
(repro.launch.costmodel) because XLA's cost_analysis counts while-loop
(scan) bodies once (see costmodel docstring); the raw per-device HLO
numbers from the dry-run artifacts are attached as ``raw_*`` lower
bounds.  ``roofline_frac`` = useful-model-compute time / dominant term —
the §Perf score."""
from __future__ import annotations

import json
import pathlib

from repro.configs.archs import ARCHS
from repro.configs.shapes import SHAPES, cell_applicable
from repro.launch.costmodel import MeshShape, cell_cost
from repro.launch.hlo_analysis import HBM_BW, ICI_BW, PEAK_FLOPS

REPO = pathlib.Path(__file__).resolve().parents[1]
RESULTS = REPO / "results" / "dryrun"


def analyze_cell(arch: str, shape: str, mesh: MeshShape = MeshShape()):
    cfg = ARCHS[arch]
    cell = SHAPES[shape]
    s = cell.seq_len
    c = cell_cost(cfg, cell.kind, cell.global_batch, s, mesh)
    t_c = c["flops"] / (mesh.chips * PEAK_FLOPS)
    t_m = c["hbm_bytes_chip"] / HBM_BW
    t_x = c["coll_bytes_chip"] / ICI_BW
    dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))[1]
    t_model = c["model_flops"] / (mesh.chips * PEAK_FLOPS)
    frac = t_model / max(t_c, t_m, t_x)
    raw = {}
    f = RESULTS / f"{arch}__{shape}__pod.json"
    if f.exists():
        r = json.loads(f.read_text())
        raw = {"raw_flops_dev": r["per_device"]["flops"],
               "raw_coll_dev": r["per_device"]["collectives"]["total"],
               "raw_coll_mix": {k: v for k, v in
                                r["per_device"]["collectives"].items()
                                if isinstance(v, int) and v and k != "total"
                                and k != "count"},
               "peak_bytes_dev": r["per_device"]["memory"]["peak_bytes"]}
    return {"arch": arch, "shape": shape, "kind": cell.kind,
            "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
            "dominant": dom, "roofline_frac": frac,
            "useful_flop_ratio": c["model_flops"] / max(c["flops"], 1.0),
            **raw}


def all_rows(mesh: MeshShape = MeshShape()):
    rows = []
    for arch in ARCHS:
        for shape in SHAPES:
            if cell_applicable(arch, shape):
                rows.append(analyze_cell(arch, shape, mesh))
    return rows


def main(emit=print, fmt: str = "csv"):
    if fmt == "json":
        out = all_rows()
        emit(json.dumps(out, indent=2))
        return out
    emit("table,name,us_per_call,derived")
    for r in all_rows():
        emit(f"roofline,{r['arch']}__{r['shape']},"
             f"{max(r['t_compute_s'], r['t_memory_s'], r['t_collective_s'])*1e6:.0f},"
             f"tc={r['t_compute_s']*1e6:.0f}us;tm={r['t_memory_s']*1e6:.0f}us;"
             f"tx={r['t_collective_s']*1e6:.0f}us;dominant={r['dominant']};"
             f"useful={r['useful_flop_ratio']:.2f};"
             f"frac={r['roofline_frac']:.3f}")
    return all_rows()


if __name__ == "__main__":
    main()
