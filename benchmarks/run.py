"""Benchmark driver — one section per paper table/figure plus the
TPU-side analyses.  Default output is the legacy
``table,name,us_per_call,derived`` CSV; ``--format json`` passes through
to the ``repro.bench`` harness (schema-validated reports for the conv
sections, structured rows for the analytic ones).

  PYTHONPATH=src python -m benchmarks.run           # everything
  PYTHONPATH=src python -m benchmarks.run --only fig4b_memory
  PYTHONPATH=src python -m benchmarks.run --format json

A section that raises no longer aborts the run mid-loop: remaining
sections still execute, the traceback is printed, and the driver exits
non-zero listing every failed section.
"""
from __future__ import annotations

import argparse
import sys
import traceback

from benchmarks import (conv_memory, conv_runtime, ks_sweep, resnet101,
                        roofline, tpu_traffic)

SECTIONS = {
    "fig4b_memory": conv_memory.main,        # Fig 4(b,e): memory overhead
    "fig4cd_runtime": conv_runtime.main,     # Fig 4(c,d): runtime
    "fig4a_ks_sweep": ks_sweep.main,         # Fig 4(a): k/s sweep
    "table3_resnet101": resnet101.main,      # Table 3: ResNet-101 weighted
    "tpu_traffic": tpu_traffic.main,         # DESIGN §2: kernel HBM model
    "roofline": roofline.main,               # assignment §Roofline
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=sorted(SECTIONS))
    ap.add_argument("--format", choices=("csv", "json"), default="csv",
                    help="json routes conv sections through repro.bench")
    args = ap.parse_args()
    failures = []
    for name, fn in SECTIONS.items():
        if args.only and name != args.only:
            continue
        print(f"# === {name} ===")
        try:
            fn(fmt=args.format)
        except Exception:
            traceback.print_exc()
            failures.append(name)
            print(f"# === {name}: FAILED ===", file=sys.stderr)
    if failures:
        raise SystemExit(
            f"{len(failures)} benchmark section(s) failed: "
            + ", ".join(failures))


if __name__ == "__main__":
    main()
