"""Benchmark driver — one section per paper table/figure plus the
TPU-side analyses.  Prints ``table,name,us_per_call,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run           # everything
  PYTHONPATH=src python -m benchmarks.run --only fig4b_memory
"""
from __future__ import annotations

import argparse

from benchmarks import (conv_memory, conv_runtime, ks_sweep, resnet101,
                        roofline, tpu_traffic)

SECTIONS = {
    "fig4b_memory": conv_memory.main,        # Fig 4(b,e): memory overhead
    "fig4cd_runtime": conv_runtime.main,     # Fig 4(c,d): runtime
    "fig4a_ks_sweep": ks_sweep.main,         # Fig 4(a): k/s sweep
    "table3_resnet101": resnet101.main,      # Table 3: ResNet-101 weighted
    "tpu_traffic": tpu_traffic.main,         # DESIGN §2: kernel HBM model
    "roofline": roofline.main,               # assignment §Roofline
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=sorted(SECTIONS))
    args = ap.parse_args()
    for name, fn in SECTIONS.items():
        if args.only and name != args.only:
            continue
        print(f"# === {name} ===")
        fn()


if __name__ == "__main__":
    main()
