"""Fig. 4(a) — cv1 with the 11x11 kernel, stride swept 1..10: both the
memory-overhead ratio (exact) and runtime ratio (measured) of MEC vs
im2col improve with the k/s ratio (Eq. 4).

Thin wrapper over the ``repro.bench`` ``ks_sweep`` suite; ``--format
json`` emits the schema-validated report.
"""
from __future__ import annotations

import json

from repro.bench.harness import run_suite


def main(emit=print, fmt: str = "csv", iters: int = 3):
    doc = run_suite("ks_sweep", iters=iters, with_hlo=False)
    if fmt == "json":
        emit(json.dumps(doc, indent=2))
        return doc
    by_scenario = {}
    for r in doc["results"]:
        by_scenario.setdefault(r["scenario"], {})[r["algorithm"]] = r
    emit("table,name,us_per_call,derived")
    mem_ratio = None
    for name, algs in by_scenario.items():
        mec, i2c = algs["mecA"], algs["im2col"]
        s_ = mec["spec"]["s_h"]
        mem_ratio = i2c["overhead_elems"] / mec["overhead_elems"]
        emit(f"fig4a_ks_sweep,s={s_},{mec['us_per_call']:.0f},"
             f"mem_ratio={mem_ratio:.2f}x;"
             f"runtime_ratio={i2c['us_per_call'] / mec['us_per_call']:.2f}x;"
             f"k_over_s={mec['spec']['k_h'] / s_:.1f}")
    return mem_ratio


if __name__ == "__main__":
    main()
