"""Fig. 4(a) — cv1 with the 11x11 kernel, stride swept 1..10: both the
memory-overhead ratio (exact) and runtime ratio (measured) of MEC vs
im2col improve with the k/s ratio (Eq. 4)."""
from __future__ import annotations

from benchmarks.convbench import make_arrays, time_us
from repro.core import conv2d
from repro.core.convspec import ConvSpec
from repro.core.memory import im2col_overhead, mec_overhead


def main(emit=print, channel_cap=8, iters: int = 3):
    emit("table,name,us_per_call,derived")
    prev_ratio = None
    for s_ in range(1, 11):
        full = ConvSpec(1, 227, 227, 3, 11, 11, 96, s_, s_)
        mem_ratio = im2col_overhead(full) / mec_overhead(full)
        s = ConvSpec(1, 227, 227, 3, 11, 11, min(96, channel_cap), s_, s_)
        inp, ker = make_arrays(s)
        t_mec = time_us(lambda: conv2d(inp, ker, stride=(s_, s_),
                                       algorithm="mec"), iters=iters)
        t_i2c = time_us(lambda: conv2d(inp, ker, stride=(s_, s_),
                                       algorithm="im2col"), iters=iters)
        emit(f"fig4a_ks_sweep,s={s_},{t_mec:.0f},"
             f"mem_ratio={mem_ratio:.2f}x;runtime_ratio={t_i2c/t_mec:.2f}x;"
             f"k_over_s={11/s_:.1f}")
        prev_ratio = mem_ratio
    return prev_ratio


if __name__ == "__main__":
    main()
