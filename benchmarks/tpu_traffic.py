"""TPU HBM-traffic model for the MEC Pallas kernels (DESIGN.md §2).

No TPU is attached, so the kernel-level win is reported as modeled HBM
bytes derived from the BlockSpecs (what the grid actually DMAs), per
cv layer, f32:

  im2col  : read I + write L_i2c + read L_i2c + write O
  lowered : read I + write L_mec + read (o_h*k_h rows of L) + write O
  fused   : read I * ceil(k_h/s_h) + write O          (no L at all)

The fused kernel is the beyond-paper variant; 'lowered' is the faithful
MEC data flow.  Arithmetic intensity (FLOPs/HBM byte) against the v5e
ridge point (197e12/819e9 = 241 FLOP/B) says whether the layer stays
memory-bound.
"""
from __future__ import annotations

import json

from repro.bench.scenarios import CV_LAYERS, layer_spec as spec
from repro.core.memory import conv_flops, im2col_overhead, mec_overhead
from repro.launch.hlo_analysis import HBM_BW, PEAK_FLOPS

RIDGE = PEAK_FLOPS / HBM_BW


def traffic(s):
    f32 = 4
    i_bytes = s.i_n * s.i_h * s.i_w * s.i_c * f32
    o_bytes = s.i_n * s.o_h * s.o_w * s.k_c * f32
    k_bytes = s.k_h * s.k_w * s.i_c * s.k_c * f32
    l_i2c = im2col_overhead(s) * f32
    l_mec = mec_overhead(s) * f32
    refetch = -(-s.k_h // s.s_h)
    gemm_reads = s.i_n * s.o_h * s.k_h * s.o_w * s.k_w * s.i_c * f32
    # fused v2: oh_blk output rows per grid step + (k_h - s_h)-row halo
    oh_blk = 8
    halo_factor = 1 + max(s.k_h - s.s_h, 0) / (oh_blk * s.s_h)
    return {
        "im2col": i_bytes + l_i2c + l_i2c + k_bytes + o_bytes,
        "lowered": i_bytes + l_mec + gemm_reads + k_bytes + o_bytes,
        "fused": i_bytes * refetch + k_bytes + o_bytes,
        "fused2": i_bytes * halo_factor + k_bytes + o_bytes,
    }


def rows(batch: int = 32):
    out = []
    for name in CV_LAYERS:
        s = spec(name, batch=batch)
        t = traffic(s)
        flops = conv_flops(s)
        out.append({"name": name, "flops": flops,
                    "ai_flop_per_byte": flops / t["fused2"],
                    "bound": "compute" if flops / t["fused2"] > RIDGE
                             else "memory", **t})
    return out


def main(emit=print, fmt: str = "csv"):
    if fmt == "json":
        out = rows()
        emit(json.dumps(out, indent=2))
        return out
    emit("table,name,us_per_call,derived")
    for name in CV_LAYERS:
        s = spec(name, batch=32)     # server batch
        t = traffic(s)
        flops = conv_flops(s)
        ai = flops / t["fused2"]
        t_mem_us = t["fused2"] / HBM_BW * 1e6
        t_cmp_us = flops / PEAK_FLOPS * 1e6
        emit(f"tpu_traffic,{name},{max(t_mem_us, t_cmp_us):.1f},"
             f"im2col={t['im2col']/2**20:.1f}MB;"
             f"lowered={t['lowered']/2**20:.1f}MB;"
             f"fused={t['fused']/2**20:.1f}MB;"
             f"fused2={t['fused2']/2**20:.1f}MB;"
             f"fused2_vs_im2col={t['im2col']/t['fused2']:.2f}x;"
             f"AI={ai:.0f}FLOP/B;"
             f"bound={'compute' if ai > RIDGE else 'memory'}")
    return None


if __name__ == "__main__":
    main()
