"""Fig. 4(c,d) — convolution runtime per algorithm on cv1-cv12 (CPU).

This container is a single CPU core, so by default channels are capped at
16 (geometry preserved) to keep the full sweep under a few minutes;
``--full`` runs the exact paper sizes.  Memory numbers (conv_memory.py)
are always exact.

Thin wrapper over ``repro.bench``: every cell is timed by
``repro.bench.harness.measure`` (pre-compiled calls, median-of-iters);
``--format json`` emits the full ``table2`` suite report instead of the
legacy CSV lines.
"""
from __future__ import annotations

import dataclasses
import json

from repro.bench.harness import measure, run_suite
from repro.bench.report import make_report
from repro.bench.scenarios import (CV_LAYERS, Scenario, eligible_algorithms,
                                   layer_spec, resolve_suite)

# The variants Fig 4(c,d) compares (the Pallas mec_* kernels are covered
# by the full table2 suite / tpu_traffic model instead).
_FIG4_ALGS = ("direct", "im2col", "mecA", "mecB", "fft", "winograd")


def run_layer(name: str, channel_cap=16, batch: int = 1, iters: int = 3):
    """{algorithm: us_per_call} for one Table 2 layer."""
    spec = layer_spec(name, batch=batch)
    sc = Scenario(name=name, spec=spec,
                  run_spec=layer_spec(name, batch=batch,
                                      channel_cap=channel_cap),
                  algorithms=eligible_algorithms(spec, _FIG4_ALGS))
    return {alg: measure(sc, alg, iters=iters,
                         with_hlo=False)["us_per_call"]
            for alg in sc.algorithms}


def main(emit=print, fmt: str = "csv", channel_cap=16, iters: int = 3):
    if fmt == "json":
        if channel_cap == 16:      # the registry's own table2 run_spec cap
            doc = run_suite("table2", iters=iters, with_hlo=False)
        else:
            # honour --full / a custom cap by re-deriving run_specs
            scenarios = [dataclasses.replace(
                sc, run_spec=layer_spec(sc.name, channel_cap=channel_cap))
                for sc in resolve_suite("table2")]
            recs = [measure(sc, alg, iters=iters, with_hlo=False)
                    for sc in scenarios for alg in sc.algorithms]
            doc = make_report("table2", recs,
                              {"iters": iters, "channel_cap": channel_cap})
        emit(json.dumps(doc, indent=2))
        return doc
    emit("table,name,us_per_call,derived")
    speedups = []
    for name in CV_LAYERS:
        r = run_layer(name, channel_cap=channel_cap, iters=iters)
        best_mec = min(r["mecA"], r["mecB"])
        sp = r["im2col"] / best_mec
        speedups.append(sp)
        extra = (f";wino={r['winograd']:.0f}us" if "winograd" in r else "")
        emit(f"fig4cd_runtime,{name},{best_mec:.0f},"
             f"im2col={r['im2col']:.0f}us;direct={r['direct']:.0f}us;"
             f"fft={r['fft']:.0f}us{extra};mec_vs_im2col={sp:.2f}x")
    gm = 1.0
    for s_ in speedups:
        gm *= s_
    gm **= 1.0 / len(speedups)
    emit(f"fig4cd_runtime,geomean,0,mec_vs_im2col={gm:.2f}x "
         f"(paper Mobile: ~1.2x, Server-CPU: up to 8.8x)")
    return speedups


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--format", choices=("csv", "json"), default="csv")
    a = ap.parse_args()
    main(fmt=a.format, channel_cap=None if a.full else 16, iters=a.iters)
