"""Fig. 4(c,d) — convolution runtime per algorithm on cv1-cv12 (CPU).

This container is a single CPU core, so by default channels are capped at
16/32 (geometry preserved) to keep the full sweep under a few minutes;
``--full`` runs the exact paper sizes.  Memory numbers (conv_memory.py)
are always exact.
"""
from __future__ import annotations

from benchmarks.convbench import CV_LAYERS, make_arrays, spec, time_us
from repro.core import conv2d


def algorithms(s):
    """Every algorithm through the one conv2d front-end (pre-padded VALID
    input, as the paper assumes)."""
    stride = (s.s_h, s.s_w)

    def via(**kwargs):
        return lambda i, k: conv2d(i, k, stride=stride, **kwargs)

    algs = {
        "direct": via(algorithm="direct"),
        "im2col": via(algorithm="im2col"),
        "mecA": via(algorithm="mec", solution="A"),
        "mecB": via(algorithm="mec", solution="B"),
        "fft": via(algorithm="fft"),
    }
    if (s.k_h, s.k_w, s.s_h, s.s_w) == (3, 3, 1, 1):
        algs["winograd"] = via(algorithm="winograd")
    return algs


def run_layer(name: str, channel_cap=16, batch: int = 1, iters: int = 3):
    s = spec(name, batch=batch, channel_cap=channel_cap)
    inp, ker = make_arrays(s)
    out = {}
    for alg, fn in algorithms(s).items():
        out[alg] = time_us(lambda fn=fn: fn(inp, ker), iters=iters)
    return out


def main(emit=print, channel_cap=16, iters: int = 3):
    emit("table,name,us_per_call,derived")
    speedups = []
    for name in CV_LAYERS:
        r = run_layer(name, channel_cap=channel_cap, iters=iters)
        best_mec = min(r["mecA"], r["mecB"])
        sp = r["im2col"] / best_mec
        speedups.append(sp)
        extra = (f";wino={r['winograd']:.0f}us" if "winograd" in r else "")
        emit(f"fig4cd_runtime,{name},{best_mec:.0f},"
             f"im2col={r['im2col']:.0f}us;direct={r['direct']:.0f}us;"
             f"fft={r['fft']:.0f}us{extra};mec_vs_im2col={sp:.2f}x")
    gm = 1.0
    for s_ in speedups:
        gm *= s_
    gm **= 1.0 / len(speedups)
    emit(f"fig4cd_runtime,geomean,0,mec_vs_im2col={gm:.2f}x "
         f"(paper Mobile: ~1.2x, Server-CPU: up to 8.8x)")
    return speedups


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--iters", type=int, default=3)
    a = ap.parse_args()
    main(channel_cap=None if a.full else 16, iters=a.iters)
