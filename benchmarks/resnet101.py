"""Table 3 — ResNet-101 weighted memory/runtime impact of MEC vs im2col.

Memory is exact (f32, batch=1, as the paper's Mobile setting); runtime
uses the measured layer timings weighted by the paper's occurrence
counts.  Paper result: 3.2x memory, 1.2x runtime.

Thin wrapper over the ``repro.bench`` ``resnet101`` suite (which carries
the occurrence weights per scenario); ``--format json`` emits the
schema-validated report.
"""
from __future__ import annotations

import json

from repro.bench.harness import run_suite


def main(emit=print, fmt: str = "csv", iters: int = 3):
    doc = run_suite("resnet101", iters=iters, with_hlo=False)
    if fmt == "json":
        emit(json.dumps(doc, indent=2))
        return doc
    by_scenario = {}
    for r in doc["results"]:
        by_scenario.setdefault(r["scenario"], {})[r["algorithm"]] = r
    emit("table,name,us_per_call,derived")
    mem_i2c = mem_mec = 0.0
    t_i2c = t_mec = 0.0
    for name, algs in by_scenario.items():
        w = algs["im2col"]["weight"]
        m_i = algs["im2col"]["overhead_bytes"] / 2 ** 20
        m_m = algs["mecA"]["overhead_bytes"] / 2 ** 20
        best_mec = min(algs["mecA"]["us_per_call"],
                       algs["mecB"]["us_per_call"])
        mem_i2c += w * m_i
        mem_mec += w * m_m
        t_i2c += w * algs["im2col"]["us_per_call"]
        t_mec += w * best_mec
        emit(f"table3_resnet101,{name},{best_mec:.0f},"
             f"weight={w};mem_im2col={m_i:.1f}MB;mem_mec={m_m:.1f}MB;"
             f"t_im2col={algs['im2col']['us_per_call']:.0f}us")
    emit(f"table3_resnet101,SUM,{t_mec:.0f},"
         f"mem_ratio={mem_i2c/mem_mec:.2f}x (paper 3.2x);"
         f"runtime_ratio={t_i2c/t_mec:.2f}x (paper 1.2x)")
    return mem_i2c / mem_mec, t_i2c / t_mec


if __name__ == "__main__":
    main()
