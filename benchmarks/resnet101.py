"""Table 3 — ResNet-101 weighted memory/runtime impact of MEC vs im2col.

Memory is exact (f32, batch=1, as the paper's Mobile setting); runtime
uses the measured layer timings weighted by the paper's occurrence
counts.  Paper result: 3.2x memory, 1.2x runtime."""
from __future__ import annotations

from benchmarks.conv_runtime import run_layer
from benchmarks.convbench import RESNET101_WEIGHTS, spec
from repro.core.memory import im2col_overhead, mec_overhead


def main(emit=print, channel_cap=16, iters: int = 3):
    emit("table,name,us_per_call,derived")
    mem_i2c = mem_mec = 0.0
    t_i2c = t_mec = 0.0
    for name, w in RESNET101_WEIGHTS.items():
        s = spec(name, batch=1)
        m_i = im2col_overhead(s) * 4 / 2 ** 20
        m_m = mec_overhead(s) * 4 / 2 ** 20
        r = run_layer(name, channel_cap=channel_cap, iters=iters)
        best_mec = min(r["mecA"], r["mecB"])
        mem_i2c += w * m_i
        mem_mec += w * m_m
        t_i2c += w * r["im2col"]
        t_mec += w * best_mec
        emit(f"table3_resnet101,{name},{best_mec:.0f},"
             f"weight={w};mem_im2col={m_i:.1f}MB;mem_mec={m_m:.1f}MB;"
             f"t_im2col={r['im2col']:.0f}us")
    emit(f"table3_resnet101,SUM,{t_mec:.0f},"
         f"mem_ratio={mem_i2c/mem_mec:.2f}x (paper 3.2x);"
         f"runtime_ratio={t_i2c/t_mec:.2f}x (paper 1.2x)")
    return mem_i2c / mem_mec, t_i2c / t_mec


if __name__ == "__main__":
    main()
