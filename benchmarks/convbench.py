"""Back-compat shim: the paper tables and timing helpers now live in the
``repro.bench`` subsystem (``repro.bench.scenarios`` owns CV_LAYERS /
RESNET101_WEIGHTS, ``repro.bench.harness`` owns arrays and timing).
This module re-exports the old names so existing imports keep working."""
from __future__ import annotations

from typing import Callable

from repro.bench.harness import make_arrays, time_compiled  # noqa: F401
from repro.bench.scenarios import (CV_LAYERS, RESNET101_WEIGHTS,  # noqa: F401
                                   layer_spec)
from repro.core.convspec import ConvSpec


def spec(name: str, batch: int = 1, channel_cap: int | None = None) -> ConvSpec:
    return layer_spec(name, batch=batch, channel_cap=channel_cap)


def time_us(fn: Callable, iters: int = 3, warmup: int = 1) -> float:
    """Median wall-clock microseconds per call (legacy name)."""
    return time_compiled(fn, iters=iters, warmup=warmup)["us_median"]
