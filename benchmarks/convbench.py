"""Paper benchmark definitions: Table 2 (cv1-cv12) and the ResNet-101
weighted set (Table 3), plus shared timing helpers."""
from __future__ import annotations

import time
from typing import Callable, Dict

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.convspec import ConvSpec

# Table 2: name -> (i_h, i_w, i_c, k_h, k_w, o_c, stride)
CV_LAYERS = {
    "cv1": (227, 227, 3, 11, 11, 96, 4),
    "cv2": (231, 231, 3, 11, 11, 96, 4),
    "cv3": (227, 227, 3, 7, 7, 64, 2),
    "cv4": (224, 224, 64, 7, 7, 64, 2),
    "cv5": (24, 24, 96, 5, 5, 256, 1),
    "cv6": (12, 12, 256, 3, 3, 512, 1),
    "cv7": (224, 224, 3, 3, 3, 64, 1),
    "cv8": (112, 112, 64, 3, 3, 128, 1),
    "cv9": (56, 56, 64, 3, 3, 64, 1),
    "cv10": (28, 28, 128, 3, 3, 128, 1),
    "cv11": (14, 14, 256, 3, 3, 256, 1),
    "cv12": (7, 7, 512, 3, 3, 512, 1),
}

# Table 3: ResNet-101 layer weights (occurrence counts)
RESNET101_WEIGHTS = {"cv4": 1, "cv9": 3, "cv10": 4, "cv11": 23, "cv12": 3}


def spec(name: str, batch: int = 1, channel_cap: int | None = None) -> ConvSpec:
    ih, iw, ic, kh, kw, oc, s = CV_LAYERS[name]
    if channel_cap:
        ic, oc = min(ic, channel_cap), min(oc, channel_cap)
    return ConvSpec(batch, ih, iw, ic, kh, kw, oc, s, s)


def make_arrays(s: ConvSpec, seed: int = 0):
    rng = np.random.RandomState(seed)
    inp = jnp.asarray(rng.randn(s.i_n, s.i_h, s.i_w, s.i_c).astype(np.float32))
    ker = jnp.asarray(rng.randn(s.k_h, s.k_w, s.i_c, s.k_c).astype(np.float32))
    return inp, ker


def time_us(fn: Callable, iters: int = 3, warmup: int = 1) -> float:
    """Median wall-clock microseconds per call (paper: mean of 10; we use
    a median of ``iters`` on this single-core container)."""
    for _ in range(warmup):
        jax.block_until_ready(fn())
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))
