"""Batched LM serving through the framework's prefill/decode path —
zamba2 (hybrid) so the MEC conv1d kernel dataflow runs in decode too.

    PYTHONPATH=src python examples/serve_lm.py
"""
from repro.launch.serve import main

if __name__ == "__main__":
    main(["--arch", "zamba2-7b", "--smoke", "--batch", "4",
          "--prompt-len", "24", "--gen", "12", "--temperature", "0.8"])
