"""Quickstart: the MEC convolution engine (Cho & Brand, ICML 2017),
every algorithm through the one ``conv2d`` front-end.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import jax.numpy as jnp

from repro.core import conv2d, conv2d_spec
from repro.core.memory import ALL_OVERHEADS
from repro.launch.costmodel import pick_conv2d_algorithm

# --- a cv7-like layer: 3x3 kernel, stride 1, SAME padding -----------------
rng = np.random.RandomState(0)
x = jnp.asarray(rng.randn(1, 56, 56, 8).astype(np.float32))
k = jnp.asarray(rng.randn(3, 3, 8, 16).astype(np.float32))

ref = conv2d(x, k, padding="SAME", algorithm="direct")
print("output:", ref.shape)
for name, kwargs in [
    ("mec (Solution A)", dict(algorithm="mec", solution="A")),
    ("mec (Solution B)", dict(algorithm="mec", solution="B")),
    ("im2col", dict(algorithm="im2col")),
    ("fft", dict(algorithm="fft")),
    ("winograd F(2x2,3x3)", dict(algorithm="winograd")),
    ("Pallas MEC kernel (fused)", dict(algorithm="mec_fused")),
    ("Pallas MEC kernel (lowered)", dict(algorithm="mec_lowered")),
]:
    err = float(jnp.max(jnp.abs(conv2d(x, k, padding="SAME", **kwargs) - ref)))
    print(f"  {name:28s} max|err| vs direct = {err:.2e}")

# --- the paper's memory story (Eqs. 2-4) ----------------------------------
spec = conv2d_spec(x, k, padding="SAME")
print(f"\nauto dispatch on this geometry -> {pick_conv2d_algorithm(spec)!r}")
print("lowered-matrix overhead (f32 MB):")
for alg, f in ALL_OVERHEADS.items():
    print(f"  {alg:10s} {f(spec) * 4 / 2**20:8.2f} MB")

# --- the planner (DESIGN.md §7): inspect, serialize, replay ---------------
from repro.plan import ConvPlan, plan_conv2d  # noqa: E402

plan = plan_conv2d(spec)                      # analytic policy (default)
print("\n" + plan.explain())
replayed = ConvPlan.from_json(plan.to_json())  # plans are values
out = conv2d(x, k, padding="SAME", plan=replayed)
print("replayed-plan output matches auto kwargs:",
      bool(jnp.all(out == conv2d(x, k, padding='SAME', algorithm='auto'))))
