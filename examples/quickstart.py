"""Quickstart: the MEC convolution engine (Cho & Brand, ICML 2017).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import jax.numpy as jnp

from repro.core import (ConvSpec, direct_conv2d, fft_conv2d, im2col_conv2d,
                        mec_conv2d, pad_same, winograd_conv2d)
from repro.core.memory import ALL_OVERHEADS
from repro.kernels import mec_conv2d_tpu

# --- a cv7-like layer: 3x3 kernel, stride 1 ------------------------------
rng = np.random.RandomState(0)
x = jnp.asarray(rng.randn(1, 56, 56, 8).astype(np.float32))
k = jnp.asarray(rng.randn(3, 3, 8, 16).astype(np.float32))
x = pad_same(x, 3, 3)

ref = direct_conv2d(x, k, 1)
print("output:", ref.shape)
for name, fn in [
    ("mec (Solution A)", lambda: mec_conv2d(x, k, 1, solution="A")),
    ("mec (Solution B)", lambda: mec_conv2d(x, k, 1, solution="B")),
    ("im2col", lambda: im2col_conv2d(x, k, 1)),
    ("fft", lambda: fft_conv2d(x, k, 1)),
    ("winograd F(2x2,3x3)", lambda: winograd_conv2d(x, k)),
    ("Pallas MEC kernel (fused)", lambda: mec_conv2d_tpu(x, k, 1, mode="fused")),
    ("Pallas MEC kernel (lowered)", lambda: mec_conv2d_tpu(x, k, 1, mode="lowered")),
]:
    err = float(jnp.max(jnp.abs(fn() - ref)))
    print(f"  {name:28s} max|err| vs direct = {err:.2e}")

# --- the paper's memory story (Eqs. 2-4) ----------------------------------
spec = ConvSpec(1, 58, 58, 8, 3, 3, 16, 1, 1)
print("\nlowered-matrix overhead (f32 MB):")
for alg, f in ALL_OVERHEADS.items():
    print(f"  {alg:10s} {f(spec) * 4 / 2**20:8.2f} MB")
