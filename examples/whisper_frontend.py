"""The whisper conv frontend, for real: the assignment stubs the audio
frontend in the dry-run (`input_specs()` supplies frame embeddings), but
the actual two-conv-layer mel frontend is implemented here with MEC
convolution and fed into the repro whisper encoder.

Two constructions of the same frontend:

* ``make_conv_frontend`` — the fixed-shape pattern: plans resolved once
  at construction for ONE mel shape (DESIGN.md §7).
* ``repro.serving.whisper_frontend_service`` — the serving pattern
  (DESIGN.md §9): plans warmed per padded shape *class*, so
  variable-length mels bucket into a bounded set of executables instead
  of recompiling per length.

    PYTHONPATH=src python examples/whisper_frontend.py
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.archs import smoke_config
from repro.core import conv2d, conv2d_spec
from repro.models.lm import LM
from repro.plan import plan_conv2d


def make_conv_frontend(key, mel_shape, d_model, plan_mode="cached"):
    """mel (B, T, n_mels) -> (B, T//2, d_model) via two MEC conv1d layers
    (expressed as height-1 conv2d: exactly the paper's Algorithm 2 with
    i_h = time).  Padding and dispatch live in the conv2d front-end; the
    stride-2 layer keeps the whisper-conventional symmetric (1, 1) time
    pad explicitly (SAME would pad (0, 1) for even T, shifting every
    window by one frame).

    The serving-path pattern (DESIGN.md §7): each layer's ConvPlan is
    resolved HERE, once, at frontend construction — every request then
    replays the frozen decision through ``conv2d(plan=)``; with
    ``plan_mode="cached"`` the decision also persists on disk across
    server restarts."""
    b, t, n_mels = mel_shape
    k1, k2 = jax.random.split(key)
    w1 = jax.random.normal(k1, (3, 1, n_mels, d_model)) * n_mels ** -0.5
    w2 = jax.random.normal(k2, (3, 1, d_model, d_model)) * d_model ** -0.5
    x1 = jax.ShapeDtypeStruct((b, t, 1, n_mels), w1.dtype)
    plan1 = plan_conv2d(conv2d_spec(x1, w1, stride=(1, 1), padding="SAME"),
                        dtype=w1.dtype, mode=plan_mode)
    x2 = jax.ShapeDtypeStruct((b, t, 1, d_model), w2.dtype)
    plan2 = plan_conv2d(conv2d_spec(x2, w2, stride=(2, 1),
                                    padding=((1, 1), (0, 0))),
                        dtype=w2.dtype, mode=plan_mode)
    print(f"[whisper] frontend plans: conv1={plan1.algorithm!r} "
          f"conv2={plan2.algorithm!r} (resolved once, mode={plan_mode!r})")

    def frontend(mel):
        x = mel[:, :, None, :]                   # (B, T, 1, mels) h=time
        x = jax.nn.gelu(conv2d(x, w1, stride=(1, 1), padding="SAME",
                               plan=plan1))
        x = jax.nn.gelu(conv2d(x, w2, stride=(2, 1),
                               padding=((1, 1), (0, 0)),
                               plan=plan2))      # stride-2 downsample
        return x[:, :, 0, :]

    return frontend


def main():
    cfg = smoke_config("whisper-tiny")
    model = LM(cfg)
    params = model.init(jax.random.key(0))
    mel = jax.random.normal(jax.random.key(1), (2, 2 * cfg.encoder_len, 80))
    frontend = make_conv_frontend(jax.random.key(2), mel.shape, cfg.d_model)
    frames = frontend(mel)
    print("[whisper] mel", mel.shape, "-> frames", frames.shape)
    assert frames.shape == (2, cfg.encoder_len, cfg.d_model)
    enc = model.encode(params, frames)
    print("[whisper] encoder output", enc.shape,
          "finite:", bool(jnp.isfinite(enc).all()))
    h, _ = model.forward(params, {
        "frames": frames,
        "tokens": jnp.zeros((2, 16), jnp.int32)})
    print("[whisper] decoder hidden", h.shape)

    # The serving construction: the same two layers as warm ConvServices
    # over (batch, T, 1) time classes.  A shorter clip pads into its
    # class, runs the frozen warmed plan, and slices back — outputs for
    # the full-length mel are bitwise those of the fixed-shape path's
    # conv (same kernels would be needed for a literal diff; here we
    # check shape discipline on a ragged batch of lengths).
    from repro.serving import fit_prefix, whisper_frontend_service
    t_full = 2 * cfg.encoder_len
    svc_frontend, services = whisper_frontend_service(
        jax.random.key(2), 80, cfg.d_model,
        classes=[(2, t_full // 2, 1), (2, t_full, 1)])
    for svc in services:
        print("[whisper]", svc.warmup.summary())
    for t in (t_full // 2 - 3, t_full // 2, t_full - 5, t_full):
        clip = jax.random.normal(jax.random.key(3), (2, t, 80))
        cls = services[0].bucket((2, t, 1))
        out = fit_prefix(svc_frontend(clip), cfg.encoder_len)
        print(f"[whisper] clip T={t:3d} -> class {cls.tag()} -> "
              f"frames {out.shape}")
        assert out.shape == (2, cfg.encoder_len, cfg.d_model)


if __name__ == "__main__":
    main()
