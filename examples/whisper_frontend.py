"""The whisper conv frontend, for real: the assignment stubs the audio
frontend in the dry-run (`input_specs()` supplies frame embeddings), but
the actual two-conv-layer mel frontend is implemented here with MEC
convolution and fed into the repro whisper encoder.

    PYTHONPATH=src python examples/whisper_frontend.py
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.archs import smoke_config
from repro.core import conv2d, conv2d_spec
from repro.models.lm import LM
from repro.plan import plan_conv2d


def make_conv_frontend(key, mel_shape, d_model, plan_mode="cached"):
    """mel (B, T, n_mels) -> (B, T//2, d_model) via two MEC conv1d layers
    (expressed as height-1 conv2d: exactly the paper's Algorithm 2 with
    i_h = time).  Padding and dispatch live in the conv2d front-end; the
    stride-2 layer keeps the whisper-conventional symmetric (1, 1) time
    pad explicitly (SAME would pad (0, 1) for even T, shifting every
    window by one frame).

    The serving-path pattern (DESIGN.md §7): each layer's ConvPlan is
    resolved HERE, once, at frontend construction — every request then
    replays the frozen decision through ``conv2d(plan=)``; with
    ``plan_mode="cached"`` the decision also persists on disk across
    server restarts."""
    b, t, n_mels = mel_shape
    k1, k2 = jax.random.split(key)
    w1 = jax.random.normal(k1, (3, 1, n_mels, d_model)) * n_mels ** -0.5
    w2 = jax.random.normal(k2, (3, 1, d_model, d_model)) * d_model ** -0.5
    x1 = jax.ShapeDtypeStruct((b, t, 1, n_mels), w1.dtype)
    plan1 = plan_conv2d(conv2d_spec(x1, w1, stride=(1, 1), padding="SAME"),
                        dtype=w1.dtype, mode=plan_mode)
    x2 = jax.ShapeDtypeStruct((b, t, 1, d_model), w2.dtype)
    plan2 = plan_conv2d(conv2d_spec(x2, w2, stride=(2, 1),
                                    padding=((1, 1), (0, 0))),
                        dtype=w2.dtype, mode=plan_mode)
    print(f"[whisper] frontend plans: conv1={plan1.algorithm!r} "
          f"conv2={plan2.algorithm!r} (resolved once, mode={plan_mode!r})")

    def frontend(mel):
        x = mel[:, :, None, :]                   # (B, T, 1, mels) h=time
        x = jax.nn.gelu(conv2d(x, w1, stride=(1, 1), padding="SAME",
                               plan=plan1))
        x = jax.nn.gelu(conv2d(x, w2, stride=(2, 1),
                               padding=((1, 1), (0, 0)),
                               plan=plan2))      # stride-2 downsample
        return x[:, :, 0, :]

    return frontend


def main():
    cfg = smoke_config("whisper-tiny")
    model = LM(cfg)
    params = model.init(jax.random.key(0))
    mel = jax.random.normal(jax.random.key(1), (2, 2 * cfg.encoder_len, 80))
    frontend = make_conv_frontend(jax.random.key(2), mel.shape, cfg.d_model)
    frames = frontend(mel)
    print("[whisper] mel", mel.shape, "-> frames", frames.shape)
    assert frames.shape == (2, cfg.encoder_len, cfg.d_model)
    enc = model.encode(params, frames)
    print("[whisper] encoder output", enc.shape,
          "finite:", bool(jnp.isfinite(enc).all()))
    h, _ = model.forward(params, {
        "frames": frames,
        "tokens": jnp.zeros((2, 16), jnp.int32)})
    print("[whisper] decoder hidden", h.shape)


if __name__ == "__main__":
    main()
