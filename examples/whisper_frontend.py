"""The whisper conv frontend, for real: the assignment stubs the audio
frontend in the dry-run (`input_specs()` supplies frame embeddings), but
the actual two-conv-layer mel frontend is implemented here with MEC
convolution and fed into the repro whisper encoder.

    PYTHONPATH=src python examples/whisper_frontend.py
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.archs import smoke_config
from repro.core import conv2d
from repro.models.lm import LM


def conv_frontend(key, mel, d_model):
    """mel (B, T, n_mels) -> (B, T//2, d_model) via two MEC conv1d layers
    (expressed as height-1 conv2d: exactly the paper's Algorithm 2 with
    i_h = time).  Padding and dispatch live in the conv2d front-end; the
    stride-2 layer keeps the whisper-conventional symmetric (1, 1) time
    pad explicitly (SAME would pad (0, 1) for even T, shifting every
    window by one frame)."""
    b, t, n_mels = mel.shape
    k1, k2 = jax.random.split(key)
    w1 = jax.random.normal(k1, (3, 1, n_mels, d_model)) * n_mels ** -0.5
    w2 = jax.random.normal(k2, (3, 1, d_model, d_model)) * d_model ** -0.5
    x = mel[:, :, None, :]                       # (B, T, 1, mels) h=time
    x = jax.nn.gelu(conv2d(x, w1, stride=(1, 1), padding="SAME",
                           algorithm="mec"))
    x = jax.nn.gelu(conv2d(x, w2, stride=(2, 1), padding=((1, 1), (0, 0)),
                           algorithm="mec"))     # stride-2 downsample
    return x[:, :, 0, :]


def main():
    cfg = smoke_config("whisper-tiny")
    model = LM(cfg)
    params = model.init(jax.random.key(0))
    mel = jax.random.normal(jax.random.key(1), (2, 2 * cfg.encoder_len, 80))
    frames = conv_frontend(jax.random.key(2), mel, cfg.d_model)
    print("[whisper] mel", mel.shape, "-> frames", frames.shape)
    assert frames.shape == (2, cfg.encoder_len, cfg.d_model)
    enc = model.encode(params, frames)
    print("[whisper] encoder output", enc.shape,
          "finite:", bool(jnp.isfinite(enc).all()))
    h, _ = model.forward(params, {
        "frames": frames,
        "tokens": jnp.zeros((2, 16), jnp.int32)})
    print("[whisper] decoder hidden", h.shape)


if __name__ == "__main__":
    main()
