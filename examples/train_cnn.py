"""End-to-end driver (the paper's native kind): train a CNN classifier
whose every convolution runs through the unified conv2d front-end with
``algorithm="mec"`` (differentiable via the MEC custom VJP), on synthetic
structured images.

    PYTHONPATH=src python examples/train_cnn.py --steps 200
    PYTHONPATH=src python examples/train_cnn.py --algorithm direct  # baseline
    PYTHONPATH=src python examples/train_cnn.py --width 64 --steps 300

The task: classify which quadrant of the image carries a bright blob —
learnable only through spatial convolution, so a falling loss is evidence
the MEC conv path trains correctly (gradients flow through the lowering).
"""
import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.models.layers import conv2d_layer, init_conv2d
from repro.optim import adamw


def conv_layer(p, x, stride=1, algorithm="mec", plan=None):
    return jax.nn.relu(conv2d_layer(p, x, stride=stride, padding="SAME",
                                    algorithm=algorithm, plan=plan))


def init_model(key, width):
    ks = jax.random.split(key, 5)
    return {
        "c1": init_conv2d(ks[0], 3, 3, 1, width),
        "c2": init_conv2d(ks[1], 3, 3, width, width),
        "c3": init_conv2d(ks[2], 3, 3, width, 2 * width),
        "head": {"w": jax.random.normal(ks[3], (2 * width, 4)) * 0.05,
                 "b": jnp.zeros((4,))},
    }


def forward(p, imgs, algorithm="mec", plans=None):
    plans = plans or {}
    x = conv_layer(p["c1"], imgs, 2, algorithm, plans.get("c1"))
    x = conv_layer(p["c2"], x, 2, algorithm, plans.get("c2"))
    x = conv_layer(p["c3"], x, 2, algorithm, plans.get("c3"))
    x = x.mean(axis=(1, 2))
    return x @ p["head"]["w"] + p["head"]["b"]


def resolve_plans(params, batch, size=32, mode="cached"):
    """algorithm="auto": the ConvPlan per conv layer is resolved ONCE
    here (DESIGN.md §7) and replayed by every training step — the plan
    cache persists the decisions across runs."""
    from repro.models.layers import plan_conv2d_layer
    plans = {}
    for name in ("c1", "c2", "c3"):
        c_in = params[name]["w"].shape[2]
        plans[name] = plan_conv2d_layer(params[name],
                                        (batch, size, size, c_in),
                                        stride=2, padding="SAME", mode=mode)
        size //= 2
    return plans


def make_batch(key, batch, size=32):
    kq, kn, kp = jax.random.split(key, 3)
    labels = jax.random.randint(kq, (batch,), 0, 4)
    noise = 0.3 * jax.random.normal(kn, (batch, size, size, 1))
    cy = (labels // 2) * (size // 2) + size // 4
    cx = (labels % 2) * (size // 2) + size // 4
    yy, xx = jnp.mgrid[0:size, 0:size]
    blob = jnp.exp(-(((yy[None] - cy[:, None, None]) ** 2
                      + (xx[None] - cx[:, None, None]) ** 2) / 18.0))
    return noise + blob[..., None], labels


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--width", type=int, default=16)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--algorithm", default="mec",
                    help="conv2d algorithm (mec, direct, im2col, ..., auto)")
    args = ap.parse_args(argv)

    params = init_model(jax.random.key(0), args.width)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"[train_cnn] {n_params/1e3:.1f}k params, every conv via "
          f"conv2d(algorithm={args.algorithm!r})")
    plans = None
    if args.algorithm == "auto":
        plans = resolve_plans(params, args.batch)
        for name, pl in plans.items():
            print(f"[train_cnn] {name} plan[{pl.mode}]: {pl.algorithm} "
                  f"(solution={pl.solution}, w_blk={pl.w_blk})")
    opt_cfg = adamw.AdamWConfig(lr=args.lr, total_steps=args.steps,
                                warmup_steps=10, weight_decay=0.01)
    opt = adamw.init(params)

    @jax.jit
    def step(params, opt, key):
        imgs, labels = make_batch(key, args.batch)

        def loss_fn(p):
            logits = forward(p, imgs, args.algorithm, plans)
            return -jax.nn.log_softmax(logits)[
                jnp.arange(args.batch), labels].mean(), logits

        (loss, logits), g = jax.value_and_grad(loss_fn, has_aux=True)(params)
        acc = (logits.argmax(-1) == labels).mean()
        params, opt, _ = adamw.update(opt_cfg, g, opt, params)
        return params, opt, loss, acc

    key = jax.random.key(1)
    t0 = time.time()
    for i in range(args.steps):
        key, sub = jax.random.split(key)
        params, opt, loss, acc = step(params, opt, sub)
        if i % 25 == 0 or i == args.steps - 1:
            print(f"[train_cnn] step {i:4d} loss {float(loss):.4f} "
                  f"acc {float(acc):.2f}")
    print(f"[train_cnn] done in {time.time()-t0:.0f}s; final acc "
          f"{float(acc):.2f} (random = 0.25)")
    assert float(acc) > 0.8, "MEC conv training failed to learn"
    return float(acc)


if __name__ == "__main__":
    main()
