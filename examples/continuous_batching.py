"""Continuous-batching serving demo: 6 requests of varying prompt lengths
stream through a 3-slot pool (vLLM-style admission + slot recycling).

    PYTHONPATH=src python examples/continuous_batching.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs.archs import smoke_config
from repro.models.lm import LM
from repro.serving.scheduler import ContinuousBatcher, Request


def main():
    cfg = smoke_config("yi-6b")
    model = LM(cfg)
    params = model.init(jax.random.key(0))
    batcher = ContinuousBatcher(model, params, n_slots=3, max_len=96)
    for i in range(6):
        prompt = jax.random.randint(jax.random.key(i), (4 + 5 * i,), 0,
                                    cfg.vocab, jnp.int32)
        batcher.submit(Request(rid=i, prompt=prompt, max_new_tokens=8))
    t0 = time.time()
    done = batcher.run_until_done()
    dt = time.time() - t0
    total = sum(len(r.out) for r in done)
    print(f"[cb] {len(done)} requests, {total} tokens in {dt:.1f}s "
          f"({total/dt:.1f} tok/s incl. compiles)")
    for r in sorted(done, key=lambda r: r.rid):
        print(f"[cb] req {r.rid} (prompt {len(r.prompt)}): {r.out}")


if __name__ == "__main__":
    main()
