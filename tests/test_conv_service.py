"""Plan-driven conv serving (repro.serving.conv_service, DESIGN.md §9):
bucketing is deterministic and total over the admitted range, padding
never shrinks, warm and cold paths are bit-identical, warmup degrades
(never crashes) on plan-cache trouble, and the conv frontend feeds the
continuous-batching scheduler without disturbing token streams or EOS.
"""
import json
import pathlib

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import conv2d
from repro.serving.conv_service import (ConvService, ShapeClass,
                                        fit_prefix, parse_shape_classes,
                                        patch_embed_service,
                                        whisper_frontend_service)

_KEY = jax.random.key(0)


def _kernel(k_h=3, k_w=3, i_c=4, k_c=8):
    return jax.random.normal(_KEY, (k_h, k_w, i_c, k_c)) \
        * (k_h * k_w * i_c) ** -0.5


def _service(classes=((1, 12, 12), (2, 16, 16)), **kw):
    kw.setdefault("stride", 2)
    kw.setdefault("padding", 1)
    kw.setdefault("plan_mode", "analytic")
    return ConvService(_kernel(), classes=classes, **kw)


# ---------------------------------------------------------------- bucketing

def test_bucket_smallest_containing_class_wins():
    svc = _service()
    assert svc.bucket((1, 9, 11)) == ShapeClass(1, 12, 12)
    assert svc.bucket((1, 12, 12)) == ShapeClass(1, 12, 12)   # exact fit
    assert svc.bucket((1, 13, 5)) == ShapeClass(2, 16, 16)    # h forces up
    assert svc.bucket((2, 3, 3)) == ShapeClass(2, 16, 16)     # n forces up
    # 4-tuples (with channel) bucket like 3-tuples
    assert svc.bucket((1, 9, 11, 4)) == ShapeClass(1, 12, 12)


def test_bucket_deterministic_and_total():
    svc = _service()
    for n in range(1, 3):
        for h in range(1, 17):
            for w in range(1, 17):
                cls = svc.bucket((n, h, w))
                assert cls.contains(n, h, w)
                assert svc.bucket((n, h, w)) == cls        # deterministic
                assert svc.bucket((cls.n, cls.h, cls.w)) == cls  # idempotent
                # smallest: no strictly earlier class contains it
                for other in svc.classes:
                    if other < cls:
                        assert not other.contains(n, h, w)


def test_bucket_rejects_out_of_range_loudly():
    svc = _service()
    with pytest.raises(ValueError, match="fits no shape class"):
        svc.bucket((1, 17, 4))
    with pytest.raises(ValueError, match="fits no shape class"):
        svc.bucket((3, 4, 4))
    with pytest.raises(ValueError, match="non-positive"):
        svc.bucket((1, 0, 4))
    with pytest.raises(ValueError, match="channels"):
        svc.bucket((1, 8, 8, 3))          # service convolves 4 channels
    with pytest.raises(ValueError, match="not"):
        svc.bucket((1, 8))


def test_parse_shape_classes():
    assert parse_shape_classes("1x32x32,4x64x64") == (
        ShapeClass(1, 32, 32), ShapeClass(4, 64, 64))
    with pytest.raises(ValueError, match="NxHxW"):
        parse_shape_classes("1x32")
    with pytest.raises(ValueError, match="no shape classes"):
        parse_shape_classes(",")


def test_duplicate_and_invalid_classes_rejected():
    with pytest.raises(ValueError, match="duplicate"):
        _service(classes=((1, 12, 12), (1, 12, 12)))
    with pytest.raises(ValueError, match="non-positive"):
        _service(classes=((1, 0, 12),))


def test_same_padding_rejected():
    # SAME's pad split depends on the input size, so a request and its
    # padded class would disagree on window alignment — the exact-slice
    # argument (module docstring) only holds for size-independent pads.
    with pytest.raises(ValueError, match="SAME"):
        _service(padding="SAME")


# ---------------------------------------------------------------- padding

def test_padding_never_shrinks_and_preserves_data():
    svc = _service()
    x = jax.random.normal(jax.random.key(1), (1, 9, 11, 4))
    cls = svc.bucket(x.shape)
    padded = svc.pad_to_class(x, cls)
    assert padded.shape == (cls.n, cls.h, cls.w, 4)
    assert all(p >= r for p, r in zip(padded.shape, x.shape))
    np.testing.assert_array_equal(np.asarray(padded[:1, :9, :11]),
                                  np.asarray(x))
    assert float(jnp.abs(padded[:, 9:]).sum()) == 0.0
    assert float(jnp.abs(padded[:, :, 11:]).sum()) == 0.0


# --------------------------------------------------------------- execution

def test_execute_matches_direct_conv_on_request():
    svc = _service()
    svc.warm()
    for shape in ((1, 9, 11, 4), (1, 12, 12, 4), (2, 13, 16, 4)):
        x = jax.random.normal(jax.random.key(2), shape)
        got = svc(x)
        ref = conv2d(x, svc.kernel, stride=2, padding=1,
                     algorithm="direct")
        assert got.shape == ref.shape == svc.request_out_shape(shape)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-5, atol=2e-6)


def test_warm_vs_cold_bit_identical():
    x = jax.random.normal(jax.random.key(3), (1, 10, 13, 4))
    warm = _service()
    warm.warm()
    assert len(warm.warmup.plans) == len(warm.classes)
    cold = _service()            # never warmed: lazy per-class resolve
    y_warm, y_cold = warm(x), cold(x)
    np.testing.assert_array_equal(np.asarray(y_warm), np.asarray(y_cold))
    assert np.asarray(y_warm).tobytes() == np.asarray(y_cold).tobytes()


def test_valid_padding_service():
    svc = ConvService(_kernel(4, 4, 3, 8), stride=4, padding="VALID",
                      classes=[(1, 16, 16), (1, 32, 32)],
                      plan_mode="analytic")
    x = jax.random.normal(jax.random.key(4), (1, 24, 20, 3))
    got = svc(x)
    ref = conv2d(x, svc.kernel, stride=4, padding="VALID",
                 algorithm="direct")
    assert got.shape == ref.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-6)


def test_fit_prefix_crops_and_pads():
    x = jnp.arange(24, dtype=jnp.float32).reshape(1, 6, 4)
    assert fit_prefix(x, 4).shape == (1, 4, 4)
    np.testing.assert_array_equal(np.asarray(fit_prefix(x, 4)),
                                  np.asarray(x[:, :4]))
    padded = fit_prefix(x, 9)
    assert padded.shape == (1, 9, 4)
    assert float(jnp.abs(padded[:, 6:]).sum()) == 0.0


# ------------------------------------------------------- warmup degradation

def test_warmup_survives_cache_dir_that_is_a_file(tmp_path, monkeypatch):
    from repro.plan.cache import reset_global_plan_cache
    bogus = tmp_path / "not-a-dir"
    bogus.write_text("i am a file, not a cache directory")
    monkeypatch.setenv("REPRO_PLAN_CACHE_DIR", str(bogus))
    reset_global_plan_cache()
    try:
        svc = _service(plan_mode="cached")
        report = svc.warm()                      # must not raise
        assert len(report.plans) == len(svc.classes)
        # the breakage is COUNTED, not hidden: reads under a non-directory
        # fail as OSError -> PlanCache.io_errors -> the report
        assert report.plan_cache_io_errors >= 1
        # and the service still serves correct results
        x = jax.random.normal(jax.random.key(5), (1, 9, 11, 4))
        ref = conv2d(x, svc.kernel, stride=2, padding=1,
                     algorithm="direct")
        np.testing.assert_allclose(np.asarray(svc(x)), np.asarray(ref),
                                   rtol=2e-5, atol=2e-6)
    finally:
        reset_global_plan_cache()


def test_warmup_survives_corrupt_cache_file(tmp_path, monkeypatch):
    from repro.plan.cache import reset_global_plan_cache, global_plan_cache
    monkeypatch.setenv("REPRO_PLAN_CACHE_DIR", str(tmp_path))
    reset_global_plan_cache()
    try:
        corrupt = global_plan_cache().path()
        corrupt.parent.mkdir(parents=True, exist_ok=True)
        corrupt.write_text("{ this is not json")
        svc = _service(plan_mode="cached")
        report = svc.warm()
        assert len(report.plans) == len(svc.classes)
        assert report.plan_cache_io_errors >= 1
    finally:
        reset_global_plan_cache()


def test_warmup_report_renders_plan_table():
    svc = _service()
    report = svc.warm()
    text = report.render()
    assert "warmed 2/2 shape class(es)" in text
    for cls in svc.classes:
        assert f"-- class {cls.tag()} --" in text
    assert "ConvPlan[" in text                  # ConvPlan.explain() output
    assert "0 plan-cache I/O error(s)" in report.summary()


def test_warmup_report_cli(capsys):
    from repro.serving.__main__ import main
    rc = main(["--warmup-report", "--kernel", "3x3x2x4", "--stride", "2",
               "--padding", "1", "--shape-classes", "1x8x8",
               "--plan-mode", "analytic"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "warmed 1/1 shape class(es)" in out
    assert "-- class 1x8x8 --" in out


# -------------------------------------------------------------- scheduler

def test_scheduler_drains_mixed_shape_image_stream():
    """Variable-shape images -> warmed patch-embed service -> vision
    tokens -> continuous batcher.  Token streams must be exactly the
    solo prefill/decode reference and EOS must still free slots."""
    from repro.configs.archs import smoke_config
    from repro.models import serve
    from repro.models.lm import LM
    from repro.serving.scheduler import ContinuousBatcher, Request

    cfg = smoke_config("llava-next-34b")
    assert cfg.family == "vlm"
    model = LM(cfg)
    params = model.init(jax.random.key(0))
    frontend, svc = patch_embed_service(
        jax.random.key(1), 3, cfg.d_model, 4,
        classes=[(1, 8, 8), (1, 16, 16)], prefix_len=cfg.prefix_len,
        plan_mode="analytic")
    assert len(svc.warmup.plans) == 2

    image_shapes = [(1, 6, 7, 3), (1, 8, 8, 3), (1, 13, 16, 3)]
    prompts = [jax.random.randint(jax.random.key(10 + i), (4 + i,), 0,
                                  cfg.vocab, jnp.int32) for i in range(3)]
    visions = [frontend(jax.random.normal(jax.random.key(20 + i), s))
               for i, s in enumerate(image_shapes)]
    for v in visions:
        assert v.shape == (1, cfg.prefix_len, cfg.d_model)

    def solo(prompt, vision, n, max_len=64):
        logits, cache = serve.prefill(
            model, params, {"tokens": prompt[None], "vision": vision},
            max_len=max_len)
        out = [int(jnp.argmax(logits[0]))]
        tok = jnp.asarray([[out[-1]]], jnp.int32)
        for _ in range(n - 1):
            logits, cache = serve.decode_step(model, params, cache, tok)
            out.append(int(jnp.argmax(logits[0])))
            tok = jnp.asarray([[out[-1]]], jnp.int32)
        return out

    refs = [solo(p, v, 5) for p, v in zip(prompts, visions)]

    # 3 mixed-shape requests through 2 slots: forces queueing + recycling
    batcher = ContinuousBatcher(model, params, n_slots=2, max_len=64)
    for i, (p, v) in enumerate(zip(prompts, visions)):
        batcher.submit(Request(rid=i, prompt=p, max_new_tokens=5,
                               extras={"vision": v}))
    done = batcher.run_until_done()
    assert len(done) == 3
    for req in done:
        assert req.out == refs[req.rid], (req.rid, req.out, refs[req.rid])

    # EOS through the frontend path still stops the stream and frees the
    # slot (the scheduler must not lose completion rules for extras)
    eos = refs[0][1]
    batcher = ContinuousBatcher(model, params, n_slots=1, max_len=64)
    batcher.submit(Request(rid=0, prompt=prompts[0], max_new_tokens=5,
                           eos_id=eos, extras={"vision": visions[0]}))
    done = batcher.run_until_done()
    assert done[0].out == refs[0][:refs[0].index(eos) + 1]
    assert int(batcher.cache["lens"][0]) == -1


# ------------------------------------------------------------ serve report

def test_committed_serve_baseline_is_valid():
    from repro.bench.report import validate_report
    path = pathlib.Path(__file__).resolve().parents[1] \
        / "benchmarks" / "baselines" / "serve.json"
    doc = json.loads(path.read_text())
    assert validate_report(doc) == []
    assert doc["suite"] == "serve"
    recs = doc["results"]
    assert {r["serve_mode"] for r in recs} == {"warm", "cold", "auto"}
    # the committed baseline must witness the tentpole claim: warm p50
    # no worse than per-call auto dispatch on every class cell
    by = {(r["scenario"], r["serve_mode"]): r for r in recs}
    for cell in {r["scenario"] for r in recs}:
        assert by[(cell, "warm")]["p50_us"] <= by[(cell, "auto")]["p50_us"]
        assert by[(cell, "warm")]["warmup_warnings"] == 0


def test_serve_record_schema_gates():
    from repro.bench.report import validate_report
    rec = {
        "scenario": "x_c1x8x8", "algorithm": "warm", "dtype": "float32",
        "weight": 1,
        "spec": {k: 1 for k in ("i_n", "i_h", "i_w", "i_c", "k_h", "k_w",
                                "k_c", "s_h", "s_w")},
        "run_spec": {k: 1 for k in ("i_n", "i_h", "i_w", "i_c", "k_h",
                                    "k_w", "k_c", "s_h", "s_w")},
        "overhead_elems": 0, "overhead_bytes": 0, "flops": 1.0,
        "run_flops": 1.0, "auto_algorithm": "direct", "out_shape": [1],
        "us_per_call": None, "timing": None, "hlo_flops": None,
        "hlo_bytes": None, "serve_mode": "warm",
        # deliberately missing shape_class etc.
    }
    doc = {"schema_version": 1, "suite": "serve",
           "environment": {k: "x" for k in ("jax", "numpy", "python",
                                            "backend", "device_count",
                                            "platform")},
           "harness": {}, "results": [rec]}
    errs = validate_report(doc)
    assert any("serve cell missing" in e for e in errs)
    rec.update(shape_class="1x8x8", n_classes=1, n_requests=4,
               warmup_warnings=0, plan_cache_io_errors=0)
    assert validate_report(doc) == []


def test_whisper_frontend_service_shapes():
    frontend, services = whisper_frontend_service(
        jax.random.key(6), n_mels=8, d_model=16,
        classes=[(1, 12, 1), (1, 24, 1)], plan_mode="analytic")
    for t in (9, 12, 24):
        out = frontend(jax.random.normal(jax.random.key(7), (1, t, 8)))
        # class execution slices the CLIP's true output back out: the
        # stride-2 (1,1)-padded layer yields ceil(t/2) frames, not a
        # class-sized result
        assert out.shape == (1, (t + 1) // 2, 16)
        assert services[0].bucket((1, t, 1)) in services[0].warmup.plans
