"""Pallas kernel validation: shape/dtype sweeps against the pure-jnp
oracles in repro.kernels.ref (interpret mode on CPU, per assignment)."""
import hypothesis
import hypothesis.strategies as st
import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.mec_conv import mec_gemm_pallas, mec_lower_pallas
from repro.kernels.ops import mec_conv1d_tpu, mec_conv2d_tpu

SWEEP = [
    # (ih, iw, ic, kh, kw, kc, stride)
    (7, 7, 1, 3, 3, 1, 1),
    (12, 14, 3, 5, 3, 8, 2),
    (9, 9, 4, 3, 3, 6, 1),
    (11, 13, 2, 4, 5, 3, (2, 3)),
    (16, 16, 8, 7, 7, 16, 2),
    (8, 8, 3, 1, 1, 4, 1),
    (24, 24, 6, 5, 5, 16, 1),
    (227 // 4, 227 // 4, 3, 11, 11, 8, 4),   # cv1-like geometry, reduced
]
DTYPES = [jnp.float32, jnp.bfloat16]


def _rand(shape, seed, dtype):
    x = np.random.RandomState(seed).randn(*shape).astype(np.float32)
    return jnp.asarray(x, dtype)


@pytest.mark.parametrize("geom", SWEEP)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("mode", ["fused", "fused2", "lowered"])
def test_mec_conv2d_kernel(geom, dtype, mode):
    ih, iw, ic, kh, kw, kc, s = geom
    inp = _rand((2, ih, iw, ic), 0, dtype)
    ker = _rand((kh, kw, ic, kc), 1, dtype)
    oracle = ref.conv2d_ref(inp.astype(jnp.float32),
                            ker.astype(jnp.float32), s)
    out = mec_conv2d_tpu(inp, ker, s, mode=mode, interpret=True)
    assert out.shape == oracle.shape
    tol = 2e-4 if dtype == jnp.float32 else 4e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(oracle), rtol=tol, atol=tol)


@pytest.mark.parametrize("geom", SWEEP[:5])
def test_mec_lower_kernel(geom):
    ih, iw, ic, kh, kw, kc, s = geom
    s_w = s[1] if isinstance(s, tuple) else s
    inp = _rand((2, ih, iw, ic), 2, jnp.float32)
    out = mec_lower_pallas(inp, kw, s_w, interpret=True)
    oracle = ref.lower_ref(inp, kw, s_w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle))


@pytest.mark.parametrize("t,c,kw", [(10, 5, 4), (1024, 256, 4), (33, 7, 3),
                                    (512, 64, 2), (5, 3, 4)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_mec_conv1d_kernel(t, c, kw, dtype):
    x = _rand((2, t, c), 3, dtype)
    k = _rand((kw, c), 4, dtype)
    oracle = ref.conv1d_ref(x.astype(jnp.float32), k.astype(jnp.float32))
    out = mec_conv1d_tpu(x, k, interpret=True)
    tol = 2e-4 if dtype == jnp.float32 else 4e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(oracle), rtol=tol, atol=tol)


@hypothesis.given(
    st.integers(4, 20), st.integers(4, 20), st.integers(1, 6),
    st.integers(1, 4), st.integers(1, 4), st.integers(1, 8),
    st.integers(1, 3), st.integers(1, 3))
@hypothesis.settings(max_examples=25, deadline=None)
def test_mec_fused_kernel_property(ih, iw, ic, kh, kw, kc, sh, sw):
    hypothesis.assume(ih >= kh and iw >= kw)
    inp = _rand((1, ih, iw, ic), 5, jnp.float32)
    ker = _rand((kh, kw, ic, kc), 6, jnp.float32)
    oracle = ref.conv2d_ref(inp, ker, (sh, sw))
    out = mec_conv2d_tpu(inp, ker, (sh, sw), mode="fused", interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               rtol=2e-4, atol=2e-4)


def test_lowered_gemm_matches_fused():
    """The two kernel modes are numerically identical paths."""
    inp = _rand((2, 14, 14, 4), 7, jnp.float32)
    ker = _rand((3, 3, 4, 8), 8, jnp.float32)
    a = mec_conv2d_tpu(inp, ker, 1, mode="fused", interpret=True)
    b = mec_conv2d_tpu(inp, ker, 1, mode="lowered", interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)


def test_accumulator_budget_env_and_default(monkeypatch):
    """pick_w_blk's VMEM accumulator budget is env-configurable
    (REPRO_MEC_ACC_BYTES) instead of a hard-coded ~2 MiB."""
    from repro.kernels import ops
    monkeypatch.delenv(ops.ACC_BYTES_ENV, raising=False)
    # off-TPU default: the v5e 16 MiB/8 heuristic
    assert ops.accumulator_budget() == 2 << 20
    assert ops.pick_w_blk(4096, 8) == 512          # hits the 512 cap
    monkeypatch.setenv(ops.ACC_BYTES_ENV, "4096")
    with pytest.warns(DeprecationWarning):
        assert ops.accumulator_budget() == 4096
    with pytest.warns(DeprecationWarning):
        assert ops.pick_w_blk(4096, 8) == 128      # 4096 / (4*8) = 128
    monkeypatch.setenv(ops.ACC_BYTES_ENV, "0x1000")  # hex accepted
    with pytest.warns(DeprecationWarning):
        assert ops.accumulator_budget() == 4096
    monkeypatch.setenv(ops.ACC_BYTES_ENV, "-1")
    with pytest.raises(ValueError), pytest.warns(DeprecationWarning):
        ops.accumulator_budget()
    # explicit argument still wins over everything
    assert ops.pick_w_blk(4096, 8, target_bytes=2 << 20) == 512


def test_acc_bytes_env_deprecation_boundary(monkeypatch, recwarn):
    """Satellite: a direct REPRO_MEC_ACC_BYTES read outside the plan
    path warns DeprecationWarning (pointing at ConvPlan.w_blk /
    plan_conv2d) with unchanged behaviour; the planner's read — the
    supported migration target — stays silent."""
    from repro.kernels import ops
    monkeypatch.setenv(ops.ACC_BYTES_ENV, "4096")
    with pytest.warns(DeprecationWarning, match="ConvPlan"):
        assert ops.accumulator_budget() == 4096    # value unchanged
    with pytest.warns(DeprecationWarning, match="plan_conv2d"):
        assert ops.pick_w_blk(4096, 8) == 128
    # the plan path: same resolved value, no warning
    assert ops.pick_w_blk(4096, 8, _warn_env=False) == 128
    from repro.core.convspec import ConvSpec
    from repro.plan import plan_conv2d
    spec = ConvSpec(1, 16, 16, 4, 3, 3, 8, 1, 1)
    n_before = len(recwarn)
    plan = plan_conv2d(spec, backend="tpu")        # Pallas pick -> w_blk
    deprecations = [w for w in recwarn.list[n_before:]
                    if issubclass(w.category, DeprecationWarning)]
    assert deprecations == []
    assert plan.w_blk == ops.pick_w_blk(spec.o_w, spec.k_c, _warn_env=False)
    # no env: nothing warns anywhere
    monkeypatch.delenv(ops.ACC_BYTES_ENV)
    n_before = len(recwarn)
    ops.accumulator_budget()
    assert not [w for w in recwarn.list[n_before:]
                if issubclass(w.category, DeprecationWarning)]


def test_pick_w_blk_never_exceeds_explicit_budget():
    """Regression: the 8-column sublane floor used to override a small
    explicit target_bytes (pick_w_blk(1000, 4, target_bytes=64) -> an
    8-column block = 128 accumulator bytes, 2x the budget)."""
    from repro.kernels import ops
    blk = ops.pick_w_blk(1000, 4, target_bytes=64)
    assert blk * 4 * 4 <= 64, (blk, blk * 4 * 4)
    assert blk == 4
    # sweep: an explicit budget >= one f32 column is never exceeded
    for k_c in (1, 3, 8, 64):
        for budget in (4 * k_c, 64, 512, 4096, 1 << 20):
            if budget < 4 * k_c:
                continue          # below the 1-column minimum
            blk = ops.pick_w_blk(10_000, k_c, target_bytes=budget)
            assert 1 <= blk <= 512
            assert blk * 4 * k_c <= budget, (k_c, budget, blk)
    # sub-column budgets clamp to the 1-column minimum (smallest
    # accumulator that exists) rather than 0
    assert ops.pick_w_blk(16, 64, target_bytes=8) == 1
    # the implicit device budget keeps its 8-column sublane floor
    assert ops.pick_w_blk(1000, 1 << 20) == 8
