"""repro.plan (DESIGN.md §7): ConvPlan JSON round-trip stability
(property-tested across algorithms/partitions), cache-hit determinism
(process LRU + on-disk JSON), the thin-executor guarantee —
``conv2d(plan=)`` bit-identical to the equivalent kwargs call for every
algorithm (and every partition, in a 4-device subprocess) — and the
plan CLI's baseline gate."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core.conv_api import conv2d, conv2d_spec
from repro.core.convspec import ConvSpec
from repro.kernels.ops import pick_w_blk
from repro.plan import (ConvPlan, PlanCache, eligible_candidates,
                        plan_conv2d, resolve_cached_plan, spec_key)
from repro.plan.cache import reset_global_plan_cache

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_ALGOS = ("direct", "im2col", "fft", "winograd", "mec", "mec_lowered",
          "mec_fused", "mec_fused2")
_PALLAS = ("mec_lowered", "mec_fused", "mec_fused2")
# (partition, axes) combos a plan may carry — None through composite.
_PARTITIONS = (
    (None, None),
    (("batch",), ("data",)),
    (("channel",), ("model",)),
    (("spatial",), ("model",)),
    (("batch", "spatial"), ("data", "model")),
    (("batch", "channel"), ("data", "model")),
    (("spatial", "channel"), ("model", "data")),
)


def _rand(shape, seed, dtype=jnp.float32):
    x = np.random.RandomState(seed).randn(*shape).astype(np.float32)
    return jnp.asarray(x, dtype)


@pytest.fixture
def fresh_cache(tmp_path, monkeypatch):
    """Point the global plan cache at an empty tmpdir for this test."""
    monkeypatch.setenv("REPRO_PLAN_CACHE_DIR", str(tmp_path))
    reset_global_plan_cache()
    yield tmp_path
    reset_global_plan_cache()


# --------------------------------------------------------------- round-trip

@settings(max_examples=60, deadline=None)
@given(st.integers(1, 4), st.integers(1, 3), st.integers(1, 8),
       st.integers(1, 2),
       st.sampled_from(_ALGOS), st.sampled_from(["A", "B", "auto"]),
       st.sampled_from(["float32", "bfloat16"]),
       st.sampled_from([None, "DEFAULT", "HIGH", "HIGHEST"]),
       st.sampled_from(_PARTITIONS), st.sampled_from(["analytic",
                                                      "measured", "cached"]))
def test_plan_json_roundtrip_property(n, c, kc, s, algorithm, solution,
                                      dtype, precision, part, mode):
    """from_json(to_json(p)) == p for every algorithm x partition x
    precision x mode combination (the JSON is the wire format of the
    disk cache AND the committed baseline — it must be lossless)."""
    k = 3
    spec = ConvSpec(n, 8 * s, 8 * s, c, k, k, kc, s, s)
    w_blk = pick_w_blk(spec.o_w, spec.k_c) if algorithm in _PALLAS else None
    plan = ConvPlan(spec=spec, dtype=dtype, algorithm=algorithm,
                    solution=solution, w_blk=w_blk, precision=precision,
                    partition=part[0], partition_axes=part[1],
                    backend="cpu", mode=mode)
    again = ConvPlan.from_json(plan.to_json())
    assert again == plan
    # and a second trip is a fixed point
    assert ConvPlan.from_json(again.to_json()) == again
    assert again.cache_key() == plan.cache_key()


def test_plan_rejects_malformed():
    spec = ConvSpec(1, 8, 8, 2, 3, 3, 4, 1, 1)
    with pytest.raises(ValueError):
        ConvPlan(spec=spec, dtype="float32", algorithm="auto")  # unresolved
    with pytest.raises(ValueError):
        ConvPlan(spec=spec, dtype="float32", algorithm="toeplitz")
    with pytest.raises(ValueError):
        ConvPlan(spec=spec, dtype="float32", algorithm="mec", solution="Z")
    with pytest.raises(ValueError):
        ConvPlan(spec=spec, dtype="float32", algorithm="mec",
                 precision="SOMETIMES")
    with pytest.raises(ValueError):   # partition without axes
        ConvPlan(spec=spec, dtype="float32", algorithm="mec",
                 partition=("batch",))
    with pytest.raises(ValueError):   # axis count mismatch
        ConvPlan(spec=spec, dtype="float32", algorithm="mec",
                 partition=("batch", "spatial"), partition_axes=("data",))
    p = plan_conv2d(spec)
    doc = p.to_dict()
    doc["plan_version"] = 999
    with pytest.raises(ValueError, match="plan_version"):
        ConvPlan.from_dict(doc)


def test_plan_conv2d_analytic_matches_costmodel():
    from repro.core.mec import pick_solution
    from repro.launch.costmodel import pick_conv2d_algorithm
    spec = ConvSpec(1, 16, 16, 4, 3, 3, 8, 1, 1)
    plan = plan_conv2d(spec)
    assert plan.algorithm == pick_conv2d_algorithm(spec)
    assert plan.mode == "analytic"
    if plan.algorithm == "mec":
        assert plan.solution == pick_solution(spec)
    # the TPU pick is a Pallas kernel and must carry a resolved w_blk
    tpu = plan_conv2d(spec, backend="tpu")
    assert tpu.algorithm == "mec_fused"
    assert tpu.w_blk == pick_w_blk(spec.o_w, spec.k_c)
    # explain() carries the why: Eq. 2-4 overheads + the winner mark
    text = plan.explain()
    assert "overhead" in text and plan.algorithm in text
    assert "im2col" in text and "Eq. 4" in text


# ---------------------------------------------------- thin-executor identity

@pytest.mark.parametrize("stride", [1, 2])
@pytest.mark.parametrize("algorithm", _ALGOS)
def test_conv2d_plan_bit_identical_to_kwargs(algorithm, stride):
    """The acceptance bar: for every algorithm, executing through a
    ConvPlan produces EXACTLY the bits the kwargs call produces."""
    if algorithm == "winograd" and stride != 1:
        pytest.skip("winograd is stride-1 only")
    inp = _rand((2, 11, 12, 3), 0)
    ker = _rand((3, 3, 3, 5), 1)
    spec = conv2d_spec(inp, ker, stride=stride, padding="SAME")
    plan = ConvPlan(
        spec=spec, dtype="float32", algorithm=algorithm,
        w_blk=(pick_w_blk(spec.o_w, spec.k_c)
               if algorithm in _PALLAS else None))
    out_plan = conv2d(inp, ker, stride=stride, padding="SAME", plan=plan)
    out_kw = conv2d(inp, ker, stride=stride, padding="SAME",
                    algorithm=algorithm, partition="none")
    assert out_plan.dtype == out_kw.dtype
    assert bool(jnp.all(out_plan == out_kw)), algorithm


def test_conv2d_auto_equals_planned_auto(fresh_cache):
    """conv2d(plan=plan_conv2d(spec)) == conv2d(algorithm='auto') to the
    bit — the kwargs auto path IS the cached analytic plan."""
    for dtype in (jnp.float32, jnp.bfloat16):
        inp = _rand((1, 10, 10, 3), 2, dtype)
        ker = _rand((3, 3, 3, 4), 3, dtype)
        spec = conv2d_spec(inp, ker, padding="SAME")
        plan = plan_conv2d(spec, dtype=dtype)
        out_plan = conv2d(inp, ker, padding="SAME", plan=plan)
        out_auto = conv2d(inp, ker, padding="SAME", algorithm="auto",
                          partition="none")
        assert bool(jnp.all(out_plan == out_auto))


def test_plan_execution_validates_geometry_and_dtype():
    inp = _rand((1, 10, 10, 3), 4)
    ker = _rand((3, 3, 3, 4), 5)
    plan = plan_conv2d(conv2d_spec(inp, ker, padding="SAME"))
    with pytest.raises(ValueError, match="geometry mismatch"):
        conv2d(inp, ker, padding="VALID", plan=plan)   # wrong padding
    with pytest.raises(ValueError, match="geometry mismatch"):
        conv2d(inp, ker, stride=2, padding="SAME", plan=plan)
    with pytest.raises(ValueError, match="dtype mismatch"):
        conv2d(inp.astype(jnp.bfloat16), ker.astype(jnp.bfloat16),
               padding="SAME", plan=plan)


def test_plan_precision_wins_over_kwargs():
    """The plan's precision reaches the lowered dots (and the kwargs
    precision is ignored when a plan is passed — plan wins)."""
    inp = _rand((1, 8, 8, 3), 6, jnp.bfloat16)
    ker = _rand((3, 3, 3, 4), 7, jnp.bfloat16)
    spec = conv2d_spec(inp, ker)
    plan_hi = ConvPlan(spec=spec, dtype="bfloat16", algorithm="mec",
                       precision="HIGHEST")
    plan_def = ConvPlan(spec=spec, dtype="bfloat16", algorithm="mec")
    hi = jax.jit(lambda i, k: conv2d(i, k, plan=plan_hi)) \
        .lower(inp, ker).as_text()
    lo = jax.jit(lambda i, k: conv2d(i, k, precision=jax.lax.Precision.HIGHEST,
                                     plan=plan_def)) \
        .lower(inp, ker).as_text()
    assert "HIGHEST" in hi
    assert "HIGHEST" not in lo            # kwargs precision ignored


# ----------------------------------------------------------------- caching

def test_cached_mode_hit_determinism(fresh_cache, monkeypatch):
    spec = ConvSpec(2, 12, 12, 3, 3, 3, 8, 1, 1)
    first = plan_conv2d(spec, mode="cached")
    assert first.algorithm == plan_conv2d(spec, mode="analytic").algorithm
    # the hit is served from the LRU: breaking the costmodel must not
    # change (or even touch) the decision
    import repro.launch.costmodel as cm

    def boom(*a, **kw):
        raise AssertionError("cache hit recomputed the analytic plan")

    monkeypatch.setattr(cm, "pick_conv2d_algorithm", boom)
    second = plan_conv2d(spec, mode="cached")
    assert second == first


def test_cache_survives_process_via_disk(fresh_cache):
    spec = ConvSpec(1, 16, 16, 4, 5, 5, 8, 1, 1)
    plan = plan_conv2d(spec, mode="cached")
    files = list(fresh_cache.glob("*.json"))
    assert len(files) == 1, "cached plan must land on disk"
    # a brand-new cache object (fresh process simulation) reads it back
    fresh = PlanCache(path=files[0])
    assert fresh.get(plan.cache_key()) == plan
    # and the disk document is the documented JSON wire format
    doc = json.loads(files[0].read_text())
    assert doc["plan_cache_version"] == 1
    assert plan.cache_key() in doc["plans"]


def test_cache_lru_and_corruption_tolerance(tmp_path):
    cache = PlanCache(path=tmp_path / "plans.json", max_entries=2)
    spec = ConvSpec(1, 8, 8, 2, 3, 3, 4, 1, 1)
    plans = [ConvPlan(spec=spec, dtype="float32", algorithm=alg)
             for alg in ("direct", "im2col", "mec")]
    for i, p in enumerate(plans):
        cache.put(f"k{i}", p)
    assert len(cache) == 2                  # LRU trimmed the oldest
    assert cache.get("k0") is None
    assert cache.get("k2") == plans[2]
    # corrupt disk file degrades to empty, never raises
    (tmp_path / "bad.json").write_text("{not json")
    assert PlanCache(path=tmp_path / "bad.json").get("k2") is None


def test_conv2d_auto_populates_global_cache(fresh_cache):
    from repro.plan.cache import global_plan_cache
    inp = _rand((1, 9, 9, 2), 8)
    ker = _rand((3, 3, 2, 4), 9)
    conv2d(inp, ker, algorithm="auto", partition="none")
    spec = conv2d_spec(inp, ker)
    key = f"{spec_key(spec)}|float32|{jax.default_backend()}"
    assert global_plan_cache().get(key) is not None
    # a second call is a pure cache hit returning the same decision
    assert resolve_cached_plan(spec).cache_key() == key


def test_cached_mode_never_serves_conflicting_hit(fresh_cache):
    """Review regression: the key is spec|dtype|backend, so a hit whose
    precision (or partition) conflicts with the request must be
    recomputed, never served silently."""
    spec = ConvSpec(1, 12, 12, 3, 3, 3, 8, 1, 1)
    base = plan_conv2d(spec, mode="cached")
    assert base.precision is None
    hi = plan_conv2d(spec, mode="cached", precision=jax.lax.Precision.HIGHEST)
    assert hi.precision == "HIGHEST"          # not the stale base hit
    again = plan_conv2d(spec, mode="cached", precision="HIGHEST")
    assert again == hi                         # new decision now cached
    # and back: a no-precision request recomputes rather than serving hi
    assert plan_conv2d(spec, mode="cached").precision is None
    # explicit partition request against a partition-free hit recomputes
    from repro.launch.mesh import make_host_mesh
    from repro.parallel.axes import ShardingRules, use_rules
    rules = ShardingRules(mesh=make_host_mesh(), rules={},
                          dp_axes=("data",), ep_axis=None, tp_axis=None)
    with use_rules(rules):
        part = plan_conv2d(spec, mode="cached", partition="batch")
    assert part.partition == ("batch",)


def test_partitioned_plans_never_persist_to_disk(fresh_cache):
    """Review regression: the disk fingerprint has no mesh topology, so
    partitioned plans must stay in the process LRU only."""
    from repro.launch.mesh import make_host_mesh
    from repro.parallel.axes import ShardingRules, use_rules
    spec = ConvSpec(2, 8, 8, 2, 3, 3, 4, 1, 1)
    rules = ShardingRules(mesh=make_host_mesh(), rules={},
                          dp_axes=("data",), ep_axis=None, tp_axis=None)
    with use_rules(rules):
        plan = plan_conv2d(spec, mode="cached", partition="batch")
    assert plan.partition == ("batch",)
    for f in fresh_cache.glob("*.json"):
        doc = json.loads(f.read_text())
        for stored in doc["plans"].values():
            assert stored["partition"] is None


def test_cached_hit_invalidated_by_budget_change(fresh_cache, monkeypatch):
    """Review regression: a cached Pallas plan bakes in w_blk, so a
    changed REPRO_MEC_ACC_BYTES (or device budget) must invalidate the
    hit rather than silently keep the stale block size."""
    from repro.kernels.ops import ACC_BYTES_ENV, pick_w_blk
    monkeypatch.delenv(ACC_BYTES_ENV, raising=False)
    spec = ConvSpec(1, 40, 40, 4, 3, 3, 8, 1, 1)
    first = plan_conv2d(spec, mode="cached", backend="tpu")
    assert first.algorithm == "mec_fused"
    assert first.w_blk == pick_w_blk(spec.o_w, spec.k_c, _warn_env=False)
    monkeypatch.setenv(ACC_BYTES_ENV, str(4 * spec.k_c * 8))  # 8 columns
    second = plan_conv2d(spec, mode="cached", backend="tpu")
    assert second.w_blk == 8 != first.w_blk


def test_cached_hit_respects_explicit_partition_axis(fresh_cache):
    """Review regression: an explicit partition_axis differing from the
    hit's recorded axes must recompute, not serve the wrong axes."""
    from repro.launch.mesh import make_host_mesh
    from repro.parallel.axes import ShardingRules, use_rules
    spec = ConvSpec(2, 8, 8, 2, 3, 3, 4, 1, 1)
    mesh = make_host_mesh(shape=(1, 1), axes=("data", "model"))
    rules = ShardingRules(mesh=mesh, rules={}, dp_axes=("data",),
                          ep_axis="model", tp_axis="model")
    with use_rules(rules):
        a = plan_conv2d(spec, mode="cached", partition="batch",
                        partition_axis="data")
        assert a.partition_axes == ("data",)
        b = plan_conv2d(spec, mode="cached", partition="batch",
                        partition_axis="model")
        assert b.partition_axes == ("model",)


def test_pick_measured_noise_margin():
    """Review regression: a sub-margin 'win' is timer jitter — the
    analytic pick must hold unless beaten decisively."""
    from repro.plan import pick_measured
    assert pick_measured({"mec": 101.4, "im2col": 101.3}, "mec") == "mec"
    assert pick_measured({"mec": 140.0, "im2col": 100.0}, "mec") == "im2col"
    assert pick_measured({"mec": 104.0, "im2col": 100.0}, "mec") == "mec"
    # analytic absent from the candidate set: plain argmin
    assert pick_measured({"im2col": 100.0, "fft": 90.0}, "mec") == "fft"


def test_plan_execution_rejects_backend_mismatch():
    """Review regression: a TPU plan must not silently interpret its
    Pallas kernel on CPU — backend drift raises at execution."""
    inp = _rand((1, 10, 10, 3), 60)
    ker = _rand((3, 3, 3, 4), 61)
    spec = conv2d_spec(inp, ker)
    tpu_plan = plan_conv2d(spec, backend="tpu")
    with pytest.raises(ValueError, match="backend mismatch"):
        conv2d(inp, ker, plan=tpu_plan)


def test_measure_candidates_stays_on_warning_free_path(fresh_cache,
                                                       monkeypatch, recwarn):
    """Review regression: measured-mode planning used to trip the
    REPRO_MEC_ACC_BYTES deprecation warning through the kernels' kwargs
    fallback — the planner must stay silent (it IS the plan path)."""
    from repro.kernels.ops import ACC_BYTES_ENV
    monkeypatch.setenv(ACC_BYTES_ENV, "4096")
    spec = ConvSpec(1, 8, 8, 2, 3, 3, 4, 1, 1)
    n_before = len(recwarn)
    plan = plan_conv2d(spec, mode="measured", iters=1, warmup=1,
                       candidates=("direct", "mec", "mec_fused"))
    assert plan.mode == "measured"
    assert not [w for w in recwarn.list[n_before:]
                if issubclass(w.category, DeprecationWarning)]


# ---------------------------------------------------------------- measured

def test_measured_mode_picks_a_timed_winner(fresh_cache):
    spec = ConvSpec(1, 10, 10, 2, 3, 3, 4, 1, 1)
    candidates = ("direct", "mec", "im2col")
    plan = plan_conv2d(spec, mode="measured", candidates=candidates,
                       iters=1, warmup=1)
    assert plan.mode == "measured"
    assert plan.algorithm in candidates
    # eligibility filter: winograd never offered on a strided spec
    strided = ConvSpec(1, 10, 10, 2, 3, 3, 4, 2, 2)
    assert "winograd" not in eligible_candidates(strided)
    assert "winograd" in eligible_candidates(spec)


# -------------------------------------------------------------- partitions

def test_plan_records_partition_and_executor_consumes_it(fresh_cache):
    """Under installed rules the plan captures partition + mesh axes at
    plan time; conv2d(plan=) then routes through the distributed layer
    with exactly that decision — and matches the kwargs sharded call to the
    bit (1-device mesh; the 4-device grid runs in the subprocess
    test)."""
    from repro.launch.mesh import make_host_mesh
    from repro.parallel.axes import ShardingRules, use_rules
    mesh = make_host_mesh()               # (1,) "data"
    rules = ShardingRules(mesh=mesh, rules={"batch": "data"},
                          dp_axes=("data",), ep_axis=None, tp_axis=None)
    inp = _rand((2, 8, 8, 2), 10)
    ker = _rand((3, 3, 2, 4), 11)
    spec = conv2d_spec(inp, ker, padding="SAME")
    with use_rules(rules):
        plan = plan_conv2d(spec, partition="batch")
        assert plan.partition == ("batch",)
        assert plan.partition_axes == ("data",)
        out_plan = conv2d(inp, ker, padding="SAME", plan=plan)
        out_kw = conv2d(inp, ker, padding="SAME", algorithm=plan.algorithm,
                        partition="batch")
    assert bool(jnp.all(out_plan == out_kw))
    # round-trip preserves the partition decision exactly
    assert ConvPlan.from_json(plan.to_json()) == plan
    # without rules the partition plan cannot be made
    with pytest.raises(ValueError, match="needs an installed mesh"):
        plan_conv2d(spec, partition="batch")


@pytest.mark.slow
def test_plan_vs_kwargs_multidevice_subprocess():
    """Acceptance grid on a real 4-device mesh: for every algorithm x
    partition combination, conv2d(plan=plan_conv2d(spec)) is
    bit-identical to the equivalent kwargs call."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        os.environ["JAX_PLATFORMS"] = "cpu"
        os.environ["REPRO_PLAN_CACHE_DIR"] = os.environ.get("TMPDIR", "/tmp")
        import json
        import numpy as np, jax, jax.numpy as jnp
        from repro.core.conv_api import conv2d, conv2d_spec
        from repro.launch.mesh import make_host_mesh
        from repro.parallel.axes import ShardingRules, use_rules
        from repro.plan import plan_conv2d

        mesh = make_host_mesh(shape=(2, 2), axes=("data", "model"))
        rules = ShardingRules(mesh=mesh, rules={"batch": "data"},
                              dp_axes=("data",), ep_axis="model",
                              tp_axis="model")
        cases = 0
        rng = np.random.RandomState(0)
        x = jnp.asarray(rng.randn(4, 12, 12, 3), jnp.float32)
        kk = jnp.asarray(rng.randn(3, 3, 3, 8), jnp.float32)
        spec = conv2d_spec(x, kk, padding="SAME")
        with use_rules(rules):
            for part, axis in [("batch", None), ("channel", None),
                               ("spatial", None),
                               (("batch", "spatial"), None),
                               (("batch", "channel"), None),
                               (("spatial", "channel"), ("model", "data"))]:
                plan = plan_conv2d(spec, partition=part,
                                   partition_axis=axis)
                for alg in ("direct", "im2col", "mec", "mec_fused"):
                    import dataclasses
                    p = dataclasses.replace(plan, algorithm=alg)
                    out_p = conv2d(x, kk, padding="SAME", plan=p)
                    out_k = conv2d(x, kk, padding="SAME", algorithm=alg,
                                   partition=part, partition_axis=axis)
                    assert bool(jnp.all(out_p == out_k)), (part, alg)
                    cases += 1
        print(json.dumps({"cases": cases}))
    """)
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", prog], env=env, cwd=REPO,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["cases"] == 24


# ------------------------------------------------------------------- CLI

def test_plan_cli_build_and_gate(tmp_path):
    from repro.plan.__main__ import build_plans, compare_plans, main
    doc = build_plans(["smoke"])
    assert set(doc["plans"]) == {"smoke/s3x3", "smoke/s5x5",
                                 "smoke/s11x11", "smoke/w520"}
    for plan in doc["plans"].values():
        assert plan["algorithm"] != "auto"
    # identical docs gate clean
    failures, _ = compare_plans(doc, json.loads(json.dumps(doc)))
    assert failures == []
    # a flipped algorithm fails loudly
    drifted = json.loads(json.dumps(doc))
    drifted["plans"]["smoke/s3x3"]["algorithm"] = "im2col"
    failures, _ = compare_plans(drifted, doc)
    assert any("algorithm changed" in f for f in failures)
    # a missing cell is a coverage regression
    shrunk = json.loads(json.dumps(doc))
    del shrunk["plans"]["smoke/s5x5"]
    failures, _ = compare_plans(shrunk, doc)
    assert any("missing" in f for f in failures)
    # end-to-end through main(): write then self-check
    out = tmp_path / "plans.json"
    assert main(["--suites", "smoke", "--out", str(out)]) == 0
    assert main(["--suites", "smoke", "--baseline", str(out)]) == 0
    drifted_path = tmp_path / "drift.json"
    drifted_path.write_text(json.dumps(drifted))
    assert main(["--suites", "smoke", "--baseline", str(drifted_path)]) == 1


def test_bench_records_plan_per_cell():
    from repro.bench.harness import measure
    from repro.bench.scenarios import Scenario
    spec = ConvSpec(1, 8, 8, 2, 3, 3, 4, 1, 1)
    sc = Scenario(name="tiny", spec=spec, run_spec=spec,
                  algorithms=("direct",))
    rec = measure(sc, "direct", iters=1, warmup=1, with_hlo=False,
                  with_timing=False)
    assert rec["plan"]["algorithm"] == rec["auto_algorithm"]
    assert rec["plan"]["spec"] == rec["spec"]


# ---------------------------------------------------- measured stage 2

def test_tune_measured_grids_the_mec_solution(fresh_cache):
    from repro.plan import tune_measured
    spec = ConvSpec(1, 10, 10, 2, 3, 3, 4, 1, 1)
    plan, detail = tune_measured(spec, candidates=("mec",),
                                 iters=1, warmup=1, record=False,
                                 calibration=None)
    assert plan.algorithm == "mec" and plan.mode == "measured"
    tuning = detail["tuning"]
    assert tuning["knob"] == "solution" and tuning["algorithm"] == "mec"
    assert set(tuning["trials"]) == {"A", "B"}
    assert tuning["picked"] in ("A", "B")
    assert plan.solution == tuning["picked"]
    # the analytic default only loses its knob with decisive evidence
    from repro.plan.convplan import pick_measured
    assert tuning["picked"] == pick_measured(
        {k: v["us_median"] for k, v in tuning["trials"].items()},
        tuning["default"])
    assert detail["candidate_us"].keys() == {"mec"}
    assert detail["skipped"] == {}


def test_tune_measured_grids_pallas_w_blk(fresh_cache):
    from repro.plan import tune_measured
    # o_w = 30 > default w_blk: the half/default/double grid is real
    spec = ConvSpec(1, 8, 32, 2, 3, 3, 4, 1, 1)
    plan, detail = tune_measured(spec, candidates=("mec_lowered",),
                                 iters=1, warmup=1, interpret=True,
                                 record=False, calibration=None)
    assert plan.algorithm == "mec_lowered"
    tuning = detail["tuning"]
    assert tuning["knob"] == "w_blk"
    assert len(tuning["trials"]) >= 2
    assert str(tuning["default"]) in tuning["trials"]
    assert plan.w_blk == int(tuning["picked"])


def test_measured_skips_are_counted_not_dropped(fresh_cache, monkeypatch):
    from repro.plan import convplan, measure_candidates_detailed

    def boom(trial, inp, ker, iters, warmup, interpret):
        if trial.algorithm == "mec":
            raise RuntimeError("compile exploded")
        return {"iters": 1, "warmup": 1, "us_median": 10.0,
                "us_min": 10.0, "us_mean": 10.0, "us_std": 0.0,
                "us_rel_spread": 0.0}

    monkeypatch.setattr(convplan, "_time_trial", boom)
    spec = ConvSpec(1, 8, 8, 2, 3, 3, 4, 1, 1)
    with pytest.warns(UserWarning, match="measured planning skips mec"):
        mc = measure_candidates_detailed(
            spec, candidates=("direct", "mec"), record=False)
    assert mc.times == {"direct": 10.0}
    assert mc.skipped["mec"].startswith("RuntimeError")
    # a Pallas candidate the geometry checker rejects is skipped the
    # same loud way, and never timed at all
    from repro.analysis import pallas_check

    class _Reject:
        ok = False

        def render(self):
            return "rejected: w_blk tile overruns VMEM"

    monkeypatch.setattr(pallas_check, "check_plan",
                        lambda plan: _Reject())
    with pytest.warns(UserWarning, match="pallas_check"):
        mc = measure_candidates_detailed(
            spec, candidates=("mec_lowered",), record=False)
    assert mc.times == {}
    assert mc.skipped["mec_lowered"].startswith("pallas_check")


def test_tune_measured_raises_when_nothing_timeable(fresh_cache,
                                                    monkeypatch):
    from repro.plan import convplan, tune_measured

    def boom(trial, inp, ker, iters, warmup, interpret):
        raise RuntimeError("no backend")

    monkeypatch.setattr(convplan, "_time_trial", boom)
    spec = ConvSpec(1, 8, 8, 2, 3, 3, 4, 1, 1)
    with pytest.warns(UserWarning):
        with pytest.raises(ValueError, match="no timeable candidate"):
            tune_measured(spec, candidates=("direct", "mec"),
                          record=False, calibration=None)


def test_measured_trials_feed_the_calibration_store(fresh_cache,
                                                    monkeypatch):
    from repro.plan import CalibrationStore, tune_measured
    from repro.plan.calibrate import reset_calibration_cache
    monkeypatch.delenv("REPRO_CALIBRATION", raising=False)
    reset_calibration_cache()
    spec = ConvSpec(1, 10, 10, 2, 3, 3, 4, 1, 1)
    tune_measured(spec, candidates=("direct", "mec"), iters=1, warmup=1)
    disk = CalibrationStore().load()
    cell = disk.cell_times(spec)
    assert set(cell) >= {"direct", "mec"}
    reset_calibration_cache()


def test_pick_measured_spread_widens_the_margin():
    from repro.plan import pick_measured
    times = {"mec": 130.0, "im2col": 100.0}
    # 30% gap beats the 5% floor...
    assert pick_measured(times, "mec") == "im2col"
    # ...but not the 40% observed jitter of the winner
    assert pick_measured(times, "mec",
                         spreads={"im2col": 0.4}) == "mec"
    # the analytic candidate's own jitter counts too
    assert pick_measured(times, "mec", spreads={"mec": 0.35}) == "mec"
    # quiet measurements keep the floor exactly
    assert pick_measured(times, "mec",
                         spreads={"mec": 0.01, "im2col": 0.0}) == "im2col"
    # absurd spreads are capped, not infinite vetoes
    assert pick_measured({"mec": 500.0, "im2col": 100.0}, "mec",
                         spreads={"im2col": 7.0}) == "im2col"
