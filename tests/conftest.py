import os
import sys

# Tests run single-device (the dry-run owns the 512-device flag; it is
# exercised via subprocess in test_dryrun.py).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# The container image ships no `hypothesis`; fall back to the minimal
# deterministic stub vendored under tests/_vendor (same API subset).
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "_vendor"))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
