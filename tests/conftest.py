import os
import sys
import tempfile

# Tests run single-device (the dry-run owns the 512-device flag; it is
# exercised via subprocess in test_dryrun.py).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# Keep the plan cache (repro.plan.cache) out of the developer's real
# ~/.cache: conv2d(algorithm="auto") resolves through it, so tests would
# otherwise read/write persistent state.  Tests that assert disk
# behaviour point REPRO_PLAN_CACHE_DIR at their own tmp_path.
os.environ.setdefault("REPRO_PLAN_CACHE_DIR",
                      tempfile.mkdtemp(prefix="repro-plan-cache-"))

# The container image ships no `hypothesis`; fall back to the minimal
# deterministic stub vendored under tests/_vendor (same API subset).
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "_vendor"))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
