import os

# Tests run single-device (the dry-run owns the 512-device flag; it is
# exercised via subprocess in test_dryrun.py).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
