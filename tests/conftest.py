import os
import sys
import tempfile

# Tests run single-device (the dry-run owns the 512-device flag; it is
# exercised via subprocess in test_dryrun.py).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# Keep the plan cache (repro.plan.cache) out of the developer's real
# ~/.cache: conv2d(algorithm="auto") resolves through it, so tests would
# otherwise read/write persistent state.  Tests that assert disk
# behaviour point REPRO_PLAN_CACHE_DIR at their own tmp_path.
os.environ.setdefault("REPRO_PLAN_CACHE_DIR",
                      tempfile.mkdtemp(prefix="repro-plan-cache-"))

# Pin the ambient calibration (repro.plan.calibrate) to a nonexistent
# file: analytic picks consult the fitted costmodel by default, and a
# measured-mode test recording trials into the session store must not
# flip a later test's analytic expectations.  Calibration tests
# monkeypatch REPRO_CALIBRATION to a real file.
os.environ.setdefault(
    "REPRO_CALIBRATION",
    os.path.join(os.environ["REPRO_PLAN_CACHE_DIR"], "calibration-off.json"))

# The container image ships no `hypothesis`; fall back to the minimal
# deterministic stub vendored under tests/_vendor (same API subset).
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "_vendor"))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
