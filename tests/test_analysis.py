"""Tests for the repro.analysis subsystem (DESIGN.md §8): the HLO
memory auditor, the static Pallas geometry checker, and the
repo-invariant lint pass."""
import dataclasses
import json
import pathlib
import textwrap

import pytest

from repro.analysis import lint
from repro.analysis.pallas_check import (PALLAS_ALGORITHMS,
                                         PallasCheckError, assert_plan,
                                         check_geometry, check_plan)
from repro.core.convspec import ConvSpec
from repro.plan.convplan import ConvPlan

SMALL = ConvSpec(1, 14, 14, 4, 3, 3, 8)
STRIDED = ConvSpec(1, 23, 23, 3, 11, 11, 8, 4, 4)


# ---------------------------------------------------------------------------
# pallas_check
# ---------------------------------------------------------------------------

def test_pallas_check_accepts_all_committed_plans():
    """Acceptance criterion: every plan in the committed baseline passes."""
    from repro.analysis.memaudit import DEFAULT_PLANS, load_plans
    root = pathlib.Path(__file__).resolve().parents[1]
    plans = load_plans(root / DEFAULT_PLANS)
    assert len(plans) >= 15
    for name, plan in plans.items():
        result = check_plan(plan)
        assert result.ok, f"{name}: {result.render()}"


@pytest.mark.parametrize("alg", PALLAS_ALGORITHMS)
@pytest.mark.parametrize("spec", [SMALL, STRIDED],
                         ids=["3x3", "11x11s4"])
def test_pallas_check_accepts_planner_geometries(alg, spec):
    """Planner-derived w_blk on every Pallas variant must check clean,
    and the mirror must actually model kernels (non-empty geometry)."""
    result = check_geometry(spec, alg, None, "float32")
    assert result.ok, result.render()
    assert result.pallas and result.kernels
    assert result.vmem_bytes > 0
    expected = 2 if alg == "mec_lowered" else 1
    assert len(result.kernels) == expected


def test_pallas_check_rejects_oversized_w_blk():
    """Acceptance criterion: a deliberately-oversized w_blk is rejected
    statically — ConvPlan itself doesn't validate w_blk against o_w, so
    the checker is the gate."""
    plan = ConvPlan(spec=SMALL, dtype="float32", algorithm="mec_fused",
                    w_blk=SMALL.o_w * 4)
    result = check_plan(plan)
    assert not result.ok
    assert {v.rule for v in result.violations} == {"w-blk-out-of-range"}
    with pytest.raises(PallasCheckError, match="w-blk-out-of-range"):
        assert_plan(plan)


def test_pallas_check_rejects_vmem_overrun():
    big = ConvSpec(1, 64, 4096, 64, 3, 3, 256)
    result = check_geometry(big, "mec_fused", 512, "float32",
                            vmem_budget=1 << 16, acc_budget=1 << 20)
    assert not result.ok
    assert any(v.rule == "vmem-budget-overrun" for v in result.violations)


def test_pallas_check_rejects_accumulator_overrun():
    result = check_geometry(SMALL, "mec_fused", SMALL.o_w, "float32",
                            acc_budget=4)   # 12*8*4 f32 >> 4 bytes
    assert any(v.rule == "accumulator-overrun"
               for v in result.violations)


def test_pallas_check_non_pallas_trivially_ok():
    plan = ConvPlan(spec=SMALL, dtype="float32", algorithm="mec",
                    solution="A")
    result = check_plan(plan)
    assert result.ok and not result.pallas and not result.kernels


def test_pallas_check_fused2_fallback_geometry():
    """k_h < s_h (halo < 0): fused2 falls back to the v1 kernel — the
    mirror must model what actually runs."""
    spec = ConvSpec(1, 16, 16, 2, 1, 1, 4, 2, 2)
    result = check_geometry(spec, "mec_fused2", None, "float32")
    assert result.ok, result.render()
    assert result.kernels[0].name == "mec_fused"


def test_plan_conv2d_never_returns_rejected_pallas_plan(monkeypatch):
    """The planner wiring: a Pallas pick whose geometry fails the static
    check raises at plan time instead of faulting at execute time."""
    from repro.plan import convplan

    def bad_w_blk(spec, algorithm):
        return None if algorithm not in convplan._PALLAS_ALGOS \
            else spec.o_w * 10
    monkeypatch.setattr(convplan, "_pallas_w_blk", bad_w_blk)
    monkeypatch.setattr(
        "repro.launch.costmodel.pick_conv2d_algorithm",
        lambda spec, backend, **kw: "mec_fused")
    with pytest.raises(PallasCheckError):
        convplan.plan_conv2d(SMALL, mode="analytic")


def test_measure_candidates_skips_rejected_pallas(monkeypatch):
    from repro.plan import convplan

    def bad_w_blk(spec, algorithm):
        return None if algorithm not in convplan._PALLAS_ALGOS \
            else spec.o_w * 10
    monkeypatch.setattr(convplan, "_pallas_w_blk", bad_w_blk)
    with pytest.warns(UserWarning, match="measured planning skips"):
        times = convplan.measure_candidates(
            SMALL, candidates=("direct", "mec_fused"), iters=1, warmup=0)
    assert "direct" in times and "mec_fused" not in times


# ---------------------------------------------------------------------------
# memaudit
# ---------------------------------------------------------------------------

def _require_memory_stats():
    """Gate for jax builds whose AOT API exposes no memory stats (the
    auditor degrades to recorded-only there; nothing to assert)."""
    import jax
    from repro.core.compat import memory_analysis
    compiled = jax.jit(lambda x: x + 1).lower(
        jax.ShapeDtypeStruct((8,), "float32")).compile()
    if memory_analysis(compiled) is None:
        pytest.skip("no compiled memory stats on this jax build")


def test_memaudit_single_cell_passes():
    from repro.analysis.memaudit import audit_plan
    _require_memory_stats()
    plan = ConvPlan(spec=SMALL, dtype="float32", algorithm="mec",
                    solution="A")
    rec, failures = audit_plan("unit/small", plan)
    assert failures == []
    assert rec["verdict"] == "pass"
    assert rec["source"] in ("memory_analysis", "buffer_assignment")
    assert rec["predicted_overhead_bytes"] == \
        SMALL.i_n * SMALL.o_w * SMALL.i_h * SMALL.k_w * SMALL.i_c * 4
    assert rec["measured_temp_bytes"] >= rec["predicted_overhead_bytes"]


def test_memaudit_im2col_exact():
    """im2col is the calibration cell: XLA materializes exactly the
    Toeplitz matrix, ratio 1.000."""
    _require_memory_stats()
    from repro.analysis.memaudit import audit_plan
    plan = ConvPlan(spec=SMALL, dtype="float32", algorithm="im2col")
    rec, failures = audit_plan("unit/im2col", plan)
    assert failures == []
    assert rec["ratio"] == pytest.approx(1.0, abs=0.02)


def test_memaudit_report_schema_and_crosscheck():
    _require_memory_stats()
    from repro.analysis.memaudit import run_audit
    from repro.bench.report import validate_report
    plans = {"unit/small": ConvPlan(spec=SMALL, dtype="float32",
                                    algorithm="mec", solution="A")}
    doc, failures = run_audit(plans=plans)
    assert failures == []
    assert validate_report(doc) == []
    assert doc["suite"] == "memaudit"
    # mec cell => an im2col companion record + a mec<im2col crosscheck
    algs = {r["algorithm"] for r in doc["results"]}
    assert algs == {"mec", "im2col"}
    (cc,) = doc["crosscheck"]
    assert cc["ok"] == "yes"
    assert cc["mec_temp_bytes"] < cc["im2col_temp_bytes"]


def test_memaudit_detects_model_drift():
    """If the implementation's footprint leaves the model's band, the
    auditor fails — simulated by shrinking the prediction (equivalent to
    an Eq. 3 regression)."""
    _require_memory_stats()
    from repro.analysis import memaudit
    plan = ConvPlan(spec=SMALL, dtype="float32", algorithm="mec",
                    solution="A")
    orig = memaudit.memory.algorithm_overhead
    try:
        memaudit.memory.algorithm_overhead = \
            lambda s, a, padding="VALID": orig(s, a, padding) // 10
        rec, failures = memaudit.audit_plan("unit/drift", plan)
    finally:
        memaudit.memory.algorithm_overhead = orig
    assert rec["verdict"] == "fail" and failures


# ---------------------------------------------------------------------------
# lint
# ---------------------------------------------------------------------------

def _lint_src(tmp_path, source, rel="src/repro/somefile.py"):
    p = tmp_path / "f.py"
    p.write_text(textwrap.dedent(source))
    return lint.lint_file(p, rel)


def test_lint_redetects_pr4_dropped_kwarg(tmp_path):
    """Acceptance criterion: reverting the PR-4 fix shape — a conv entry
    point accepting precision and never forwarding it — is re-detected."""
    findings = _lint_src(tmp_path, """
        def mec_conv2d(inp, kernel, stride=1, precision=None):
            return _run(inp, kernel, stride)
        """)
    assert [f.rule for f in findings] == ["accepted-kwarg-not-forwarded"]
    assert findings[0].symbol == "mec_conv2d:precision"


def test_lint_forwarded_and_underscore_params_ok(tmp_path):
    assert _lint_src(tmp_path, """
        def conv(inp, kernel, precision=None, _debug=False, **kw):
            return run(inp, kernel, precision=precision, **kw)
        """) == []


def test_lint_stub_bodies_exempt(tmp_path):
    assert _lint_src(tmp_path, """
        def iface(a, b):
            ...

        def iface2(a, b):
            raise NotImplementedError

        def iface3(a, b):
            \"\"\"doc\"\"\"
            pass
        """) == []


def test_lint_suppression_comment(tmp_path):
    findings = _lint_src(tmp_path, """
        def conv(inp, kernel, precision=None):  # lint-ignore: accepted-kwarg-not-forwarded
            return run(inp, kernel)
        """)
    assert findings == []


def test_lint_environ_read_flagged_outside_compat(tmp_path):
    src = """
        import os
        FLAG = os.environ.get("REPRO_FLAG")
        OTHER = os.getenv("OTHER")
        THIRD = os.environ["THIRD"]
        """
    findings = _lint_src(tmp_path, src)
    assert [f.rule for f in findings] == \
        ["raw-environ-read-outside-compat"] * 3
    # the same reads inside the compat shim (or plan cache) are allowed
    assert _lint_src(tmp_path, src, rel="src/repro/core/compat.py") == []
    assert _lint_src(tmp_path, src, rel="src/repro/plan/cache.py") == []


def test_lint_environ_write_not_flagged(tmp_path):
    assert _lint_src(tmp_path, """
        import os
        os.environ["JAX_PLATFORMS"] = "cpu"
        """) == []


def test_lint_deprecated_acc_bytes_env(tmp_path):
    findings = _lint_src(
        tmp_path, """
        import os
        v = os.environ.get("REPRO_MEC_ACC_BYTES")
        """, rel="src/repro/core/compat.py")   # allowed file: env rule off
    assert [f.rule for f in findings] == ["deprecated-acc-bytes-env"]


def test_lint_shard_map_import_outside_compat(tmp_path):
    findings = _lint_src(tmp_path, """
        from jax.experimental.shard_map import shard_map
        """)
    assert [f.rule for f in findings] == ["shard-map-import-outside-compat"]
    assert _lint_src(tmp_path, """
        from repro.core.compat import shard_map
        """) == []


def test_lint_bare_dot_precision_flagged_in_numeric_core(tmp_path):
    src = """
        import jax.numpy as jnp
        def f(a, b):
            return jnp.einsum("ij,jk->ik", a, b)
        """
    findings = _lint_src(tmp_path, src, rel="src/repro/core/x.py")
    assert [f.rule for f in findings] == ["no-bare-dot-precision"]
    assert findings[0].symbol == "f:jnp.einsum"
    # same call inside kernels/parallel is in scope too...
    assert _lint_src(tmp_path, src, rel="src/repro/parallel/x.py") != []
    # ...but bench/launch glue may use backend defaults
    assert _lint_src(tmp_path, src, rel="src/repro/bench/x.py") == []


def test_lint_bare_dot_precision_annotated_or_splat_ok(tmp_path):
    assert _lint_src(tmp_path, """
        import jax
        import jax.numpy as jnp
        def f(a, b, kw):
            x = jnp.dot(a, b, precision="highest")
            y = jnp.einsum("ij,jk->ik", a, b,
                           preferred_element_type=jnp.float32)
            z = jax.lax.dot_general(a, b, (((1,), (0,)), ((), ())), **kw)
            return x + y + z
        """, rel="src/repro/kernels/x.py") == []


def test_lint_bare_dot_precision_suppression(tmp_path):
    assert _lint_src(tmp_path, """
        import jax.numpy as jnp
        def f(a, b):
            return jnp.dot(a, b)  # lint-ignore: no-bare-dot-precision
        """, rel="src/repro/core/x.py") == []


def test_lint_baseline_roundtrip_and_fixed_detection(tmp_path):
    f1 = lint.Finding("accepted-kwarg-not-forwarded", "src/a.py",
                      "f:x", 3, "msg")
    f2 = lint.Finding("raw-environ-read-outside-compat", "src/b.py",
                      "os.getenv:K", 9, "msg")
    path = tmp_path / "baseline.json"
    lint.write_baseline([f1, f2], path)
    keys = lint.load_baseline(path)
    assert keys == sorted([f1.key(), f2.key()])
    # f2 fixed, f3 new
    f3 = lint.Finding("deprecated-acc-bytes-env", "src/c.py",
                      "os.getenv:REPRO_MEC_ACC_BYTES", 1, "msg")
    split = lint.apply_baseline([f1, f3], keys)
    assert split["new"] == [f3]
    assert split["grandfathered"] == [f1]
    assert split["fixed"] == [f2.key()]


def test_lint_baseline_version_gate(tmp_path):
    path = tmp_path / "baseline.json"
    path.write_text(json.dumps({"lint_baseline_version": 99,
                                "findings": []}))
    with pytest.raises(ValueError, match="version"):
        lint.load_baseline(path)


def test_lint_tree_is_clean_against_committed_baseline():
    """Acceptance criterion: the lint suite starts green on a clean
    checkout — every current finding is grandfathered or suppressed."""
    root = lint.repo_root()
    baseline = lint.load_baseline(
        root / "benchmarks/baselines/lint_baseline.json")
    split = lint.apply_baseline(lint.lint_tree(root), baseline)
    assert split["new"] == [], [f.render() for f in split["new"]]


# ---------------------------------------------------------------------------
# autotune trial replay (the --suite pallas coverage extension)
# ---------------------------------------------------------------------------

def test_autotune_stage2_w520_grid_passes_geometry():
    """The committed w520 cell tuned w_blk=520, past pick_w_blk's 512
    default cap — every stage-2 grid candidate the autotuner trials must
    be geometry-admissible, including that over-cap one."""
    from repro.plan.convplan import _pallas_w_blk, _stage2_trials
    spec = ConvSpec(1, 3, 522, 3, 3, 3, 8, 1, 1)       # o_w = 520
    assert _pallas_w_blk(spec, "mec_fused") == 512
    knob, plans = _stage2_trials(spec, "float32", "mec_fused", None, "cpu")
    assert knob == "w_blk"
    assert set(plans) == {"256", "512", "520"}
    for label, trial in plans.items():
        res = check_geometry(trial.spec, "mec_fused", trial.w_blk,
                             "float32")
        assert res.ok, f"w_blk={label}: {res.render()}"


def test_committed_autotune_trials_replay_clean():
    """Every (Pallas) w_blk the committed BENCH_autotune.json actually
    trialed replays through the static geometry gate."""
    root = pathlib.Path(__file__).resolve().parents[1]
    doc = json.loads((root / "BENCH_autotune.json").read_text())
    replayed = 0
    for r in doc["results"]:
        tuning = r.get("tuning")
        if not tuning or tuning.get("algorithm") not in PALLAS_ALGORITHMS:
            continue
        spec = ConvSpec(**r["run_spec"])
        for label, t in tuning["trials"].items():
            res = check_geometry(spec, tuning["algorithm"], t.get("w_blk"),
                                 r["dtype"])
            assert res.ok, f"{r['scenario']} w_blk={label}: {res.render()}"
            replayed += 1
    assert replayed >= 3      # the w520 grid alone contributes three


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def test_cli_lint_and_pallas_suites_green():
    from repro.analysis.__main__ import main
    assert main(["--suite", "lint"]) == 0
    assert main(["--suite", "pallas"]) == 0
