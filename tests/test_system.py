"""End-to-end system behaviour through the public entry points."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp


def test_train_launcher_end_to_end(tmp_path):
    from repro.launch.train import main
    loss = main(["--arch", "whisper-tiny", "--smoke", "--steps", "6",
                 "--global-batch", "4", "--seq-len", "32",
                 "--ckpt-dir", str(tmp_path), "--ckpt-every", "3",
                 "--log-every", "100"])
    assert np.isfinite(loss)
    # checkpoints were written
    assert any(tmp_path.glob("step_*/manifest.json"))


def test_serve_launcher_end_to_end():
    from repro.launch.serve import main
    gen = main(["--arch", "xlstm-125m", "--smoke", "--batch", "2",
                "--prompt-len", "8", "--gen", "6"])
    assert gen.shape == (2, 6)
    assert int(gen.min()) >= 0


def test_greedy_decode_is_deterministic():
    from repro.launch.serve import main
    g1 = main(["--arch", "yi-6b", "--smoke", "--batch", "2",
               "--prompt-len", "8", "--gen", "5"])
    g2 = main(["--arch", "yi-6b", "--smoke", "--batch", "2",
               "--prompt-len", "8", "--gen", "5"])
    np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))


def test_mec_conv_used_in_ssm_blocks():
    """The paper's kernel is the conv engine inside Mamba2/xLSTM blocks:
    the block output must change when the conv kernel weights change."""
    from repro.configs.archs import smoke_config
    from repro.models import mamba2
    cfg = smoke_config("zamba2-7b")
    p = mamba2.init_mamba(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model))
    y1 = mamba2.mamba_forward(p, cfg, x, chunk=8)
    p2 = dict(p, conv_w=p["conv_w"] + 1.0)
    y2 = mamba2.mamba_forward(p2, cfg, x, chunk=8)
    assert float(jnp.abs(y1 - y2).max()) > 1e-4


def test_ssd_chunk_invariance():
    """Mamba2 SSD: output independent of chunk size (exactness of the
    chunked state hand-off)."""
    from repro.models.mamba2 import ssd_chunked
    key = jax.random.key(2)
    b, s, h, p, n = 2, 32, 3, 4, 5
    x = jax.random.normal(key, (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.key(3), (b, s, h)))
    a = -jnp.exp(jax.random.normal(jax.random.key(4), (h,)) * 0.3)
    bm = jax.random.normal(jax.random.key(5), (b, s, n))
    cm = jax.random.normal(jax.random.key(6), (b, s, n))
    y8, s8 = ssd_chunked(x, dt, a, bm, cm, chunk=8)
    y32, s32 = ssd_chunked(x, dt, a, bm, cm, chunk=32)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y32), rtol=1e-4,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(s8), np.asarray(s32), rtol=1e-4,
                               atol=1e-5)


def test_chunked_attention_matches_dense():
    from repro.models.layers import chunked_attention
    b, s, h, kv, d = 2, 33, 8, 4, 16
    q = jax.random.normal(jax.random.key(7), (b, s, h, d))
    k = jax.random.normal(jax.random.key(8), (b, s, kv, d))
    v = jax.random.normal(jax.random.key(9), (b, s, kv, d))
    out = chunked_attention(q, k, v, causal=True, q_chunk=8, kv_chunk=8)
    # dense reference
    g = h // kv
    qg = q.reshape(b, s, kv, g, d)
    scores = jnp.einsum("bikgd,bjkd->bkgij", qg, k) * d ** -0.5
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    ref = jnp.einsum("bkgij,bjkd->bikgd", jax.nn.softmax(scores, -1), v)
    ref = ref.transpose(0, 1, 2, 3, 4).reshape(b, s, h, d)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4,
                               atol=2e-4)


def test_decode_attention_respects_cache_len():
    from repro.models.layers import decode_attention
    b, smax, kv, d = 2, 16, 2, 8
    q = jax.random.normal(jax.random.key(10), (b, 1, 4, d))
    k = jax.random.normal(jax.random.key(11), (b, smax, kv, d))
    v = jax.random.normal(jax.random.key(12), (b, smax, kv, d))
    out5 = decode_attention(q, k, v, jnp.asarray(5))
    # junk beyond position 5 must not matter
    k2 = k.at[:, 5:].set(99.0)
    v2 = v.at[:, 5:].set(-99.0)
    out5b = decode_attention(q, k2, v2, jnp.asarray(5))
    np.testing.assert_allclose(np.asarray(out5), np.asarray(out5b),
                               rtol=1e-5, atol=1e-5)
