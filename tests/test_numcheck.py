"""Numerics contract checker (repro.analysis.numcheck, DESIGN.md §8.5):
signature extraction + detector units, the narrow-widen taint pass, skip
semantics, the plan hook, the measured error probe vs the f64 oracle
(property-tested across backends x dtypes x seeds with tolerances drawn
from the contracts, never this file), the fft/winograd output-cast HLO
regression, and three seeded-mutation subprocess tests proving the
checker catches a dropped ``preferred_element_type``, a stray mid-chain
downcast, and a neutered f32 weight-grad accumulation — each naming the
culprit op."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.analysis import numcheck
from repro.analysis.numcheck import (NUMCHECK_ALGORITHMS, NumCheckError,
                                     assert_plan_numerics, cast_kind,
                                     cell_numcheck, check_numerics,
                                     error_probe, extract_signature,
                                     f64_conv2d, f64_conv2d_grads,
                                     hlo_convert_counts,
                                     narrow_widen_findings, probe_spec,
                                     signature_findings)
from repro.core.numerics import CONTRACT_DTYPES, contract_for

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SPEC = probe_spec()

# numpy dtype name -> HLO element-type name (for convert counting)
_HLO_NAME = {"float32": "f32", "bfloat16": "bf16", "float16": "f16"}


# ---------------------------------------------------------------------------
# units: cast classification, HLO convert counting, the f64 oracle
# ---------------------------------------------------------------------------

def test_cast_kind_classification():
    assert cast_kind("float32", "bfloat16") == "narrow"
    assert cast_kind("float16", "float32") == "widen"
    assert cast_kind("bfloat16", "float16") == "reformat"
    assert cast_kind("float32", "float32") == "same"
    assert cast_kind("float32", "complex64") == "complexify"
    assert cast_kind("complex64", "float32") == "realify"
    assert cast_kind("complex128", "complex64") == "complex-narrow"
    assert cast_kind("complex64", "complex128") == "complex-widen"
    assert cast_kind("int32", "float32") == "other"


def test_hlo_convert_counts_parses_fusion_lines():
    hlo = textwrap.dedent("""\
        %fused = bf16[2,14,14,4]{3,2,1,0} convert(f32[2,14,14,4]{3,2,1,0} %y)
        %w = f32[3,3,3,4]{3,2,1,0} convert(bf16[3,3,3,4]{3,2,1,0} %k)
        %z = bf16[2,14,14,4]{3,2,1,0} convert(f32[2,14,14,4]{3,2,1,0} %q)
        %noise = f32[4]{0} add(f32[4]{0} %a, f32[4]{0} %b)
    """)
    counts = hlo_convert_counts(hlo)
    assert counts[("f32", "bf16")] == 2
    assert counts[("bf16", "f32")] == 1


def test_f64_oracle_matches_lax_conv():
    rng = np.random.RandomState(0)
    x = rng.randn(SPEC.i_n, SPEC.i_h, SPEC.i_w, SPEC.i_c).astype(np.float32)
    k = rng.randn(SPEC.k_h, SPEC.k_w, SPEC.i_c, SPEC.k_c).astype(np.float32)
    ref = jax.lax.conv_general_dilated(
        x, k, (SPEC.s_h, SPEC.s_w), "VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        precision=jax.lax.Precision.HIGHEST)
    got = f64_conv2d(x.astype(np.float64), k.astype(np.float64),
                     SPEC.s_h, SPEC.s_w)
    np.testing.assert_allclose(got, np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_f64_oracle_grads_match_jax():
    rng = np.random.RandomState(1)
    x = rng.randn(SPEC.i_n, SPEC.i_h, SPEC.i_w, SPEC.i_c).astype(np.float32)
    k = rng.randn(SPEC.k_h, SPEC.k_w, SPEC.i_c, SPEC.k_c).astype(np.float32)

    def loss(xv, kv):
        o = jax.lax.conv_general_dilated(
            xv, kv, (SPEC.s_h, SPEC.s_w), "VALID",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            precision=jax.lax.Precision.HIGHEST)
        return jnp.sum(o * o)

    dx_j, dk_j = jax.grad(loss, argnums=(0, 1))(x, k)
    x64, k64 = x.astype(np.float64), k.astype(np.float64)
    g64 = 2.0 * f64_conv2d(x64, k64, SPEC.s_h, SPEC.s_w)
    dx, dk = f64_conv2d_grads(x64, k64, g64, SPEC.s_h, SPEC.s_w)
    np.testing.assert_allclose(dx, np.asarray(dx_j), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(dk, np.asarray(dk_j), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# units: signature extraction + static detectors
# ---------------------------------------------------------------------------

def test_extract_signature_sees_dot_and_casts():
    def f(a, b):
        y = jnp.dot(a, b, preferred_element_type=jnp.float32)
        return y.astype(a.dtype)

    closed = jax.make_jaxpr(f)(
        jax.ShapeDtypeStruct((4, 8), "bfloat16"),
        jax.ShapeDtypeStruct((8, 2), "bfloat16"))
    sig = extract_signature(closed)
    [dot] = sig["dots"]
    assert dot["op"] == "dot_general"
    assert dot["operands"] == ["bfloat16", "bfloat16"]
    assert dot["out"] == "float32"
    assert dot["preferred_element_type"] == "float32"
    assert not dot["pallas"]
    assert ("float32", "bfloat16") in [(c["src"], c["dst"])
                                       for c in sig["casts"]]


def _findings(sig, algorithm, direction, dtype):
    return signature_findings(sig, contract_for(algorithm), direction, dtype)


def test_detector_accumulation_fires_on_sub_f32_output():
    sig = {"dots": [{"op": "dot_general",
                     "operands": ["bfloat16", "bfloat16"],
                     "out": "bfloat16", "preferred_element_type": None,
                     "precision": None, "pallas": False}],
           "casts": []}
    rules = [v.rule for v in _findings(sig, "im2col", "grad", "bfloat16")]
    assert "accumulation" in rules


def test_detector_disallowed_dtype_and_f64_leak():
    sig = {"dots": [],
           "casts": [{"op": "convert_element_type", "src": "float32",
                      "dst": "bfloat16", "kind": "narrow", "pallas": False},
                     {"op": "convert_element_type", "src": "float32",
                      "dst": "float64", "kind": "widen", "pallas": False}]}
    rules = {v.rule for v in _findings(sig, "mec", "fwd", "float32")}
    # bf16 in an f32 program is a stray downcast; f64 is its own rule.
    assert rules == {"disallowed-dtype", "f64-leak"}


def test_detector_pallas_accum_requires_explicit_preferred_type():
    sig = {"dots": [{"op": "dot_general",
                     "operands": ["float16", "float16"],
                     "out": "float32", "preferred_element_type": None,
                     "precision": None, "pallas": True}],
           "casts": []}
    rules = [v.rule for v in _findings(sig, "mec_fused", "grad", "float16")]
    assert "pallas-accum" in rules
    # the same dot with the annotation is clean
    sig["dots"][0]["preferred_element_type"] = "float32"
    assert not _findings(sig, "mec_fused", "grad", "float16")


def test_detector_output_cast_count():
    base = {"op": "convert_element_type", "src": "float32",
            "dst": "bfloat16", "kind": "narrow", "pallas": False}
    # zero narrows: accumulator never narrowed
    rules = [v.rule for v in _findings({"dots": [], "casts": []},
                                       "im2col", "fwd", "bfloat16")]
    assert "output-cast-count" in rules
    # exactly one: clean
    assert not _findings({"dots": [], "casts": [dict(base)]},
                         "im2col", "fwd", "bfloat16")
    # two: double rounding
    rules = [v.rule for v in _findings(
        {"dots": [], "casts": [dict(base), dict(base)]},
        "im2col", "fwd", "bfloat16")]
    assert "output-cast-count" in rules
    # grad direction never counts output narrows
    assert not _findings({"dots": [], "casts": []},
                         "im2col", "grad", "bfloat16")


def test_narrow_widen_taint_fires_through_structural_ops_only():
    def bad(x):
        y = x.astype(jnp.bfloat16)
        y = y.reshape(2, 8).T
        return y.astype(jnp.float32)

    def ok(x):
        y = x.astype(jnp.bfloat16)
        z = y * y                       # arithmetic consumes the taint
        return z.astype(jnp.float32)

    s = jax.ShapeDtypeStruct((4, 4), "float32")
    bad_v = narrow_widen_findings(jax.make_jaxpr(bad)(s), "fwd")
    assert [v.rule for v in bad_v] == ["narrow-widen"]
    assert "bfloat16" in bad_v[0].message
    assert not narrow_widen_findings(jax.make_jaxpr(ok)(s), "fwd")


# ---------------------------------------------------------------------------
# the checker: contracts, skips, passing cells, the bench/plan wiring
# ---------------------------------------------------------------------------

def test_every_swept_backend_declares_a_contract():
    for alg in NUMCHECK_ALGORITHMS:
        c = contract_for(alg)
        assert c is not None, alg
        for dtype in CONTRACT_DTYPES:
            assert c.tolerance(dtype, "fwd") > 0
            assert c.tolerance(dtype, "grad") >= c.tolerance(dtype, "fwd")
        allowed = c.allowed_dtypes("bfloat16")
        assert "bfloat16" in allowed and "float32" in allowed
        assert ("complex64" in allowed) == c.complex_pair


def test_check_numerics_skips_are_not_failures():
    unknown = check_numerics(SPEC, "does_not_exist", "float32", probe=False)
    assert unknown.ok and unknown.skipped and \
        unknown.record["verdict"] == "skipped"
    from repro.core.convspec import ConvSpec
    off = ConvSpec(2, 16, 16, 3, 5, 5, 4, 1, 1)
    wino = check_numerics(off, "winograd", "float32", probe=False)
    assert wino.skipped and "3x3" in wino.skipped


@pytest.mark.parametrize("alg", ["im2col", "fft", "mec", "mec_fused"])
def test_static_contract_passes_bf16(alg):
    res = check_numerics(SPEC, alg, "bfloat16", interpret=True, probe=False)
    assert res.ok and not res.skipped, res.render()
    fwd = res.record["directions"]["fwd"]
    assert fwd["dots"] >= 1
    assert fwd["narrows_to_input"] == 1
    if alg == "mec_fused":
        assert fwd["pallas_dots"] >= 1


def test_cell_numcheck_is_reduced_and_memoized():
    numcheck._CELL_CACHE.clear()
    a = cell_numcheck(SPEC, "im2col", "bfloat16", interpret=True)
    assert set(a) == {"verdict", "skipped_reason", "violations"}
    assert a["verdict"] == "pass"
    b = cell_numcheck(SPEC, "im2col", "bfloat16", interpret=True)
    assert a == b and len(numcheck._CELL_CACHE) == 1


class _FakePlan:
    def __init__(self, **kw):
        self.__dict__.update(kw)


def test_assert_plan_numerics_hook(monkeypatch):
    # auto / unresolved plans are not checkable -> silently fine
    assert_plan_numerics(_FakePlan(algorithm="auto", spec=SPEC,
                                   dtype="float32"))
    assert_plan_numerics(_FakePlan(algorithm=None, spec=SPEC,
                                   dtype="float32"))
    # a healthy resolved plan passes (and is duck-typed, no repro.plan)
    assert_plan_numerics(_FakePlan(algorithm="im2col", spec=SPEC,
                                   dtype="bfloat16", solution="auto",
                                   precision=None))
    # a failing check raises and the verdict is memoized
    calls = []

    def fake_check(spec, algorithm, dtype="float32", **kw):
        calls.append(algorithm)
        return numcheck.NumCheck(algorithm, dtype,
                                 [numcheck.ContractViolation(
                                     "accumulation", "grad", "boom")],
                                 {"verdict": "fail"})

    monkeypatch.setattr(numcheck, "check_numerics", fake_check)
    bad = _FakePlan(algorithm="im2col", spec="fake-spec-for-hook-test",
                    dtype="bfloat16", solution="auto", precision=None)
    with pytest.raises(NumCheckError, match="accumulation"):
        assert_plan_numerics(bad)
    with pytest.raises(NumCheckError):
        assert_plan_numerics(bad)           # cached verdict, no re-trace
    assert len(calls) == 1


def test_plan_conv2d_asserts_the_contract():
    # the real wiring: plan_conv2d runs the hook before returning a plan
    from repro.plan.convplan import plan_conv2d
    plan = plan_conv2d(SPEC, dtype="bfloat16", mode="analytic")
    assert plan.algorithm            # resolved and contract-clean


# ---------------------------------------------------------------------------
# measured error budgets (tolerances from the contract, never this file)
# ---------------------------------------------------------------------------

ALGS_ST = st.sampled_from(NUMCHECK_ALGORITHMS)
DTYPES_ST = st.sampled_from(["float32", "bfloat16"])
SEEDS_ST = st.integers(min_value=0, max_value=3)


@settings(max_examples=10, deadline=None)
@given(ALGS_ST, DTYPES_ST, SEEDS_ST)
def test_property_probe_error_within_contract_budget(alg, dtype, seed):
    c = contract_for(alg)
    errs = error_probe(SPEC, alg, dtype, interpret=True, seed=seed)
    assert errs["fwd_err"] <= c.tolerance(dtype, "fwd"), (alg, dtype, errs)
    grad_tol = c.tolerance(dtype, "grad")
    assert errs["din_err"] <= grad_tol, (alg, dtype, errs)
    assert errs["dk_err"] <= grad_tol, (alg, dtype, errs)


# ---------------------------------------------------------------------------
# fft / winograd output round-trip: exactly one final narrowing cast
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("alg", ["fft", "winograd"])
@pytest.mark.parametrize("dtype", ["bfloat16", "float16"])
def test_output_roundtrip_single_narrow(alg, dtype):
    """The f32 (or c64) pipeline must narrow back to the input dtype
    exactly once — in the jaxpr *and* in the optimized HLO the compiler
    actually runs (a second narrow would be double rounding)."""
    from repro.core.conv_api import conv2d

    def fwd(xv, kv):
        return conv2d(xv, kv, stride=(SPEC.s_h, SPEC.s_w), algorithm=alg,
                      partition="none")

    x_s = jax.ShapeDtypeStruct((SPEC.i_n, SPEC.i_h, SPEC.i_w, SPEC.i_c),
                               dtype)
    k_s = jax.ShapeDtypeStruct((SPEC.k_h, SPEC.k_w, SPEC.i_c, SPEC.k_c),
                               dtype)
    sig = extract_signature(jax.make_jaxpr(fwd)(x_s, k_s))
    narrows = [c for c in sig["casts"]
               if c["kind"] == "narrow" and c["dst"] == dtype]
    assert len(narrows) == 1, narrows
    hlo = jax.jit(fwd).lower(x_s, k_s).compile().as_text()
    counts = hlo_convert_counts(hlo)
    lowered = sum(n for (src, dst), n in counts.items()
                  if dst == _HLO_NAME[dtype] and src == "f32")
    assert lowered == 1, counts


# ---------------------------------------------------------------------------
# seeded mutations: the checker must fail naming the culprit op
# ---------------------------------------------------------------------------

def _run(prog, timeout=900):
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(prog)],
                         env=env, cwd=REPO, capture_output=True,
                         text=True, timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


_MUTATION_HEADER = """
        import os
        os.environ["JAX_PLATFORMS"] = "cpu"
        import json
        import jax.numpy as jnp
        from repro.analysis.numcheck import check_numerics, probe_spec
"""


def test_mutation_dropped_preferred_element_type_is_caught():
    """Strip ``preferred_element_type`` off im2col's GEMM (the PR 4/PR 5
    bug class): the bf16 cell must fail with an accumulation violation
    naming the dot."""
    res = _run(_MUTATION_HEADER + """
        import repro.core.im2col as im2col_mod

        class _BareDotJnp:
            def __getattr__(self, name):
                return getattr(jnp, name)
            def dot(self, a, b, precision=None, preferred_element_type=None):
                return jnp.dot(a, b, precision=precision)

        im2col_mod.jnp = _BareDotJnp()
        chk = check_numerics(probe_spec(), "im2col", "bfloat16",
                             probe=False)
        print(json.dumps({"verdict": chk.record["verdict"],
                          "violations": chk.record["violations"]}))
    """)
    assert res["verdict"] == "fail"
    acc = [v for v in res["violations"] if v.startswith("[accumulation]")]
    assert acc and any("dot_general" in v for v in acc), res["violations"]


def test_mutation_stray_mid_chain_downcast_is_caught():
    """Insert a stray bf16 round-trip after ``mec_lower`` in an f32
    program: disallowed-dtype (naming the convert) plus the
    narrow-widen taint must both fire."""
    res = _run(_MUTATION_HEADER + """
        import repro.core.mec as mec_mod
        import repro.core.conv_api as conv_api

        _orig = mec_mod.mec_lower
        def leaky_lower(inp, k_w, s_w):
            low = _orig(inp, k_w, s_w)
            return low.astype(jnp.bfloat16).astype(low.dtype)
        mec_mod.mec_lower = leaky_lower
        conv_api.mec_lower = leaky_lower

        chk = check_numerics(probe_spec(), "mec", "float32", probe=False)
        print(json.dumps({"verdict": chk.record["verdict"],
                          "violations": chk.record["violations"]}))
    """)
    assert res["verdict"] == "fail"
    rules = {v.split("]")[0].lstrip("[") for v in res["violations"]}
    assert "disallowed-dtype" in rules, res["violations"]
    assert "narrow-widen" in rules, res["violations"]
    assert any("convert_element_type" in v and "bfloat16" in v
               for v in res["violations"]), res["violations"]


def test_mutation_neutered_weight_grad_accumulation_is_caught():
    """Replace the VJP's f32-accumulating weight grad with a bf16
    einsum: the grad direction must fail with an accumulation violation
    naming the dot (the forward stays clean)."""
    res = _run(_MUTATION_HEADER + """
        from jax import lax
        import repro.core.conv_api as conv_api

        def bf16_wgrad(inp, g, s_h, s_w, k_h, k_w, precision=None):
            low = conv_api.mec_lower(inp, k_w, s_w)
            o_h = g.shape[1]
            gb = g.astype(jnp.bfloat16)
            lowb = low.astype(jnp.bfloat16)
            rows = []
            for r in range(k_h):
                lr = lax.slice_in_dim(lowb, r, r + s_h * (o_h - 1) + 1,
                                      stride=s_h, axis=2)
                rows.append(jnp.einsum("nwhjc,nhwo->jco", lr, gb))
            return jnp.stack(rows, axis=0)

        conv_api._mec_weight_grad = bf16_wgrad
        chk = check_numerics(probe_spec(), "mec", "bfloat16", probe=False)
        fwd_only = check_numerics(probe_spec(), "mec", "bfloat16",
                                  probe=False, directions=("fwd",))
        print(json.dumps({"verdict": chk.record["verdict"],
                          "violations": chk.record["violations"],
                          "fwd_verdict": fwd_only.record["verdict"]}))
    """)
    assert res["fwd_verdict"] == "pass"
    assert res["verdict"] == "fail"
    acc = [v for v in res["violations"]
           if v.startswith("[accumulation] grad")]
    assert acc and any("dot_general" in v for v in acc), res["violations"]
