"""Strategy objects for the vendored hypothesis stub (see __init__.py)."""
from __future__ import annotations

import random
from typing import Callable, Sequence

_FILTER_TRIES = 200


class _Strategy:
    """A draw rule plus an optional chain of .filter predicates."""

    def __init__(self, draw: Callable[[random.Random], object]):
        self._draw = draw

    def filter(self, pred: Callable[[object], bool]) -> "_Strategy":
        def draw(rng: random.Random):
            for _ in range(_FILTER_TRIES):
                v = self._draw(rng)
                if pred(v):
                    return v
            from . import UnsatisfiedAssumption
            raise UnsatisfiedAssumption()

        return _Strategy(draw)

    def example(self, rng: random.Random):
        return self._draw(rng)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def sampled_from(elements: Sequence) -> _Strategy:
    elements = list(elements)
    return _Strategy(lambda rng: rng.choice(elements))


def tuples(*strats: _Strategy) -> _Strategy:
    return _Strategy(lambda rng: tuple(s.example(rng) for s in strats))


__all__ = ["integers", "sampled_from", "tuples"]
