"""Minimal, deterministic stand-in for the ``hypothesis`` API surface this
suite uses (the container image does not ship hypothesis and nothing may
be pip-installed).  Only loaded when the real library is absent — see
``tests/conftest.py``.

Supported: ``given``, ``settings(max_examples=, deadline=)``, ``assume``,
and the strategies in ``hypothesis.strategies`` (``integers``, ``tuples``,
``sampled_from``, each with ``.filter``).  Examples are drawn from a
seeded PRNG so runs are reproducible; ``assume``/filter rejections retry
up to a bounded number of times per example.
"""
from __future__ import annotations

import random

from . import strategies  # noqa: F401  (registers hypothesis.strategies)
from .strategies import _Strategy

_DEFAULT_MAX_EXAMPLES = 20
_MAX_REJECTIONS = 2000


class UnsatisfiedAssumption(Exception):
    pass


def assume(condition) -> bool:
    if not condition:
        raise UnsatisfiedAssumption()
    return True


class settings:  # noqa: N801 — mirrors hypothesis' public name
    def __init__(self, max_examples: int = _DEFAULT_MAX_EXAMPLES,
                 deadline=None, **_ignored):
        self.max_examples = max_examples
        self.deadline = deadline

    def __call__(self, fn):
        fn._stub_settings = self
        return fn


def given(*strats: _Strategy):
    for s in strats:
        if not isinstance(s, _Strategy):
            raise TypeError(f"given() expects strategies, got {s!r}")

    def decorate(fn):
        cfg = getattr(fn, "_stub_settings", None)
        max_examples = cfg.max_examples if cfg else _DEFAULT_MAX_EXAMPLES

        # NB: no functools.wraps — pytest would follow __wrapped__ to the
        # original signature and try to resolve the strategy-bound
        # parameters as fixtures.  All parameters come from strategies, so
        # the collected test takes no arguments.
        def wrapper(*args, **kwargs):
            # Seed on the test name so every run draws the same examples.
            rng = random.Random(fn.__qualname__)
            ran = rejected = 0
            while ran < max_examples:
                if rejected > _MAX_REJECTIONS:
                    raise RuntimeError(
                        f"{fn.__qualname__}: exceeded {_MAX_REJECTIONS} "
                        "filter/assume rejections")
                try:
                    values = [s.example(rng) for s in strats]
                except UnsatisfiedAssumption:
                    rejected += 1
                    continue
                try:
                    fn(*args, *values, **kwargs)
                except UnsatisfiedAssumption:
                    rejected += 1
                    continue
                ran += 1

        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper

    return decorate


__all__ = ["assume", "given", "settings", "strategies",
           "UnsatisfiedAssumption"]
