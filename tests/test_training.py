"""Optimizer, loss, and training-loop behaviour."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.optim import adamw
from repro.training.loss import chunked_softmax_xent


def test_adamw_converges_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                            total_steps=200, clip_norm=100.0)
    target = jnp.asarray([1.5, -2.0, 0.5])
    params = {"w": jnp.zeros(3)}
    opt = adamw.init(params)
    loss_fn = lambda p: jnp.sum((p["w"] - target) ** 2)
    for _ in range(150):
        g = jax.grad(loss_fn)(params)
        params, opt, _ = adamw.update(cfg, g, opt, params)
    assert float(loss_fn(params)) < 1e-3


def test_adamw_clips_gradients():
    cfg = adamw.AdamWConfig(clip_norm=1.0, warmup_steps=1, total_steps=10)
    params = {"w": jnp.zeros(4)}
    opt = adamw.init(params)
    g = {"w": jnp.full(4, 1e6)}
    _, _, metrics = adamw.update(cfg, g, opt, params)
    assert float(metrics["grad_norm"]) > 1e5  # reported pre-clip


def test_schedule_warmup_and_cosine():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            min_lr_frac=0.1)
    assert float(adamw.schedule(cfg, jnp.asarray(0))) == 0.0
    assert abs(float(adamw.schedule(cfg, jnp.asarray(10))) - 1.0) < 1e-6
    assert abs(float(adamw.schedule(cfg, jnp.asarray(100))) - 0.1) < 1e-6
    mid = float(adamw.schedule(cfg, jnp.asarray(55)))
    assert 0.1 < mid < 1.0


def test_chunked_xent_matches_dense():
    key = jax.random.key(0)
    b, s, d, v = 2, 13, 8, 31
    h = jax.random.normal(key, (b, s, d))
    w = jax.random.normal(jax.random.key(1), (d, v))
    labels = jax.random.randint(jax.random.key(2), (b, s), 0, v)
    loss, metrics = chunked_softmax_xent(h, w, labels, chunk=4, z_loss=0.0)
    logits = h @ w
    dense = -jax.nn.log_softmax(logits)[
        jnp.arange(b)[:, None], jnp.arange(s)[None], labels].mean()
    np.testing.assert_allclose(float(loss), float(dense), rtol=1e-5)
    assert int(metrics["tokens"]) == b * s


def test_chunked_xent_ignores_masked():
    h = jax.random.normal(jax.random.key(3), (1, 6, 4))
    w = jax.random.normal(jax.random.key(4), (4, 9))
    labels = jnp.asarray([[1, 2, -1, -1, 3, -1]])
    loss, metrics = chunked_softmax_xent(h, w, labels, chunk=2, z_loss=0.0)
    assert int(metrics["tokens"]) == 3
    assert np.isfinite(float(loss))


def test_chunked_xent_grad_matches_dense():
    b, s, d, v = 2, 8, 6, 17
    h = jax.random.normal(jax.random.key(5), (b, s, d))
    w = jax.random.normal(jax.random.key(6), (d, v))
    labels = jax.random.randint(jax.random.key(7), (b, s), 0, v)

    def f_chunked(w):
        return chunked_softmax_xent(h, w, labels, chunk=3, z_loss=0.0)[0]

    def f_dense(w):
        logits = h @ w
        return -jax.nn.log_softmax(logits)[
            jnp.arange(b)[:, None], jnp.arange(s)[None], labels].mean()

    g1, g2 = jax.grad(f_chunked)(w), jax.grad(f_dense)(w)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-4,
                               atol=1e-6)


def test_train_loss_decreases_end_to_end():
    """A tiny dense model on structured synthetic data must learn."""
    from repro.launch.train import main
    loss = main(["--arch", "qwen3-4b", "--smoke", "--steps", "60",
                 "--global-batch", "16", "--seq-len", "64", "--lr", "3e-3",
                 "--log-every", "100"])
    # random floor ln(256)=5.55; the topic structure is worth ln(16)=2.77
    assert loss < 4.3, loss
