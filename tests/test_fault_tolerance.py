"""Fault tolerance: atomic checkpointing, exact resume after a simulated
crash, elastic restore, async writer, retention, and the straggler
watchdog."""
import json
import os
import pathlib
import shutil
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.ckpt.manager import CheckpointManager
from repro.configs.archs import smoke_config
from repro.data.pipeline import DataState, SyntheticLMData
from repro.models.lm import LM
from repro.optim.adamw import AdamWConfig
from repro.training.steps import init_opt_state, make_train_step
from repro.training.watchdog import StepWatchdog


def _tree_allclose(a, b):
    ok = jax.tree.map(
        lambda x, y: np.allclose(np.asarray(x, np.float32),
                                 np.asarray(y, np.float32), atol=1e-7), a, b)
    return all(jax.tree.leaves(ok))


def test_ckpt_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path)
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.bfloat16),
            "b": {"c": jnp.ones((4,))}}
    mgr.save(7, {"params": tree})
    assert mgr.latest_step() == 7
    out = mgr.restore(7, {"params": tree})
    assert _tree_allclose(out["params"], tree)
    # dtype preserved
    assert out["params"]["a"].dtype == jnp.bfloat16


def test_ckpt_atomic_no_partial(tmp_path):
    """A leftover .tmp directory is never considered a checkpoint."""
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, {"params": {"w": jnp.ones(3)}})
    fake_tmp = tmp_path / "step_00000002.tmp"
    fake_tmp.mkdir()
    (fake_tmp / "garbage").write_text("crash mid-write")
    assert mgr.latest_step() == 1


def test_ckpt_retention(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"params": {"w": jnp.ones(2) * s}})
    assert mgr.all_steps() == [3, 4]


def test_ckpt_async(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save_async(5, {"params": {"w": jnp.zeros(128)}})
    mgr.wait()
    assert mgr.latest_step() == 5


def test_crash_resume_is_exact(tmp_path):
    """Train 8 steps straight vs 4 steps + 'crash' + resume 4 steps: the
    final params must be bit-identical (atomic ckpt + resumable data)."""
    cfg = smoke_config("yi-6b")
    model = LM(cfg)
    opt_cfg = AdamWConfig(total_steps=8, warmup_steps=2)
    step_fn = jax.jit(make_train_step(model, opt_cfg))

    def fresh():
        params = model.init(jax.random.key(0))
        return params, init_opt_state(params), SyntheticLMData(cfg, 4, 32)

    # --- straight run
    params, opt, data = fresh()
    for _ in range(8):
        params, opt, _ = step_fn(params, opt, data.next_batch())
    straight = params

    # --- interrupted run
    mgr = CheckpointManager(tmp_path)
    params, opt, data = fresh()
    for _ in range(4):
        params, opt, _ = step_fn(params, opt, data.next_batch())
    mgr.save(4, {"params": params, "opt": opt, "data": data.state.to_dict()})
    del params, opt, data                      # "crash"

    params, opt, data = fresh()                # cold restart
    restored = mgr.restore(4, {"params": params, "opt": opt,
                               "data": data.state.to_dict()})
    params, opt = restored["params"], restored["opt"]
    data.state = DataState.from_dict(restored["data"])
    assert data.state.step == 4
    for _ in range(4):
        params, opt, _ = step_fn(params, opt, data.next_batch())

    same = jax.tree.map(
        lambda a, b: bool((np.asarray(a) == np.asarray(b)).all()),
        straight, params)
    assert all(jax.tree.leaves(same)), "resume diverged from straight run"


def test_elastic_restore_changes_sharding(tmp_path):
    """Restore with explicit shardings (the elastic path) round-trips."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    mgr = CheckpointManager(tmp_path)
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    mgr.save(1, {"params": tree})
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1), ("data",))
    shard = {"params": {"w": NamedSharding(mesh, P("data", None))}}
    out = mgr.restore(1, {"params": tree}, shardings=shard)
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                  np.asarray(tree["w"]))
    assert out["params"]["w"].sharding == shard["params"]["w"]


def test_watchdog_flags_straggler():
    dog = StepWatchdog(threshold=2.0, warmup_steps=0)
    for dt in [0.01] * 8:
        dog.start_step()
        time.sleep(dt)
        dog.end_step()
    dog.start_step()
    time.sleep(0.1)                  # 10x median
    dog.end_step()
    assert dog.straggler_events >= 1


def test_watchdog_hard_deadline():
    dog = StepWatchdog(hard_timeout_s=0.01)
    dog.start_step()
    time.sleep(0.05)
    with pytest.raises(TimeoutError):
        dog.check_deadline()


def test_data_pipeline_host_sharding():
    cfg = smoke_config("yi-6b")
    full = SyntheticLMData(cfg, 8, 16, host_id=0, num_hosts=1)
    h0 = SyntheticLMData(cfg, 8, 16, host_id=0, num_hosts=2)
    h1 = SyntheticLMData(cfg, 8, 16, host_id=1, num_hosts=2)
    bf, b0, b1 = full.next_batch(), h0.next_batch(), h1.next_batch()
    np.testing.assert_array_equal(np.asarray(bf["tokens"][0::2]),
                                  np.asarray(b0["tokens"]))
    np.testing.assert_array_equal(np.asarray(bf["tokens"][1::2]),
                                  np.asarray(b1["tokens"]))
