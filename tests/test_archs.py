"""Per-assigned-architecture smoke tests: reduced same-family config, one
forward + one train step on CPU, asserting shapes and no NaNs; plus
prefill->decode == full-prefill consistency (validates caches, including
the closed-form mLSTM/Mamba2 prefill states)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.archs import ARCHS, smoke_config
from repro.configs.shapes import SHAPES, make_batch, smoke_shape
from repro.models import serve
from repro.models.lm import LM
from repro.optim.adamw import AdamWConfig
from repro.training.steps import init_opt_state, make_train_step

ALL_ARCHS = sorted(ARCHS)


@pytest.fixture(scope="module")
def cell():
    return smoke_shape(SHAPES["train_4k"])


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_and_train_step(arch, cell):
    cfg = smoke_config(arch)
    model = LM(cfg)
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg, cell)
    h, aux = model.forward(params, batch)
    exp_s = batch["tokens"].shape[1]
    assert h.shape == (cell.global_batch, exp_s, cfg.d_model)
    assert not bool(jnp.isnan(h).any())

    step = jax.jit(make_train_step(model, AdamWConfig(total_steps=10)))
    opt = init_opt_state(params)
    p2, o2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                                - b.astype(jnp.float32)).sum()),
                     params, p2))
    assert delta > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_matches_prefill(arch):
    cfg = smoke_config(arch)
    model = LM(cfg)
    params = model.init(jax.random.key(0))
    key = jax.random.key(1)
    b, s = 2, 17
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab, dtype=jnp.int32)
    batch = {"tokens": toks[:, :s - 1]}
    full = {"tokens": toks}
    max_len = s + 8 + (cfg.prefix_len if cfg.family == "vlm" else 0)
    if cfg.family == "vlm":
        vis = jax.random.normal(key, (b, cfg.prefix_len, cfg.d_model))
        batch["vision"] = vis
        full["vision"] = vis
    if cfg.family == "audio":
        fr = jax.random.normal(key, (b, cfg.encoder_len, cfg.d_model))
        batch["frames"] = fr
        full["frames"] = fr
    _, cache = serve.prefill(model, params, batch, max_len=max_len)
    logits_dec, _ = serve.decode_step(model, params, cache, toks[:, s - 1:s])
    logits_ref, _ = serve.prefill(model, params, full, max_len=max_len)
    rel = (float(jnp.max(jnp.abs(logits_dec - logits_ref)))
           / (float(jnp.max(jnp.abs(logits_ref))) + 1e-9))
    assert rel < 2e-2, f"{arch}: decode/prefill mismatch rel={rel}"


def test_exact_configs_match_assignment():
    """Spot-check the full configs against the assignment table."""
    c = ARCHS["qwen3-4b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (36, 2560, 32, 8, 9728, 151936) and c.qk_norm
    c = ARCHS["kimi-k2-1t-a32b"]
    assert (c.n_layers, c.d_model, c.n_experts, c.top_k) == (61, 7168, 384, 8)
    assert c.param_count() > 0.9e12                 # the 1T-param MoE
    assert c.param_count(active_only=True) < 40e9   # ~32B active
    c = ARCHS["zamba2-7b"]
    assert (c.n_layers, c.d_model, c.ssm_state) == (81, 3584, 64)
    c = ARCHS["whisper-tiny"]
    assert (c.n_layers, c.encoder_layers, c.d_model, c.d_ff) == (4, 4, 384, 1536)
    c = ARCHS["xlstm-125m"]
    assert (c.n_layers, c.d_model, c.vocab) == (12, 768, 50304)


@pytest.mark.parametrize("arch", ["zamba2-7b", "xlstm-125m"])
def test_long_context_archs_have_o1_decode_state(arch):
    """long_500k applicability: decode state must not grow with seq_len
    (except the hybrid's shared-attn KV cache, which is seq-sharded)."""
    cfg = smoke_config(arch)
    model = LM(cfg)
    c1 = serve.init_decode_cache(model, batch=2, max_len=64)
    c2 = serve.init_decode_cache(model, batch=2, max_len=128)

    def nonattn_bytes(tree):
        total = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
            keys = "/".join(str(getattr(k, "key", k)) for k in path)
            if "attn" not in keys and "len" not in keys:
                total += leaf.size
        return total

    assert nonattn_bytes(c1) == nonattn_bytes(c2)
