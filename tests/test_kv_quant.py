"""int8 KV-cache decode (beyond-paper §Roofline lever for decode cells)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.archs import smoke_config
from repro.models import serve
from repro.models.layers import decode_attention, quantize_kv
from repro.models.lm import LM


def test_quantize_kv_roundtrip():
    x = jax.random.normal(jax.random.key(0), (2, 7, 3, 16)) * 2.5
    q, s = quantize_kv(x)
    back = q.astype(jnp.float32) * s.astype(jnp.float32)
    err = np.abs(np.asarray(back - x))
    # half an int8 step plus the bf16 rounding of the scale itself
    # (|q| <= 127 and bf16 has ~0.4% relative error: 127*0.004 ~ 0.5)
    assert (err <= np.asarray(s, np.float32) * 1.01 + 1e-6).all()


def test_decode_attention_int8_close_to_exact():
    b, smax, kv, g, d = 2, 24, 2, 2, 16
    q = jax.random.normal(jax.random.key(1), (b, 1, kv * g, d))
    k = jax.random.normal(jax.random.key(2), (b, smax, kv, d))
    v = jax.random.normal(jax.random.key(3), (b, smax, kv, d))
    exact = decode_attention(q, k, v, jnp.asarray(20))
    kq, ks = quantize_kv(k)
    vq, vs = quantize_kv(v)
    quant = decode_attention(q, kq, vq, jnp.asarray(20),
                             k_scale=ks, v_scale=vs)
    rel = float(jnp.max(jnp.abs(quant - exact))) / \
        float(jnp.max(jnp.abs(exact)))
    assert rel < 0.03, rel


@pytest.mark.parametrize("arch", ["yi-6b", "qwen3-4b"])
def test_int8_cache_decode_dense(arch):
    """Full decode loop: int8 cache tracks the bf16 cache closely on dense
    archs.  (MoE is excluded: discrete top-k routing in a random-weight
    model flips under tiny perturbations — router sensitivity, not a
    cache bug; logits remain finite, checked below.)"""
    cfg = smoke_config(arch)
    model = LM(cfg)
    params = model.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 8), 0, cfg.vocab,
                              jnp.int32)
    model8 = LM(cfg.with_(kv_cache_int8=True))

    def run(m):
        c = serve.init_decode_cache(m, 2, 16)
        c = dict(c, len=jnp.asarray(0, jnp.int32))
        for t in range(6):
            logits, c = serve.decode_step(m, params, c, toks[:, t:t + 1])
        return logits

    l_exact, l_q = run(model), run(model8)
    rel = float(jnp.max(jnp.abs(l_exact - l_q))) / \
        float(jnp.max(jnp.abs(l_exact)))
    assert rel < 0.05, rel


def test_int8_cache_decode_moe_finite():
    cfg = smoke_config("qwen3-moe-30b-a3b").with_(kv_cache_int8=True)
    model = LM(cfg)
    params = model.init(jax.random.key(0))
    c = serve.init_decode_cache(model, 2, 8)
    c = dict(c, len=jnp.asarray(0, jnp.int32))
    logits, c = serve.decode_step(model, params, c,
                                  jnp.ones((2, 1), jnp.int32))
    assert bool(jnp.isfinite(logits).all())


def test_int8_cache_half_bytes():
    cfg = smoke_config("yi-6b")
    m_bf, m_q8 = LM(cfg), LM(cfg.with_(kv_cache_int8=True))
    def nbytes(m):
        c = serve.init_decode_cache(m, 4, 64)
        return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(c))
    assert nbytes(m_q8) < 0.6 * nbytes(m_bf)
