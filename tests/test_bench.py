"""repro.bench: scenario-registry completeness, report schema validation,
determinism of the analytic memory/flops fields, and the regression
gate's pass/fail behaviour (including the committed CI smoke baseline)."""
import copy
import json
import pathlib

import pytest

from repro.bench import (CV_LAYERS, RESNET101_WEIGHTS, SUITES,
                         ALGORITHM_VARIANTS, resolve_suite, validate_report)
from repro.bench.check import compare
from repro.bench.harness import measure
from repro.bench.report import make_report
from repro.bench.scenarios import Scenario
from repro.core.convspec import ConvSpec

REPO = pathlib.Path(__file__).resolve().parents[1]
BASELINE = REPO / "benchmarks" / "baselines" / "smoke.json"


# ---------------------------------------------------------------- registry

def test_table2_suite_has_every_paper_layer():
    names = {sc.name for sc in resolve_suite("table2")}
    assert len(CV_LAYERS) == 12
    assert names == set(CV_LAYERS)


def test_every_registered_suite_resolves():
    for suite in SUITES:
        scenarios = resolve_suite(suite)
        assert scenarios, suite
        for sc in scenarios:
            assert sc.algorithms, (suite, sc.name)
            sc.spec.validate()
            sc.run_spec.validate()


def test_resnet101_suite_carries_paper_weights():
    weights = {sc.name: sc.weight for sc in resolve_suite("resnet101")}
    assert weights == RESNET101_WEIGHTS


def test_smoke_suite_covers_every_algorithm_variant():
    algs = set()
    for sc in resolve_suite("smoke"):
        algs.update(sc.algorithms)
    assert algs == set(ALGORITHM_VARIANTS)


def test_unknown_suite_rejected():
    with pytest.raises(KeyError):
        resolve_suite("nope")


# ------------------------------------------------------------ report schema

def _tiny_scenario():
    spec = ConvSpec(1, 8, 8, 2, 3, 3, 4, 1, 1)
    return Scenario(name="tiny", spec=spec, run_spec=spec,
                    algorithms=("direct", "im2col", "mecA", "mec_fused"))


@pytest.fixture(scope="module")
def tiny_doc():
    sc = _tiny_scenario()
    recs = [measure(sc, alg, iters=1, warmup=1) for alg in sc.algorithms]
    return make_report("smoke", recs, {"iters": 1, "warmup": 1})


def test_emitted_report_is_schema_valid(tiny_doc):
    assert validate_report(tiny_doc) == []
    # and survives a JSON round-trip (what check/CI actually consume)
    assert validate_report(json.loads(json.dumps(tiny_doc))) == []


def test_schema_rejects_malformed_reports(tiny_doc):
    bad = copy.deepcopy(tiny_doc)
    del bad["results"][0]["overhead_bytes"]
    assert any("overhead_bytes" in e for e in validate_report(bad))
    bad = copy.deepcopy(tiny_doc)
    bad["results"][0]["flops"] = "lots"
    assert any("flops" in e for e in validate_report(bad))
    bad = copy.deepcopy(tiny_doc)
    bad["schema_version"] = 99
    assert any("schema_version" in e for e in validate_report(bad))
    assert validate_report({"suite": "x"})  # no results at all


def test_memory_and_flops_fields_deterministic():
    sc = _tiny_scenario()
    runs = [[measure(sc, alg, with_hlo=False, with_timing=False)
             for alg in sc.algorithms] for _ in range(2)]
    assert runs[0] == runs[1]
    by_alg = {r["algorithm"]: r for r in runs[0]}
    # Eq. 2 vs Eq. 3 on the tiny spec: im2col strictly bigger, fused zero.
    assert by_alg["im2col"]["overhead_bytes"] > \
        by_alg["mecA"]["overhead_bytes"] > 0
    assert by_alg["mec_fused"]["overhead_bytes"] == 0
    assert by_alg["direct"]["flops"] == by_alg["mecA"]["flops"]


# ----------------------------------------------------------- check gating

def test_check_passes_against_itself(tiny_doc):
    failures, _ = compare(copy.deepcopy(tiny_doc), copy.deepcopy(tiny_doc))
    assert failures == []


def test_check_fails_on_perturbed_memory_overhead(tiny_doc):
    bad = copy.deepcopy(tiny_doc)
    bad["results"][1]["overhead_bytes"] += 4
    failures, _ = compare(bad, tiny_doc, schema_only_on_timing=True)
    assert any("overhead_bytes" in f for f in failures)


def test_check_fails_on_lost_coverage(tiny_doc):
    shrunk = copy.deepcopy(tiny_doc)
    shrunk["results"] = shrunk["results"][1:]
    failures, _ = compare(shrunk, tiny_doc, schema_only_on_timing=True)
    assert any("missing" in f for f in failures)


def test_check_timing_tolerance_and_schema_only(tiny_doc):
    slow = copy.deepcopy(tiny_doc)
    slow["results"][0]["us_per_call"] = \
        tiny_doc["results"][0]["us_per_call"] * 10
    failures, _ = compare(slow, tiny_doc, timing_rtol=1.0)
    assert any("us_per_call regressed" in f for f in failures)
    failures, _ = compare(slow, tiny_doc, schema_only_on_timing=True)
    assert failures == []
    # hlo drift is informational, never a failure
    drift = copy.deepcopy(tiny_doc)
    drift["results"][0]["hlo_bytes"] = 12345.0
    failures, notes = compare(drift, tiny_doc, schema_only_on_timing=True)
    assert failures == []
    assert any("hlo_bytes" in n for n in notes)


# ------------------------------------------------------- committed baseline

def test_committed_smoke_baseline_is_valid_and_complete():
    doc = json.loads(BASELINE.read_text())
    assert validate_report(doc) == []
    assert doc["suite"] == "smoke"
    got = {(r["scenario"], r["algorithm"]) for r in doc["results"]}
    want = {(sc.name, alg) for sc in resolve_suite("smoke")
            for alg in sc.algorithms}
    assert got == want


def test_dist_suite_layers_and_smoke_cells():
    """The dist suite covers cv1-cv12 at 2/8/256-way, the composite 2-D
    analytic cells, plus the 2-device smoke cells (one per 1-D partition
    mode) and the 2x2 composite smoke cells (DESIGN.md §6)."""
    dist = resolve_suite("dist")
    names = {sc.name for sc in dist}
    for layer in CV_LAYERS:
        for n in (2, 8, 256):
            assert f"{layer}_d{n}" in names
        assert f"{layer}_bs2x2" in names
    for part in ("batch", "channel", "spatial"):
        sc = next(s for s in dist if s.name == f"smoke2_{part}")
        assert sc.partition == part and sc.n_dev == 2
    for a, b in (("batch", "spatial"), ("batch", "channel"),
                 ("spatial", "channel")):
        sc = next(s for s in dist if s.name == f"smoke4_{a}_{b}")
        assert sc.partition == (a, b) and sc.n_dev == (2, 2)
    assert all(sc.partition is not None for sc in dist)


def test_dist_composite_measure_emits_analytic_fields():
    """A composite 2-D cell carries partition 'batch+spatial', the
    device product in n_dev, the per-sub-axis split in n_dev_axes, and
    halo bytes scaled by the local batch shard — without needing 4 real
    devices."""
    sc = next(s for s in resolve_suite("dist") if s.name == "cv9_bs2x2")
    rec = measure(sc, "mecB", with_hlo=False, with_timing=False)
    assert rec["partition"] == "batch+spatial"
    assert rec["n_dev"] == 4 and rec["n_dev_axes"] == [2, 2]
    # halo = (k_h - s_h) rows x the 4-sample local batch shard
    assert rec["halo_bytes_per_device"] == 4 * 2 * 56 * 64 * 4
    assert rec["per_device_overhead_elems"] > 0
    assert rec["comm_bytes_per_device"] >= rec["halo_bytes_per_device"]
    doc = make_report("dist", [rec], {})
    assert validate_report(doc) == []


def test_dist_measure_emits_analytic_fields_without_devices():
    """A 256-way cell on this 1-device process still carries the exact
    per-device/halo analytics (timing/HLO skipped), and the report
    schema accepts the block."""
    sc = next(s for s in resolve_suite("dist") if s.name == "cv9_d256")
    rec = measure(sc, "mecB", with_hlo=True, with_timing=True)
    assert rec["partition"] == "spatial" and rec["n_dev"] == 256
    assert rec["us_per_call"] is None and rec["hlo_flops"] is None
    # halo = (k_h - s_h) input rows per device: 2 * 56 * 64 * 4 bytes
    assert rec["halo_bytes_per_device"] == 2 * 56 * 64 * 4
    assert rec["per_device_overhead_elems"] > 0
    assert rec["comm_bytes_per_device"] >= rec["halo_bytes_per_device"]
    doc = make_report("dist", [rec], {})
    assert validate_report(doc) == []


def test_dist_fields_gated_exactly_by_check():
    sc = next(s for s in resolve_suite("dist") if s.name == "cv9_d2")
    rec = measure(sc, "mecB", with_hlo=False, with_timing=False)
    doc = make_report("dist", [rec], {})
    base = json.loads(json.dumps(doc))
    fails, _ = compare(doc, base, schema_only_on_timing=True)
    assert fails == []
    doc2 = json.loads(json.dumps(doc))
    doc2["results"][0]["halo_bytes_per_device"] += 1
    fails, _ = compare(doc2, base, schema_only_on_timing=True)
    assert any("halo_bytes_per_device" in f for f in fails)


def test_dist_record_missing_sibling_field_rejected():
    sc = next(s for s in resolve_suite("dist") if s.name == "cv9_d2")
    rec = measure(sc, "mecB", with_hlo=False, with_timing=False)
    broken = dict(rec)
    del broken["halo_bytes_per_device"]
    errs = validate_report(make_report_unchecked("dist", [broken]))
    assert any("distributed cell missing" in e for e in errs)
    # n_dev_axes postdates the first dist baselines: a record without it
    # (a pre-composite baseline) must still validate
    legacy = dict(rec)
    del legacy["n_dev_axes"]
    assert validate_report(make_report_unchecked("dist", [legacy])) == []


def make_report_unchecked(suite, results):
    from repro.bench.report import SCHEMA_VERSION, environment_fingerprint
    return {"schema_version": SCHEMA_VERSION, "suite": suite,
            "environment": environment_fingerprint(), "harness": {},
            "results": results}


def test_committed_dist_baseline_is_valid():
    doc = json.loads((REPO / "benchmarks" / "baselines" /
                      "dist.json").read_text())
    assert validate_report(doc) == []
    assert doc["suite"] == "dist"
    # 12 layers x {2,8,256}-way 1-D + 12 batch x spatial + 3 batch x
    # channel + 2 spatial x channel analytic cells, and (3 smoke2 +
    # 3 smoke4) x 2 algorithms
    assert len(doc["results"]) == 12 * 3 + 12 + 3 + 2 + (3 + 3) * 2


# ------------------------------------------------------------- autotune

def _autotune_doc():
    """Minimal schema-v2 autotune document (one smoke cell)."""
    spec = ConvSpec(1, 14, 14, 4, 3, 3, 8, 1, 1)
    import dataclasses
    return {
        "autotune_schema_version": 2,
        "suite": "autotune",
        "base_suite": "smoke",
        "environment": {"backend": "cpu", "jax": "0"},
        "calibration": {"active": False, "source": None},
        "harness": {"iters": 3, "warmup": 1, "noise_margin": 0.05},
        "results": [{
            "scenario": "s3x3",
            "dtype": "float32",
            "run_spec": dataclasses.asdict(spec),
            "analytic_algorithm": "mec",
            "analytic_us": 230.0,
            "measured_algorithm": "mec",
            "measured_us": 230.0,
            "candidate_us": {"mec": 230.0, "direct": 410.0},
            "candidate_stats": {"mec": {"us_median": 230.0,
                                        "us_std": 4.0,
                                        "us_rel_spread": 0.017}},
            "skipped": {},
            "n_skipped": 0,
            "max_rel_spread": 0.017,
            "tuning": None,
            "pick_agrees": True,
        }],
    }


def test_autotune_check_gates_decision_fields_exactly():
    base = _autotune_doc()
    failures, _ = compare(copy.deepcopy(base), base)
    assert failures == []
    drift = copy.deepcopy(base)
    drift["results"][0]["analytic_algorithm"] = "direct"
    failures, _ = compare(drift, base)
    assert any("analytic_algorithm" in f for f in failures)
    missing = copy.deepcopy(base)
    missing["results"] = [dict(missing["results"][0], scenario="other")]
    failures, _ = compare(missing, base)
    assert any("missing" in f for f in failures)


def test_autotune_check_spread_and_measured_drift_never_fail():
    base = _autotune_doc()
    drift = copy.deepcopy(base)
    drift["results"][0].update(measured_algorithm="direct",
                               pick_agrees=False, max_rel_spread=0.4)
    drift["results"][0]["candidate_stats"]["mec"]["us_std"] = 90.0
    failures, notes = compare(drift, base)
    assert failures == []
    assert any("measured_algorithm" in n for n in notes)
    assert any("max_rel_spread" in n for n in notes)
    # timing stays under the tolerance policy, not exactness
    slow = copy.deepcopy(base)
    slow["results"][0]["measured_us"] = 230.0 * 2.5
    failures, _ = compare(slow, base, timing_rtol=1.0)
    assert any("measured_us regressed" in f for f in failures)
    failures, _ = compare(slow, base, schema_only_on_timing=True)
    assert failures == []


def test_autotune_check_newly_skipped_candidate_fails():
    base = _autotune_doc()
    lost = copy.deepcopy(base)
    lost["results"][0]["skipped"] = {"fft": "XlaRuntimeError: boom"}
    lost["results"][0]["n_skipped"] = 1
    failures, _ = compare(lost, base)
    assert any("newly skipped" in f for f in failures)
    # an already-skipped candidate staying skipped is not a regression
    failures, _ = compare(copy.deepcopy(lost), lost)
    assert failures == []


def test_autotune_check_calibration_flip_is_not_a_failure():
    base = _autotune_doc()
    calibrated = copy.deepcopy(base)
    calibrated["calibration"] = {"active": True, "source": "env:x"}
    calibrated["results"][0]["analytic_algorithm"] = "direct"
    failures, notes = compare(calibrated, base)
    assert failures == []
    assert any("calibration active differs" in n for n in notes)


def test_time_compiled_reports_spread():
    from repro.bench.harness import time_compiled
    t = time_compiled(lambda: None, iters=4, warmup=1)
    assert t["us_std"] >= 0.0
    assert t["us_rel_spread"] == pytest.approx(
        t["us_std"] / t["us_median"])


def test_committed_autotune_baseline_checks_against_itself():
    doc = json.loads((REPO / "BENCH_autotune.json").read_text())
    assert doc["autotune_schema_version"] == 2
    failures, _ = compare(copy.deepcopy(doc), doc,
                          schema_only_on_timing=True)
    assert failures == []
    for rec in doc["results"]:
        assert "candidate_stats" in rec and "skipped" in rec
        assert rec["n_skipped"] == len(rec["skipped"])


def test_smoke_w520_is_a_kernel_tuning_cell():
    # The w520 geometry exists to audit pick_w_blk's 512-column cap:
    # its o_w must exceed the planner default so the stage-2 grid has a
    # strictly larger (single-grid-step) block to find.
    from repro.kernels.ops import pick_w_blk
    sc = {s.name: s for s in resolve_suite("smoke")}["w520"]
    assert sc.tune_candidates == ("mec_lowered", "mec_fused", "mec_fused2")
    assert set(sc.algorithms) == set(sc.tune_candidates)
    default = pick_w_blk(sc.run_spec.o_w, sc.run_spec.k_c, _warn_env=False)
    assert default < sc.run_spec.o_w


def test_committed_autotune_baseline_tunes_w_blk_off_default():
    # DESIGN.md §10 acceptance: measured mode demonstrably tunes the
    # knob — the committed report's w520 cell must carry a non-default
    # w_blk backed by before/after trial timings.
    doc = json.loads((REPO / "BENCH_autotune.json").read_text())
    rec = {r["scenario"]: r for r in doc["results"]}["w520"]
    tuning = rec["tuning"]
    assert tuning["knob"] == "w_blk"
    assert tuning["picked"] != tuning["default"]
    assert rec["plan"]["w_blk"] == int(tuning["picked"])
    trials = tuning["trials"]
    assert tuning["default"] in trials and tuning["picked"] in trials
    assert trials[tuning["picked"]]["us_median"] < \
        trials[tuning["default"]]["us_median"]
