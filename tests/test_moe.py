"""MoE routing/dispatch invariants and the local<->EP equivalence."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs.archs import smoke_config
from repro.models import moe
from repro.parallel.axes import ShardingRules, use_rules


@pytest.fixture
def cfg():
    return smoke_config("qwen3-moe-30b-a3b")


def test_route_topk_properties(cfg):
    x = jax.random.normal(jax.random.key(0), (64, cfg.d_model))
    router = jax.random.normal(jax.random.key(1),
                               (cfg.d_model, cfg.n_experts))
    gw, idx, aux = moe._route(x, router, cfg)
    assert gw.shape == (64, cfg.top_k)
    assert idx.shape == (64, cfg.top_k)
    np.testing.assert_allclose(np.asarray(gw.sum(-1)), 1.0, rtol=1e-5)
    assert float(aux) > 0
    # top-k ids are distinct per token
    for row in np.asarray(idx):
        assert len(set(row.tolist())) == cfg.top_k


def test_pack_unpack_roundtrip(cfg):
    """With ample capacity, pack->identity-expert->unpack == weighted sum
    of the token itself: y = sum_k gw_k * x = x."""
    t, d = 32, cfg.d_model
    x = jax.random.normal(jax.random.key(2), (t, d))
    router = jax.random.normal(jax.random.key(3), (d, cfg.n_experts))
    gw, idx, _ = moe._route(x, router, cfg)
    cap = t  # no drops possible
    buckets, routing = moe._pack(x, gw, idx, cap, cfg)
    y = moe._unpack(buckets, routing, gw, t, d)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), rtol=1e-5,
                               atol=1e-5)


def test_capacity_drops_are_bounded(cfg):
    """Over-capacity tokens are dropped, never mis-routed."""
    cfg = cfg.with_(capacity_factor=0.25)
    t, d = 64, cfg.d_model
    x = jnp.ones((t, d))
    router = jax.random.normal(jax.random.key(4), (d, cfg.n_experts))
    gw, idx, _ = moe._route(x, router, cfg)
    cap = moe._capacity(t, cfg)
    buckets, routing = moe._pack(x, gw, idx, cap, cfg)
    # every bucket row is either a token (all-ones) or empty (all-zeros)
    b = np.asarray(buckets)
    rowsum = b.sum(-1)
    assert set(np.unique(rowsum)).issubset({0.0, float(d)})


def test_moe_local_vs_ep_single_device(cfg):
    """The shard_map EP path on a 1-device mesh must equal the local path
    (same routing math, degenerate all_to_all)."""
    cfg_ep = cfg.with_(moe_impl="ep", n_experts=8, top_k=2)
    cfg_lo = cfg_ep.with_(moe_impl="local")
    p = moe.init_moe(jax.random.key(5), cfg_ep, jnp.float32)
    x = jax.random.normal(jax.random.key(6), (2, 16, cfg.d_model))
    y_lo, aux_lo = moe.moe_ffn(p, cfg_lo, x)

    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    rules = ShardingRules(mesh=mesh, rules={"batch": "data"},
                          dp_axes=("data",), ep_axis="model",
                          tp_axis="model")
    with mesh, use_rules(rules):
        y_ep, aux_ep = jax.jit(lambda p, x: moe.moe_ffn(p, cfg_ep, x))(p, x)
    np.testing.assert_allclose(np.asarray(y_lo), np.asarray(y_ep),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(float(aux_lo), float(aux_ep), rtol=1e-5)


def test_moe_grads_flow(cfg):
    p = moe.init_moe(jax.random.key(7), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(8), (2, 8, cfg.d_model))

    def loss(p):
        y, aux = moe.moe_ffn(p, cfg, x)
        return jnp.sum(y ** 2) + aux

    g = jax.grad(loss)(p)
    gnorm = sum(float(jnp.abs(l).sum()) for l in jax.tree.leaves(g))
    assert np.isfinite(gnorm) and gnorm > 0
    # router receives gradient through the gate weights
    assert float(jnp.abs(g["router"]).sum()) > 0
