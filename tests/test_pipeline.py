"""GPipe pipeline primitive: 4-stage correctness + gradient flow
(subprocess with 4 forced host devices)."""
import json
import os
import pathlib
import subprocess
import sys
import textwrap

REPO = pathlib.Path(__file__).resolve().parents[1]


def test_pipeline_matches_sequential_and_trains():
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import json
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.parallel.pipeline import pipeline_apply

        L, D, B = 8, 16, 12
        key = jax.random.key(0)
        params = {
            "w": jax.random.normal(key, (L, D, D)) * D ** -0.5,
            "b": jnp.zeros((L, D)),
        }
        x = jax.random.normal(jax.random.key(1), (B, D))

        def block(p, h):
            return jnp.tanh(h @ p["w"] + p["b"]) + h

        def sequential(params, x):
            out, _ = jax.lax.scan(lambda h, p: (block(p, h), None), x, params)
            return out

        mesh = Mesh(np.asarray(jax.devices()).reshape(4), ("pipe",))
        ref = sequential(params, x)
        with mesh:
            out = jax.jit(lambda p, x: pipeline_apply(
                block, p, x, mesh, "pipe", n_microbatches=4))(params, x)
        err = float(jnp.max(jnp.abs(out - ref)))

        # gradient flow: pipeline loss grads match sequential grads
        def loss_pipe(p):
            with mesh:
                return jnp.sum(pipeline_apply(block, p, x, mesh, "pipe",
                                              n_microbatches=4) ** 2)
        def loss_seq(p):
            return jnp.sum(sequential(p, x) ** 2)
        g1 = jax.grad(loss_pipe)(params)
        g2 = jax.grad(loss_seq)(params)
        gerr = max(float(jnp.max(jnp.abs(a - b)))
                   for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)))
        print(json.dumps({"err": err, "gerr": gerr}))
    """)
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", prog], env=env, cwd=REPO,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["err"] < 1e-5, res
    assert res["gerr"] < 1e-4, res
