"""The beyond-paper perf features must preserve training semantics."""
import json
import os
import pathlib
import subprocess
import sys
import textwrap

import hypothesis
import hypothesis.strategies as st
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.mec import mec_conv1d_depthwise, mec_conv1d_shift

REPO = pathlib.Path(__file__).resolve().parents[1]


@hypothesis.given(st.integers(1, 40), st.integers(1, 12), st.integers(1, 5))
@hypothesis.settings(max_examples=25, deadline=None)
def test_conv1d_shift_equals_lowered(t, c, k_w):
    """The fused (shift-add) conv dataflow is numerically identical to the
    lowered (gather) dataflow."""
    x = jnp.asarray(np.random.RandomState(t).randn(2, t, c), jnp.float32)
    k = jnp.asarray(np.random.RandomState(k_w).randn(k_w, c), jnp.float32)
    a = mec_conv1d_depthwise(x, k)
    b = mec_conv1d_shift(x, k)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)


def test_dots_remat_and_sp_preserve_loss():
    """remat_policy='dots' and seq_parallel are exact transforms: the
    training losses must match full remat / no-SP bit-for-bit-ish on a
    DPxTP mesh."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import json
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh, NamedSharding
        from repro.configs.archs import smoke_config
        from repro.models.lm import LM
        from repro.optim.adamw import AdamWConfig
        from repro.parallel import sharding
        from repro.parallel.axes import default_rules
        from repro.training.steps import init_opt_state, make_train_step
        from repro.data.pipeline import SyntheticLMData

        mesh = Mesh(np.asarray(jax.devices()).reshape(2, 2),
                    ("data", "model"))
        rules = default_rules(mesh)
        opt_cfg = AdamWConfig(lr=1e-3, total_steps=6, warmup_steps=2)

        def run(**overrides):
            cfg = smoke_config("yi-6b").with_(remat=True, **overrides)
            model = LM(cfg)
            params = model.init(jax.random.key(0))
            specs = sharding.param_specs(params, mesh)
            params = jax.tree.map(
                lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
                params, specs)
            opt = init_opt_state(params)
            fn = jax.jit(make_train_step(model, opt_cfg, rules))
            data = SyntheticLMData(cfg, 8, 32)
            with mesh:
                losses = []
                for _ in range(6):
                    params, opt, m = fn(params, opt, data.next_batch())
                    losses.append(float(m["loss"]))
            return losses

        base = run()
        dots = run(remat_policy="dots")
        sp = run(seq_parallel=True)
        print(json.dumps({"base": base, "dots": dots, "sp": sp}))
    """)
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", prog], env=env, cwd=REPO,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    np.testing.assert_allclose(res["base"], res["dots"], rtol=2e-4)
    np.testing.assert_allclose(res["base"], res["sp"], rtol=2e-4)


def test_int8_a2a_is_differentiable_and_accurate():
    from repro.models.moe import _q8_a2a, int8_all_to_all  # noqa: F401
    # numerics of the quantize-dequantize pair (a2a on 1 device = identity)
    import numpy as np
    from jax.sharding import Mesh
    from jax.sharding import PartitionSpec as P

    from repro.core.compat import shard_map
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1), ("model",))
    x = jax.random.normal(jax.random.key(0), (8, 4, 16))

    def f(x):
        return int8_all_to_all(x, "model", 0, 1)

    with mesh:
        y = shard_map(f, mesh=mesh, in_specs=P(), out_specs=P(),
                      check_vma=False)(x)
        g = jax.grad(lambda x: jnp.sum(shard_map(
            f, mesh=mesh, in_specs=P(), out_specs=P(),
            check_vma=False)(x) ** 2))(x)
    rel = float(jnp.max(jnp.abs(y - x))) / float(jnp.max(jnp.abs(x)))
    assert rel < 0.02, rel
    assert bool(jnp.isfinite(g).all()) and float(jnp.abs(g).sum()) > 0


def test_triangular_attention_matches_masked():
    import numpy as np
    from repro.models.layers import chunked_attention, chunked_attention_tri
    for (s, h, kv, d, qc, kc) in [(33, 8, 4, 16, 8, 8), (64, 4, 2, 8, 16, 8),
                                  (17, 2, 2, 4, 4, 8)]:
        q = jax.random.normal(jax.random.key(1), (2, s, h, d))
        k = jax.random.normal(jax.random.key(2), (2, s, kv, d))
        v = jax.random.normal(jax.random.key(3), (2, s, kv, d))
        a = chunked_attention(q, k, v, causal=True, q_chunk=qc, kv_chunk=kc)
        b = chunked_attention_tri(q, k, v, q_chunk=qc, kv_chunk=kc)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4,
                                   atol=2e-5)
    # gradient parity
    g1 = jax.grad(lambda q: jnp.sum(chunked_attention(
        q, k, v, causal=True, q_chunk=4, kv_chunk=8) ** 2))(q)
    g2 = jax.grad(lambda q: jnp.sum(chunked_attention_tri(
        q, k, v, q_chunk=4, kv_chunk=8) ** 2))(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-3,
                               atol=1e-4)


def test_attn_skip_masked_preserves_forward():
    import numpy as np
    from repro.configs.archs import smoke_config
    from repro.models.lm import LM
    cfg = smoke_config("yi-6b")
    model_a = LM(cfg)
    model_b = LM(cfg.with_(attn_skip_masked=True))
    params = model_a.init(jax.random.key(0))
    batch = {"tokens": jax.random.randint(jax.random.key(1), (2, 24), 0,
                                          cfg.vocab, jnp.int32)}
    ha, _ = model_a.forward(params, batch)
    hb, _ = model_b.forward(params, batch)
    np.testing.assert_allclose(np.asarray(ha), np.asarray(hb), rtol=2e-4,
                               atol=2e-4)
