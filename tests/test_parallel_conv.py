"""Distributed conv execution layer (repro.parallel.conv, DESIGN.md §6):
property-style equivalence against the single-device conv2d oracle on a
1-device mesh, a 4-fake-device subprocess sweep over {partition, stride,
kernel, dtype} including jax.grad through the halo exchange, the
rules-aware conv_api routing, the partition cost model, and the
make_host_mesh regression."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core.conv_api import conv2d
from repro.core.convspec import ConvSpec
from repro.launch.costmodel import conv_partition_costs, pick_conv_partition
from repro.launch.mesh import make_host_mesh
from repro.parallel.axes import ShardingRules, use_rules
from repro.parallel.conv import (COMPOSITE_PARTITIONS, PARTITIONS,
                                 conv_partition_specs, default_axis,
                                 normalize_partition, partition_name,
                                 partition_viable, sharded_conv2d,
                                 spatial_halo_rows)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _rand(shape, seed, dtype=jnp.float32):
    x = np.random.RandomState(seed).randn(*shape).astype(np.float32)
    return jnp.asarray(x, dtype)


def _oracle(inp, kernel, stride):
    return conv2d(inp, kernel, stride=stride, algorithm="direct",
                  partition="none")


# ---------------------------------------------------------------------------
# make_host_mesh regression (satellite): explicit shape without axes used
# to pass axes=None straight into Mesh() and crash.
# ---------------------------------------------------------------------------

def test_make_host_mesh_shape_without_axes():
    mesh = make_host_mesh(shape=(1,))
    assert mesh.axis_names == ("ax0",)
    mesh2 = make_host_mesh(shape=(1, 1))
    assert mesh2.axis_names == ("ax0", "ax1")
    assert make_host_mesh(shape=(1,), axes=("tp",)).axis_names == ("tp",)
    assert make_host_mesh().axis_names == ("data",)
    with pytest.raises(ValueError):
        make_host_mesh(shape=(1, 1), axes=("only_one",))
    with pytest.raises(ValueError):
        make_host_mesh(shape=(jax.device_count() + 1,))


# ---------------------------------------------------------------------------
# property-style oracle equivalence on a 1-device mesh (the shard_map /
# ppermute path runs for real; multi-device behaviour is covered by the
# subprocess sweep below)
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.sampled_from([1, 3, 5]), st.sampled_from([1, 2]),
       st.sampled_from(PARTITIONS),
       st.sampled_from(["float32", "bfloat16"]),
       st.integers(1, 3), st.integers(0, 2), st.integers(0, 3))
def test_sharded_matches_oracle_property(k, s, partition, dtype, mult,
                                         extra_w, seed):
    i_h = s * (k + mult)               # spatial-viable: s | i_h, halo <= i_h
    i_w = i_h + extra_w
    if i_w < k:
        i_w = k
    inp = _rand((2, i_h, i_w, 3), seed, dtype)
    ker = _rand((k, k, 3, 4), seed + 100, dtype)
    mesh = make_host_mesh(shape=(1,))
    out = sharded_conv2d(inp, ker, stride=s, algorithm="mec",
                         partition=partition, mesh=mesh)
    ref = _oracle(inp, ker, s)
    assert out.shape == ref.shape
    tol = 5e-2 if dtype == "bfloat16" else 1e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@settings(max_examples=8, deadline=None)
@given(st.sampled_from([3, 5]), st.sampled_from([1, 2]),
       st.sampled_from(PARTITIONS), st.integers(0, 3))
def test_sharded_grad_matches_oracle_property(k, s, partition, seed):
    i_h = s * (k + 2)
    inp = _rand((2, i_h, i_h + 1, 2), seed, jnp.float32)
    ker = _rand((k, k, 2, 4), seed + 50, jnp.float32)
    mesh = make_host_mesh(shape=(1,))

    def loss(fn):
        return lambda i, kk: jnp.sum(jnp.sin(fn(i, kk)))

    gi, gk = jax.grad(loss(lambda i, kk: sharded_conv2d(
        i, kk, stride=s, algorithm="mec", partition=partition, mesh=mesh)),
        argnums=(0, 1))(inp, ker)
    ri, rk = jax.grad(loss(lambda i, kk: _oracle(i, kk, s)),
                      argnums=(0, 1))(inp, ker)
    np.testing.assert_allclose(np.asarray(gi), np.asarray(ri),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(gk), np.asarray(rk),
                               rtol=2e-4, atol=2e-4)


def test_sharded_conv2d_every_algorithm_backend():
    """Partitioning composes with every conv2d algorithm backend."""
    inp = _rand((2, 12, 12, 3), 0)
    ker = _rand((3, 3, 3, 4), 1)
    mesh = make_host_mesh(shape=(1,))
    ref = _oracle(inp, ker, 1)
    for alg in ("direct", "im2col", "fft", "winograd", "mec",
                "mec_lowered", "mec_fused", "mec_fused2", "auto"):
        out = sharded_conv2d(inp, ker, algorithm=alg, partition="spatial",
                             mesh=mesh)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-3, atol=1e-3,
                                   err_msg=f"algorithm={alg}")


def test_explicit_partition_rejects_bad_geometry():
    mesh = make_host_mesh(shape=(1,))
    inp = _rand((1, 9, 9, 2), 2)
    ker = _rand((3, 3, 2, 4), 3)
    # i_h=9, stride 2: per-device rows are not a stride multiple
    with pytest.raises(ValueError):
        sharded_conv2d(inp, ker, stride=2, partition="spatial", mesh=mesh)
    with pytest.raises(ValueError):
        sharded_conv2d(inp, ker, partition="toeplitz", mesh=mesh)


# ---------------------------------------------------------------------------
# composite (2-D) partitions: normalization, axis resolution, specs, and
# oracle equivalence on a (1,1) 2-axis mesh (the real 2x2 sweep runs in
# the subprocess test below)
# ---------------------------------------------------------------------------

def test_normalize_partition_and_name_roundtrip():
    assert normalize_partition("spatial") == ("spatial",)
    assert normalize_partition(("batch", "spatial")) == ("batch", "spatial")
    assert normalize_partition(["batch", "channel"]) == ("batch", "channel")
    for comp in COMPOSITE_PARTITIONS:
        assert normalize_partition(partition_name(comp)) == comp
    assert partition_name("batch") == "batch"
    with pytest.raises(ValueError):
        normalize_partition(("spatial", "batch"))   # non-canonical order
    with pytest.raises(ValueError):
        normalize_partition(("batch", "batch"))
    with pytest.raises(ValueError):
        normalize_partition(("batch", "toeplitz"))
    with pytest.raises(ValueError):
        normalize_partition(("batch", "spatial", "channel"))


def test_composite_partition_viability():
    spec = ConvSpec(4, 16, 16, 3, 3, 3, 8, 1, 1)
    assert partition_viable(spec, ("batch", "spatial"), (4, 4))
    assert not partition_viable(spec, ("batch", "spatial"), (3, 4))
    assert not partition_viable(spec, ("batch", "spatial"), (4, 5))
    assert partition_viable(spec, ("batch", "channel"), (2, 8))
    assert not partition_viable(spec, ("batch", "channel"), (2, 3))
    assert partition_viable(spec, ("spatial", "channel"), (2, 2))
    # component count must match the n_dev tuple
    with pytest.raises(ValueError):
        partition_viable(spec, ("batch", "spatial"), 4)
    with pytest.raises(ValueError):
        partition_viable(spec, "batch", (2, 2))


def test_composite_default_axis_resolution():
    mesh = make_host_mesh(shape=(1, 1), axes=("data", "model"))
    assert default_axis(("batch", "spatial"), mesh) == ("data", "model")
    assert default_axis(("batch", "channel"), mesh) == ("data", "model")
    # both spatial and channel prefer the TP axis; the second component
    # falls through to the only unclaimed axis
    assert default_axis(("spatial", "channel"), mesh) == ("model", "data")
    # a 1-D mesh cannot host two distinct sub-axes
    with pytest.raises(ValueError):
        default_axis(("batch", "spatial"), make_host_mesh(shape=(1,)))


def test_composite_conv_partition_specs():
    from jax.sharding import PartitionSpec as P
    assert conv_partition_specs(("batch", "spatial"), ("data", "model")) == \
        (P("data", "model"), P(None, None, None, None),
         P("data", "model", None, None))
    assert conv_partition_specs(("batch", "channel"), ("data", "model")) == \
        (P("data", None), P(None, None, None, "model"),
         P("data", None, None, "model"))
    assert conv_partition_specs(("spatial", "channel"), ("model", "data")) == \
        (P(None, "model"), P(None, None, None, "data"),
         P(None, "model", None, "data"))
    with pytest.raises(ValueError):
        conv_partition_specs(("batch", "spatial"), "data")


@settings(max_examples=12, deadline=None)
@given(st.sampled_from([1, 3, 5]), st.sampled_from([1, 2]),
       st.sampled_from(COMPOSITE_PARTITIONS),
       st.sampled_from(["float32", "bfloat16"]), st.integers(0, 3))
def test_composite_matches_oracle_property(k, s, partition, dtype, seed):
    i_h = s * (k + 2)
    inp = _rand((2, i_h, i_h + 1, 3), seed, dtype)
    ker = _rand((k, k, 3, 4), seed + 100, dtype)
    mesh = make_host_mesh(shape=(1, 1), axes=("data", "model"))
    out = sharded_conv2d(inp, ker, stride=s, algorithm="mec",
                         partition=partition, mesh=mesh)
    ref = _oracle(inp, ker, s)
    assert out.shape == ref.shape
    tol = 5e-2 if dtype == "bfloat16" else 1e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@settings(max_examples=6, deadline=None)
@given(st.sampled_from([3, 5]), st.sampled_from([1, 2]),
       st.sampled_from(COMPOSITE_PARTITIONS), st.integers(0, 2))
def test_composite_grad_matches_oracle_property(k, s, partition, seed):
    i_h = s * (k + 2)
    inp = _rand((2, i_h, i_h + 1, 2), seed, jnp.float32)
    ker = _rand((k, k, 2, 4), seed + 50, jnp.float32)
    mesh = make_host_mesh(shape=(1, 1), axes=("data", "model"))

    def loss(fn):
        return lambda i, kk: jnp.sum(jnp.sin(fn(i, kk)))

    gi, gk = jax.grad(loss(lambda i, kk: sharded_conv2d(
        i, kk, stride=s, algorithm="mec", partition=partition, mesh=mesh)),
        argnums=(0, 1))(inp, ker)
    ri, rk = jax.grad(loss(lambda i, kk: _oracle(i, kk, s)),
                      argnums=(0, 1))(inp, ker)
    np.testing.assert_allclose(np.asarray(gi), np.asarray(ri),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(gk), np.asarray(rk),
                               rtol=2e-4, atol=2e-4)


def test_auto_with_bad_explicit_axis_raises():
    """partition='auto' must not swallow an explicit-axis typo into a
    silent single-device fallback."""
    mesh = make_host_mesh(shape=(1, 1), axes=("data", "model"))
    inp, ker = _rand((2, 8, 8, 2), 20), _rand((3, 3, 2, 4), 21)
    with pytest.raises(ValueError, match="not in mesh axes"):
        sharded_conv2d(inp, ker, partition="auto", axis="bogus", mesh=mesh)
    with pytest.raises(ValueError, match="not in mesh axes"):
        sharded_conv2d(inp, ker, partition="auto",
                       axis=("data", "bogus"), mesh=mesh)
    with pytest.raises(ValueError, match="distinct"):
        sharded_conv2d(inp, ker, partition="auto",
                       axis=("data", "data"), mesh=mesh)
    # a 1-tuple axis is the same as its string
    out = sharded_conv2d(inp, ker, partition="batch", axis=("data",),
                         mesh=mesh)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(_oracle(inp, ker, 1)),
                               rtol=1e-4, atol=1e-4)


def test_composite_explicit_rejects_bad_geometry_and_axes():
    mesh = make_host_mesh(shape=(1, 1), axes=("data", "model"))
    inp = _rand((3, 9, 9, 2), 11)          # i_n=3: 1-way batch still fine
    ker = _rand((3, 3, 2, 4), 12)
    out = sharded_conv2d(inp, ker, partition=("batch", "spatial"), mesh=mesh)
    np.testing.assert_allclose(np.asarray(out), np.asarray(_oracle(inp, ker, 1)),
                               rtol=1e-4, atol=1e-4)
    # axis tuple must match the component count and be distinct
    with pytest.raises(ValueError):
        sharded_conv2d(inp, ker, partition=("batch", "spatial"),
                       axis="data", mesh=mesh)
    with pytest.raises(ValueError):
        sharded_conv2d(inp, ker, partition=("batch", "spatial"),
                       axis=("data", "data"), mesh=mesh)


# ---------------------------------------------------------------------------
# hoisted validation (satellite): a typo'd algorithm/solution raises at
# the call site, BEFORE any shard_map tracing starts
# ---------------------------------------------------------------------------

def _forbid_shard_map(monkeypatch):
    import repro.parallel.conv as pconv

    def boom(*a, **kw):
        raise AssertionError("shard_map entered before validation")

    monkeypatch.setattr(pconv, "shard_map", boom)


def test_bad_algorithm_raises_before_tracing_1d(monkeypatch):
    _forbid_shard_map(monkeypatch)
    mesh = make_host_mesh(shape=(1,))
    inp, ker = _rand((2, 8, 8, 2), 0), _rand((3, 3, 2, 4), 1)
    with pytest.raises(ValueError, match="unknown algorithm 'toeplitz'"):
        sharded_conv2d(inp, ker, algorithm="toeplitz", partition="batch",
                       mesh=mesh)
    with pytest.raises(ValueError, match="unknown MEC solution 'Z'"):
        sharded_conv2d(inp, ker, algorithm="mec", solution="Z",
                       partition="spatial", mesh=mesh)


def test_bad_algorithm_raises_before_tracing_2d(monkeypatch):
    _forbid_shard_map(monkeypatch)
    mesh = make_host_mesh(shape=(1, 1), axes=("data", "model"))
    inp, ker = _rand((2, 8, 8, 2), 2), _rand((3, 3, 2, 4), 3)
    with pytest.raises(ValueError, match="unknown algorithm 'toeplitz'"):
        sharded_conv2d(inp, ker, algorithm="toeplitz",
                       partition=("batch", "spatial"), mesh=mesh)
    with pytest.raises(ValueError, match="unknown MEC solution 'Z'"):
        sharded_conv2d(inp, ker, algorithm="mec", solution="Z",
                       partition=("batch", "channel"), mesh=mesh)


def test_no_mesh_is_a_noop():
    inp = _rand((1, 8, 8, 2), 4)
    ker = _rand((3, 3, 2, 4), 5)
    out = sharded_conv2d(inp, ker, padding="SAME", partition="spatial")
    ref = conv2d(inp, ker, padding="SAME", algorithm="direct",
                 partition="none")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# conv_api routing: partition=None is rules-aware, "none" never routes
# ---------------------------------------------------------------------------

def test_conv2d_rules_aware_routing(monkeypatch):
    import repro.parallel.conv as pconv
    calls = []
    orig = pconv.sharded_conv2d

    def spy(*a, **kw):
        calls.append(kw.get("partition"))
        return orig(*a, **kw)

    monkeypatch.setattr(pconv, "sharded_conv2d", spy)
    inp = _rand((2, 8, 8, 2), 6)
    ker = _rand((3, 3, 2, 4), 7)
    ref = conv2d(inp, ker, algorithm="direct", partition="none")
    # outside any rules: partition=None must not touch the parallel layer
    conv2d(inp, ker, algorithm="mec")
    assert calls == []
    mesh = make_host_mesh()
    rules = ShardingRules(mesh=mesh, rules={"batch": "data"},
                          dp_axes=("data",), ep_axis=None, tp_axis=None)
    with use_rules(rules):
        out = conv2d(inp, ker, algorithm="mec")
    assert calls == ["auto"]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    # explicit partition routes even without rules installed
    conv2d(inp, ker, algorithm="mec", partition="batch")
    assert calls == ["auto", "batch"]


def test_auto_degrades_on_unnamed_multi_axis_mesh():
    """partition='auto' must fall back to single-device (not raise) when
    no mesh axis can be resolved — e.g. rules over a generated-name
    2-D host mesh."""
    mesh = make_host_mesh(shape=(1, 1))        # axes ("ax0", "ax1")
    rules = ShardingRules(mesh=mesh, rules={}, dp_axes=(),
                          ep_axis=None, tp_axis=None)
    inp = _rand((2, 8, 8, 2), 9)
    ker = _rand((3, 3, 2, 4), 10)
    ref = conv2d(inp, ker, algorithm="direct", partition="none")
    with use_rules(rules):
        out = conv2d(inp, ker, algorithm="mec")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_conv2d_layer_partition_passthrough():
    from repro.models.layers import conv2d_layer, init_conv2d
    p = init_conv2d(jax.random.key(0), 3, 3, 2, 4)
    x = _rand((2, 8, 8, 2), 8)
    mesh = make_host_mesh()
    rules = ShardingRules(mesh=mesh, rules={"batch": "data"},
                          dp_axes=("data",), ep_axis=None, tp_axis=None)
    ref = conv2d_layer(p, x)
    with use_rules(rules):
        out = conv2d_layer(p, x, partition="batch")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# cost model: viability, halo bytes, picking
# ---------------------------------------------------------------------------

def test_partition_viability_rules():
    spec = ConvSpec(4, 16, 16, 3, 3, 3, 8, 1, 1)
    assert partition_viable(spec, "batch", 4)
    assert not partition_viable(spec, "batch", 3)
    assert partition_viable(spec, "channel", 8)
    assert not partition_viable(spec, "channel", 3)
    assert partition_viable(spec, "spatial", 4)
    assert not partition_viable(spec, "spatial", 5)
    # stride must divide the per-device rows
    s2 = ConvSpec(1, 18, 18, 3, 3, 3, 8, 2, 2)
    assert not partition_viable(s2, "spatial", 2)   # 9 rows, stride 2
    assert partition_viable(ConvSpec(1, 20, 20, 3, 3, 3, 8, 2, 2),
                            "spatial", 2)
    # halo must fit in one neighbour
    assert not partition_viable(ConvSpec(1, 16, 16, 3, 11, 11, 8, 1, 1),
                                "spatial", 8)       # halo 10 > 2 rows
    with pytest.raises(ValueError):
        partition_viable(spec, "toeplitz", 2)


def test_conv_partition_costs_fields():
    spec = ConvSpec(2, 16, 16, 3, 5, 5, 8, 1, 1)
    costs = conv_partition_costs(spec, 4, dtype_bytes=4)
    assert set(costs) == set(PARTITIONS)
    halo = spatial_halo_rows(5, 1)
    assert costs["spatial"]["halo_bytes_per_device"] == \
        2 * halo * 16 * 3 * 4
    # batch/channel exchange no halo
    assert costs["batch"]["halo_bytes_per_device"] == 0
    assert costs["channel"]["halo_bytes_per_device"] == 0
    # channel does NOT shrink the compact L; batch and spatial do
    from repro.core.memory import mec_overhead
    assert costs["channel"]["per_device_overhead_elems"] == mec_overhead(spec)
    assert costs["batch"]["per_device_overhead_elems"] < mec_overhead(spec)
    assert costs["spatial"]["per_device_overhead_elems"] < mec_overhead(spec)
    # backward comm: batch psums the kernel, channel psums the input
    assert costs["batch"]["comm_bytes_bwd_per_device"] == 5 * 5 * 3 * 8 * 4
    assert costs["channel"]["comm_bytes_bwd_per_device"] == \
        2 * 16 * 16 * 3 * 4


def test_pick_conv_partition_preferences():
    sizes = {p: 4 for p in PARTITIONS}
    # batch divisible -> embarrassingly parallel wins
    assert pick_conv_partition(ConvSpec(4, 16, 16, 3, 3, 3, 8), sizes) == \
        "batch"
    # batch=1: spatial's halo is far cheaper than channel's input psum
    assert pick_conv_partition(ConvSpec(1, 16, 16, 3, 3, 3, 8), sizes) == \
        "spatial"
    # spatial non-viable (odd rows) -> channel
    assert pick_conv_partition(ConvSpec(1, 15, 16, 3, 3, 3, 8), sizes) == \
        "channel"
    # nothing viable -> None (caller goes single-device)
    assert pick_conv_partition(ConvSpec(1, 15, 16, 3, 3, 3, 9), sizes) is None
    # 1-way axes are never a partition
    assert pick_conv_partition(ConvSpec(4, 16, 16, 3, 3, 3, 8),
                               {p: 1 for p in PARTITIONS}) is None


def test_default_axis_resolution():
    mesh = make_host_mesh()          # 1-D ("data",)
    for p in PARTITIONS:
        assert default_axis(p, mesh) == "data"
    mesh2 = make_host_mesh(shape=(1, 1), axes=("data", "model"))
    assert default_axis("batch", mesh2) == "data"
    assert default_axis("channel", mesh2) == "model"
    assert default_axis("spatial", mesh2) == "model"


def test_composite_partition_costs_fields():
    spec = ConvSpec(4, 16, 16, 3, 5, 5, 8, 1, 1)
    costs = conv_partition_costs(spec, (2, 2), dtype_bytes=4)
    assert set(costs) == set(COMPOSITE_PARTITIONS)
    halo = spatial_halo_rows(5, 1)
    bs = costs[("batch", "spatial")]
    # the halo rides the LOCAL batch shard: i_n/2 samples worth of rows
    assert bs["halo_bytes_per_device"] == 2 * halo * 16 * 3 * 4
    assert bs["n_dev"] == 4 and bs["n_dev_axes"] == [2, 2]
    assert bs["viable"] is True
    # kernel replicated on both axes -> full-kernel psum + halo back
    assert bs["comm_bytes_bwd_per_device"] == \
        bs["halo_bytes_per_device"] + 5 * 5 * 3 * 8 * 4
    # batch x channel: each psum operand is the other component's shard
    bc = costs[("batch", "channel")]
    assert bc["halo_bytes_per_device"] == 0
    assert bc["comm_bytes_fwd_per_device"] == 0
    assert bc["comm_bytes_bwd_per_device"] == \
        (5 * 5 * 3 * 8 * 4) // 2 + (4 * 16 * 16 * 3 * 4) // 2
    # both shrinks apply to the local compact-L overhead
    from repro.core.memory import mec_overhead
    assert bs["per_device_overhead_elems"] < mec_overhead(spec)
    # flops split by the device product
    from repro.core.memory import conv_flops
    for entry in costs.values():
        assert entry["flops_per_device"] == conv_flops(spec) / 4
    with pytest.raises(ValueError):
        conv_partition_costs(spec, (2, 2, 2))


def test_pick_conv_partition_selects_composite():
    # i_n=2: 4-way batch is non-viable, but batch x spatial (2, 2) is —
    # and its halo-only comm beats channel's full-input psum.
    spec = ConvSpec(2, 16, 16, 3, 3, 3, 8, 1, 1)
    sizes = {"batch": 4, "channel": 4, "spatial": 4,
             ("batch", "spatial"): (2, 2)}
    assert pick_conv_partition(spec, sizes) == ("batch", "spatial")
    # a viable 1-D batch split is free -> still preferred over composites
    sizes4 = dict(sizes, batch=2)
    assert pick_conv_partition(ConvSpec(2, 16, 16, 3, 3, 3, 8), sizes4) == \
        "batch"
    # composites with a 1-way sub-axis never compete
    assert pick_conv_partition(
        spec, {("batch", "spatial"): (1, 4)}) is None
    # a misspelled / non-canonical candidate key raises instead of being
    # silently skipped (parallelism must never be lost quietly)
    with pytest.raises(ValueError, match="unknown partition candidate"):
        pick_conv_partition(spec, {"bach": 4})
    with pytest.raises(ValueError, match="unknown partition candidate"):
        pick_conv_partition(spec, {("spatial", "batch"): (2, 2)})
    # ... and so does a value whose shape does not match its key
    with pytest.raises(ValueError, match="takes 2 axis sizes"):
        pick_conv_partition(spec, {("batch", "spatial"): 4})
    with pytest.raises(ValueError, match="takes one axis size"):
        pick_conv_partition(spec, {"batch": (2, 2)})


# ---------------------------------------------------------------------------
# the real thing: 4 fake host devices in a subprocess
# ---------------------------------------------------------------------------

def test_sharded_conv_multidevice_subprocess():
    """sharded_conv2d == single-device oracle (fwd + grad) on a real
    4-device mesh for every partition axis x {stride, kernel, dtype}."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        os.environ["JAX_PLATFORMS"] = "cpu"
        import json
        import numpy as np, jax, jax.numpy as jnp
        from repro.core.conv_api import conv2d
        from repro.launch.mesh import make_host_mesh
        from repro.parallel.axes import ShardingRules, use_rules
        from repro.parallel.conv import sharded_conv2d

        mesh = make_host_mesh()          # (4,) "data"
        worst = {"fwd": 0.0, "gi": 0.0, "gk": 0.0, "rules": 0.0}
        cases = 0
        for part in ("batch", "channel", "spatial"):
            for k in (1, 3, 5):
                for s in (1, 2):
                    for dt in ("float32", "bfloat16"):
                        i_h = 4 * s * max(k, 2)      # 4-way spatial viable
                        rng = np.random.RandomState(cases)
                        x = jnp.asarray(rng.randn(4, i_h, i_h + 3, 3), dt)
                        kk = jnp.asarray(rng.randn(k, k, 3, 8), dt)
                        ref = conv2d(x, kk, stride=s, algorithm="direct",
                                     partition="none")
                        out = sharded_conv2d(x, kk, stride=s,
                                             algorithm="mec",
                                             partition=part, mesh=mesh)
                        tol_ref = jnp.maximum(jnp.max(jnp.abs(ref)), 1.0)
                        err = float(jnp.max(jnp.abs(
                            out.astype(jnp.float32)
                            - ref.astype(jnp.float32))) / tol_ref)
                        if dt == "float32":
                            worst["fwd"] = max(worst["fwd"], err)
                        assert err < (5e-2 if dt == "bfloat16" else 1e-4), \\
                            (part, k, s, dt, err)
                        cases += 1
        # grads through every partition (incl. the halo transpose)
        for part in ("batch", "channel", "spatial"):
            rng = np.random.RandomState(99)
            x = jnp.asarray(rng.randn(4, 12, 13, 3), jnp.float32)
            kk = jnp.asarray(rng.randn(3, 3, 3, 8), jnp.float32)
            loss = lambda f: (lambda a, b: jnp.sum(jnp.sin(f(a, b))))
            gi, gk = jax.grad(loss(lambda a, b: sharded_conv2d(
                a, b, algorithm="mec", partition=part, mesh=mesh)),
                argnums=(0, 1))(x, kk)
            ri, rk = jax.grad(loss(lambda a, b: conv2d(
                a, b, algorithm="direct", partition="none")),
                argnums=(0, 1))(x, kk)
            worst["gi"] = max(worst["gi"], float(jnp.max(jnp.abs(gi - ri))))
            worst["gk"] = max(worst["gk"], float(jnp.max(jnp.abs(gk - rk))))
        # rules-aware transparent routing on the real mesh
        rules = ShardingRules(mesh=mesh, rules={"batch": "data"},
                              dp_axes=("data",), ep_axis=None, tp_axis=None)
        rng = np.random.RandomState(7)
        x = jnp.asarray(rng.randn(4, 10, 10, 3), jnp.float32)
        kk = jnp.asarray(rng.randn(3, 3, 3, 8), jnp.float32)
        ref = conv2d(x, kk, padding="SAME", algorithm="direct",
                     partition="none")
        with use_rules(rules):
            out = jax.jit(lambda a, b: conv2d(a, b, padding="SAME",
                                              algorithm="mec"))(x, kk)
        worst["rules"] = float(jnp.max(jnp.abs(out - ref)))
        print(json.dumps({"cases": cases, **worst}))
    """)
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", prog], env=env, cwd=REPO,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["cases"] == 36
    assert res["gi"] < 2e-4 and res["gk"] < 2e-4, res
    assert res["rules"] < 1e-4, res


def test_composite_conv_multidevice_subprocess():
    """Composite 2-D partitions == single-device oracle (fwd + grad
    through the halo) on a real 2x2 data x model mesh for every
    composite mode x {stride, kernel, dtype}, plus the
    conv2d(partition=tuple) front-end routing."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        os.environ["JAX_PLATFORMS"] = "cpu"
        import json
        import numpy as np, jax, jax.numpy as jnp
        from repro.core.conv_api import conv2d
        from repro.launch.mesh import make_host_mesh
        from repro.parallel.axes import ShardingRules, use_rules
        from repro.parallel.conv import COMPOSITE_PARTITIONS, sharded_conv2d

        mesh = make_host_mesh(shape=(2, 2), axes=("data", "model"))
        worst = {"fwd": 0.0, "gi": 0.0, "gk": 0.0, "front": 0.0}
        cases = 0
        for part in COMPOSITE_PARTITIONS:
            for k in (1, 3, 5):
                for s in (1, 2):
                    for dt in ("float32", "bfloat16"):
                        # 2-way spatial viable: 2 | i_h, s | i_h/2,
                        # halo <= i_h/2; batch 4 % 2; channel 8 % 2
                        i_h = 2 * s * max(k, 2)
                        rng = np.random.RandomState(cases)
                        x = jnp.asarray(rng.randn(4, i_h, i_h + 3, 3), dt)
                        kk = jnp.asarray(rng.randn(k, k, 3, 8), dt)
                        ref = conv2d(x, kk, stride=s, algorithm="direct",
                                     partition="none")
                        out = sharded_conv2d(x, kk, stride=s,
                                             algorithm="mec",
                                             partition=part, mesh=mesh)
                        tol_ref = jnp.maximum(jnp.max(jnp.abs(ref)), 1.0)
                        err = float(jnp.max(jnp.abs(
                            out.astype(jnp.float32)
                            - ref.astype(jnp.float32))) / tol_ref)
                        if dt == "float32":
                            worst["fwd"] = max(worst["fwd"], err)
                        assert err < (5e-2 if dt == "bfloat16" else 1e-4), \\
                            (part, k, s, dt, err)
                        cases += 1
        # grads through every composite (incl. the halo transpose on the
        # spatial sub-axis)
        for part in COMPOSITE_PARTITIONS:
            rng = np.random.RandomState(99)
            x = jnp.asarray(rng.randn(4, 12, 13, 3), jnp.float32)
            kk = jnp.asarray(rng.randn(3, 3, 3, 8), jnp.float32)
            loss = lambda f: (lambda a, b: jnp.sum(jnp.sin(f(a, b))))
            gi, gk = jax.grad(loss(lambda a, b: sharded_conv2d(
                a, b, algorithm="mec", partition=part, mesh=mesh)),
                argnums=(0, 1))(x, kk)
            ri, rk = jax.grad(loss(lambda a, b: conv2d(
                a, b, algorithm="direct", partition="none")),
                argnums=(0, 1))(x, kk)
            worst["gi"] = max(worst["gi"], float(jnp.max(jnp.abs(gi - ri))))
            worst["gk"] = max(worst["gk"], float(jnp.max(jnp.abs(gk - rk))))
        # the conv2d front-end takes the tuple (and partition_axis tuple)
        rules = ShardingRules(mesh=mesh, rules={"batch": "data"},
                              dp_axes=("data",), ep_axis="model",
                              tp_axis="model")
        rng = np.random.RandomState(7)
        x = jnp.asarray(rng.randn(4, 12, 12, 3), jnp.float32)
        kk = jnp.asarray(rng.randn(3, 3, 3, 8), jnp.float32)
        ref = conv2d(x, kk, padding="SAME", algorithm="direct",
                     partition="none")
        with use_rules(rules):
            out = jax.jit(lambda a, b: conv2d(
                a, b, padding="SAME", algorithm="mec",
                partition=("batch", "spatial")))(x, kk)
            out2 = jax.jit(lambda a, b: conv2d(
                a, b, padding="SAME", algorithm="mec",
                partition=("spatial", "channel"),
                partition_axis=("model", "data")))(x, kk)
        worst["front"] = float(max(jnp.max(jnp.abs(out - ref)),
                                   jnp.max(jnp.abs(out2 - ref))))
        print(json.dumps({"cases": cases, **worst}))
    """)
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", prog], env=env, cwd=REPO,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["cases"] == 36
    assert res["gi"] < 2e-4 and res["gk"] < 2e-4, res
    assert res["front"] < 1e-4, res


# ----------------------------------------------------- halo edge cases

def test_zero_halo_when_stride_covers_kernel():
    # k_h == s_h: adjacent output windows tile the input exactly, so no
    # rows cross the shard boundary (and overshoot clamps at zero).
    assert spatial_halo_rows(3, 3) == 0
    assert spatial_halo_rows(2, 3) == 0
    spec = ConvSpec(1, 12, 12, 3, 3, 3, 8, 3, 3)
    assert partition_viable(spec, "spatial", 4)
    c = conv_partition_costs(spec, 4)["spatial"]
    assert c["viable"]
    assert c["halo_bytes_per_device"] == 0.0
    assert c["comm_bytes_fwd_per_device"] == 0.0
    # backward still psums the kernel cotangent over the spatial axis
    assert c["comm_bytes_bwd_per_device"] == 3 * 3 * 3 * 8 * 4
    # the local Eq. 3 overhead uses the halo-free 3-row shard
    import dataclasses
    from repro.core import memory
    lspec = dataclasses.replace(spec, i_h=3)
    assert c["per_device_overhead_elems"] == float(
        memory.mec_overhead(lspec))


def test_single_row_shard_halo_equals_full_local_height():
    # i_h=4 split 4 ways with k_h=2, s_h=1: each device owns ONE input
    # row and needs exactly one more — the halo IS the local height.
    # Viability is the boundary case halo <= h_loc, not halo < h_loc.
    spec = ConvSpec(2, 4, 8, 3, 2, 2, 4, 1, 1)
    assert spatial_halo_rows(2, 1) == 1
    assert partition_viable(spec, "spatial", 4)
    c = conv_partition_costs(spec, 4)["spatial"]
    assert c["viable"]
    # every exchange ships one full local row per batch element
    assert c["halo_bytes_per_device"] == 2 * 1 * 8 * 3 * 4
    import dataclasses
    from repro.core import memory
    lspec = dataclasses.replace(spec, i_h=2)    # 1 owned + 1 halo row
    assert c["per_device_overhead_elems"] == float(
        memory.mec_overhead(lspec))
    assert c["per_device_im2col_elems"] == float(
        memory.im2col_overhead(lspec))
    # sharper than the rows: more devices than rows can never split
    assert not partition_viable(spec, "spatial", 8)
    # ...and a halo exceeding the local height is rejected (k_h=3 needs
    # 2 neighbour rows from a 1-row shard: multi-hop, not supported)
    tall_kernel = ConvSpec(2, 4, 8, 3, 3, 3, 4, 1, 1)
    assert not partition_viable(tall_kernel, "spatial", 4)


def test_single_row_shard_matches_oracle():
    # The boundary geometry above must also be numerically right.
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                                   " --xla_force_host_platform_device_count=4")
        os.environ["JAX_PLATFORMS"] = "cpu"
        import numpy as np, jax, jax.numpy as jnp
        from repro.core.conv_api import conv2d
        from repro.parallel.conv import sharded_conv2d
        rng = np.random.RandomState(7)
        inp = jnp.asarray(rng.randn(2, 4, 8, 3), jnp.float32)
        ker = jnp.asarray(rng.randn(2, 2, 3, 4), jnp.float32)
        ref = conv2d(inp, ker, algorithm="direct")
        out = sharded_conv2d(inp, ker, partition="spatial")
        print(float(jnp.max(jnp.abs(out - ref))))
    """)
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", prog], env=env, cwd=REPO,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert float(out.stdout.strip().splitlines()[-1]) < 1e-4
