"""Distributed-conv collective contract checker (repro.analysis.shardcheck,
DESIGN.md §8): contract derivation units (trim_reshard /
expected_collectives / verify_collectives), skip semantics, the plan
hook, and seeded-mutation subprocess tests proving the checker actually
catches a deleted halo exchange and a dropped VJP transpose."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.analysis.shardcheck import (COLLECTIVE_KINDS,
                                       SCALAR_REDUCE_ALLOWANCE_BYTES,
                                       check_plan_contract, check_sharding,
                                       expected_collectives, trim_reshard,
                                       verify_collectives)
from repro.core.convspec import ConvSpec
from repro.plan.convplan import ConvPlan

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# o_h=14 splits evenly 2 ways; halo 2 rows; trim shifts f=1 row.
SPEC = ConvSpec(2, 16, 16, 3, 3, 3, 4, 1, 1)


def _costs(spec, sizes):
    from repro.launch.costmodel import conv_partition_costs
    return conv_partition_costs(
        spec, sizes if isinstance(sizes, tuple) else sizes, 4)


# ---------------------------------------------------------------------------
# contract derivation
# ---------------------------------------------------------------------------

def test_trim_reshard_even_split_prices_the_shift():
    # r=8 rows/device, o_h=14 -> f = 8 - ceil(14/2) = 1 shifted row of
    # i_n_loc * o_w * k_c_loc output elements.
    reason, slab = trim_reshard(SPEC, ("spatial",), (2,), 4)
    assert reason is None
    assert slab == SPEC.i_n * 1 * SPEC.o_w * SPEC.k_c * 4
    # non-spatial partitions never trim
    assert trim_reshard(SPEC, ("batch",), (2,), 4) == (None, 0.0)
    # k_h == s_h tiles exactly: nothing trimmed
    exact = ConvSpec(1, 12, 12, 3, 3, 3, 8, 3, 3)
    assert trim_reshard(exact, ("spatial",), (2,), 4) == (None, 0.0)


def test_trim_reshard_uneven_output_fwd_only():
    spec = ConvSpec(1, 18, 18, 3, 4, 4, 4, 1, 1)       # o_h=15, odd
    reason, slab = trim_reshard(spec, ("spatial",), (2,), 4)
    assert reason is not None and "gather+slice" in reason
    assert slab == 1 * 1 * spec.o_w * spec.k_c * 4     # still finite
    # ...so the grad direction stays verifiable, fwd does not
    req, opt, un_fwd = expected_collectives(spec, "spatial", 2, 4, "fwd")
    assert un_fwd is not None
    req, opt, un_grad = expected_collectives(spec, "spatial", 2, 4, "grad")
    assert un_grad is None


def test_trim_reshard_multiway_shift_unpriceable():
    import math
    spec = ConvSpec(1, 16, 16, 3, 5, 5, 4, 1, 1)       # 4-way: f=1
    reason, slab = trim_reshard(spec, ("spatial",), (4,), 4)
    assert reason is not None and "multiple sources" in reason
    assert math.isnan(slab)
    # neither direction can be priced
    for direction in ("fwd", "grad"):
        _, _, un = expected_collectives(spec, "spatial", 4, 4, direction)
        assert un is not None


def test_expected_collectives_match_costmodel():
    for part, sizes in (("batch", (2,)), ("channel", (2,)),
                        ("spatial", (2,)), (("batch", "spatial"), (2, 2)),
                        (("batch", "channel"), (2, 2))):
        entry = _costs(SPEC, sizes if len(sizes) > 1 else sizes[0])[
            part if isinstance(part, tuple) else part]
        halo = entry["halo_bytes_per_device"]
        psum = entry["comm_bytes_bwd_per_device"] - halo
        req_f, opt_f, un_f = expected_collectives(SPEC, part, sizes, 4,
                                                  "fwd")
        req_g, opt_g, un_g = expected_collectives(SPEC, part, sizes, 4,
                                                  "grad")
        assert un_f is None and un_g is None, (part, un_f, un_g)
        assert req_f["collective-permute"] == halo
        assert req_g["collective-permute"] == 2 * halo          # + VJP
        assert req_f["all-reduce"] == 0.0
        assert req_g["all-reduce"] == psum
        for kind in ("all-gather", "all-to-all", "reduce-scatter"):
            assert req_f[kind] == req_g[kind] == 0.0            # never
        assert opt_g["collective-permute"] == 2 * opt_f["collective-permute"]


def test_expected_collectives_replica_combine_on_oversized_mesh():
    """Production-mesh dry-runs: unused mesh axes replicate the cell and
    GSPMD may shard the backward over them, combining the one gradient
    that has no modeled psum with an extra (optional) all-reduce."""
    from repro.analysis.shardcheck import replica_combine_bytes
    # spatial: the input gradient pays its local shard bytes
    assert replica_combine_bytes(SPEC, ("spatial",), (2,), 4) == \
        SPEC.i_n * (SPEC.i_h // 2) * SPEC.i_w * SPEC.i_c * 4
    # pure channel: the kernel gradient pays its local shard bytes
    assert replica_combine_bytes(SPEC, ("channel",), (2,), 4) == \
        SPEC.k_h * SPEC.k_w * SPEC.i_c * (SPEC.k_c // 2) * 4
    # any channel composite: both gradients merge into modeled psums
    assert replica_combine_bytes(SPEC, ("batch", "channel"), (2, 2), 4) \
        == 0.0
    # exact-size mesh (replicated_ways=1): no optional all-reduce at all
    _, opt, _ = expected_collectives(SPEC, "spatial", 2, 4, "grad")
    assert opt["all-reduce"] == 0.0
    _, opt, _ = expected_collectives(SPEC, "spatial", 2, 4, "grad",
                                     replicated_ways=16)
    assert opt["all-reduce"] == \
        replica_combine_bytes(SPEC, ("spatial",), (2,), 4)
    # fwd never combines gradients
    _, opt, _ = expected_collectives(SPEC, "spatial", 2, 4, "fwd",
                                     replicated_ways=16)
    assert opt["all-reduce"] == 0.0


def test_expected_collectives_rejects_bad_inputs():
    with pytest.raises(ValueError, match="unknown direction"):
        expected_collectives(SPEC, "spatial", 2, 4, "backward")
    with pytest.raises(ValueError, match="component"):
        expected_collectives(SPEC, ("batch", "spatial"), 2, 4, "fwd")


# ---------------------------------------------------------------------------
# verification rules
# ---------------------------------------------------------------------------

def _zero():
    return {k: 0.0 for k in COLLECTIVE_KINDS}


def test_verify_collectives_exact_and_optional():
    req = dict(_zero(), **{"collective-permute": 100.0})
    opt = dict(_zero(), **{"collective-permute": 40.0})
    ok = dict.fromkeys(COLLECTIVE_KINDS, 0)
    assert verify_collectives(dict(ok, **{"collective-permute": 100}),
                              req, "fwd", optional=opt) == []
    # required + optional (GSPMD chose to rebalance) also exact-matches
    assert verify_collectives(dict(ok, **{"collective-permute": 140}),
                              req, "fwd", optional=opt) == []
    # anything in between is a mismatch, and the message is actionable
    (v,) = verify_collectives(dict(ok, **{"collective-permute": 120}),
                              req, "fwd", optional=opt)
    assert v.rule == "collective-bytes-mismatch"
    assert "VJP transpose" in v.message


def test_verify_collectives_missing_and_unexpected():
    req = dict(_zero(), **{"collective-permute": 100.0,
                           "all-reduce": 200.0})
    got = {"collective-permute": 0, "all-reduce": 0, "all-gather": 64}
    viol = verify_collectives(got, req, "grad", label="cell")
    rules = {v.rule for v in viol}
    assert rules == {"missing-collective", "unexpected-collective"}
    permute = next(v for v in viol if "collective-permute" in v.message)
    assert "lax.ppermute" in permute.message
    assert "VJP transpose" in permute.message       # grad direction hint
    psum = next(v for v in viol if "all-reduce" in v.message)
    assert "psum" in psum.message
    gather = next(v for v in viol if "all-gather" in v.message)
    assert "reshard" in gather.message and "conv_partition_specs" \
        in gather.message


def test_verify_collectives_scalar_allowance_grad_only():
    req = dict(_zero(), **{"all-reduce": 200.0})
    over = {"all-reduce": 200 + SCALAR_REDUCE_ALLOWANCE_BYTES}
    assert verify_collectives(over, req, "grad") == []
    assert len(verify_collectives(over, req, "fwd")) == 1
    way_over = {"all-reduce": 200 + SCALAR_REDUCE_ALLOWANCE_BYTES + 1}
    assert len(verify_collectives(way_over, req, "grad")) == 1


def test_verify_collectives_sub_f32_width():
    # CPU hoists the bf16->f32 upcast above the collective: 2x the
    # declared width is admissible for dtype_bytes=2, nothing else is.
    req = dict(_zero(), **{"collective-permute": 100.0})
    assert verify_collectives({"collective-permute": 200}, req, "fwd",
                              dtype_bytes=2) == []
    assert len(verify_collectives({"collective-permute": 200}, req, "fwd",
                                  dtype_bytes=4)) == 1
    assert len(verify_collectives({"collective-permute": 150}, req, "fwd",
                                  dtype_bytes=2)) == 1


# ---------------------------------------------------------------------------
# skip semantics (this pytest process has one device: every real
# lowering must degrade to a recorded skip, never a crash or a pass)
# ---------------------------------------------------------------------------

def test_check_sharding_skips_are_recorded():
    one_way = check_sharding(SPEC, "spatial", 1)
    assert one_way.skipped and "1-way" in one_way.skipped
    assert one_way.record["verdict"] == "skipped"
    assert one_way.ok                        # a skip is not a failure...
    assert one_way.record["verdict"] != "pass"   # ...and not a pass

    bad_geo = check_sharding(ConvSpec(1, 15, 16, 3, 3, 3, 4, 1, 1),
                             "spatial", 2)
    assert "partition_viable" in bad_geo.skipped

    import jax
    too_big = check_sharding(SPEC, "spatial", jax.device_count() + 1)
    assert "xla_force_host_platform_device_count" in too_big.skipped


def test_check_sharding_rejects_bad_arguments():
    with pytest.raises(ValueError, match="n_dev"):
        check_sharding(SPEC, "spatial")
    with pytest.raises(ValueError, match="axis size"):
        check_sharding(SPEC, ("batch", "spatial"), 2)
    from repro.launch.mesh import make_host_mesh
    with pytest.raises(ValueError, match="axes"):
        check_sharding(SPEC, "spatial", mesh=make_host_mesh(shape=(1,)))


def test_plan_hook_skips_without_mesh():
    from repro.analysis.shardcheck import assert_plan_contract
    bare = ConvPlan(spec=SPEC, dtype="float32", algorithm="mec")
    res = check_plan_contract(bare)
    assert res.skipped == "no partition"
    assert assert_plan_contract(bare) is None
    parted = ConvPlan(spec=SPEC, dtype="float32", algorithm="mec",
                      partition=("spatial",), partition_axes=("data",))
    res = check_plan_contract(parted)       # no rules installed here
    assert res.skipped and "no installed mesh" in res.skipped
    assert assert_plan_contract(parted) is None


# ---------------------------------------------------------------------------
# the real thing: forced 2-device lowerings in subprocesses
# ---------------------------------------------------------------------------

def _run(prog, timeout=900):
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(prog)],
                         env=env, cwd=REPO, capture_output=True,
                         text=True, timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_check_sharding_end_to_end_2dev_subprocess():
    """Unmutated executor: every partition honors the contract on a real
    2-device mesh, in both directions, and a declared precision flows
    through every lowered GEMM."""
    res = _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
        os.environ["JAX_PLATFORMS"] = "cpu"
        import json
        from repro.analysis.shardcheck import check_sharding
        from repro.core.convspec import ConvSpec
        spec = ConvSpec(2, 16, 16, 3, 3, 3, 4, 1, 1)
        out = {}
        for part in ("batch", "channel", "spatial"):
            chk = check_sharding(spec, part, 2, precision="HIGHEST")
            out[part] = {"verdict": chk.record["verdict"],
                         "violations": chk.record["violations"],
                         "flow": chk.record["precision_flow"]}
        bf16 = check_sharding(spec, "spatial", 2, dtype="bfloat16")
        out["bf16"] = {"verdict": bf16.record["verdict"],
                       "violations": bf16.record["violations"]}
        print(json.dumps(out))
    """)
    for part in ("batch", "channel", "spatial", "bf16"):
        assert res[part]["verdict"] == "pass", (part, res[part])
    for part in ("batch", "channel", "spatial"):
        flow = res[part]["flow"]
        assert flow["dot_ops"] > 0 and flow["unannotated_dot_ops"] == 0
        assert flow["hlo_dots"] > 0 and flow["hlo_unannotated"] == 0


def test_shardcheck_flags_deleted_halo_exchange_subprocess():
    """Seeded mutation 1: neuter lax.ppermute inside sharded_conv2d (the
    halo never ships).  The checker must fail BOTH directions with an
    actionable missing-collective message naming the mechanism."""
    res = _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
        os.environ["JAX_PLATFORMS"] = "cpu"
        import json
        import jax.numpy as jnp
        from jax import lax as real_lax
        import repro.parallel.conv as pconv
        from repro.analysis.shardcheck import check_sharding
        from repro.core.convspec import ConvSpec

        class NoHalo:
            def __getattr__(self, n):
                return getattr(real_lax, n)
            @staticmethod
            def ppermute(x, axis_name, perm):
                return jnp.zeros_like(x)     # halo deleted

        pconv.lax = NoHalo()
        chk = check_sharding(ConvSpec(2, 16, 16, 3, 3, 3, 4, 1, 1),
                             "spatial", 2)
        print(json.dumps({"verdict": chk.record["verdict"],
                          "violations": chk.record["violations"]}))
    """)
    assert res["verdict"] == "fail"
    fwd = [v for v in res["violations"] if "] fwd:" in v]
    grad = [v for v in res["violations"] if "] grad:" in v]
    assert fwd and grad
    for v in fwd + grad:
        assert "missing-collective" in v
        assert "lax.ppermute" in v and "sharded_conv2d" in v


def test_shardcheck_flags_dropped_vjp_transpose_subprocess():
    """Seeded mutation 2: the forward halo exchange is intact but its
    VJP transpose is dropped (custom_vjp returning a zero cotangent).
    The forward program must still verify; the grad program must fail
    naming the transpose — and the plan_conv2d hook must refuse the
    plan with a ShardCheckError."""
    res = _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
        os.environ["JAX_PLATFORMS"] = "cpu"
        import json
        import jax
        import jax.numpy as jnp
        from jax import lax as real_lax
        import repro.parallel.conv as pconv
        from repro.analysis.shardcheck import (ShardCheckError,
                                               assert_plan_contract,
                                               check_sharding)
        from repro.core.convspec import ConvSpec
        from repro.launch.mesh import make_host_mesh
        from repro.plan.convplan import ConvPlan

        def leaky_ppermute(x, axis_name, perm):
            @jax.custom_vjp
            def f(v):
                return real_lax.ppermute(v, axis_name, perm)
            def fwd(v):
                return real_lax.ppermute(v, axis_name, perm), None
            def bwd(_, g):
                return (jnp.zeros_like(g),)  # transpose permute dropped
            f.defvjp(fwd, bwd)
            return f(x)

        class LeakyVJP:
            def __getattr__(self, n):
                return getattr(real_lax, n)
            ppermute = staticmethod(leaky_ppermute)

        pconv.lax = LeakyVJP()
        spec = ConvSpec(2, 16, 16, 3, 3, 3, 4, 1, 1)
        chk = check_sharding(spec, "spatial", 2)
        plan = ConvPlan(spec=spec, dtype="float32", algorithm="mec",
                        partition=("spatial",), partition_axes=("data",))
        try:
            assert_plan_contract(plan, mesh=make_host_mesh())
            hook = "no-raise"
        except ShardCheckError as e:
            hook = "raised" if "permute" in str(e) else "raised-unnamed"
        print(json.dumps({"verdict": chk.record["verdict"],
                          "violations": chk.record["violations"],
                          "hook": hook}))
    """)
    assert res["verdict"] == "fail"
    assert res["hook"] == "raised"
    # the forward halo is intact: every violation is in the grad program
    assert res["violations"], res
    for v in res["violations"]:
        assert "] grad:" in v
        assert "collective-permute" in v and "VJP transpose" in v
