"""Sharding rules, ZeRO-1 specs, gradient compression, and multi-device
behaviour (multi-device cases run in a subprocess with forced host
devices, since the main test process is single-device)."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.archs import smoke_config
from repro.models.lm import LM
from repro.parallel import compression, sharding
from repro.parallel.axes import default_rules


def _fake_mesh(shape=(2, 4), axes=("data", "model")):
    """An abstract mesh for spec computation only (no devices needed)."""
    from repro.core.compat import abstract_mesh
    return abstract_mesh(shape, axes)


def test_param_rules_respect_divisibility():
    cfg = smoke_config("qwen3-4b")          # kv=2 heads, model axis = 4
    model = LM(cfg)
    shapes = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    mesh = _fake_mesh()
    specs = sharding.param_specs(shapes, mesh)
    blocks = specs["blocks"]
    # wq column-sharded (out dim divisible), wo row-sharded
    assert blocks["attn"]["wq"]["w"] == P(None, None, "model")
    assert blocks["attn"]["wo"]["w"] == P(None, "model", None)
    assert blocks["mlp"]["gate"]["w"] == P(None, None, "model")
    assert blocks["mlp"]["down"]["w"] == P(None, "model", None)
    # embedding vocab-sharded
    assert specs["emb"] == P("model", None)
    # norms replicated
    assert specs["final_norm"] == P(None)


def test_zero1_adds_dp_axis():
    cfg = smoke_config("qwen3-4b")
    model = LM(cfg)
    shapes = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    mesh = _fake_mesh()
    p_specs = sharding.param_specs(shapes, mesh)
    z = sharding.zero1_specs(p_specs, shapes, mesh, zero_axes=("data",))
    # wq (L=4, 64, H*hd): first unsharded divisible dim (L) gets 'data'
    assert z["blocks"]["attn"]["wq"]["w"] == P("data", None, "model")
    # a previously replicated norm (L, d) is now DP-sharded
    spec = z["blocks"]["norm1"]
    assert "data" in str(spec)


def test_quantize_roundtrip_error_bound():
    x = jax.random.normal(jax.random.key(0), (1000,)) * 3.0
    q, s = compression.quantize(x)
    err = np.abs(np.asarray(compression.dequantize(q, s) - x))
    assert err.max() <= float(s) / 2 + 1e-6   # half-ulp of the int8 grid


def test_compressed_training_multidevice_subprocess():
    """4 fake host devices: int8-EF compressed DP training must converge
    and stay close to uncompressed training."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import numpy as np, jax, jax.numpy as jnp, json
        from jax.sharding import Mesh
        from repro.configs.archs import smoke_config
        from repro.models.lm import LM
        from repro.optim.adamw import AdamWConfig
        from repro.parallel.axes import ShardingRules
        from repro.training.steps import (init_opt_state, make_train_step,
                                          make_compressed_train_step)
        from repro.data.pipeline import SyntheticLMData

        cfg = smoke_config("yi-6b")
        model = LM(cfg)
        mesh = Mesh(np.asarray(jax.devices()).reshape(4), ("data",))
        rules = ShardingRules(mesh=mesh, rules={"batch": "data"},
                              dp_axes=("data",), ep_axis=None, tp_axis=None)
        opt_cfg = AdamWConfig(lr=1e-3, total_steps=12, warmup_steps=2)

        def run(compressed):
            params = model.init(jax.random.key(0))
            opt = init_opt_state(params, compressed=compressed)
            if compressed:
                fn = make_compressed_train_step(model, opt_cfg, rules)
            else:
                fn = make_train_step(model, opt_cfg, rules)
            fn = jax.jit(fn)
            data = SyntheticLMData(cfg, 8, 32)
            with mesh:
                losses = []
                for _ in range(12):
                    params, opt, m = fn(params, opt, data.next_batch())
                    losses.append(float(m["loss"]))
            return losses

        lc = run(True)
        lu = run(False)
        print(json.dumps({"compressed": lc, "plain": lu}))
    """)
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", prog], env=env, cwd=os.path.
                         dirname(os.path.dirname(os.path.abspath(__file__))),
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    lc, lu = res["compressed"], res["plain"]
    assert lc[-1] < lc[0], "compressed training did not reduce loss"
    assert abs(lc[-1] - lu[-1]) < 0.35, (lc[-1], lu[-1])


def test_ep_moe_multidevice_subprocess():
    """shard_map expert parallelism on 4 fake devices matches the local
    executor bit-for-bit-ish."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import numpy as np, jax, jax.numpy as jnp, json
        from jax.sharding import Mesh
        from repro.configs.archs import smoke_config
        from repro.models import moe
        from repro.parallel.axes import ShardingRules, use_rules

        cfg = smoke_config("qwen3-moe-30b-a3b").with_(moe_impl="ep",
                                                      n_experts=8, top_k=2)
        p = moe.init_moe(jax.random.key(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.key(1), (4, 8, cfg.d_model))
        y_local, aux_l = moe.moe_ffn(p, cfg.with_(moe_impl="local"), x)

        mesh = Mesh(np.asarray(jax.devices()).reshape(2, 2),
                    ("data", "model"))
        rules = ShardingRules(mesh=mesh, rules={"batch": "data"},
                              dp_axes=("data",), ep_axis="model",
                              tp_axis="model")
        with mesh, use_rules(rules):
            y_ep, aux_e = jax.jit(lambda p, x: moe.moe_ffn(p, cfg, x))(p, x)
        err = float(jnp.max(jnp.abs(y_ep - y_local)))
        print(json.dumps({"err": err, "aux_l": float(aux_l),
                          "aux_e": float(aux_e)}))
    """)
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", prog], env=env, cwd=os.path.
                         dirname(os.path.dirname(os.path.abspath(__file__))),
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["err"] < 5e-4, res
    # per-shard aux (pmean of local Switch estimators) is a different but
    # consistent estimator of the global one — same scale, not identical
    assert res["aux_e"] > 0
    assert abs(res["aux_l"] - res["aux_e"]) / res["aux_l"] < 0.25, res


@pytest.mark.skipif(
    tuple(int(x) for x in jax.__version__.split(".")[:2]) < (0, 5),
    reason="partial-manual shard_map (auto= over the model axis) aborts the "
           "XLA SPMD partitioner on jax 0.4.x (fatal "
           "'Check failed: sharding.IsManualSubgroup()'); needs jax >= 0.5")
def test_compressed_training_dp_tp_mesh_subprocess():
    """int8-EF gradient reduction composes with tensor parallelism via
    partial-manual shard_map (manual over DP, auto over model)."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import json
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh, NamedSharding
        from repro.configs.archs import smoke_config
        from repro.models.lm import LM
        from repro.optim.adamw import AdamWConfig
        from repro.parallel import sharding
        from repro.parallel.axes import default_rules
        from repro.training.steps import (init_opt_state, make_train_step,
                                          make_compressed_train_step)
        from repro.data.pipeline import SyntheticLMData

        cfg = smoke_config("yi-6b")
        model = LM(cfg)
        mesh = Mesh(np.asarray(jax.devices()).reshape(2, 2),
                    ("data", "model"))
        rules = default_rules(mesh)
        opt_cfg = AdamWConfig(lr=1e-3, total_steps=10, warmup_steps=2)

        def run(compressed):
            params = model.init(jax.random.key(0))
            specs = sharding.param_specs(params, mesh)
            params = jax.tree.map(
                lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
                params, specs)
            opt = init_opt_state(params, compressed=compressed)
            builder = (make_compressed_train_step if compressed
                       else make_train_step)
            fn = jax.jit(builder(model, opt_cfg, rules))
            data = SyntheticLMData(cfg, 8, 32)
            with mesh:
                losses = []
                for _ in range(10):
                    params, opt, m = fn(params, opt, data.next_batch())
                    losses.append(float(m["loss"]))
            return losses

        lc, lu = run(True), run(False)
        print(json.dumps({"c": lc, "u": lu}))
    """)
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", prog], env=env, cwd=os.path.
                         dirname(os.path.dirname(os.path.abspath(__file__))),
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["c"][-1] < res["c"][0]
    assert abs(res["c"][-1] - res["u"][-1]) < 0.3, res
