"""The unified conv2d front-end (repro.core.conv_api): every algorithm
cross-checked against ``lax.conv_general_dilated`` over (stride, padding,
dtype), the auto dispatch, and gradients through the MEC custom VJP
against the direct-conv gradient and numerical differences."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import ALGORITHMS, MEC_ALGORITHMS, conv2d, conv2d_spec

GRID_ALGS = ["direct", "im2col", "fft", "winograd", "mec", "mec_lowered",
             "mec_fused", "mec_fused2", "auto"]


def _rand(shape, seed, dtype=jnp.float32):
    x = np.random.RandomState(seed).randn(*shape).astype(np.float32)
    return jnp.asarray(x, dtype)


def _lax_ref(inp, kernel, stride, padding):
    s = (stride, stride) if isinstance(stride, int) else tuple(stride)
    return lax.conv_general_dilated(
        inp.astype(jnp.float32), kernel.astype(jnp.float32), s, padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


@pytest.mark.parametrize("algorithm", GRID_ALGS)
@pytest.mark.parametrize("stride", [1, 2])
@pytest.mark.parametrize("padding", ["SAME", "VALID"])
def test_conv2d_matches_lax(algorithm, stride, padding):
    if algorithm == "winograd" and stride != 1:
        pytest.skip("winograd F(2x2,3x3) is stride-1 only by construction")
    inp = _rand((2, 11, 12, 3), 0)
    ker = _rand((3, 3, 3, 5), 1)             # 3x3 so winograd is eligible
    ref = _lax_ref(inp, ker, stride, padding)
    out = conv2d(inp, ker, stride=stride, padding=padding,
                 algorithm=algorithm)
    assert out.shape == ref.shape
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("algorithm", list(MEC_ALGORITHMS))
def test_conv2d_mec_bf16(algorithm):
    inp = _rand((1, 10, 10, 4), 2, jnp.bfloat16)
    ker = _rand((3, 3, 4, 6), 3, jnp.bfloat16)
    ref = _lax_ref(inp, ker, 1, "SAME")
    out = conv2d(inp, ker, padding="SAME", algorithm=algorithm)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                               rtol=5e-2, atol=5e-2)


def test_conv2d_explicit_padding():
    inp = _rand((1, 9, 9, 2), 4)
    ker = _rand((3, 3, 2, 3), 5)
    ref = _lax_ref(inp, ker, 1, [(1, 2), (0, 1)])
    out = conv2d(inp, ker, padding=((1, 2), (0, 1)), algorithm="mec")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    out_int = conv2d(inp, ker, padding=1, algorithm="im2col")
    ref_int = _lax_ref(inp, ker, 1, [(1, 1), (1, 1)])
    np.testing.assert_allclose(np.asarray(out_int), np.asarray(ref_int),
                               rtol=1e-4, atol=1e-4)


def test_conv2d_rejects_bad_requests():
    inp = _rand((1, 8, 8, 2), 6)
    with pytest.raises(ValueError):
        conv2d(inp, _rand((3, 3, 2, 4), 7), stride=2, algorithm="winograd")
    with pytest.raises(ValueError):
        conv2d(inp, _rand((5, 5, 2, 4), 8), algorithm="winograd")
    with pytest.raises(ValueError):
        conv2d(inp, _rand((3, 3, 2, 4), 7), algorithm="toeplitz")
    with pytest.raises(ValueError):  # channel mismatch caught by ConvSpec
        conv2d(inp, _rand((3, 3, 5, 4), 9), algorithm="direct")


def test_auto_dispatch_consults_costmodel():
    from repro.launch.costmodel import (conv2d_algorithm_costs,
                                        pick_conv2d_algorithm)
    inp = _rand((1, 16, 16, 4), 10)
    # 1x1 kernels: lowering is pointless, direct wins
    s1 = conv2d_spec(inp, _rand((1, 1, 4, 8), 11))
    assert pick_conv2d_algorithm(s1, backend="cpu") == "direct"
    # overlapping 3x3 stride-1: MEC saves memory -> picked on CPU
    s3 = conv2d_spec(inp, _rand((3, 3, 4, 8), 12), padding="SAME")
    assert pick_conv2d_algorithm(s3, backend="cpu") == "mec"
    # TPU always routes to the fused no-L-in-HBM Pallas kernel
    assert pick_conv2d_algorithm(s3, backend="tpu") == "mec_fused"
    costs = conv2d_algorithm_costs(s3)
    assert set(costs) == {"direct", "im2col", "mec", "fft", "winograd"}
    assert costs["mec"]["overhead_elems"] < costs["im2col"]["overhead_elems"]
    # every pick is a dispatchable algorithm name
    assert pick_conv2d_algorithm(s3) in ALGORITHMS


@pytest.mark.parametrize("stride", [1, 2])
@pytest.mark.parametrize("algorithm", ["mec", "mec_fused", "mec_lowered"])
def test_mec_grad_matches_direct(algorithm, stride):
    inp = _rand((2, 9, 10, 3), 13)
    ker = _rand((3, 3, 3, 4), 14)

    def loss(alg):
        return lambda i, k: jnp.sum(jnp.sin(
            conv2d(i, k, stride=stride, padding="SAME", algorithm=alg)))

    gi, gk = jax.grad(loss(algorithm), argnums=(0, 1))(inp, ker)
    ri, rk = jax.grad(loss("direct"), argnums=(0, 1))(inp, ker)
    np.testing.assert_allclose(np.asarray(gi), np.asarray(ri),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(gk), np.asarray(rk),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("algorithm", list(MEC_ALGORITHMS))
def test_mec_precision_reaches_lowered_dots(algorithm):
    """Regression: conv2d used to drop ``precision`` on every MEC
    algorithm (the custom VJP was called without it).  For a bf16 input,
    Precision.HIGHEST must change the lowered dot — and the gradient's
    einsums must carry it too."""
    inp = _rand((1, 8, 8, 3), 30, jnp.bfloat16)
    ker = _rand((3, 3, 3, 4), 31, jnp.bfloat16)

    def lowered(precision, grad=False):
        def f(i, k):
            out = conv2d(i, k, algorithm=algorithm, precision=precision,
                         partition="none")
            return jnp.sum(out.astype(jnp.float32) ** 2)
        fn = jax.grad(f, argnums=(0, 1)) if grad else f
        return jax.jit(fn).lower(inp, ker).as_text()

    assert "HIGHEST" in lowered(jax.lax.Precision.HIGHEST)
    assert "HIGHEST" not in lowered(None)
    assert "HIGHEST" in lowered(jax.lax.Precision.HIGHEST, grad=True)
    assert "HIGHEST" not in lowered(None, grad=True)
    # and the result still matches the oracle
    out = conv2d(inp, ker, algorithm=algorithm,
                 precision=jax.lax.Precision.HIGHEST)
    ref = _lax_ref(inp, ker, 1, "VALID")
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                               rtol=5e-2, atol=5e-2)


@pytest.mark.parametrize("algorithm", ["fft", "winograd"])
def test_fft_winograd_precision_reaches_lowered_dots(algorithm):
    """Regression: conv2d silently dropped ``precision`` on the fft and
    winograd branches (threaded everywhere else since the MEC fix).
    Mirrors the bf16 MEC check: Precision.HIGHEST must change the
    lowered dot — winograd's transform/product GEMMs and the FFT
    pointwise-multiply both carry it now."""
    inp = _rand((1, 8, 8, 3), 40, jnp.bfloat16)
    ker = _rand((3, 3, 3, 4), 41, jnp.bfloat16)

    def lowered(precision):
        def f(i, k):
            return conv2d(i, k, algorithm=algorithm, precision=precision,
                          partition="none")
        return jax.jit(f).lower(inp, ker).as_text()

    assert "HIGHEST" in lowered(jax.lax.Precision.HIGHEST)
    assert "HIGHEST" not in lowered(None)
    # and the result still matches the oracle
    out = conv2d(inp, ker, algorithm=algorithm,
                 precision=jax.lax.Precision.HIGHEST)
    ref = _lax_ref(inp, ker, 1, "VALID")
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                               rtol=5e-2, atol=5e-2)


def test_apply_padding_rejects_negative_pads():
    """Satellite: a negative explicit pad used to surface as an opaque
    jnp.pad trace error; now it is a plain ValueError at the API edge."""
    inp = _rand((1, 8, 8, 2), 42)
    ker = _rand((3, 3, 2, 4), 43)
    for bad in (-1, ((-1, 0), (0, 0)), ((0, 0), (1, -2))):
        with pytest.raises(ValueError, match="non-negative"):
            conv2d(inp, ker, padding=bad, algorithm="direct")
    # zero/positive pads unchanged
    out = conv2d(inp, ker, padding=0, algorithm="direct")
    assert out.shape == (1, 6, 6, 4)


def test_stride_normalizer_is_shared():
    """Satellite: conv_api and spec_of resolve strides through the one
    convspec.normalize_stride — bad strides fail identically."""
    from repro.core.convspec import normalize_stride
    assert normalize_stride(2) == (2, 2)
    assert normalize_stride((1, 3)) == (1, 3)
    assert normalize_stride([2, 1]) == (2, 1)
    with pytest.raises(ValueError, match="strides must be >= 1"):
        normalize_stride(0)
    inp = _rand((1, 8, 8, 2), 44)
    ker = _rand((3, 3, 2, 4), 45)
    with pytest.raises(ValueError, match="strides must be >= 1"):
        conv2d(inp, ker, stride=0, algorithm="direct")
    with pytest.raises(ValueError, match="strides must be >= 1"):
        conv2d(inp, ker, stride=(1, 0), algorithm="mec")


def test_mec_grad_matches_numerical():
    """Central-difference spot check of the custom VJP (both operands)."""
    inp = _rand((1, 6, 6, 2), 15)
    ker = _rand((3, 3, 2, 2), 16)

    def f(i, k):
        return float(jnp.sum(conv2d(i, k, stride=2, padding="VALID",
                                    algorithm="mec") ** 2))

    gi, gk = jax.grad(
        lambda i, k: jnp.sum(conv2d(i, k, stride=2, padding="VALID",
                                    algorithm="mec") ** 2),
        argnums=(0, 1))(inp, ker)
    eps = 1e-3
    rng = np.random.RandomState(17)
    for arr, grad, which in [(inp, gi, 0), (ker, gk, 1)]:
        flat = np.asarray(arr).ravel()
        for idx in rng.choice(flat.size, size=5, replace=False):
            e = np.zeros_like(flat)
            e[idx] = eps
            pert = jnp.asarray(flat + e).reshape(arr.shape)
            pert2 = jnp.asarray(flat - e).reshape(arr.shape)
            args_p = (pert, ker) if which == 0 else (inp, pert)
            args_m = (pert2, ker) if which == 0 else (inp, pert2)
            num = (f(*args_p) - f(*args_m)) / (2 * eps)
            np.testing.assert_allclose(np.asarray(grad).ravel()[idx], num,
                                       rtol=2e-2, atol=2e-2)


def test_training_step_through_mec_is_finite():
    """One SGD step of a tiny conv net through conv2d(algorithm='mec')
    (the examples/train_cnn.py path, miniaturized)."""
    from repro.models.layers import conv2d_layer, init_conv2d
    k1, k2 = jax.random.split(jax.random.key(0))
    params = {"c1": init_conv2d(k1, 3, 3, 1, 4),
              "c2": init_conv2d(k2, 3, 3, 4, 4)}
    imgs = _rand((2, 8, 8, 1), 18)

    def loss_fn(p):
        x = jax.nn.relu(conv2d_layer(p["c1"], imgs, stride=2,
                                     algorithm="mec"))
        x = conv2d_layer(p["c2"], x, stride=2, algorithm="mec")
        return jnp.sum(x ** 2)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss))
    leaves = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in leaves)
    assert sum(float(jnp.abs(g).sum()) for g in leaves) > 0
