"""Property tests for the MEC algorithm (paper §3) against direct
convolution, plus the paper's analytic memory claims (Eqs. 2-4)."""
import hypothesis
import hypothesis.strategies as st
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (ConvSpec, direct_conv2d, fft_conv2d, im2col_conv2d,
                        im2col_lower, mec_conv2d, mec_lower, pad_same,
                        vanilla_mec, winograd_conv2d)
from repro.core.memory import (conv_flops, im2col_overhead, mec_overhead,
                               mec_saving)

conv_geoms = st.tuples(
    st.integers(1, 3),        # n
    st.integers(4, 18),       # i_h
    st.integers(4, 18),       # i_w
    st.integers(1, 5),        # i_c
    st.integers(1, 4),        # k_h
    st.integers(1, 4),        # k_w
    st.integers(1, 6),        # k_c
    st.integers(1, 3),        # s_h
    st.integers(1, 3),        # s_w
).filter(lambda g: g[1] >= g[4] and g[2] >= g[5])


def _rand(shape, seed):
    return jnp.asarray(np.random.RandomState(seed).randn(*shape)
                       .astype(np.float32))


@hypothesis.given(conv_geoms, st.sampled_from(["A", "B", "auto"]))
@hypothesis.settings(max_examples=60, deadline=None)
def test_mec_equals_direct(geom, solution):
    n, ih, iw, ic, kh, kw, kc, sh, sw = geom
    inp = _rand((n, ih, iw, ic), 0)
    ker = _rand((kh, kw, ic, kc), 1)
    ref = direct_conv2d(inp, ker, (sh, sw))
    out = mec_conv2d(inp, ker, (sh, sw), solution=solution)
    assert out.shape == ref.shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


@hypothesis.given(conv_geoms)
@hypothesis.settings(max_examples=40, deadline=None)
def test_im2col_equals_direct(geom):
    n, ih, iw, ic, kh, kw, kc, sh, sw = geom
    inp = _rand((n, ih, iw, ic), 2)
    ker = _rand((kh, kw, ic, kc), 3)
    ref = direct_conv2d(inp, ker, (sh, sw))
    out = im2col_conv2d(inp, ker, (sh, sw))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


@hypothesis.given(conv_geoms)
@hypothesis.settings(max_examples=25, deadline=None)
def test_fft_equals_direct(geom):
    n, ih, iw, ic, kh, kw, kc, sh, sw = geom
    inp = _rand((n, ih, iw, ic), 4)
    ker = _rand((kh, kw, ic, kc), 5)
    ref = direct_conv2d(inp, ker, (sh, sw))
    out = fft_conv2d(inp, ker, (sh, sw))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-3, atol=1e-3)


def test_winograd_equals_direct():
    inp = _rand((2, 12, 13, 5), 6)
    ker = _rand((3, 3, 5, 7), 7)
    ref = direct_conv2d(inp, ker, 1)
    out = winograd_conv2d(inp, ker)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_vanilla_mec_fig1():
    """The worked example of Fig. 1/2: 7x7 input, 3x3 kernel, s=1."""
    inp = _rand((7, 7), 8)
    ker = _rand((3, 3), 9)
    ref = direct_conv2d(inp[None, :, :, None], ker[:, :, None, None], 1)
    out = vanilla_mec(inp, ker, 1)
    assert out.shape == (5, 5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref)[0, :, :, 0],
                               rtol=1e-4, atol=1e-4)


@hypothesis.given(conv_geoms)
@hypothesis.settings(max_examples=60, deadline=None)
def test_memory_model_eq4(geom):
    """Eq. 4: R = i_n k_c? exact difference; MEC always <= im2col when
    k_h > s_h, equal-or-larger never otherwise claimed."""
    n, ih, iw, ic, kh, kw, kc, sh, sw = geom
    s = ConvSpec(n, ih, iw, ic, kh, kw, kc, sh, sw)
    r = mec_saving(s)
    # the closed form of Eq. 4 (elements): i_n*i_c*o_w*k_w*(o_h*k_h - i_h)
    closed = n * ic * s.o_w * kw * (s.o_h * kh - ih)
    assert r == closed
    # The paper's factorization (i_h-k_h)(k_h/s_h - 1) implicitly assumes
    # s_h | (i_h - k_h); with floor-division o_h the saving can be slightly
    # negative when rows at the bottom are never visited by the kernel.
    if kh > sh and ih > kh and (ih - kh) % sh == 0:
        assert r > 0        # paper: always saves when kernel rows overlap


@hypothesis.given(conv_geoms)
@hypothesis.settings(max_examples=30, deadline=None)
def test_lowered_sizes_match_actual(geom):
    """The materialized L tensors match Eqs. 2 and 3 exactly."""
    n, ih, iw, ic, kh, kw, kc, sh, sw = geom
    s = ConvSpec(n, ih, iw, ic, kh, kw, kc, sh, sw)
    inp = _rand((n, ih, iw, ic), 10)
    low_mec = mec_lower(inp, kw, sw)
    assert low_mec.size == mec_overhead(s)          # Eq. 3
    low_i2c = im2col_lower(inp, kh, kw, sh, sw)
    assert low_i2c.size == im2col_overhead(s)       # Eq. 2


def test_pad_same_roundtrip():
    inp = _rand((2, 9, 11, 3), 11)
    padded = pad_same(inp, 3, 3)
    out = direct_conv2d(padded, _rand((3, 3, 3, 4), 12), 1)
    assert out.shape == (2, 9, 11, 4)


def test_mec_flops_identical_to_im2col():
    s = ConvSpec(2, 12, 12, 3, 3, 3, 8, 1, 1)
    # paper §3.2: "total number of mult/add operations remains identical"
    assert conv_flops(s) == 2 * 2 * 10 * 10 * 3 * 3 * 3 * 8


@hypothesis.given(conv_geoms)
@hypothesis.settings(max_examples=80, deadline=None)
def test_memory_model_eq4_identity(geom):
    """Eq. 4 three ways (repro.analysis rests on this identity): the
    saving IS the Eq. 2 - Eq. 3 difference, and both equal the paper's
    closed form i_n*i_c*o_w*k_w*(o_h*k_h - i_h) -- element-exact, no
    float arithmetic anywhere in the model."""
    n, ih, iw, ic, kh, kw, kc, sh, sw = geom
    s = ConvSpec(n, ih, iw, ic, kh, kw, kc, sh, sw)
    assert mec_saving(s) == im2col_overhead(s) - mec_overhead(s)
    assert mec_saving(s) == n * ic * s.o_w * kw * (s.o_h * kh - ih)


@hypothesis.given(conv_geoms)
@hypothesis.settings(max_examples=60, deadline=None)
def test_overhead_padding_resolution(geom):
    """algorithm_overhead(padding=...) must size the post-padding
    geometry -- identical to calling the model on padded_spec directly,
    and identical to the VALID value when no padding is added."""
    from repro.core.convspec import padded_spec
    from repro.core.memory import algorithm_overhead, fft_overhead
    n, ih, iw, ic, kh, kw, kc, sh, sw = geom
    s = ConvSpec(n, ih, iw, ic, kh, kw, kc, sh, sw)
    ps = padded_spec(s, "SAME")
    assert ps.i_h >= s.i_h and ps.i_w >= s.i_w
    for alg in ("im2col", "mec", "fft", "winograd", "direct"):
        assert algorithm_overhead(s, alg, padding="SAME") == \
            algorithm_overhead(ps, alg)
        assert algorithm_overhead(s, alg, padding="VALID") == \
            algorithm_overhead(s, alg)
    # the satellite fix: fft spectra are sized on PADDED spatial dims
    # (>= not >: a 1-col pad can vanish in the rfft half-spectrum)
    assert fft_overhead(s, padding="SAME") == fft_overhead(ps)
    if (ps.i_h, ps.i_w) != (s.i_h, s.i_w):
        assert fft_overhead(s, padding="SAME") >= fft_overhead(s)
