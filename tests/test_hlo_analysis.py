"""Direct tests for repro.launch.hlo_analysis: collective-bytes parsing
(async pairs, iota vs explicit replica_groups, tuple-typed -start) and
the no-silent-dtype-default contract of _shape_bytes."""
import pytest

from repro.launch.hlo_analysis import (_shape_bytes, collective_bytes)


def test_shape_bytes_known_dtypes():
    assert _shape_bytes("f32", "8,128") == 8 * 128 * 4
    assert _shape_bytes("bf16", "2,3,4") == 24 * 2
    assert _shape_bytes("pred", "16") == 16
    assert _shape_bytes("c128", "2") == 32
    assert _shape_bytes("f8e4m3fn", "64") == 64
    assert _shape_bytes("f4e2m1fn", "64") == 64      # packed-byte floor
    assert _shape_bytes("token", "") == 0
    assert _shape_bytes("f32", "") == 4              # scalar


def test_shape_bytes_unknown_dtype_raises():
    """The PR-4-era silent 4-byte default is gone: an unknown dtype must
    fail loudly, not mis-count collective/memaudit bytes invisibly."""
    with pytest.raises(ValueError, match="unknown HLO dtype 'f6e3m2fn'"):
        _shape_bytes("f6e3m2fn", "8,8")


def test_collective_bytes_sync_ops_iota_groups():
    hlo = "\n".join([
        "  %ag = f32[8,128]{1,0} all-gather(f32[2,128] %p), "
        "replica_groups=[4,4]<=[16], dimensions={0}",
        "  %ar = f32[4,64]{1,0} all-reduce(f32[4,64] %q), "
        "replica_groups=[2,8]<=[16], to_apply=%add",
        "  %rs = f32[2,128]{1,0} reduce-scatter(f32[8,128] %r), "
        "replica_groups=[4,4]<=[16], dimensions={0}",
    ])
    out = collective_bytes(hlo)
    # all-gather operand = result / group_size
    assert out["all-gather"] == 8 * 128 * 4 // 4
    # all-reduce moves result-sized operands
    assert out["all-reduce"] == 4 * 64 * 4
    # reduce-scatter operand = result * group_size
    assert out["reduce-scatter"] == 2 * 128 * 4 * 4
    assert out["count"] == 3
    assert out["total"] == sum(
        out[k] for k in ("all-gather", "all-reduce", "reduce-scatter"))


def test_collective_bytes_explicit_groups_match_iota():
    """{{0,1,2,3}} and [4,4]<=[16] describe the same group size — the
    accounting must not depend on which form the dump printed."""
    iota = ("  %ag = f32[8,128]{1,0} all-gather(f32[2,128] %p), "
            "replica_groups=[4,4]<=[16]")
    expl = ("  %ag = f32[8,128]{1,0} all-gather(f32[2,128] %p), "
            "replica_groups={{0,1,2,3},{4,5,6,7}}")
    assert collective_bytes(iota) == collective_bytes(expl)


def test_collective_bytes_async_pair_counted_once():
    """-start/-done pairs are one logical collective: bytes and count
    come from the -start line only."""
    hlo = "\n".join([
        "  %ags = (f32[2,128]{1,0}, f32[8,128]{1,0}) "
        "all-gather-start(f32[2,128] %p), replica_groups=[4,4]<=[16]",
        "  %agd = f32[8,128]{1,0} all-gather-done("
        "(f32[2,128], f32[8,128]) %ags)",
    ])
    out = collective_bytes(hlo)
    assert out["count"] == 1
    # tuple-typed -start: the RESULT half of (operand, result) is what
    # the wire moves — 8*128*4 / group 4
    assert out["all-gather"] == 8 * 128 * 4 // 4


def test_collective_bytes_permute_and_all_to_all():
    hlo = "\n".join([
        "  %cp = bf16[4,256]{1,0} collective-permute(bf16[4,256] %p), "
        "source_target_pairs={{0,1},{1,0}}",
        "  %a2a = f32[16,16]{1,0} all-to-all(f32[16,16] %q), "
        "replica_groups={{0,1,2,3}}, dimensions={0}",
    ])
    out = collective_bytes(hlo)
    assert out["collective-permute"] == 4 * 256 * 2
    assert out["all-to-all"] == 16 * 16 * 4
    assert out["count"] == 2


def test_collective_bytes_empty_and_non_collective_lines():
    hlo = "  %m = f32[8,8]{1,0} multiply(f32[8,8] %a, f32[8,8] %b)"
    out = collective_bytes(hlo)
    assert out["count"] == 0 and out["total"] == 0
