"""Continuous batching: slot interleaving must be token-exact vs serving
each request alone through the standard prefill/decode path."""
import jax
import jax.numpy as jnp

from repro.configs.archs import smoke_config
from repro.models import serve
from repro.models.lm import LM
from repro.serving.scheduler import ContinuousBatcher, Request


def _solo(model, params, prompt, n, max_len=64):
    logits, cache = serve.prefill(model, params, {"tokens": prompt[None]},
                                  max_len=max_len)
    out = [int(jnp.argmax(logits[0]))]
    tok = jnp.asarray([[out[-1]]], jnp.int32)
    for _ in range(n - 1):
        logits, cache = serve.decode_step(model, params, cache, tok)
        out.append(int(jnp.argmax(logits[0])))
        tok = jnp.asarray([[out[-1]]], jnp.int32)
    return out


def test_continuous_batching_token_exact():
    cfg = smoke_config("yi-6b")
    model = LM(cfg)
    params = model.init(jax.random.key(0))
    prompts = [jax.random.randint(jax.random.key(i), (5 + 3 * i,), 0,
                                  cfg.vocab, jnp.int32) for i in range(3)]
    refs = [_solo(model, params, p, 6) for p in prompts]

    # 3 requests through 2 slots forces waiting + slot recycling
    batcher = ContinuousBatcher(model, params, n_slots=2, max_len=64)
    for i, p in enumerate(prompts):
        batcher.submit(Request(rid=i, prompt=p, max_new_tokens=6))
    done = batcher.run_until_done()
    assert len(done) == 3
    for req in done:
        assert req.out == refs[req.rid], (req.rid, req.out, refs[req.rid])


def test_eos_frees_slot_early():
    cfg = smoke_config("yi-6b")
    model = LM(cfg)
    params = model.init(jax.random.key(0))
    prompt = jax.random.randint(jax.random.key(9), (6,), 0, cfg.vocab,
                                jnp.int32)
    ref = _solo(model, params, prompt, 8)
    eos = ref[2]     # force early stop no later than the 3rd token
    batcher = ContinuousBatcher(model, params, n_slots=1, max_len=64)
    batcher.submit(Request(rid=0, prompt=prompt, max_new_tokens=8,
                           eos_id=eos))
    done = batcher.run_until_done()
    # generation stops at the FIRST eos in the stream (the untrained smoke
    # model may emit it before position 2), including a prefill-step eos
    assert done[0].out == ref[:ref.index(eos) + 1]
    # the slot was recycled
    assert int(batcher.cache["lens"][0]) == -1
