"""Fitted-costmodel calibration (repro.plan.calibrate, DESIGN.md §10).

Covers the store round-trip + PlanCache-style silent degradation, the
fit math (cell medians, memory geomeans, lstsq constants), the
cell-evidence pick flip with its noise margin, ambient resolution via
$REPRO_CALIBRATION, the calibrate CLI (--fit / --check / --report), and
the committed baseline's headline claim: s5x5 flips to ``direct``.
"""
import dataclasses
import json
import pathlib

import pytest

from repro.core.convspec import ConvSpec
from repro.launch.costmodel import (conv2d_algorithm_costs,
                                    pick_conv2d_algorithm)
from repro.plan.calibrate import (CALIBRATION_ENV, Calibration,
                                  CalibrationStore, calibration_info,
                                  calibration_path, check_calibration,
                                  calibrate_main, current_calibration,
                                  ingest_autotune, ingest_memaudit,
                                  parse_spec_key, render_report,
                                  reset_calibration_cache,
                                  resolve_calibration)
from repro.plan.convplan import spec_key

ROOT = pathlib.Path(__file__).resolve().parents[1]

# The smoke s5x5 cell: analytic Eq. 2-3 says mec, the committed autotune
# timings say direct wins 2.1x.
S5X5 = ConvSpec(1, 16, 16, 3, 5, 5, 8, 2, 2)


@pytest.fixture
def fresh_store(tmp_path, monkeypatch):
    """Isolated store dir + no ambient-file override."""
    monkeypatch.setenv("REPRO_PLAN_CACHE_DIR", str(tmp_path))
    monkeypatch.delenv(CALIBRATION_ENV, raising=False)
    reset_calibration_cache()
    yield tmp_path
    reset_calibration_cache()


def _evidence(spec=S5X5, mec_us=453.0, direct_us=212.0):
    calib = Calibration.for_current_env()
    calib.add_time(spec, "float32", "mec", mec_us, solution="A")
    calib.add_time(spec, "float32", "direct", direct_us)
    return calib


# ------------------------------------------------------------------- keys

def test_parse_spec_key_roundtrips():
    for spec in (S5X5, ConvSpec(2, 7, 9, 3, 3, 2, 5, 1, 2)):
        assert parse_spec_key(spec_key(spec)) == spec


# ------------------------------------------------------------------ store

def test_store_flush_load_roundtrip(fresh_store):
    store = CalibrationStore()
    store.add_time(S5X5, "float32", "mec", 453.0, solution="A")
    store.add_memory(S5X5, "float32", "mec", 1.39)
    store.flush()
    assert store.io_errors == 0
    assert calibration_path().exists()
    disk = CalibrationStore().load()
    assert disk.cell_times(S5X5)["mec"] == 453.0
    assert disk.mem_ratio_for("mec") == pytest.approx(1.39)
    # flush merges rather than clobbers: a second writer's samples append
    other = CalibrationStore()
    other.add_time(S5X5, "float32", "direct", 212.0)
    other.flush()
    merged = CalibrationStore().load()
    assert set(merged.cell_times(S5X5)) == {"mec", "direct"}


def test_store_corrupt_file_degrades_and_counts(fresh_store):
    calibration_path().parent.mkdir(parents=True, exist_ok=True)
    calibration_path().write_text("{not json")
    store = CalibrationStore()
    assert store.load().is_empty()
    assert store.io_errors == 1
    assert current_calibration() is None      # ambient degrades silently


def test_store_fingerprint_mismatch_is_invalidation(fresh_store):
    calib = _evidence()
    doc = calib.to_dict(with_fit=False)
    doc["fingerprint"] = "0" * 16
    calibration_path().parent.mkdir(parents=True, exist_ok=True)
    calibration_path().write_text(json.dumps(doc))
    store = CalibrationStore()
    assert store.load().is_empty()            # stale env: ignored...
    assert store.io_errors == 0               # ...but not an I/O error
    assert current_calibration() is None


def test_sample_cap_bounds_the_file(fresh_store):
    from repro.plan.calibrate import MAX_SAMPLES_PER_KEY
    calib = Calibration.for_current_env()
    for i in range(3 * MAX_SAMPLES_PER_KEY):
        calib.add_time(S5X5, "float32", "mec", float(i))
    (key,) = calib.time_samples
    assert len(calib.time_samples[key]) == MAX_SAMPLES_PER_KEY


# -------------------------------------------------------------------- fit

def test_time_cells_take_min_over_variants_of_medians():
    calib = Calibration.for_current_env()
    for us in (100.0, 120.0, 110.0):          # median 110
        calib.add_time(S5X5, "float32", "mec", us, solution="A")
    calib.add_time(S5X5, "float32", "mec", 90.0, solution="B")
    assert calib.cell_times(S5X5)["mec"] == 90.0


def test_mem_ratios_geomean_and_default():
    calib = Calibration.for_current_env()
    calib.add_memory(S5X5, "float32", "mec", 1.0)
    calib.add_memory(S5X5, "float32", "mec", 4.0)
    assert calib.mem_ratio_for("mec") == pytest.approx(2.0)
    assert calib.mem_ratio_for("im2col") == 1.0   # unfitted: paper constant


def test_time_constants_recover_a_planted_linear_model():
    calib = Calibration.for_current_env()
    from repro.plan.calibrate import _features
    specs = [ConvSpec(1, h, h, 3, 3, 3, 8, 1, 1) for h in (8, 12, 16, 24)]
    for spec in specs:
        flops, overhead = _features(spec, "mec")
        calib.add_time(spec, "float32", "mec",
                       5.0 + 2e-6 * flops + 3e-5 * overhead)
    c = calib.time_constants()["mec"]
    assert c["n"] == len(specs)
    assert c["c0"] == pytest.approx(5.0, rel=1e-3)
    assert c["c_flops"] == pytest.approx(2e-6, rel=1e-3)
    assert c["c_overhead"] == pytest.approx(3e-5, rel=1e-3)
    est = calib.time_estimate(specs[0], "mec")
    assert est == pytest.approx(calib.cell_times(specs[0])["mec"], rel=1e-3)
    assert calib.time_estimate(specs[0], "fft") is None


# ------------------------------------------------------------------ picks

def test_cell_evidence_flips_the_analytic_pick():
    assert pick_conv2d_algorithm(S5X5, "cpu", calibration=None) == "mec"
    calib = _evidence()
    assert pick_conv2d_algorithm(S5X5, "cpu", calibration=calib) == "direct"
    d = calib.decisions()[spec_key(S5X5)]
    assert d == {"uncalibrated": "mec", "calibrated": "direct"}


def test_sub_margin_evidence_keeps_the_paper_rule():
    # a 1% "win" for direct is timer jitter: the analytic pick holds
    calib = _evidence(mec_us=101.0, direct_us=100.0)
    assert pick_conv2d_algorithm(S5X5, "cpu", calibration=calib) == "mec"


def test_no_evidence_cells_keep_the_paper_rule():
    calib = _evidence()
    other = ConvSpec(1, 14, 14, 4, 3, 3, 8, 1, 1)    # no samples
    assert pick_conv2d_algorithm(other, "cpu", calibration=calib) == \
        pick_conv2d_algorithm(other, "cpu", calibration=None)
    # evidence on the analytic pick alone (no rival) cannot flip either
    solo = Calibration.for_current_env()
    solo.add_time(S5X5, "float32", "mec", 453.0)
    assert pick_conv2d_algorithm(S5X5, "cpu", calibration=solo) == "mec"


def test_calibration_never_crosses_backends():
    calib = _evidence()
    assert calib.backend == "cpu"
    assert resolve_calibration(calib, "tpu") is None
    assert pick_conv2d_algorithm(S5X5, "tpu", calibration=calib) \
        == pick_conv2d_algorithm(S5X5, "tpu", calibration=None)


def test_costmodel_carries_calibrated_columns():
    calib = _evidence()
    calib.add_memory(S5X5, "float32", "mec", 1.39)
    costs = conv2d_algorithm_costs(S5X5, calibration=calib)
    mec = costs["mec"]
    assert mec["calibrated_overhead_elems"] == \
        pytest.approx(mec["overhead_elems"] * 1.39)
    assert mec["measured_us"] == pytest.approx(453.0)
    # im2col unfitted: ratio 1.0, no measurement
    assert costs["im2col"]["calibrated_overhead_elems"] == \
        costs["im2col"]["overhead_elems"]
    assert costs["im2col"]["measured_us"] is None
    # uncalibrated call shape is unchanged (no surprise columns)
    assert "calibrated_overhead_elems" not in \
        conv2d_algorithm_costs(S5X5)["mec"]


# ---------------------------------------------------------------- ambient

def test_ambient_env_file_and_info(fresh_store, tmp_path, monkeypatch):
    path = tmp_path / "committed.json"
    path.write_text(json.dumps(_evidence().to_dict()))
    monkeypatch.setenv(CALIBRATION_ENV, str(path))
    reset_calibration_cache()
    ambient = current_calibration()
    assert ambient is not None and ambient.cell_times(S5X5)
    # "ambient" is the planner default: the flip flows through
    assert pick_conv2d_algorithm(S5X5, "cpu") == "direct"
    info = calibration_info()
    assert info["active"] and info["source"] == f"env:{path}"
    assert info["cells"] == 1
    # a backend-mismatched committed file never applies
    doc = _evidence().to_dict()
    doc["backend"] = "tpu"
    path.write_text(json.dumps(doc))
    reset_calibration_cache()
    assert current_calibration() is None
    assert calibration_info()["active"] is False


def test_conftest_pins_ambient_off_by_default(fresh_store):
    # With no env override and an empty store dir the planner is
    # uncalibrated — the hermeticity every analytic test relies on.
    assert current_calibration() is None
    assert pick_conv2d_algorithm(S5X5, "cpu") == "mec"


# -------------------------------------------------------------------- CLI

def _report_docs(tmp_path):
    autotune = {"results": [{
        "scenario": "s5x5", "dtype": "float32",
        "run_spec": dataclasses.asdict(S5X5),
        "candidate_us": {"mec": 453.0, "direct": 212.0},
        "candidate_stats": {"mec": {"solution": "A", "w_blk": None}},
        "tuning": {"knob": "solution", "algorithm": "mec", "default": "A",
                   "picked": "B",
                   "trials": {"A": {"us_median": 453.0},
                              "B": {"us_median": 440.0}}},
    }]}
    memaudit = {"results": [
        {"policy": "gated", "ratio": 1.39, "algorithm": "mecA",
         "dtype": "float32", "spec": dataclasses.asdict(S5X5)},
        {"policy": "recorded", "ratio": 9.0, "algorithm": "mec_fused",
         "dtype": "float32", "spec": dataclasses.asdict(S5X5)},
    ]}
    at, ma = tmp_path / "at.json", tmp_path / "ma.json"
    at.write_text(json.dumps(autotune))
    ma.write_text(json.dumps(memaudit))
    return at, ma


def test_ingest_reports_and_recorded_cells_never_train():
    calib = Calibration.for_current_env()
    import tempfile
    with tempfile.TemporaryDirectory() as d:
        at, ma = _report_docs(pathlib.Path(d))
        assert ingest_autotune(calib, json.loads(at.read_text())) == 4
        assert ingest_memaudit(calib, json.loads(ma.read_text())) == 1
    assert calib.cell_times(S5X5) == {"mec": 440.0, "direct": 212.0}
    assert calib.mem_ratio_for("mec") == pytest.approx(1.39)
    assert calib.mem_ratio_for("mec_fused") == 1.0   # recorded-only: unfit


def test_cli_fit_check_report_cycle(fresh_store, tmp_path, capsys):
    at, ma = _report_docs(tmp_path)
    out = tmp_path / "calibration.json"
    assert calibrate_main(["--fit", "--autotune", str(at),
                           "--memaudit", str(ma), "--out", str(out)]) == 0
    doc = json.loads(out.read_text())
    assert doc["fitted"]["decisions"][spec_key(S5X5)] == \
        {"uncalibrated": "mec", "calibrated": "direct"}
    assert calibrate_main(["--check", "--baseline", str(out)]) == 0
    assert calibrate_main(["--report", "--baseline", str(out)]) == 0
    text = capsys.readouterr().out
    assert "<-- flip" in text and "calibrated=direct" in text


def test_cli_check_catches_tampered_fit(fresh_store, tmp_path):
    at, ma = _report_docs(tmp_path)
    out = tmp_path / "calibration.json"
    calibrate_main(["--fit", "--autotune", str(at), "--memaudit", str(ma),
                    "--out", str(out)])
    doc = json.loads(out.read_text())
    doc["fitted"]["decisions"][spec_key(S5X5)]["calibrated"] = "mec"
    out.write_text(json.dumps(doc))
    assert calibrate_main(["--check", "--baseline", str(out)]) == 1
    # a coefficient nudge outside rtol also fails
    doc = json.loads(out.read_text())
    doc["fitted"]["decisions"][spec_key(S5X5)]["calibrated"] = "direct"
    doc["fitted"]["mem_ratio"]["mec"]["ratio"] *= 1.2
    out.write_text(json.dumps(doc))
    assert calibrate_main(["--check", "--baseline", str(out)]) == 1
    assert calibrate_main(["--check", "--rtol", "0.5",
                           "--baseline", str(out)]) == 0
    assert calibrate_main(["--fit"]) == 2     # empty store: loud usage error
    assert calibrate_main(
        ["--check", "--baseline", str(tmp_path / "absent.json")]) == 2


def test_check_requires_a_fitted_block():
    doc = _evidence().to_dict(with_fit=False)
    assert any("fitted" in f for f in check_calibration(doc))
    assert check_calibration(_evidence().to_dict()) == []


def test_render_report_lists_every_cell():
    calib = _evidence()
    text = "\n".join(render_report(calib))
    assert spec_key(S5X5) in text
    assert "paper=mec calibrated=direct" in text


# ------------------------------------------------------- committed baseline

def test_committed_baseline_is_self_consistent_and_flips_s5x5():
    doc = json.loads(
        (ROOT / "benchmarks/baselines/calibration.json").read_text())
    assert doc["backend"] == "cpu"
    assert check_calibration(doc) == []
    decisions = doc["fitted"]["decisions"]
    s5 = decisions[spec_key(S5X5)]
    assert s5 == {"uncalibrated": "mec", "calibrated": "direct"}
    # no other smoke cell flips: calibration refines, not rewrites
    for cell, d in decisions.items():
        if cell != spec_key(S5X5):
            assert d["uncalibrated"] == d["calibrated"], cell
