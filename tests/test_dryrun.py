"""Dry-run machinery: HLO collective parser units + one real 512-device
lower/compile in a subprocess (the full 64-cell sweep is run via
``python -m repro.launch.dryrun --all --both-meshes``; its outputs live in
results/dryrun/ and are checked here if present)."""
import json
import os
import pathlib
import subprocess
import sys

import pytest

from repro.launch.hlo_analysis import (collective_bytes, roofline_terms,
                                       PEAK_FLOPS, HBM_BW, ICI_BW)

REPO = pathlib.Path(__file__).resolve().parents[1]


def test_collective_parser_counts_bytes():
    hlo = """
  %ar = f32[128,256]{1,0} all-reduce(%x), replica_groups=[16,16]<=[16,16]T(1,0), to_apply=%sum
  %ag = bf16[16,1024]{1,0} all-gather(%y), replica_groups=[16,16]<=[256], dimensions={1}
  %rs = f32[8,64]{1,0} reduce-scatter(%z), replica_groups=[4,4]<=[16], dimensions={1}
  %aa = bf16[384,54,7168]{2,1,0} all-to-all(%w), replica_groups=[32,16]<=[512]
  %cp = f32[32]{0} collective-permute(%v), source_target_pairs={{0,1}}
  %nn = f32[4]{0} add(%a, %b)
"""
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 128 * 256 * 4
    assert out["all-gather"] == 16 * 1024 * 2 // 16
    assert out["reduce-scatter"] == 8 * 64 * 4 * 4
    assert out["all-to-all"] == 384 * 54 * 7168 * 2
    assert out["collective-permute"] == 32 * 4
    assert out["count"] == 5


def test_collective_parser_async_pairs_counted_once():
    hlo = """
  %ags = (f32[8,16]{1,0}, f32[8,64]{1,0}) all-gather-start(%x), replica_groups=[4,4]<=[16], dimensions={1}
  %agd = f32[8,64]{1,0} all-gather-done(%ags)
"""
    out = collective_bytes(hlo)
    assert out["count"] == 1
    assert out["all-gather"] == 8 * 64 * 4 // 4


def test_roofline_dominant_term():
    t = roofline_terms(flops=197e12, hbm_bytes=0, coll_bytes=0, n_chips=1)
    assert abs(t["t_compute_s"] - 1.0) < 1e-9
    assert t["dominant"] == "compute"
    t = roofline_terms(flops=0, hbm_bytes=819e9, coll_bytes=819e9, n_chips=1)
    assert t["dominant"] == "collective"   # ICI is ~16x slower than HBM


@pytest.mark.slow
def test_dryrun_one_cell_subprocess(tmp_path):
    """Real .lower().compile() on the 16x16 production mesh (512 forced
    host devices) for the smallest arch."""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "whisper-tiny", "--shape", "train_4k", "--out", str(tmp_path)],
        env=env, cwd=REPO, capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(
        (tmp_path / "whisper-tiny__train_4k__pod.json").read_text())
    assert res["n_chips"] == 256
    assert res["per_device"]["flops"] > 0
    assert res["per_device"]["collectives"]["total"] > 0
    assert res["roofline"]["dominant"] in ("compute", "memory", "collective")


def test_sweep_results_complete_if_present():
    """When the full sweep has been run, every applicable cell must have
    succeeded on both meshes (this is the multi-pod deliverable gate)."""
    rdir = REPO / "results" / "dryrun"
    if not rdir.exists() or len(list(rdir.glob("*.json"))) < 60:
        pytest.skip("full dry-run sweep not present")
    from repro.configs.archs import ARCHS
    from repro.configs.shapes import SHAPES, cell_applicable
    missing = []
    for arch in ARCHS:
        for shape in SHAPES:
            if not cell_applicable(arch, shape):
                continue
            for mesh in ("pod", "multipod"):
                tag = f"{arch}__{shape}__{mesh}"
                if not (rdir / f"{tag}.json").exists():
                    missing.append(tag)
    assert not missing, f"missing dry-run cells: {missing}"
    # sanity: every result has positive flops and a dominant term
    for f in rdir.glob("*.json"):
        res = json.loads(f.read_text())
        assert res["per_device"]["flops"] > 0, f.name
        assert res["roofline"]["dominant"] in ("compute", "memory",
                                               "collective")


@pytest.mark.slow
def test_dryrun_conv_cells_subprocess(tmp_path):
    """Real .lower().compile() of sharded_conv2d (fwd + grad) on the
    multi-pod 512-chip mesh: cells with a spatial component (the 1-D
    spatial cell AND the composite batch x spatial cell) must show halo
    traffic (collective-permute) and every cell must carry the analytic
    per-device/halo fields from the partition cost model."""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--conv", "all",
         "--multi-pod", "--out", str(tmp_path)],
        env=env, cwd=REPO, capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, out.stderr[-3000:]
    for name, partition in (("conv_channel", "channel"),
                            ("conv_spatial", "spatial"),
                            ("conv_batch_spatial", "batch+spatial")):
        res = json.loads((tmp_path / f"{name}__multipod.json").read_text())
        assert res["n_chips"] == 512
        assert res["partition"] == partition
        assert res["analytic"]["viable"] is True
        assert res["analytic"]["flops_per_device"] > 0
        if "spatial" in partition:
            assert res["analytic"]["halo_bytes_per_device"] > 0
            assert res["per_device"]["collectives"].get(
                "collective-permute", 0) > 0
        else:
            assert res["analytic"]["halo_bytes_per_device"] == 0
    # the composite cell shards input on (i_n, i_h) over two mesh axes
    res = json.loads(
        (tmp_path / "conv_batch_spatial__multipod.json").read_text())
    assert res["axis"] == ["pod", "model"]
    assert res["n_axis"] == [2, 16]
    assert res["analytic"]["n_dev"] == 32
    assert res["analytic"]["n_dev_axes"] == [2, 16]
