"""Warm-plan conv serving (DESIGN.md §9).

MEC's per-shape lowering decision (paper Table 2: no single algorithm
wins everywhere) only pays off in production if its setup cost
amortizes across requests — the Indirect-Convolution-paper argument for
plan/indirection reuse.  The planner/executor split (DESIGN.md §7)
produced a frozen, cacheable :class:`~repro.plan.ConvPlan`; this module
cashes it in under live traffic:

* :class:`ShapeClass` / :meth:`ConvService.bucket` — a *bounded* set of
  padded input shape classes.  Variable ``(n, h, w)`` requests map
  deterministically to the smallest class that contains them (padding
  never shrinks a dimension); one :class:`~repro.plan.ConvPlan` — one
  ``cache_key()`` — per class, not per request shape.
* :meth:`ConvService.warm` — at startup, resolve the plan for every
  class through the persistent plan cache (``plan_conv2d(mode=
  "cached")``) and AOT-compile the class executor.  Warmup is strictly
  best-effort: an unreadable/corrupt/read-only ``$REPRO_PLAN_CACHE_DIR``
  degrades to analytic planning with a warning *counter* (surfaced in
  the serve report), never a crash — the same stance the plan cache
  itself takes on reads.
* :meth:`ConvService.execute` — bucket, zero-pad into the class, run the
  frozen plan through the compiled executor, slice the request's true
  output back out.  A class the service was never warmed for resolves
  and compiles lazily (the measured "cold" path of the bench ``serve``
  suite).

Padding must be ``"VALID"``, an int, or explicit ``((lo, hi), (lo,
hi))`` — ``"SAME"`` derives its pad split from the input size, so a
request and its padded class would disagree on window alignment and the
class result could not be sliced back exactly.  With size-independent
pads the slice IS exact: every output element the request needs reads
only rows/cols that hold identical values in the padded class input
(real data, then zeros either way).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.conv_api import Padding, apply_padding, conv2d
from repro.core.convspec import ConvSpec, normalize_stride

__all__ = [
    "ShapeClass", "ConvService", "WarmupReport", "parse_shape_classes",
    "fit_prefix", "whisper_frontend_service", "patch_embed_service",
]


@dataclasses.dataclass(frozen=True, order=True)
class ShapeClass:
    """One padded input class: requests with ``n <= n_, h <= h_, w <= w_``
    are zero-padded up to exactly this shape and share one ConvPlan.
    Ordering is (n, h, w) — the bucketing tie-break."""

    n: int
    h: int
    w: int

    def contains(self, n: int, h: int, w: int) -> bool:
        return n <= self.n and h <= self.h and w <= self.w

    def tag(self) -> str:
        return f"{self.n}x{self.h}x{self.w}"


def parse_shape_classes(text: str) -> Tuple[ShapeClass, ...]:
    """``"1x32x32,4x64x64"`` -> ShapeClass tuple (the ``--shape-classes``
    flag format of ``launch/serve`` and ``python -m repro.serving``)."""
    classes = []
    for part in text.split(","):
        part = part.strip()
        if not part:
            continue
        dims = part.split("x")
        if len(dims) != 3:
            raise ValueError(f"shape class {part!r} is not NxHxW")
        classes.append(ShapeClass(*(int(d) for d in dims)))
    if not classes:
        raise ValueError(f"no shape classes in {text!r}")
    return tuple(classes)


@dataclasses.dataclass
class WarmupReport:
    """What :meth:`ConvService.warm` did — the serve report and the
    ``--warmup-report`` CLI both render from this."""

    classes: List[ShapeClass] = dataclasses.field(default_factory=list)
    plans: Dict[ShapeClass, "object"] = dataclasses.field(
        default_factory=dict)
    warnings: List[str] = dataclasses.field(default_factory=list)
    plan_cache_io_errors: int = 0
    warm_seconds: float = 0.0

    @property
    def warning_count(self) -> int:
        return len(self.warnings)

    def summary(self) -> str:
        return (f"warmed {len(self.plans)}/{len(self.classes)} shape "
                f"class(es) in {self.warm_seconds:.2f}s; "
                f"{self.warning_count} warning(s), "
                f"{self.plan_cache_io_errors} plan-cache I/O error(s)")

    def render(self) -> str:
        lines = [self.summary()]
        for w in self.warnings:
            lines.append(f"  warning: {w}")
        for cls in sorted(self.plans):
            plan = self.plans[cls]
            lines.append(f"-- class {cls.tag()} --")
            lines.extend("  " + ln for ln in plan.explain().splitlines())
        return "\n".join(lines)


class ConvService:
    """One convolution served over a bounded set of padded shape classes.

    kernel: HWIO weights (a concrete array — the service owns it).
    stride/padding: fixed geometry every class shares; padding must be
    size-independent (VALID / int / explicit pair), see module docstring.
    classes: the bounded shape-class set ((n, h, w) tuples or
    :class:`ShapeClass`), each of which must admit at least one output
    window.  plan_mode: policy for :func:`repro.plan.plan_conv2d` at
    warmup ("cached" persists decisions across restarts).
    """

    def __init__(self, kernel: jnp.ndarray, *, stride=1,
                 padding: Padding = "VALID",
                 classes: Sequence[Union[ShapeClass, Tuple[int, int, int]]],
                 plan_mode: str = "cached",
                 interpret: Optional[bool] = None):
        if isinstance(padding, str) and padding.upper() == "SAME":
            raise ValueError(
                "ConvService cannot serve SAME padding: its pad split "
                "depends on the input size, so a request and its padded "
                "class would disagree; pass the explicit ((lo, hi), "
                "(lo, hi)) pads instead")
        self.kernel = kernel
        self.stride = normalize_stride(stride)
        self.padding = padding
        self.plan_mode = plan_mode
        self.interpret = interpret
        self.dtype = jnp.dtype(kernel.dtype).name
        norm = []
        for c in classes:
            cls = c if isinstance(c, ShapeClass) else ShapeClass(*c)
            if min(cls.n, cls.h, cls.w) < 1:
                raise ValueError(f"shape class {cls} has a non-positive "
                                 "dimension")
            norm.append(cls)
        # Sorted ascending: bucket() takes the FIRST containing class, so
        # "smallest wins" and the map is deterministic.  Duplicates would
        # make "exactly one class" ambiguous.
        self.classes: Tuple[ShapeClass, ...] = tuple(sorted(set(norm)))
        if len(self.classes) != len(norm):
            raise ValueError(f"duplicate shape classes in {classes!r}")
        for cls in self.classes:
            self.class_spec(cls).validate()   # every class must be servable
        self._plans: Dict[ShapeClass, object] = {}
        self._compiled: Dict[ShapeClass, object] = {}
        self._out_shapes: Dict[Tuple[int, int, int], Tuple[int, ...]] = {}
        self.warmup = WarmupReport(classes=list(self.classes))

    # ------------------------------------------------------------ bucketing

    def bucket(self, shape: Sequence[int]) -> ShapeClass:
        """The one class serving this request shape: the smallest (by
        (n, h, w) order) class containing it.  Total over every request
        the bounded set admits; anything larger is a loud error —
        serving must never silently grow a class."""
        if len(shape) == 4:
            n, h, w, c = shape
            if c != self.kernel.shape[2]:
                raise ValueError(
                    f"request has {c} channels; this service convolves "
                    f"{self.kernel.shape[2]}")
        elif len(shape) == 3:
            n, h, w = shape
        else:
            raise ValueError(f"request shape {tuple(shape)!r} is not "
                             "(n, h, w[, c])")
        if min(n, h, w) < 1:
            raise ValueError(f"request shape {tuple(shape)!r} has a "
                             "non-positive dimension")
        for cls in self.classes:
            if cls.contains(n, h, w):
                return cls
        raise ValueError(
            f"request {n}x{h}x{w} fits no shape class "
            f"{[c.tag() for c in self.classes]}; add a class or shrink "
            "the request")

    def class_spec(self, cls: ShapeClass) -> ConvSpec:
        """The post-padding ConvSpec all requests of a class execute."""
        k_h, k_w = self.kernel.shape[0], self.kernel.shape[1]
        s_h, s_w = self.stride
        x = jax.eval_shape(
            lambda a: apply_padding(a, k_h, k_w, s_h, s_w, self.padding),
            jax.ShapeDtypeStruct((cls.n, cls.h, cls.w, self.kernel.shape[2]),
                                 self.dtype))
        return ConvSpec(x.shape[0], x.shape[1], x.shape[2], x.shape[3],
                        k_h, k_w, self.kernel.shape[3], s_h, s_w)

    def request_out_shape(self, shape: Sequence[int]) -> Tuple[int, ...]:
        """The request's own output shape — what execute() slices back.
        Memoized: eval_shape is a trace, too slow for the request path."""
        cached = self._out_shapes.get((shape[0], shape[1], shape[2]))
        if cached is not None:
            return cached
        n, h, w = shape[0], shape[1], shape[2]
        k_h, k_w = self.kernel.shape[0], self.kernel.shape[1]
        s_h, s_w = self.stride
        x = jax.eval_shape(
            lambda a: apply_padding(a, k_h, k_w, s_h, s_w, self.padding),
            jax.ShapeDtypeStruct((n, h, w, self.kernel.shape[2]),
                                 self.dtype))
        spec = ConvSpec(x.shape[0], x.shape[1], x.shape[2], x.shape[3],
                        k_h, k_w, self.kernel.shape[3], s_h, s_w)
        out = tuple(spec.out_shape)
        self._out_shapes[(n, h, w)] = out
        return out

    # -------------------------------------------------------------- warmup

    def warm(self) -> WarmupReport:
        """Resolve every class's ConvPlan through the plan cache and
        AOT-compile the class executors.  Best-effort: a class whose
        cached resolution fails falls back to an analytic plan; a class
        that cannot be planned at all is recorded as a warning and
        served lazily — warmup never raises for cache trouble."""
        from repro.plan.cache import global_plan_cache
        t0 = time.perf_counter()
        cache = global_plan_cache()
        io_before = cache.io_errors
        for cls in self.classes:
            if cls in self._compiled:
                continue
            try:
                plan = self._resolve_plan(cls)
                self._compiled[cls] = self._compile(cls, plan)
            except Exception as e:  # degraded, not down (DESIGN.md §9)
                self.warmup.warnings.append(
                    f"class {cls.tag()}: {type(e).__name__}: {e}")
                continue
            self._plans[cls] = plan
            self.warmup.plans[cls] = plan
        self.warmup.plan_cache_io_errors = cache.io_errors - io_before
        self.warmup.warm_seconds = time.perf_counter() - t0
        return self.warmup

    def _resolve_plan(self, cls: ShapeClass):
        from repro.plan import plan_conv2d
        spec = self.class_spec(cls)
        try:
            return plan_conv2d(spec, dtype=self.dtype, mode=self.plan_mode,
                               partition="none")
        except Exception as e:
            if self.plan_mode == "analytic":
                raise
            # The cached policy's failure modes (a poisoned cache object,
            # a cache dir that is actually a file, ...) must not take the
            # service down — replan analytically and count the warning.
            self.warmup.warnings.append(
                f"class {cls.tag()}: {self.plan_mode!r} planning failed "
                f"({type(e).__name__}: {e}); fell back to analytic")
            return plan_conv2d(spec, dtype=self.dtype, mode="analytic",
                               partition="none")

    def _compile(self, cls: ShapeClass, plan):
        # A jitted callable — NOT ``.lower().compile()`` — so steady-state
        # requests ride jit's C++ dispatch cache (an AOT ``Compiled``
        # object dispatches through a slower Python path on every call).
        # One throwaway execution here pays the compile, which is the
        # whole point of warming.
        fn = jax.jit(lambda x, k, _p=plan: conv2d(
            x, k, stride=self.stride, padding=self.padding, plan=_p,
            interpret=self.interpret))
        x = jnp.zeros((cls.n, cls.h, cls.w, self.kernel.shape[2]),
                      self.dtype)
        jax.block_until_ready(fn(x, self.kernel))
        return fn

    @property
    def plans(self) -> Dict[ShapeClass, object]:
        return dict(self._plans)

    # ------------------------------------------------------------ execution

    def pad_to_class(self, x: jnp.ndarray, cls: ShapeClass) -> jnp.ndarray:
        """Zero-pad a request into its class shape (bottom/right/batch
        growth only — bucket() guarantees no dimension shrinks)."""
        n, h, w = x.shape[0], x.shape[1], x.shape[2]
        return jnp.pad(x, ((0, cls.n - n), (0, cls.h - h),
                           (0, cls.w - w), (0, 0)))

    def execute(self, x: jnp.ndarray) -> jnp.ndarray:
        """Serve one request: bucket -> pad -> frozen-plan executor ->
        slice the request's true output back out."""
        if x.dtype != jnp.dtype(self.dtype):
            raise ValueError(f"request dtype {x.dtype} != service dtype "
                             f"{self.dtype}")
        cls = self.bucket(x.shape)
        compiled = self._compiled.get(cls)
        if compiled is None:           # cold start for this class
            plan = self._resolve_plan(cls)
            compiled = self._compile(cls, plan)
            self._plans[cls] = plan
            self._compiled[cls] = compiled
        out = compiled(self.pad_to_class(x, cls), self.kernel)
        o_n, o_h, o_w, o_c = self.request_out_shape(x.shape)
        return out[:o_n, :o_h, :o_w, :]

    __call__ = execute


# ---------------------------------------------------------------------------
# frontends: conv encoders ahead of the LM stack
# ---------------------------------------------------------------------------

def fit_prefix(frames: jnp.ndarray, prefix_len: int) -> jnp.ndarray:
    """Crop/zero-pad the time axis of (B, T, d) frontend output to the
    model's fixed prefix length (vlm prefill concatenates exactly
    ``cfg.prefix_len`` vision tokens ahead of the prompt)."""
    t = frames.shape[1]
    if t >= prefix_len:
        return frames[:, :prefix_len]
    return jnp.pad(frames, ((0, 0), (0, prefix_len - t), (0, 0)))


def whisper_frontend_service(key, n_mels: int, d_model: int,
                             classes: Sequence[Tuple[int, int, int]],
                             plan_mode: str = "cached"):
    """The whisper mel frontend (examples/whisper_frontend.py) as two
    warm ConvServices over time-bucketed shape classes.

    classes are (batch, T, 1) — conv1d expressed as height-1 conv2d with
    i_h = time, exactly the paper's Algorithm 2 framing.  Layer 1 keeps
    SAME's stride-1 split explicitly as (1, 1) (size-independent, so it
    is class-servable); layer 2 is the whisper-conventional stride-2
    (1, 1) pad.  Returns ``(frontend, [service1, service2])`` where
    ``frontend(mel)`` maps (B, T, n_mels) -> (B, ceil(T/2), d_model)
    through the warmed plans.
    """
    k1, k2 = jax.random.split(key)
    w1 = jax.random.normal(k1, (3, 1, n_mels, d_model)) * n_mels ** -0.5
    w2 = jax.random.normal(k2, (3, 1, d_model, d_model)) * d_model ** -0.5
    svc1 = ConvService(w1, stride=(1, 1), padding=((1, 1), (0, 0)),
                       classes=classes, plan_mode=plan_mode)
    svc2 = ConvService(w2, stride=(2, 1), padding=((1, 1), (0, 0)),
                       classes=classes, plan_mode=plan_mode)
    svc1.warm()
    svc2.warm()

    def frontend(mel: jnp.ndarray) -> jnp.ndarray:
        x = mel[:, :, None, :]                   # (B, T, 1, mels), h=time
        x = jax.nn.gelu(svc1(x))
        x = jax.nn.gelu(svc2(x))                 # stride-2 downsample
        return x[:, :, 0, :]

    return frontend, [svc1, svc2]


def patch_embed_service(key, in_channels: int, d_model: int, patch: int,
                        classes: Sequence[Tuple[int, int, int]],
                        prefix_len: int, plan_mode: str = "cached"):
    """A ViT-style patch-embed vision frontend: one k=s=patch conv maps
    (B, H, W, C) images — bucketed into ``classes`` — to (B, prefix_len,
    d_model) vision tokens for the vlm prefill path.  Returns
    ``(frontend, service)``."""
    w = jax.random.normal(key, (patch, patch, in_channels, d_model)) \
        * (patch * patch * in_channels) ** -0.5
    svc = ConvService(w, stride=(patch, patch), padding="VALID",
                      classes=classes, plan_mode=plan_mode)
    svc.warm()

    def frontend(image: jnp.ndarray) -> jnp.ndarray:
        grid = svc(image)                        # (B, H/p, W/p, d_model)
        tokens = grid.reshape(grid.shape[0], -1, grid.shape[3])
        return fit_prefix(tokens, prefix_len)

    return frontend, svc
