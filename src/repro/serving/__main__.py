"""Operator CLI: audit what a conv service will run before deploying.

  PYTHONPATH=src python -m repro.serving --warmup-report \\
      --kernel 3x3x4x8 --stride 2 --padding 1 \\
      --shape-classes 1x12x12,2x16x16

  PYTHONPATH=src python -m repro.serving --warmup-report \\
      --frontend whisper --shape-classes 1x24x1,2x64x1

``--warmup-report`` builds the service, warms every shape class through
the persistent plan cache, and prints the resolved
:class:`~repro.plan.ConvPlan` table per class
(:meth:`ConvPlan.explain`) plus the warning / plan-cache-I/O counters —
exactly what the serve report will carry at runtime.  Exit status is
non-zero when any class failed to warm (the service would still run,
degraded; deploy gates can choose to care).
"""
from __future__ import annotations

import argparse
import sys

from repro.serving.conv_service import (ConvService, parse_shape_classes,
                                        whisper_frontend_service)


def _parse_kernel(text: str):
    dims = text.split("x")
    if len(dims) != 4:
        raise argparse.ArgumentTypeError(
            f"kernel {text!r} is not KHxKWxICxOC")
    return tuple(int(d) for d in dims)


def _parse_padding(text: str):
    if text.upper() == "VALID":
        return "VALID"
    parts = [int(p) for p in text.split(",")]
    if len(parts) == 1:
        return parts[0]
    if len(parts) == 4:
        return ((parts[0], parts[1]), (parts[2], parts[3]))
    raise argparse.ArgumentTypeError(
        f"padding {text!r} is not VALID, P, or HLO,HHI,WLO,WHI")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.serving",
        description="Plan-driven conv serving (DESIGN.md §9)")
    ap.add_argument("--warmup-report", action="store_true", required=True,
                    help="warm the service and print the per-class "
                         "resolved-plan table")
    ap.add_argument("--shape-classes", required=True,
                    help="comma-separated NxHxW padded classes, e.g. "
                         "1x32x32,4x64x64")
    ap.add_argument("--frontend", choices=("whisper",), default=None,
                    help="audit a named conv frontend instead of a bare "
                         "kernel (whisper: the two-layer mel frontend)")
    ap.add_argument("--kernel", type=_parse_kernel, default=(3, 3, 4, 8),
                    help="KHxKWxICxOC kernel geometry (default 3x3x4x8)")
    ap.add_argument("--stride", type=int, default=1)
    ap.add_argument("--padding", type=_parse_padding, default="VALID",
                    help="VALID, a single int, or HLO,HHI,WLO,WHI")
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--plan-mode", choices=("cached", "analytic"),
                    default="cached")
    ap.add_argument("--n-mels", type=int, default=80,
                    help="whisper frontend: mel bins")
    ap.add_argument("--d-model", type=int, default=64,
                    help="whisper frontend: model width")
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp
    classes = parse_shape_classes(args.shape_classes)
    if args.frontend == "whisper":
        _, services = whisper_frontend_service(
            jax.random.key(0), args.n_mels, args.d_model,
            classes, plan_mode=args.plan_mode)
        labels = ["conv1 (stride 1)", "conv2 (stride 2)"]
    else:
        k_h, k_w, i_c, k_c = args.kernel
        kernel = jax.random.normal(
            jax.random.key(0), (k_h, k_w, i_c, k_c),
            jnp.dtype(args.dtype)) * (k_h * k_w * i_c) ** -0.5
        svc = ConvService(kernel, stride=args.stride, padding=args.padding,
                          classes=classes, plan_mode=args.plan_mode)
        svc.warm()
        services, labels = [svc], [f"conv {args.kernel}"]

    rc = 0
    for label, svc in zip(labels, services):
        print(f"== {label} ==")
        print(svc.warmup.render())
        if len(svc.warmup.plans) < len(svc.classes):
            rc = 1
    return rc


if __name__ == "__main__":
    sys.exit(main())
