"""Continuous batching for LM serving (dense/vlm families).

A fixed pool of B slots shares one layer-stacked KV cache with *per-slot*
lengths; requests stream in, prefill writes a finished prompt's KV into a
free slot, and every decode step advances all live slots at once —
the vLLM-style scheduler loop, sized down to this framework's cache
layout (contiguous per-slot regions rather than paged blocks; paging is
the documented next step).

Components:
* ``batched_decode_step`` — one token for every slot, per-slot lengths
  (vectorized scatter into the caches + per-slot causal masks).
* ``insert_prefill``     — scatter a (1, S, ...) prefill cache into slot b.
* ``ContinuousBatcher``  — the Python-side queue/slot manager (admission,
  completion by EOS or max_new_tokens, slot recycling).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import serve
from repro.models.layers import decode_attention, linear, rms_norm, swiglu
from repro.models.lm import LM


# ---------------------------------------------------------------------------
# per-slot-length decode (dense/vlm)
# ---------------------------------------------------------------------------

def _attn_decode_multi(p, cfg, x, kc, vc, lens):
    """x (B,1,d); kc/vc (B,Smax,KV,hd); lens (B,) per-slot lengths."""
    b = x.shape[0]
    hd = cfg.head_dim
    from repro.models.layers import attention_qkv
    q, k, v = attention_qkv(p, cfg, x, None, use_rope=False)
    # RoPE at each slot's own position
    from repro.models.layers import rope_cos_sin, apply_rope

    def rope_one(qi, ki, pos):
        cos, sin = rope_cos_sin(pos[None], hd, cfg.rope_theta)
        return apply_rope(qi[None], cos, sin)[0], \
            apply_rope(ki[None], cos, sin)[0]

    q, k = jax.vmap(rope_one)(q, k, lens)
    kc = kc.at[jnp.arange(b), lens].set(k[:, 0].astype(kc.dtype))
    vc = vc.at[jnp.arange(b), lens].set(v[:, 0].astype(vc.dtype))
    out = decode_attention(q, kc, vc, (lens + 1)[:, None])
    out = out.reshape(b, 1, cfg.n_heads * hd)
    return linear(out, p["wo"]), kc, vc


def batched_decode_step(model: LM, params, cache: Dict, tokens: jnp.ndarray):
    """tokens (B,1); cache {k,v: (L,B,Smax,KV,hd), lens: (B,)}.

    Returns (logits (B,V), new cache) with every slot advanced by one.
    Dead slots (lens < 0) still compute but their writes go to row 0 of a
    scratch region — callers mask them out.
    """
    cfg = model.cfg
    lens = jnp.maximum(cache["lens"], 0)
    h = model.embed(params, tokens)

    def body(x, inputs):
        p, kc, vc = inputs
        xn = rms_norm(x, p["norm1"], cfg.norm_eps)
        a, kc, vc = _attn_decode_multi(p["attn"], cfg, xn, kc, vc, lens)
        x = x + a
        f = swiglu(rms_norm(x, p["norm2"], cfg.norm_eps), p["mlp"])
        return x + f, (kc, vc)

    h, (kc, vc) = lax.scan(body, h, (params["blocks"], cache["k"],
                                     cache["v"]))
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    w = model.head_weights(params)
    logits = jnp.einsum("bd,dv->bv", h[:, -1].astype(jnp.float32),
                        w.astype(jnp.float32),
                        preferred_element_type=jnp.float32)
    return logits, {"k": kc, "v": vc, "lens": cache["lens"] + 1}


def insert_prefill(cache: Dict, slot: int, pre_cache: Dict) -> Dict:
    """Scatter a batch-1 prefill cache (from serve.prefill) into a slot."""
    s = pre_cache["k"].shape[2]
    k = cache["k"].at[:, slot, :s].set(pre_cache["k"][:, 0, :s])
    v = cache["v"].at[:, slot, :s].set(pre_cache["v"][:, 0, :s])
    lens = cache["lens"].at[slot].set(pre_cache["len"])
    return {"k": k, "v": v, "lens": lens}


def init_pool(model: LM, n_slots: int, max_len: int) -> Dict:
    cfg = model.cfg
    hd = cfg.head_dim
    shp = (cfg.n_layers, n_slots, max_len, cfg.n_kv_heads, hd)
    dt = jnp.dtype(cfg.dtype)
    return {"k": jnp.zeros(shp, dt), "v": jnp.zeros(shp, dt),
            "lens": jnp.full((n_slots,), -1, jnp.int32)}


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    rid: int
    prompt: jnp.ndarray              # (S,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    # Extra prefill-batch entries beyond "tokens", already batch-1 shaped
    # — e.g. {"vision": (1, prefix_len, d_model)} tokens a warm
    # conv-service frontend produced (DESIGN.md §9).  Decode is
    # untouched: prefix state lives in the KV cache after prefill.
    extras: Optional[Dict[str, jnp.ndarray]] = None
    out: List[int] = dataclasses.field(default_factory=list)
    slot: int = -1


class ContinuousBatcher:
    def __init__(self, model: LM, params, n_slots: int = 4,
                 max_len: int = 256):
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.cache = init_pool(model, n_slots, max_len)
        self.queue: deque = deque()
        self.live: Dict[int, Request] = {}
        self.done: List[Request] = []
        self._next_tok = jnp.zeros((n_slots, 1), jnp.int32)
        self._decode = jax.jit(
            lambda p, c, t: batched_decode_step(model, p, c, t))
        self._prefill = jax.jit(
            lambda p, b: serve.prefill(model, p, b, max_len))

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        free = [s for s in range(self.n_slots) if s not in
                {r.slot for r in self.live.values()}]
        while free and self.queue:
            req = self.queue.popleft()
            slot = free.pop(0)
            batch = {"tokens": req.prompt[None]}
            if req.extras:
                batch.update(req.extras)
            logits, pre = self._prefill(self.params, batch)
            self.cache = insert_prefill(self.cache, slot, pre)
            tok = int(jnp.argmax(logits[0]))
            req.slot = slot
            req.out.append(tok)
            # The prefill-produced token obeys the same completion rules as
            # decode tokens (EOS can legitimately be the first token).
            if (len(req.out) >= req.max_new_tokens
                    or (req.eos_id is not None and tok == req.eos_id)):
                self.cache["lens"] = self.cache["lens"].at[slot].set(-1)
                self.done.append(req)
                free.insert(0, slot)
                continue
            self._next_tok = self._next_tok.at[slot, 0].set(tok)
            self.live[req.rid] = req

    def step(self) -> None:
        """One scheduler tick: admit waiting requests, decode all live."""
        self._admit()
        if not self.live:
            return
        logits, self.cache = self._decode(self.params, self.cache,
                                          self._next_tok)
        toks = jnp.argmax(logits, axis=-1)
        finished = []
        for rid, req in self.live.items():
            tok = int(toks[req.slot])
            req.out.append(tok)
            self._next_tok = self._next_tok.at[req.slot, 0].set(tok)
            if (len(req.out) >= req.max_new_tokens
                    or (req.eos_id is not None and tok == req.eos_id)):
                finished.append(rid)
        for rid in finished:
            req = self.live.pop(rid)
            self.cache["lens"] = self.cache["lens"].at[req.slot].set(-1)
            self.done.append(req)

    def run_until_done(self, max_ticks: int = 1000) -> List[Request]:
        ticks = 0
        while (self.queue or self.live) and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.done
