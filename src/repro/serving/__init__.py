"""Serving layer: continuous batching + warm-plan conv serving.

* :mod:`repro.serving.scheduler` — the vLLM-style slot scheduler
  (admission, batched decode, EOS completion, slot recycling).
* :mod:`repro.serving.conv_service` — plan-driven conv serving
  (DESIGN.md §9): bounded padded shape classes, one warm
  :class:`~repro.plan.ConvPlan` per class, AOT-compiled class
  executors, best-effort plan-cache warmup.

CLI::

  PYTHONPATH=src python -m repro.serving --warmup-report \\
      --shape-classes 1x32x32,4x64x64
"""
from repro.serving.conv_service import (ConvService, ShapeClass,
                                        WarmupReport, fit_prefix,
                                        parse_shape_classes,
                                        patch_embed_service,
                                        whisper_frontend_service)
from repro.serving.scheduler import ContinuousBatcher, Request

__all__ = [
    "ConvService", "ShapeClass", "WarmupReport", "parse_shape_classes",
    "fit_prefix", "whisper_frontend_service", "patch_embed_service",
    "ContinuousBatcher", "Request",
]
