"""Core MEC algorithm (paper contribution), the baselines it is compared
against in §4 of the paper, and the unified ``conv2d`` front-end that
dispatches among them (DESIGN.md §1)."""
from repro.core.conv_api import ALGORITHMS, MEC_ALGORITHMS, conv2d, conv2d_spec
from repro.core.convspec import ConvSpec, pad_same, spec_of
from repro.core.direct import direct_conv2d
from repro.core.fft_conv import fft_conv2d
from repro.core.im2col import im2col_conv2d, im2col_lower
from repro.core.mec import (mec_conv1d_depthwise, mec_conv2d, mec_lower,
                            vanilla_mec)
from repro.core.winograd import winograd_conv2d

__all__ = [
    "ALGORITHMS", "MEC_ALGORITHMS", "conv2d", "conv2d_spec",
    "ConvSpec", "pad_same", "spec_of",
    "mec_conv2d", "mec_lower", "vanilla_mec", "mec_conv1d_depthwise",
    "im2col_conv2d", "im2col_lower",
    "direct_conv2d", "fft_conv2d", "winograd_conv2d",
]
