"""FFT-based convolution (paper §2.2, FFT.gpu baseline).

Every kernel is zero-padded to the input spatial size (this is exactly the
memory overhead the paper criticizes: ``k_c`` padded kernel spectra of the
input's size), multiplied in the frequency domain, and the valid region is
cropped.  Strides are applied by decimating the full-correlation output.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.convspec import spec_of


@functools.partial(jax.jit, static_argnames=("stride", "precision"))
def fft_conv2d(inp: jnp.ndarray, kernel: jnp.ndarray, stride=1,
               precision=None) -> jnp.ndarray:
    spec = spec_of(inp, kernel, stride)
    i_h, i_w = spec.i_h, spec.i_w
    # Pad kernels to input size (the FFT memory-overhead, Eq. cited in §2.2).
    k_pad = jnp.pad(
        kernel, ((0, i_h - spec.k_h), (0, i_w - spec.k_w), (0, 0), (0, 0)))
    f_inp = jnp.fft.rfft2(inp.astype(jnp.float32), axes=(1, 2))      # (n,h,wf,c)
    f_ker = jnp.fft.rfft2(k_pad.astype(jnp.float32), axes=(0, 1))    # (h,wf,c,kc)
    # Cross-correlation theorem: corr = irfft(conj(F[k]) * F[i]).
    f_out = jnp.einsum("nhwc,hwco->nhwo", f_inp, jnp.conj(f_ker),
                       precision=precision,
                       preferred_element_type=jnp.complex64)
    full = jnp.fft.irfft2(f_out, s=(i_h, i_w), axes=(1, 2))
    valid = full[:, : i_h - spec.k_h + 1 : spec.s_h,
                 : i_w - spec.k_w + 1 : spec.s_w, :]
    return valid.astype(inp.dtype)
