"""Analytic memory-overhead model (paper §3.4, Eqs. 2-4) for every
convolution algorithm compared in §4.  "Overhead" = temporary storage
beyond input/kernel/output, in elements (multiply by dtype size for bytes).
"""
from __future__ import annotations

from repro.core.convspec import ConvSpec, padded_spec


def im2col_overhead(s: ConvSpec) -> int:
    """Eq. 2: the lowered Toeplitz matrix."""
    return s.i_n * s.o_h * s.o_w * s.k_h * s.k_w * s.i_c


def mec_overhead(s: ConvSpec) -> int:
    """Eq. 3: MEC's compact lowered matrix L."""
    return s.i_n * s.o_w * s.i_h * s.k_w * s.i_c


def mec_saving(s: ConvSpec) -> int:
    """Eq. 4: R = i_n k_c o_w k_w (i_h - k_h)(k_h/s_h - 1)  [elements].

    Note the paper's R is expressed per output channel block; we return the
    exact difference im2col_overhead - mec_overhead, which the paper shows
    equals i_n * i_c * o_w * k_w * (o_h*k_h - i_h).
    """
    return im2col_overhead(s) - mec_overhead(s)


def fft_overhead(s: ConvSpec, padding="VALID") -> int:
    """Kernels padded to input size + input/output spectra (complex => x2).

    rfft halves the last freq axis (+1); counted in real elements.
    The spectra are sized on the *post-padding* spatial dims — the input
    ``fft_conv2d`` actually transforms — so a pre-padding spec with
    SAME/explicit padding no longer understates the overhead.
    """
    s = padded_spec(s, padding)
    w_f = s.i_w // 2 + 1
    ker = s.i_h * w_f * s.i_c * s.k_c * 2        # padded kernel spectra
    inp = s.i_n * s.i_h * w_f * s.i_c * 2        # input spectrum
    out = s.i_n * s.i_h * w_f * s.k_c * 2        # product spectrum
    return ker + inp + out


def winograd_overhead(s: ConvSpec) -> int:
    """F(2x2,3x3): transformed kernels U, tiles V, and products M."""
    t_h, t_w = -(-s.o_h // 2), -(-s.o_w // 2)
    u = 16 * s.i_c * s.k_c
    v = 16 * s.i_n * t_h * t_w * s.i_c
    m = 16 * s.i_n * t_h * t_w * s.k_c
    return u + v + m


def direct_overhead(s: ConvSpec) -> int:  # lint-ignore: accepted-kwarg-not-forwarded
    return 0          # no temporaries; s kept for ALL_OVERHEADS uniformity


def conv_flops(s: ConvSpec) -> int:
    """Mult-adds x2 — identical for direct/im2col/MEC (paper §3.2)."""
    return 2 * s.i_n * s.o_h * s.o_w * s.k_h * s.k_w * s.i_c * s.k_c


ALL_OVERHEADS = {
    "direct": direct_overhead,
    "im2col": im2col_overhead,
    "mec": mec_overhead,
    "fft": fft_overhead,
    "winograd": winograd_overhead,
}

# conv2d dispatch names -> the base overhead model above.  The Pallas
# 'lowered' mode materializes the same compact L as the reference; the
# fused kernels keep the lowering in VMEM, so their HBM overhead is the
# direct conv's (zero).
_DISPATCH_BASE = {
    "mecA": "mec", "mecB": "mec", "mec_lowered": "mec",
    "mec_fused": "direct", "mec_fused2": "direct",
}


def algorithm_overhead(s: ConvSpec, algorithm: str,
                       padding="VALID") -> int:
    """Overhead in elements for any ``conv2d`` dispatch name (including
    solution/Pallas variants not listed in :data:`ALL_OVERHEADS`).

    ``padding`` resolves a *pre-padding* spec to the geometry the
    algorithm actually allocates on (``convspec.padded_spec``); the
    default VALID keeps post-padding specs — the repo norm — unchanged.
    """
    return ALL_OVERHEADS[_DISPATCH_BASE.get(algorithm, algorithm)](
        padded_spec(s, padding))
