"""Winograd F(2x2, 3x3) convolution (paper's Wino.cpu/Wino.gpu baseline).

Applicable only when k_h == k_w == 3 and s == 1 (the paper notes the same
restriction).  Implements the Lavin (2015) formulation: kernel transform
U = G g G^T, input-tile transform V = B^T d B, elementwise products
M = U . V reduced over input channels, inverse transform Y = A^T M A.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.convspec import spec_of

_BT = jnp.array(
    [[1, 0, -1, 0],
     [0, 1, 1, 0],
     [0, -1, 1, 0],
     [0, 1, 0, -1]], dtype=jnp.float32)
_G = jnp.array(
    [[1, 0, 0],
     [0.5, 0.5, 0.5],
     [0.5, -0.5, 0.5],
     [0, 0, 1]], dtype=jnp.float32)
_AT = jnp.array(
    [[1, 1, 1, 0],
     [0, 1, -1, -1]], dtype=jnp.float32)


@functools.partial(jax.jit, static_argnames=("precision",))
def winograd_conv2d(inp: jnp.ndarray, kernel: jnp.ndarray,
                    precision=None) -> jnp.ndarray:
    """inp (n, h, w, c) pre-padded; kernel (3, 3, i_c, k_c); stride 1 VALID.

    precision reaches every GEMM of the formulation: the tile/kernel
    transforms (B^T d B, G g G^T), the channel-reduction product M, and
    the inverse transform A^T M A."""
    spec = spec_of(inp, kernel, 1)
    if (spec.k_h, spec.k_w) != (3, 3):
        raise ValueError("Winograd F(2x2,3x3) requires a 3x3 kernel")
    o_h, o_w = spec.o_h, spec.o_w
    t_h, t_w = -(-o_h // 2), -(-o_w // 2)          # number of 2x2 output tiles
    need_h, need_w = 2 * t_h + 2, 2 * t_w + 2      # input extent covered by tiles
    x = jnp.pad(inp.astype(jnp.float32),
                ((0, 0), (0, need_h - spec.i_h), (0, need_w - spec.i_w), (0, 0)))

    # Extract overlapping 4x4 input tiles at stride 2: (n, t_h, t_w, 4, 4, c).
    hidx = 2 * jnp.arange(t_h)[:, None] + jnp.arange(4)[None, :]
    widx = 2 * jnp.arange(t_w)[:, None] + jnp.arange(4)[None, :]
    tiles = x[:, hidx[:, None, :, None], widx[None, :, None, :], :]

    # V = B^T d B  (transform each tile)
    v = jnp.einsum("ij,nthjkc,lk->nthilc", _BT, tiles, _BT,
                   precision=precision)
    # U = G g G^T  (transform each kernel) -> (4, 4, c, kc)
    u = jnp.einsum("ij,jkco,lk->ilco", _G, kernel.astype(jnp.float32), _G,
                   precision=precision)
    # M = sum_c U . V  -> (n, t_h, t_w, 4, 4, kc)
    m = jnp.einsum("nthilc,ilco->nthilo", v, u, precision=precision,
                   preferred_element_type=jnp.float32)
    # Y = A^T M A -> (n, t_h, t_w, 2, 2, kc)
    y = jnp.einsum("ij,nthjko,lk->nthilo", _AT, m, _AT,
                   precision=precision)
    out = y.transpose(0, 1, 3, 2, 4, 5).reshape(spec.i_n, 2 * t_h, 2 * t_w, spec.k_c)
    return out[:, :o_h, :o_w, :].astype(inp.dtype)
