"""Direct convolution (paper Fig. 1a) — via lax.conv_general_dilated.

XLA's direct convolution is the "no memory-overhead" reference point and
the numerical ground truth for every other algorithm in this package.

Sub-f32 inputs need a custom VJP: ``preferred_element_type=f32`` makes
the forward emit an f32 accumulator (the numeric contract, DESIGN.md
§8.5), but jax's ``conv_general_dilated`` transpose rule cannot consume
the resulting f32 cotangent against bf16/f16 residuals ("requires
arguments to have the same dtypes") — dot_general's transpose handles
this, conv's does not.  The backward therefore differentiates the
f32-upcast convolution (bit-identical products: a bf16xbf16 product is
exact in f32 either way) and narrows each gradient back to its operand
dtype — the same one-terminal-narrow structure as the MEC VJP in
``conv_api``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax


def _conv(inp: jnp.ndarray, kernel: jnp.ndarray, s, precision):
    return lax.conv_general_dilated(
        inp, kernel,
        window_strides=s,
        padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        precision=precision,
        preferred_element_type=jnp.float32,
    ).astype(inp.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _direct(inp: jnp.ndarray, kernel: jnp.ndarray, s, precision):
    return _conv(inp, kernel, s, precision)


def _direct_fwd(inp, kernel, s, precision):
    return _conv(inp, kernel, s, precision), (inp, kernel)


def _direct_bwd(s, precision, res, g):
    inp, kernel = res

    def f32_conv(x32, k32):
        return lax.conv_general_dilated(
            x32, k32,
            window_strides=s,
            padding="VALID",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            precision=precision)

    _, vjp = jax.vjp(f32_conv, inp.astype(jnp.float32),
                     kernel.astype(jnp.float32))
    d_inp, d_ker = vjp(g.astype(jnp.float32))
    return d_inp.astype(inp.dtype), d_ker.astype(kernel.dtype)


_direct.defvjp(_direct_fwd, _direct_bwd)


@functools.partial(jax.jit, static_argnames=("stride", "precision"))
def direct_conv2d(inp: jnp.ndarray, kernel: jnp.ndarray, stride=1,
                  precision=None) -> jnp.ndarray:
    """inp (n, h, w, c) pre-padded; kernel (k_h, k_w, i_c, k_c); VALID."""
    s = (stride, stride) if isinstance(stride, int) else tuple(stride)
    return _direct(inp, kernel, s, precision)
