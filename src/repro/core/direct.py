"""Direct convolution (paper Fig. 1a) — via lax.conv_general_dilated.

XLA's direct convolution is the "no memory-overhead" reference point and
the numerical ground truth for every other algorithm in this package.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax


@functools.partial(jax.jit, static_argnames=("stride", "precision"))
def direct_conv2d(inp: jnp.ndarray, kernel: jnp.ndarray, stride=1,
                  precision=None) -> jnp.ndarray:
    """inp (n, h, w, c) pre-padded; kernel (k_h, k_w, i_c, k_c); VALID."""
    s = (stride, stride) if isinstance(stride, int) else tuple(stride)
    return lax.conv_general_dilated(
        inp, kernel,
        window_strides=s,
        padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        precision=precision,
        preferred_element_type=jnp.float32,
    ).astype(inp.dtype)
