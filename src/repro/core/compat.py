"""Version-compat shims for the pinned jax (0.4.37).

``shard_map`` moved from ``jax.experimental.shard_map`` to the top-level
``jax`` namespace in later releases, and two kwargs were renamed along
the way:

* ``check_rep``  -> ``check_vma``
* ``auto={axes left automatic}`` -> ``axis_names={axes made manual}``
  (complementary sets over the mesh axes)

Every module in this package imports ``shard_map`` from here and uses
the *new* spellings; the shim rewrites them for old builds so one compat
file covers the whole repo.

``abstract_mesh`` papers over the ``AbstractMesh`` constructor change
(new: ``AbstractMesh(axis_sizes, axis_names)``; old 0.4.x:
``AbstractMesh(((name, size), ...))``).

``cost_analysis`` papers over the ``Compiled.cost_analysis()`` return
change: 0.4.x returns a one-element list of dicts (or an empty list on
backends without an HLO cost model), newer jax returns the dict itself.
"""
from __future__ import annotations

import functools
import inspect
from typing import Sequence

from jax.sharding import AbstractMesh as _AbstractMesh

try:  # jax >= 0.6-ish exports it at top level
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]
except ImportError:  # the pinned 0.4.x line
    from jax.experimental.shard_map import shard_map as _shard_map

if "check_vma" in inspect.signature(_shard_map).parameters:
    shard_map = _shard_map
else:
    @functools.wraps(_shard_map)
    def shard_map(*args, **kwargs):
        if "check_vma" in kwargs:
            kwargs["check_rep"] = kwargs.pop("check_vma")
        if "axis_names" in kwargs:
            manual = frozenset(kwargs.pop("axis_names"))
            mesh = kwargs.get("mesh") or (args[1] if len(args) > 1 else None)
            if mesh is None:
                raise TypeError("shard_map compat: axis_names requires mesh")
            kwargs["auto"] = frozenset(mesh.axis_names) - manual
        return _shard_map(*args, **kwargs)


def cost_analysis(compiled) -> dict:
    """Properties dict of ``compiled.cost_analysis()`` across jax versions.

    Returns ``{}`` when the backend provides no cost model, so callers can
    always ``.get("flops", 0.0)`` without version branches.
    """
    try:
        cost = compiled.cost_analysis()
    except Exception:          # some backends raise instead of returning []
        return {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost) if cost else {}


def abstract_mesh(axis_sizes: Sequence[int],
                  axis_names: Sequence[str]) -> _AbstractMesh:
    """AbstractMesh across the constructor-signature change."""
    try:
        return _AbstractMesh(tuple(axis_sizes), tuple(axis_names))
    except TypeError:  # 0.4.x: one tuple of (name, size) pairs
        return _AbstractMesh(tuple(zip(axis_names, axis_sizes)))


__all__ = ["abstract_mesh", "cost_analysis", "shard_map"]
