"""Version-compat shims for the pinned jax (0.4.37).

``shard_map`` moved from ``jax.experimental.shard_map`` to the top-level
``jax`` namespace in later releases, and two kwargs were renamed along
the way:

* ``check_rep``  -> ``check_vma``
* ``auto={axes left automatic}`` -> ``axis_names={axes made manual}``
  (complementary sets over the mesh axes)

Every module in this package imports ``shard_map`` from here and uses
the *new* spellings; the shim rewrites them for old builds so one compat
file covers the whole repo.

``abstract_mesh`` papers over the ``AbstractMesh`` constructor change
(new: ``AbstractMesh(axis_sizes, axis_names)``; old 0.4.x:
``AbstractMesh(((name, size), ...))``).

``cost_analysis`` papers over the ``Compiled.cost_analysis()`` return
change: 0.4.x returns a one-element list of dicts (or an empty list on
backends without an HLO cost model), newer jax returns the dict itself.

``memory_analysis`` papers over the buffer-assignment accessor: newer
jax exposes ``Compiled.memory_analysis()`` (a ``CompiledMemoryStats``
with ``temp_size_in_bytes`` etc.; some versions wrap it in a list);
builds without it fall back to parsing ``allocation N: size B`` lines
from the buffer-assignment dump when one is reachable.  Returns ``None``
when neither source exists, so callers (``repro.analysis.memaudit``)
can record "unavailable" instead of crashing.
"""
from __future__ import annotations

import functools
import inspect
import re
from typing import Optional, Sequence

from jax.sharding import AbstractMesh as _AbstractMesh

try:  # jax >= 0.6-ish exports it at top level
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]
except ImportError:  # the pinned 0.4.x line
    from jax.experimental.shard_map import shard_map as _shard_map

if "check_vma" in inspect.signature(_shard_map).parameters:
    shard_map = _shard_map
else:
    @functools.wraps(_shard_map)
    def shard_map(*args, **kwargs):
        if "check_vma" in kwargs:
            kwargs["check_rep"] = kwargs.pop("check_vma")
        if "axis_names" in kwargs:
            manual = frozenset(kwargs.pop("axis_names"))
            mesh = kwargs.get("mesh") or (args[1] if len(args) > 1 else None)
            if mesh is None:
                raise TypeError("shard_map compat: axis_names requires mesh")
            kwargs["auto"] = frozenset(mesh.axis_names) - manual
        return _shard_map(*args, **kwargs)


def cost_analysis(compiled) -> dict:
    """Properties dict of ``compiled.cost_analysis()`` across jax versions.

    Returns ``{}`` when the backend provides no cost model, so callers can
    always ``.get("flops", 0.0)`` without version branches.
    """
    try:
        cost = compiled.cost_analysis()
    except Exception:          # some backends raise instead of returning []
        return {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost) if cost else {}


# CompiledMemoryStats attribute -> the normalized key memaudit reads.
_MEMORY_STAT_FIELDS = {
    "temp_size_in_bytes": "temp_bytes",
    "argument_size_in_bytes": "argument_bytes",
    "output_size_in_bytes": "output_bytes",
    "alias_size_in_bytes": "alias_bytes",
    "generated_code_size_in_bytes": "generated_code_bytes",
}

# e.g. "allocation 3: 12.3KiB, size 23232, thread-local: ..." — only the
# decimal byte size is load-bearing; classification flags follow on the
# same line.
_ALLOCATION_RE = re.compile(r"^\s*allocation\s+\d+:.*?\bsize\s+(\d+)\b(.*)$",
                            re.IGNORECASE)


def parse_allocation_lines(text: str) -> dict:
    """Peak buffer bytes from a buffer-assignment dump's ``allocation:``
    lines.  Classification mirrors XLA's: ``parameter`` allocations are
    arguments, ``maybe-live-out`` are outputs, ``constant`` is code-side,
    everything else is temporary scratch — the quantity Eqs. 2-4 bound.
    """
    out = {"temp_bytes": 0, "argument_bytes": 0, "output_bytes": 0,
           "alias_bytes": 0, "generated_code_bytes": 0}
    for line in text.splitlines():
        m = _ALLOCATION_RE.match(line)
        if not m:
            continue
        size, flags = int(m.group(1)), m.group(2)
        if "parameter" in flags:
            out["argument_bytes"] += size
        elif "maybe-live-out" in flags:
            out["output_bytes"] += size
        elif "constant" in flags:
            out["generated_code_bytes"] += size
        else:
            out["temp_bytes"] += size
    return out


def _buffer_assignment_text(compiled) -> Optional[str]:
    """Best-effort buffer-assignment dump of a compiled executable."""
    for attr in ("buffer_assignment_text", "buffer_assignment"):
        fn = getattr(compiled, attr, None)
        if callable(fn):
            try:
                text = fn()
            except Exception:
                continue
            if isinstance(text, str) and "allocation" in text:
                return text
    try:  # runtime executable's memory-annotated HLO dump, where offered
        text = compiled.runtime_executable().hlo_modules()[0].to_string()
    except Exception:
        return None
    return text if isinstance(text, str) and "allocation" in text else None


def memory_analysis(compiled) -> Optional[dict]:
    """Normalized buffer-assignment byte counts of a compiled executable.

    Returns ``{"temp_bytes", "argument_bytes", "output_bytes",
    "alias_bytes", "generated_code_bytes", "source"}`` — ``temp_bytes``
    is XLA's peak temporary-allocation total, the measured side of the
    paper's Eq. 2-4 overhead claims.  ``None`` when this build exposes
    neither ``Compiled.memory_analysis()`` nor a parseable
    buffer-assignment dump.
    """
    stats = None
    fn = getattr(compiled, "memory_analysis", None)
    if callable(fn):
        try:
            stats = fn()
        except Exception:
            stats = None
    if isinstance(stats, (list, tuple)):
        stats = stats[0] if stats else None
    if stats is not None and hasattr(stats, "temp_size_in_bytes"):
        out = {key: int(getattr(stats, attr, 0))
               for attr, key in _MEMORY_STAT_FIELDS.items()}
        out["source"] = "memory_analysis"
        return out
    text = _buffer_assignment_text(compiled)
    if text is None:
        return None
    out = parse_allocation_lines(text)
    out["source"] = "buffer_assignment"
    return out


def abstract_mesh(axis_sizes: Sequence[int],
                  axis_names: Sequence[str]) -> _AbstractMesh:
    """AbstractMesh across the constructor-signature change."""
    try:
        return _AbstractMesh(tuple(axis_sizes), tuple(axis_names))
    except TypeError:  # 0.4.x: one tuple of (name, size) pairs
        return _AbstractMesh(tuple(zip(axis_names, axis_sizes)))


__all__ = ["abstract_mesh", "cost_analysis", "memory_analysis",
           "parse_allocation_lines", "shard_map"]
