"""Per-backend numeric contracts (DESIGN.md §8.5).

MEC's Table 2 claim is that swapping the lowering trades memory for
speed *without* changing the convolution's result.  Each backend in
:data:`repro.core.conv_api.ALGORITHMS` therefore declares a
:class:`NumericContract`: the accumulation width its GEMMs must keep,
the cast structure its forward program is allowed to emit, and a
*measured* error budget against an f64 reference — the numbers
``repro.analysis.numcheck`` verifies statically (jaxpr dataflow) and
dynamically (the fixed-seed probe).

The shared baseline every current backend satisfies:

* all dot/conv contractions with sub-f32 operands accumulate at f32
  (``preferred_element_type=jnp.float32`` on every GEMM — in-kernel
  Pallas dots included);
* the forward program narrows back to the input dtype through exactly
  one cast edge (``fwd_output_narrows``) — MEC's per-row narrow inside
  the scan body is that one edge, written per output row;
* f64/complex128 never appear (``allow_f64=False`` everywhere: this is
  an f32-accumulate reproduction, a stray f64 means an unintended
  promotion);
* only ``fft`` may touch complex, and only at ``complex64`` — exactly
  2x the f32 compute width (``complex_pair``).

Error budgets are scale-normalized max errors (``max|y-ref| /
max|ref|``) measured on the fixed-seed probe spec (`numcheck`'s
``probe_spec()``) and recorded here with ~4x headroom over the observed
error — the contract, not the test file, owns the tolerance (a new
backend must declare its own before it can enter the plan candidate
set; ROADMAP "algorithm zoo").  ``grad`` budgets cover both cotangents
(input and kernel) of a quadratic probe loss, whose cotangent is
quantized at the input dtype — the honest training-time error.

Layering: pure data + stdlib; importable from anywhere (core, plan,
analysis, tests) without touching jax.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Optional, Tuple

#: dtypes every backend must hold a contract (and budget) for.
CONTRACT_DTYPES = ("float32", "bfloat16", "float16")

_FLOAT_BITS = {"float16": 16, "bfloat16": 16, "float32": 32, "float64": 64}


@dataclasses.dataclass(frozen=True)
class NumericContract:
    """The declared dtype-flow rules for one conv backend.

    ``error_budget`` maps dtype -> {"fwd": tol, "grad": tol}; a dtype
    missing from the map means the backend makes no accuracy claim
    there and the probe records (but cannot gate) its error.
    """

    algorithm: str
    #: minimum accumulation dtype for contractions with sub-f32 operands
    accum_dtype: str = "float32"
    #: complex64 admitted beside f32 compute (FFT round-trip only)
    complex_pair: bool = False
    #: narrowing casts back to the input dtype in the *forward* program
    #: when the input is sub-f32 (f32 inputs must narrow zero times)
    fwd_output_narrows: int = 1
    #: f64/complex128 are never part of the contract
    allow_f64: bool = False
    #: scale-normalized max-error budget vs the f64 reference
    error_budget: Mapping[str, Mapping[str, float]] = \
        dataclasses.field(default_factory=dict)

    def allowed_dtypes(self, input_dtype: str) -> Tuple[str, ...]:
        """Float/complex dtypes a program on ``input_dtype`` may touch."""
        allowed = {input_dtype, self.accum_dtype}
        if self.complex_pair:
            allowed.add("complex64")
        return tuple(sorted(allowed))

    def tolerance(self, dtype: str, direction: str) -> Optional[float]:
        budget = self.error_budget.get(dtype)
        return None if budget is None else budget.get(direction)

    def to_dict(self) -> Dict:
        return {
            "algorithm": self.algorithm,
            "accum_dtype": self.accum_dtype,
            "complex_pair": self.complex_pair,
            "fwd_output_narrows": self.fwd_output_narrows,
            "allow_f64": self.allow_f64,
            "error_budget": {d: dict(b)
                             for d, b in sorted(self.error_budget.items())},
        }


def float_bits(dtype: str) -> Optional[int]:
    """Float width in bits; None for non-float dtypes (by name, so the
    contract layer never needs jax/numpy)."""
    return _FLOAT_BITS.get(str(dtype))


# Budgets measured on numcheck's probe_spec() at seed 0, recorded with
# ~4x headroom over the worst observed backend (BENCH_numcheck.json
# carries the raw measurements).  f32: every backend sits at a few ulps
# of the f64 reference (worst fwd 1.8e-7, worst grad 2.9e-7); fft and
# winograd get a slightly wider band for the complex round-trip /
# transform conditioning.  bf16 (8-bit mantissa) dominates the sub-f32
# budgets (worst fwd 2.9e-3, worst grad 6.3e-3 — im2col's d_input,
# whose cotangent is quantized bf16 before the f32-accumulated VJP
# GEMMs consume it); f16's 11-bit mantissa lands ~8x tighter.
_F32 = {"fwd": 1e-6, "grad": 2e-6}
_F32_FFT = {"fwd": 2e-6, "grad": 4e-6}
_BF16 = {"fwd": 1.2e-2, "grad": 2.5e-2}
_F16 = {"fwd": 1.2e-3, "grad": 2e-3}

_MEC_BUDGET = {"float32": _F32, "bfloat16": _BF16, "float16": _F16}

CONTRACTS: Dict[str, NumericContract] = {
    "direct": NumericContract(
        "direct",
        error_budget={"float32": _F32, "bfloat16": _BF16, "float16": _F16}),
    "im2col": NumericContract(
        "im2col",
        error_budget={"float32": _F32, "bfloat16": _BF16, "float16": _F16}),
    "fft": NumericContract(
        "fft", complex_pair=True,
        error_budget={"float32": _F32_FFT, "bfloat16": _BF16,
                      "float16": _F16}),
    "winograd": NumericContract(
        "winograd",
        error_budget={"float32": _F32_FFT, "bfloat16": _BF16,
                      "float16": _F16}),
    "mec": NumericContract("mec", error_budget=_MEC_BUDGET),
    "mec_lowered": NumericContract("mec_lowered", error_budget=_MEC_BUDGET),
    "mec_fused": NumericContract("mec_fused", error_budget=_MEC_BUDGET),
    "mec_fused2": NumericContract("mec_fused2", error_budget=_MEC_BUDGET),
}


def contract_for(algorithm: str) -> Optional[NumericContract]:
    """The declared contract, or None for unregistered backends (the
    checker records those as skips — a backend without a contract is a
    ROADMAP violation, not a crash)."""
    return CONTRACTS.get(algorithm)
