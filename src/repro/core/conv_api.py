"""Unified, trainable 2-D convolution front-end (DESIGN.md §1, §7).

Every conv call site in this repo — models, examples, benchmarks — goes
through ``conv2d``.  It owns padding (SAME/VALID/explicit), validates
geometry through :class:`~repro.core.convspec.ConvSpec`, and dispatches
to one of the algorithm back-ends the paper compares in §4:

=============  ============================================================
``direct``     ``lax.conv_general_dilated`` (XLA direct; numerical oracle)
``im2col``     full Toeplitz lowering + one GEMM (paper Eq. 2 baseline)
``fft``        frequency-domain (paper §2.2 FFT baseline)
``winograd``   F(2x2, 3x3); requires a 3x3 kernel and stride 1
``mec``        paper Algorithm 2, pure JAX (Solutions A/B)
``mec_lowered``  Pallas: L materialized in HBM (paper-faithful kernels)
``mec_fused``    Pallas: lowering fused into the GEMM, no L in HBM
``mec_fused2``   Pallas: h-blocked fused variant with halo fetch
``auto``       cached :class:`repro.plan.ConvPlan` (analytic on miss)
=============  ============================================================

Since the planner redesign (DESIGN.md §7) ``conv2d`` is a thin
*executor*: the full decision — algorithm, MEC solution, Pallas
``w_blk``, precision, partition — lives in a frozen
:class:`repro.plan.ConvPlan`.  ``conv2d(..., plan=)`` executes exactly
that plan (plan fields win over kwargs); bare kwargs with
``algorithm="auto"`` resolve through the process/disk plan cache
(``repro.plan.resolve_cached_plan``), which computes the analytic plan
on a miss — the same pick the pre-planner dispatch made.

All MEC paths are wrapped in a single ``jax.custom_vjp`` so the compact
lowering is trainable end-to-end:

* input gradient = a *transposed MEC conv*: the cotangent, stride-dilated
  and fully padded, is itself MEC-convolved with the spatially-flipped,
  channel-transposed kernel;
* weight gradient reuses ``mec_lower``'s compact L — one small einsum per
  kernel row over shifted views of L, never an im2col-sized buffer.
"""
from __future__ import annotations

import functools
from typing import TYPE_CHECKING, Optional, Tuple, Union

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.convspec import (ConvSpec, normalize_stride, pad_same,
                                 spec_of)
from repro.core.direct import direct_conv2d
from repro.core.fft_conv import fft_conv2d
from repro.core.im2col import im2col_conv2d
from repro.core.mec import mec_conv2d as _mec_reference, mec_lower
from repro.core.winograd import winograd_conv2d

if TYPE_CHECKING:  # repro.plan imports core; the cycle is runtime-lazy
    from repro.plan import ConvPlan

MEC_ALGORITHMS = ("mec", "mec_lowered", "mec_fused", "mec_fused2")
ALGORITHMS = ("auto", "direct", "im2col", "fft", "winograd") + MEC_ALGORITHMS

Padding = Union[str, int, Tuple]


def apply_padding(inp: jnp.ndarray, k_h: int, k_w: int, s_h: int, s_w: int,
                  padding: Padding) -> jnp.ndarray:
    """SAME / VALID / explicit padding, applied once so every algorithm
    sees an identical pre-padded input (paper §2.1).  Negative explicit
    pads are rejected here — ``jnp.pad`` would otherwise raise deep in
    the trace with an opaque message."""
    if isinstance(padding, str):
        mode = padding.upper()
        if mode == "VALID":
            return inp
        if mode == "SAME":
            return pad_same(inp, k_h, k_w, s_h, s_w)
        raise ValueError(f"unknown padding {padding!r}")
    if isinstance(padding, int):
        padding = ((padding, padding), (padding, padding))
    p_h, p_w = padding
    if isinstance(p_h, int):
        p_h = (p_h, p_h)
    if isinstance(p_w, int):
        p_w = (p_w, p_w)
    p_h, p_w = tuple(p_h), tuple(p_w)
    if min(p_h + p_w) < 0:
        raise ValueError(
            f"padding must be non-negative, got {(p_h, p_w)}; negative "
            "pads (cropping) are not a convolution padding")
    return jnp.pad(inp, ((0, 0), p_h, p_w, (0, 0)))


# ---------------------------------------------------------------------------
# MEC custom VJP (shared by the reference and all Pallas variants)
# ---------------------------------------------------------------------------

def _mec_forward(inp, kernel, s_h, s_w, variant, solution, interpret,
                 precision, w_blk):
    if variant == "mec":
        return _mec_reference(inp, kernel, (s_h, s_w), solution=solution,
                              precision=precision)
    from repro.kernels.ops import mec_conv2d_tpu
    mode = variant[len("mec_"):]          # lowered | fused | fused2
    return mec_conv2d_tpu(inp, kernel, (s_h, s_w), mode=mode,
                          interpret=interpret, precision=precision,
                          w_blk=w_blk)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5, 6, 7, 8))
def _mec_conv(inp, kernel, s_h, s_w, variant, solution, interpret,
              precision, w_blk):
    return _mec_forward(inp, kernel, s_h, s_w, variant, solution, interpret,
                        precision, w_blk)


def _mec_fwd(inp, kernel, s_h, s_w, variant, solution, interpret, precision,
             w_blk):
    out = _mec_forward(inp, kernel, s_h, s_w, variant, solution, interpret,
                       precision, w_blk)
    return out, (inp, kernel)


def _mec_input_grad(g: jnp.ndarray, kernel: jnp.ndarray, s_h: int, s_w: int,
                    i_h: int, i_w: int, precision=None) -> jnp.ndarray:
    """dL/dI as a transposed MEC conv: stride-dilate the cotangent, pad it
    fully, and MEC-convolve with the spatially-flipped kernel whose
    channel axes are swapped (HWIO -> HWOI)."""
    k_h, k_w = kernel.shape[:2]
    g32 = g.astype(jnp.float32)
    i_n, o_h, o_w, k_c = g.shape
    if s_h > 1 or s_w > 1:
        gd = jnp.zeros((i_n, (o_h - 1) * s_h + 1, (o_w - 1) * s_w + 1, k_c),
                       jnp.float32)
        gd = gd.at[:, ::s_h, ::s_w, :].set(g32)
    else:
        gd = g32
    gp = jnp.pad(gd, ((0, 0), (k_h - 1, k_h - 1), (k_w - 1, k_w - 1), (0, 0)))
    k_t = jnp.transpose(kernel[::-1, ::-1], (0, 1, 3, 2)).astype(jnp.float32)
    # (n, (o_h-1)s_h + k_h, ..., i_c)
    di = _mec_reference(gp, k_t, (1, 1), precision=precision)
    # Input rows/cols beyond the last kernel window receive zero gradient.
    return jnp.pad(di, ((0, 0), (0, i_h - di.shape[1]),
                        (0, i_w - di.shape[2]), (0, 0)))


def _mec_weight_grad(inp: jnp.ndarray, g: jnp.ndarray, s_h: int, s_w: int,
                     k_h: int, k_w: int, precision=None) -> jnp.ndarray:
    """dL/dK from the compact L (Eq. 3): for each kernel row r, the
    stride-s_h shifted view of L against the cotangent — the same
    k_h-decomposition the Pallas kernels use, run in reverse."""
    low = mec_lower(inp, k_w, s_w)        # (n, o_w, i_h, k_w, i_c)
    o_h = g.shape[1]
    g32 = g.astype(jnp.float32)
    low32 = low.astype(jnp.float32)
    rows = []
    for r in range(k_h):
        lr = lax.slice_in_dim(low32, r, r + s_h * (o_h - 1) + 1,
                              stride=s_h, axis=2)  # (n, o_w, o_h, k_w, i_c)
        rows.append(jnp.einsum("nwhjc,nhwo->jco", lr, g32,
                               precision=precision,
                               preferred_element_type=jnp.float32))
    return jnp.stack(rows, axis=0)        # (k_h, k_w, i_c, k_c)


def _mec_bwd(s_h, s_w, _variant, _solution, _interpret, precision, _w_blk,
             res, g):
    # The nondiff args arrive positionally; variant/solution/interpret/
    # w_blk shape the forward lowering only — the VJP math is identical
    # for every MEC execution path.
    inp, kernel = res
    d_inp = _mec_input_grad(g, kernel, s_h, s_w, inp.shape[1], inp.shape[2],
                            precision)
    d_ker = _mec_weight_grad(inp, g, s_h, s_w, kernel.shape[0],
                             kernel.shape[1], precision)
    return d_inp.astype(inp.dtype), d_ker.astype(kernel.dtype)


_mec_conv.defvjp(_mec_fwd, _mec_bwd)


# ---------------------------------------------------------------------------
# public dispatch
# ---------------------------------------------------------------------------

def _dispatch(x: jnp.ndarray, kernel: jnp.ndarray, spec: ConvSpec,
              s_h: int, s_w: int, algorithm: str, solution: str,
              interpret: Optional[bool], precision,
              w_blk: Optional[int]) -> jnp.ndarray:
    """Single-device execution of a *resolved* algorithm on the
    pre-padded input — the executor core shared by the kwargs path and
    ``conv2d(plan=)``."""
    if algorithm == "direct":
        return direct_conv2d(x, kernel, (s_h, s_w), precision=precision)
    if algorithm == "im2col":
        return im2col_conv2d(x, kernel, (s_h, s_w), precision=precision)
    if algorithm == "fft":
        return fft_conv2d(x, kernel, (s_h, s_w), precision=precision)
    if algorithm == "winograd":
        if (spec.k_h, spec.k_w, s_h, s_w) != (3, 3, 1, 1):
            raise ValueError(
                "winograd F(2x2,3x3) requires a 3x3 kernel and stride 1; "
                f"got kernel {(spec.k_h, spec.k_w)} stride {(s_h, s_w)}")
        return winograd_conv2d(x, kernel, precision=precision)
    return _mec_conv(x, kernel, s_h, s_w, algorithm, solution, interpret,
                     precision, w_blk)


def conv2d(inp: jnp.ndarray, kernel: jnp.ndarray, *, stride=1,
           padding: Padding = "VALID", algorithm: str = "auto",
           solution: str = "auto", interpret: Optional[bool] = None,
           precision=None,
           partition: Union[str, Tuple[str, ...], None] = None,
           partition_axis: Union[str, Tuple[str, ...], None] = None,
           plan: Optional["ConvPlan"] = None) -> jnp.ndarray:
    """2-D convolution, NHWC x HWIO -> NHWC.

    inp: (i_n, i_h, i_w, i_c); kernel: (k_h, k_w, i_c, k_c).
    stride: int or (s_h, s_w).  padding: 'SAME' | 'VALID' | int |
    ((lo, hi), (lo, hi)).  algorithm: one of :data:`ALGORITHMS`.
    solution: MEC Solution 'A' | 'B' | 'auto' (reference path only).
    interpret: force Pallas interpret mode (None = auto: interpret
    everywhere but real TPU).  All MEC algorithms are differentiable via
    the shared custom VJP.

    plan: a resolved :class:`repro.plan.ConvPlan` (DESIGN.md §7).  When
    given, the plan's decision fields — algorithm, solution, precision,
    Pallas ``w_blk``, partition + mesh axes — *win over the kwargs*;
    only the geometry kwargs (stride, padding) remain the caller's and
    must reproduce ``plan.spec`` exactly (mismatch raises).  Without a
    plan, ``algorithm="auto"`` resolves through the plan cache
    (``repro.plan.resolve_cached_plan``: process LRU -> on-disk JSON ->
    analytic costmodel), so repeated shapes reuse one decision.

    partition routes through the distributed layer
    (``repro.parallel.conv.sharded_conv2d``, DESIGN.md §6):
    'batch' | 'channel' | 'spatial' | a composite 2-tuple from
    ``parallel.conv.COMPOSITE_PARTITIONS`` (e.g. ``("batch", "spatial")``
    on a ``data x model`` mesh) | 'auto' split over the installed
    ``parallel.axes`` mesh (no mesh -> single-device no-op); 'none'
    forces single-device; None (default) is rules-aware — sharded 'auto'
    exactly when ``parallel.axes.use_rules`` rules are installed (1-D
    and composite candidates both enumerated by the cost model), so the
    same model code runs on a laptop and a pod.  partition_axis names the
    mesh axis explicitly (a tuple, paired positionally, for composites).
    """
    if plan is not None:
        return _execute_plan(inp, kernel, plan, stride=stride,
                             padding=padding, interpret=interpret)

    if partition != "none":
        # Lazy import: parallel sits above core; call-time routing keeps
        # core import-clean (mirrors the plan/costmodel imports below).
        from repro.parallel.axes import current_rules
        if partition is not None or current_rules() is not None:
            from repro.parallel.conv import sharded_conv2d
            return sharded_conv2d(
                inp, kernel, stride=stride, padding=padding,
                algorithm=algorithm, solution=solution,
                partition=partition or "auto", axis=partition_axis,
                interpret=interpret, precision=precision)

    s_h, s_w = normalize_stride(stride)
    k_h, k_w = kernel.shape[0], kernel.shape[1]
    x = apply_padding(inp, k_h, k_w, s_h, s_w, padding)
    spec = spec_of(x, kernel, (s_h, s_w))

    algorithm = algorithm.lower()
    if algorithm not in ALGORITHMS:
        raise ValueError(
            f"unknown algorithm {algorithm!r}; expected one of {ALGORITHMS}")
    w_blk = None
    if algorithm == "auto":
        # Bare kwargs resolve through the plan cache (DESIGN.md §7):
        # process LRU -> on-disk JSON -> the analytic costmodel pick the
        # pre-planner dispatch made.  Lazy import: plan sits above core.
        from repro.plan import resolve_cached_plan
        cached = resolve_cached_plan(spec, dtype=x.dtype)
        algorithm = cached.algorithm
        w_blk = cached.w_blk
    return _dispatch(x, kernel, spec, s_h, s_w, algorithm, solution,
                     interpret, precision, w_blk)


def _execute_plan(inp: jnp.ndarray, kernel: jnp.ndarray, plan: "ConvPlan",
                  *, stride, padding: Padding,
                  interpret: Optional[bool]) -> jnp.ndarray:
    """Execute exactly the decision a :class:`repro.plan.ConvPlan`
    captured.  The caller's geometry (stride/padding/shapes) must
    reproduce ``plan.spec``; every decision field comes from the plan."""
    s_h, s_w = normalize_stride(stride)
    k_h, k_w = kernel.shape[0], kernel.shape[1]
    x = apply_padding(inp, k_h, k_w, s_h, s_w, padding)
    spec = spec_of(x, kernel, (s_h, s_w))
    plan.check_executable(spec, x.dtype)
    if plan.partition is not None:
        # The plan already holds the partition decision (components +
        # mesh axes); the distributed layer executes it without
        # re-enumerating candidates.  w_blk is not forwarded: the
        # per-device body sees a *local* geometry the global block was
        # not picked for, so it re-derives its own (DESIGN.md §7).
        from repro.parallel.conv import sharded_conv2d
        return sharded_conv2d(
            x, kernel, stride=(s_h, s_w), padding="VALID",
            algorithm=plan.algorithm, solution=plan.solution,
            partition=plan.partition, axis=plan.partition_axes,
            interpret=interpret, precision=plan.precision_value())
    return _dispatch(x, kernel, spec, s_h, s_w, plan.algorithm,
                     plan.solution, interpret, plan.precision_value(),
                     plan.w_blk)


def conv2d_spec(inp: jnp.ndarray, kernel: jnp.ndarray, *, stride=1,
                padding: Padding = "VALID") -> ConvSpec:
    """The post-padding ConvSpec ``conv2d`` would dispatch on (for cost
    and memory accounting — and planning — without running the conv)."""
    s_h, s_w = normalize_stride(stride)
    x = jax.eval_shape(
        lambda a: apply_padding(a, kernel.shape[0], kernel.shape[1],
                                s_h, s_w, padding), inp)
    i_n, i_h, i_w, i_c = x.shape
    return ConvSpec(i_n, i_h, i_w, i_c, kernel.shape[0], kernel.shape[1],
                    kernel.shape[3], s_h, s_w)
