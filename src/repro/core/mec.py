"""MEC: Memory-efficient Convolution (Cho & Brand, ICML 2017) — pure JAX.

Faithful implementation of Algorithm 1 (VanillaMEC) and Algorithm 2 (MEC
with channels/mini-batch and Solutions A/B).  The lowered tensor
``L (i_n, o_w, i_h, k_w, i_c)`` is materialized exactly as in the paper
(Eq. 3) and the o_h output rows are produced by *shifted* reads of L at
stride ``s_h * k_w * i_c`` (the BLAS ld-aliasing trick, here expressed as a
``lax.scan`` of ``dynamic_slice`` + GEMM so no im2col-sized intermediate is
ever created).

The Pallas TPU kernels in ``repro.kernels`` implement the same algorithm
with explicit HBM->VMEM tiling; this module is the algorithmic reference
and the CPU/benchmark path.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.convspec import ConvSpec, spec_of

# Paper §3.3: platform-dependent threshold T for choosing Solution A vs B.
# ("we found T around 100 to be a good threshold for latest GPUs")
SOLUTION_T = 100

# The valid ``solution=`` values (Algorithm 2 line 8); callers that
# validate ahead of tracing (parallel.conv) import this rather than
# duplicating the set.
SOLUTIONS = ("A", "B", "auto")


def pick_solution(spec, threshold: int = SOLUTION_T) -> str:
    """Algorithm 2 line 8: Solution A iff o_w <= T and |O| <= |L|.

    The one copy of the rule — ``mec_conv2d(solution="auto")`` and the
    planner (``repro.plan``) both resolve through it, so a plan's
    recorded solution is exactly what the reference path would pick."""
    size_o = spec.i_n * spec.o_h * spec.o_w * spec.k_c
    size_l = spec.i_n * spec.o_w * spec.i_h * spec.k_w * spec.i_c
    return "A" if (spec.o_w <= threshold and size_o <= size_l) else "B"


def mec_lower(inp: jnp.ndarray, k_w: int, s_w: int) -> jnp.ndarray:
    """Compact lowering, Algorithm 2 lines 4-6.

    inp: (i_n, i_h, i_w, i_c)  ->  L: (i_n, o_w, i_h, k_w, i_c)
    L[n, w, h, :, :] = I[n, h, s_w*w : s_w*w + k_w, :]
    """
    i_n, i_h, i_w, i_c = inp.shape
    o_w = (i_w - k_w) // s_w + 1
    # Gather of width-windows: idx[w, j] = s_w*w + j.
    idx = s_w * jnp.arange(o_w)[:, None] + jnp.arange(k_w)[None, :]
    # (i_n, i_h, o_w, k_w, i_c) -> (i_n, o_w, i_h, k_w, i_c)
    low = inp[:, :, idx, :]
    return jnp.transpose(low, (0, 2, 1, 3, 4))


def _shifted_rows_scan(l_mat: jnp.ndarray, kernel_mat: jnp.ndarray,
                       o_h: int, row_stride: int, window: int,
                       precision) -> jnp.ndarray:
    """Compute the o_h shifted GEMMs: out[h] = L[:, h*row_stride : +window] @ K.

    l_mat: (rows, i_h*k_w*i_c); kernel_mat: (window, k_c).
    Returns (o_h, rows, k_c).  Uses scan so only one window is live at a
    time (this is the JAX analogue of the paper's o_h BLAS calls on
    overlapping sub-matrix views).
    """

    def body(_, h):
        win = lax.dynamic_slice_in_dim(l_mat, h * row_stride, window, axis=1)
        out = jnp.dot(win, kernel_mat, precision=precision,
                      preferred_element_type=jnp.float32)
        return None, out.astype(l_mat.dtype)

    _, rows = lax.scan(body, None, jnp.arange(o_h))
    return rows


@functools.partial(jax.jit, static_argnames=("stride", "solution", "threshold", "precision"))
def mec_conv2d(
    inp: jnp.ndarray,
    kernel: jnp.ndarray,
    stride=1,
    solution: str = "auto",
    threshold: int = SOLUTION_T,
    precision=None,
) -> jnp.ndarray:
    """O = I * K via MEC (Algorithm 2).

    inp: (i_n, i_h, i_w, i_c) pre-padded; kernel: (k_h, k_w, i_c, k_c).
    solution: 'A' | 'B' | 'auto' (paper line 8: A iff o_w <= T and |O| <= |L|).
    Returns (i_n, o_h, o_w, k_c) in n-h-w-c.
    """
    spec = spec_of(inp, kernel, stride)
    i_n, i_h, i_c = spec.i_n, spec.i_h, spec.i_c
    k_h, k_w, k_c = spec.k_h, spec.k_w, spec.k_c
    o_h, o_w = spec.o_h, spec.o_w
    s_h = spec.s_h

    if solution == "auto":
        solution = pick_solution(spec, threshold)

    low = mec_lower(inp, k_w, spec.s_w)  # (i_n, o_w, i_h, k_w, i_c)
    kernel_mat = kernel.reshape(k_h * k_w * i_c, k_c).astype(low.dtype)
    row_stride = s_h * k_w * i_c
    window = k_h * k_w * i_c

    if solution == "A":
        # Lines 9-19: one GEMM per output row over the whole mini-batch.
        l_mat = low.reshape(i_n * o_w, i_h * k_w * i_c)
        rows = _shifted_rows_scan(l_mat, kernel_mat, o_h, row_stride, window,
                                  precision)  # (o_h, i_n*o_w, k_c)
        # Intermediate is h-n-w-c (line 13); restore n-h-w-c (lines 14-19).
        out = rows.reshape(o_h, i_n, o_w, k_c)
        return jnp.transpose(out, (1, 0, 2, 3))

    if solution == "B":
        # Lines 21-25: per-sample GEMMs -> directly n-h-w-c.
        l_mat = low.reshape(i_n, o_w, i_h * k_w * i_c)

        def body(_, h):
            win = lax.dynamic_slice_in_dim(l_mat, h * row_stride, window, axis=2)
            out = jnp.einsum("nwk,kc->nwc", win, kernel_mat,
                             precision=precision,
                             preferred_element_type=jnp.float32)
            return None, out.astype(low.dtype)

        _, rows = lax.scan(body, None, jnp.arange(o_h))  # (o_h, i_n, o_w, k_c)
        return jnp.transpose(rows, (1, 0, 2, 3))

    raise ValueError(f"unknown solution {solution!r}")


@functools.partial(jax.jit, static_argnames=("stride",))
def vanilla_mec(inp: jnp.ndarray, kernel: jnp.ndarray, stride=1) -> jnp.ndarray:
    """Algorithm 1: single channel, single sample.

    inp: (i_h, i_w); kernel: (k_h, k_w).  Returns (o_h, o_w).
    """
    i_h, i_w = inp.shape
    k_h, k_w = kernel.shape
    s_h, s_w = (stride, stride) if isinstance(stride, int) else stride
    o_h = (i_h - k_h) // s_h + 1
    o_w = (i_w - k_w) // s_w + 1

    # Lines 4-6: L[w, h, 0:k_w] = I[h, s_w*w : s_w*w + k_w]
    idx = s_w * jnp.arange(o_w)[:, None] + jnp.arange(k_w)[None, :]
    low = jnp.transpose(inp[:, idx], (1, 0, 2))  # (o_w, i_h, k_w)
    l_mat = low.reshape(o_w, i_h * k_w)
    kernel_mat = kernel.reshape(k_h * k_w, 1)

    # Lines 10-12: O[h] = L[0:o_w, s_h*k_w*h : +k_h*k_w] x K
    def body(_, h):
        win = lax.dynamic_slice_in_dim(l_mat, h * s_h * k_w, k_h * k_w, axis=1)
        return None, (win @ kernel_mat)[:, 0]

    _, out = lax.scan(body, None, jnp.arange(o_h))
    return out  # (o_h, o_w)


def mec_conv1d_shift(inp: jnp.ndarray, kernel: jnp.ndarray,
                     causal: bool = True) -> jnp.ndarray:
    """Fused-dataflow causal depthwise conv1d: k_w shifted scaled adds,
    no lowered tensor at all (the XLA-level expression of what the fused
    Pallas kernel does in VMEM).  Same math as mec_conv1d_depthwise but
    ~k_w x less intermediate HBM traffic."""
    n, t, c = inp.shape
    k_w, kc = kernel.shape
    assert kc == c, (kernel.shape, inp.shape)
    pad = k_w - 1 if causal else 0
    xp = jnp.pad(inp, ((0, 0), (pad, 0), (0, 0))) if pad else inp
    acc = jnp.zeros((n, t, c), jnp.float32)
    for j in range(k_w):
        acc = acc + xp[:, j:j + t, :].astype(jnp.float32) * kernel[j]
    return acc.astype(inp.dtype)


def mec_conv1d_depthwise(inp: jnp.ndarray, kernel: jnp.ndarray,
                         causal: bool = True,
                         precision=None) -> jnp.ndarray:
    """Depthwise causal conv1d via the MEC column-strip lowering.

    inp: (n, t, c); kernel: (k_w, c).  In 1-D the compact L coincides with
    im2col (no vertical axis to deduplicate — Eq. 4 with i_h == k_h == 1);
    the memory win here comes from the fused Pallas kernel
    (repro.kernels.mec_conv1d) which never materializes L.  This reference
    materializes the small L for oracle purposes.
    """
    n, t, c = inp.shape
    k_w, kc = kernel.shape
    assert kc == c, (kernel.shape, inp.shape)
    if causal:
        inp = jnp.pad(inp, ((0, 0), (k_w - 1, 0), (0, 0)))
    idx = jnp.arange(t)[:, None] + jnp.arange(k_w)[None, :]
    low = inp[:, idx, :]  # (n, t, k_w, c)
    return jnp.einsum("ntkc,kc->ntc", low, kernel, precision=precision)
