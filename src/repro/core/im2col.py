"""Conventional im2col-based convolution (the paper's main baseline).

Lowers the input into the full Toeplitz matrix ``(i_n*o_h*o_w, k_h*k_w*i_c)``
(paper Eq. 2) and performs a single GEMM — exactly the Conv.cpu/Conv.gpu
baseline of §4.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.convspec import spec_of


def im2col_lower(inp: jnp.ndarray, k_h: int, k_w: int, s_h: int, s_w: int) -> jnp.ndarray:
    """inp (i_n, i_h, i_w, i_c) -> L (i_n*o_h*o_w, k_h*k_w*i_c)."""
    i_n, i_h, i_w, i_c = inp.shape
    o_h = (i_h - k_h) // s_h + 1
    o_w = (i_w - k_w) // s_w + 1
    hidx = s_h * jnp.arange(o_h)[:, None] + jnp.arange(k_h)[None, :]  # (o_h, k_h)
    widx = s_w * jnp.arange(o_w)[:, None] + jnp.arange(k_w)[None, :]  # (o_w, k_w)
    # (i_n, o_h, k_h, o_w, k_w, i_c)
    low = inp[:, hidx[:, :, None, None], widx[None, None, :, :], :]
    low = jnp.transpose(low, (0, 1, 3, 2, 4, 5))  # (i_n, o_h, o_w, k_h, k_w, i_c)
    return low.reshape(i_n * o_h * o_w, k_h * k_w * i_c)


@functools.partial(jax.jit, static_argnames=("stride", "precision"))
def im2col_conv2d(inp: jnp.ndarray, kernel: jnp.ndarray, stride=1,
                  precision=None) -> jnp.ndarray:
    spec = spec_of(inp, kernel, stride)
    low = im2col_lower(inp, spec.k_h, spec.k_w, spec.s_h, spec.s_w)
    kernel_mat = kernel.reshape(spec.k_h * spec.k_w * spec.i_c, spec.k_c)
    out = jnp.dot(low, kernel_mat.astype(low.dtype), precision=precision,
                  preferred_element_type=jnp.float32).astype(low.dtype)
    return out.reshape(spec.out_shape)
