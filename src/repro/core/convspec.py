"""Shared convolution geometry helpers (paper Table 1 / Eq. 1).

All tensors are NHWC (the paper's n-h-w-c) and kernels are HWIO
(k_h, k_w, i_c, k_c).  Padding is assumed to have been applied to the
input already (paper §2.1); helpers to apply SAME/VALID padding live here
so every algorithm sees an identical pre-padded input.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    """Geometry of one 2-D convolution, pre-padding (paper Eq. 1)."""

    i_n: int
    i_h: int
    i_w: int
    i_c: int
    k_h: int
    k_w: int
    k_c: int
    s_h: int = 1
    s_w: int = 1

    @property
    def o_h(self) -> int:
        return (self.i_h - self.k_h) // self.s_h + 1

    @property
    def o_w(self) -> int:
        return (self.i_w - self.k_w) // self.s_w + 1

    @property
    def out_shape(self) -> Tuple[int, int, int, int]:
        return (self.i_n, self.o_h, self.o_w, self.k_c)

    def validate(self) -> None:
        if self.i_h < self.k_h or self.i_w < self.k_w:
            raise ValueError(f"kernel larger than input: {self}")
        if min(self.s_h, self.s_w) < 1:
            raise ValueError(f"strides must be >= 1: {self}")


def normalize_stride(stride) -> Tuple[int, int]:
    """Canonical ``(s_h, s_w)`` from an int or a 2-sequence.

    The one stride normalizer in the repo: ``spec_of``, the ``conv2d``
    front-end, and the distributed layer all resolve strides here, so a
    bad stride fails identically everywhere."""
    s_h, s_w = (stride, stride) if isinstance(stride, int) else tuple(stride)
    if min(s_h, s_w) < 1:
        raise ValueError(f"strides must be >= 1, got {(s_h, s_w)}")
    return s_h, s_w


def padding_amounts(i_h: int, i_w: int, k_h: int, k_w: int,
                    s_h: int, s_w: int, padding) -> Tuple[int, int]:
    """Total (rows, cols) ``conv_api.apply_padding`` would add — the same
    SAME/VALID/int/explicit resolution, as pure arithmetic (no arrays),
    so analytic models can size post-padding geometry without tracing."""
    if isinstance(padding, str):
        mode = padding.upper()
        if mode == "VALID":
            return 0, 0
        if mode == "SAME":
            o_h, o_w = -(-i_h // s_h), -(-i_w // s_w)
            return (max((o_h - 1) * s_h + k_h - i_h, 0),
                    max((o_w - 1) * s_w + k_w - i_w, 0))
        raise ValueError(f"unknown padding {padding!r}")
    if isinstance(padding, int):
        padding = ((padding, padding), (padding, padding))
    p_h, p_w = padding
    if isinstance(p_h, int):
        p_h = (p_h, p_h)
    if isinstance(p_w, int):
        p_w = (p_w, p_w)
    if min(tuple(p_h) + tuple(p_w)) < 0:
        raise ValueError(f"padding must be non-negative, got {(p_h, p_w)}")
    return sum(p_h), sum(p_w)


def padded_spec(s: ConvSpec, padding) -> ConvSpec:
    """The post-padding ConvSpec of a pre-padding geometry + padding mode
    — what ``conv2d`` actually dispatches (and every algorithm actually
    allocates) on.  VALID is the identity."""
    pad_h, pad_w = padding_amounts(s.i_h, s.i_w, s.k_h, s.k_w,
                                   s.s_h, s.s_w, padding)
    if pad_h == 0 and pad_w == 0:
        return s
    return dataclasses.replace(s, i_h=s.i_h + pad_h, i_w=s.i_w + pad_w)


def spec_of(inp: jnp.ndarray, kernel: jnp.ndarray, stride) -> ConvSpec:
    s_h, s_w = normalize_stride(stride)
    i_n, i_h, i_w, i_c = inp.shape
    k_h, k_w, kic, k_c = kernel.shape
    if kic != i_c:
        raise ValueError(f"channel mismatch: input {i_c} kernel {kic}")
    spec = ConvSpec(i_n, i_h, i_w, i_c, k_h, k_w, k_c, s_h, s_w)
    spec.validate()
    return spec


def pad_same(inp: jnp.ndarray, k_h: int, k_w: int, s_h: int = 1, s_w: int = 1) -> jnp.ndarray:
    """Explicit SAME padding (the paper assumes pre-padded input)."""
    _, i_h, i_w, _ = inp.shape
    o_h = -(-i_h // s_h)
    o_w = -(-i_w // s_w)
    pad_h = max((o_h - 1) * s_h + k_h - i_h, 0)
    pad_w = max((o_w - 1) * s_w + k_w - i_w, 0)
    return jnp.pad(
        inp,
        ((0, 0), (pad_h // 2, pad_h - pad_h // 2), (pad_w // 2, pad_w - pad_w // 2), (0, 0)),
    )
