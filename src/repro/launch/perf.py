import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Perf-iteration harness (assignment §Perf): re-lower one cell with
config overrides and report before/after evidence:

* analytic roofline terms (repro.launch.costmodel),
* collective mix of the partitioned HLO (per-loop-body operand bytes —
  XLA counts while bodies once, so these are per-layer-ish units, ideal
  for before/after comparison of the collective *pattern*),
* compiled peak memory per device.

  PYTHONPATH=src python -m repro.launch.perf --arch qwen3-moe-30b-a3b \
      --shape train_4k --set seq_parallel=True --tag sp
"""
import argparse
import ast
import json
import pathlib

from repro.launch.dryrun import RESULTS, run_cell

PERF_DIR = RESULTS.parent / "perf"


def parse_override(kv: str):
    k, v = kv.split("=", 1)
    try:
        return k, ast.literal_eval(v)
    except (ValueError, SyntaxError):
        return k, v


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override, e.g. seq_parallel=True")
    ap.add_argument("--tag", default="base")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    overrides = dict(parse_override(kv) for kv in args.set)
    PERF_DIR.mkdir(parents=True, exist_ok=True)
    res = run_cell(args.arch, args.shape, args.multi_pod, PERF_DIR,
                   overrides=overrides or None,
                   tag_suffix=f"__{args.tag}")
    # attach analytic terms for the same overrides
    from repro.configs.archs import ARCHS
    from repro.configs.shapes import SHAPES
    from repro.launch.costmodel import MeshShape, cell_cost
    from repro.launch.hlo_analysis import HBM_BW, ICI_BW, PEAK_FLOPS
    cfg = ARCHS[args.arch].with_(**overrides)
    cell = SHAPES[args.shape]
    mesh = MeshShape(pod=2 if args.multi_pod else 1)
    c = cell_cost(cfg, cell.kind, cell.global_batch, cell.seq_len, mesh)
    t_c = c["flops"] / (mesh.chips * PEAK_FLOPS)
    t_m = c["hbm_bytes_chip"] / HBM_BW
    t_x = c["coll_bytes_chip"] / ICI_BW
    t_model = c["model_flops"] / (mesh.chips * PEAK_FLOPS)
    analytic = {"t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
                "roofline_frac": t_model / max(t_c, t_m, t_x)}
    tag = (f"{args.arch}__{args.shape}__"
           f"{'multipod' if args.multi_pod else 'pod'}__{args.tag}")
    path = PERF_DIR / f"{tag}.json"
    data = json.loads(path.read_text())
    data["analytic"] = analytic
    path.write_text(json.dumps(data, indent=2))
    print(f"[perf] {tag}: frac={analytic['roofline_frac']:.3f} "
          f"tc={t_c:.3f}s tm={t_m:.3f}s tx={t_x:.3f}s")


if __name__ == "__main__":
    main()
