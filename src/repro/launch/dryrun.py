import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production meshes and record memory/cost/collective analysis.

The two lines above MUST stay first: jax locks the device count on first
initialization.  Usage:

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all            # every cell
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod

Results are appended as JSON files under results/dryrun/ (one per cell) —
benchmarks/roofline.py and EXPERIMENTS.md read from there.
"""
import argparse
import dataclasses
import json
import math
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.archs import ARCHS
from repro.configs.shapes import SHAPES, cell_applicable, input_specs
from repro.core.compat import cost_analysis
from repro.core.convspec import ConvSpec
from repro.launch.costmodel import conv_partition_costs
from repro.launch.hlo_analysis import collective_bytes, roofline_terms
from repro.launch.mesh import make_production_mesh
from repro.models.lm import LM
from repro.optim.adamw import AdamWConfig
from repro.parallel import sharding
from repro.parallel.axes import default_rules
from repro.parallel.conv import (conv_partition_specs, default_axis,
                                 normalize_partition, partition_name,
                                 sharded_conv2d)
from repro.training import steps

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"

# Distributed-conv dry-run cells (DESIGN.md §6): one per partition mode,
# geometry sized so the 16-way production axes divide it (specs are
# pre-padded / VALID).  Each cell compiles a value_and_grad so the halo
# exchange AND its transpose are exercised at mesh scale.  The composite
# batch x spatial cell shards the input on (i_n, i_h) over data x model
# (pod x model on the 512-chip mesh) and subsumes the old batch-only
# cell — batch is its comm-free sub-axis, so a separate 1-D batch cell
# would only re-compile the same body and push the slow-dryrun CI
# workflow past its budget.
CONV_CELLS = {
    "conv_channel": {"spec": ConvSpec(8, 56, 56, 64, 3, 3, 256, 1, 1),
                     "partition": "channel"},
    "conv_spatial": {"spec": ConvSpec(8, 224, 224, 3, 7, 7, 64, 2, 2),
                     "partition": "spatial"},
    "conv_batch_spatial": {
        "spec": ConvSpec(32, 224, 224, 3, 7, 7, 64, 2, 2),
        "partition": ("batch", "spatial")},
}


def _named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def batch_shardings(mesh, rules, batch_specs):
    b_ax = rules.rules.get("batch")
    sizes = dict(mesh.shape)

    def one(_path, leaf):
        spec = [None] * len(leaf.shape)
        if len(leaf.shape) >= 1 and b_ax is not None:
            axes = (b_ax,) if isinstance(b_ax, str) else tuple(b_ax)
            prod = 1
            for a in axes:
                prod *= sizes[a]
            if leaf.shape[0] % prod == 0:
                spec[0] = b_ax
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, batch_specs)


def lower_cell(arch: str, shape: str, mesh, rules, opt_total_steps=1000,
               cfg=None):
    cfg = cfg or ARCHS[arch]
    cell = SHAPES[shape]
    model = LM(cfg)
    specs = input_specs(cfg, cell)

    params_shape = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    p_specs = sharding.param_specs(params_shape, mesh)
    p_shard = _named(mesh, p_specs)

    if cell.kind == "train":
        compressed = getattr(cfg, "grad_compress_int8", False)
        opt_shape = jax.eval_shape(
            lambda: steps.init_opt_state(params_shape, compressed=compressed))
        o_specs = sharding.opt_state_specs(
            p_specs, params_shape, mesh,
            zero_axes=tuple(a for a in ("pod", "data") if a in mesh.axis_names))
        if compressed:
            # ef holds per-DP-shard residuals behind an (unchecked)
            # replicated spec — see make_compressed_train_step
            o_specs = dict(o_specs, ef=jax.tree.map(
                lambda l: P(*([None] * len(l.shape))), params_shape))
        o_shard = _named(mesh, o_specs)
        b_shard = batch_shardings(mesh, rules, specs)
        builder = (steps.make_compressed_train_step if compressed
                   else steps.make_train_step)
        step = builder(model, AdamWConfig(total_steps=opt_total_steps), rules)
        fn = jax.jit(step,
                     in_shardings=(p_shard, o_shard, b_shard),
                     out_shardings=(p_shard, o_shard, None),
                     donate_argnums=(0, 1))
        args = (params_shape,
                jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype),
                             opt_shape), specs)
    elif cell.kind == "prefill":
        b_shard = batch_shardings(mesh, rules, specs)
        cache_shape = jax.eval_shape(
            lambda p, b: steps.make_prefill_step(model, cell.seq_len, rules)(p, b),
            params_shape, specs)[1]
        c_specs = sharding.cache_specs(cache_shape, mesh, rules)
        fn = jax.jit(steps.make_prefill_step(model, cell.seq_len, rules),
                     in_shardings=(p_shard, b_shard),
                     out_shardings=(None, _named(mesh, c_specs)))
        args = (params_shape, specs)
    else:  # decode
        c_specs = sharding.cache_specs(specs["cache"], mesh, rules)
        c_shard = _named(mesh, c_specs)
        t_shard = batch_shardings(mesh, rules, specs["tokens"])
        fn = jax.jit(steps.make_decode_step(model, rules),
                     in_shardings=(p_shard, c_shard, t_shard),
                     out_shardings=(None, c_shard),
                     donate_argnums=(1,))
        args = (params_shape, specs["cache"], specs["tokens"])

    lowered = fn.lower(*args)
    return lowered, cfg, cell


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: pathlib.Path,
             overrides=None, tag_suffix: str = ""):
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = default_rules(mesh)
    n_chips = mesh.devices.size
    cfg = ARCHS[arch].with_(**overrides) if overrides else None
    t0 = time.time()
    with mesh:
        lowered, cfg, cell = lower_cell(arch, shape, mesh, rules, cfg=cfg)
        t_lower = time.time() - t0
        compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = cost_analysis(compiled)     # per-device (partitioned module)
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)

    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    terms = roofline_terms(flops_dev, bytes_dev, float(coll["total"]),
                           n_chips=1)   # per-chip inputs
    model_flops = 6 * cfg.param_count(active_only=True) * \
        cell.seq_len * cell.global_batch
    if cell.kind == "decode":
        model_flops = 2 * cfg.param_count(active_only=True) * cell.global_batch
    if cell.kind == "prefill":
        model_flops = 2 * cfg.param_count(active_only=True) * \
            cell.seq_len * cell.global_batch

    result = {
        "arch": arch, "shape": shape, "kind": cell.kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "per_device": {
            "flops": flops_dev, "bytes_accessed": bytes_dev,
            "collectives": coll,
            "memory": {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
            },
        },
        "roofline": terms,
        "model_flops_global": model_flops,
        "model_flops_per_chip": model_flops / n_chips,
        "useful_flop_ratio": (model_flops / n_chips) / max(flops_dev, 1.0),
    }
    out_dir.mkdir(parents=True, exist_ok=True)
    tag = f"{arch}__{shape}__{'multipod' if multi_pod else 'pod'}{tag_suffix}"
    if overrides:
        result["overrides"] = {k: str(v) for k, v in overrides.items()}
    (out_dir / f"{tag}.json").write_text(json.dumps(result, indent=2))
    print(f"[dryrun] {tag}: compile={t_compile:.0f}s "
          f"flops/dev={flops_dev:.3e} coll/dev={coll['total']:.3e}B "
          f"dominant={terms['dominant']}")
    return result


def run_conv_cell(name: str, multi_pod: bool, out_dir: pathlib.Path,
                  algorithm: str = "mec"):
    """Lower + compile one sharded_conv2d train-style cell (fwd + grad)
    on the production mesh and record memory / collective analysis.
    The compiled collectives are verified against the full shardcheck
    contract (repro.analysis.shardcheck, DESIGN.md §8) — halo permute
    and backward-psum bytes must match the costmodel exactly, and no
    unpriced reshard collective may appear — so a silent loss of the
    halo exchange (or any GSPMD reshard regression) fails the dry-run
    with the breach spelled out, not just a bare `> 0` check."""
    cell = CONV_CELLS[name]
    spec, partition = cell["spec"], cell["partition"]
    parts = normalize_partition(partition)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = default_rules(mesh)
    axis = default_axis(partition, mesh, rules)
    axes = (axis,) if isinstance(axis, str) else axis
    n_axes = tuple(int(mesh.shape[a]) for a in axes)
    n_dev = n_axes[0] if len(parts) == 1 else n_axes
    x_spec, k_spec, _ = conv_partition_specs(partition, axis)
    x = jax.ShapeDtypeStruct((spec.i_n, spec.i_h, spec.i_w, spec.i_c),
                             jnp.float32)
    k = jax.ShapeDtypeStruct((spec.k_h, spec.k_w, spec.i_c, spec.k_c),
                             jnp.float32)

    def loss(xv, kv):
        out = sharded_conv2d(xv, kv, stride=(spec.s_h, spec.s_w),
                             padding="VALID", algorithm=algorithm,
                             partition=partition, axis=axis, mesh=mesh,
                             rules=rules)
        return jnp.sum(out * out)

    x_sh = NamedSharding(mesh, x_spec)
    k_sh = NamedSharding(mesh, k_spec)
    t0 = time.time()
    with mesh:
        # Gradients pinned to the input shardings (the shard_map
        # transpose already produces them that way) and the scalar loss
        # replicated: left free, GSPMD reshards the gradient outputs and
        # the extra traffic would (rightly) fail the contract below.
        fn = jax.jit(jax.value_and_grad(loss, argnums=(0, 1)),
                     in_shardings=(x_sh, k_sh),
                     out_shardings=(NamedSharding(mesh, P()),
                                    (x_sh, k_sh)))
        lowered = fn.lower(x, k)
        t_lower = time.time() - t0
        compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = cost_analysis(compiled)
    coll = collective_bytes(compiled.as_text())
    analytic = conv_partition_costs(spec, n_dev)[
        parts if len(parts) > 1 else parts[0]]
    # The dry-run program is value_and_grad, i.e. shardcheck's 'grad'
    # direction: forward halo + transposed cotangent on the permute,
    # every backward psum on the all-reduce.
    from repro.analysis.shardcheck import (expected_collectives,
                                           verify_collectives)
    # The production mesh is larger than the partition: the unused axes
    # replicate the cell, and GSPMD may shard the backward over them
    # (expected_collectives prices that combine as optional traffic).
    replicated = int(mesh.devices.size) // math.prod(n_axes)
    required, optional, unmodeled = expected_collectives(
        spec, parts, n_axes, 4, "grad", replicated_ways=replicated)
    if unmodeled is not None:
        violations = []
        shardcheck = {"verdict": "skipped", "skipped_reason": unmodeled}
    else:
        violations = verify_collectives(
            coll, required, "grad", label=name, dtype_bytes=4,
            optional=optional)
        shardcheck = {
            "verdict": "pass" if not violations else "fail",
            "skipped_reason": None,
            "replicated_ways": replicated,
            "expected": required, "optional": optional,
            "observed": {k: int(coll.get(k, 0))
                         for k in required},
            "violations": [v.render() for v in violations],
        }
    assert not violations, (
        f"{name}: compiled collectives break the shardcheck contract:\n  "
        + "\n  ".join(v.render() for v in violations))
    result = {
        "cell": name, "kind": "conv_grad", "algorithm": algorithm,
        "partition": partition_name(partition), "axis": list(axes),
        "n_axis": list(n_axes),
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": int(mesh.devices.size),
        "spec": dataclasses.asdict(spec),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "per_device": {
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
            "collectives": coll,
            "memory": {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
            },
        },
        "analytic": analytic,
        "shardcheck": shardcheck,
    }
    out_dir.mkdir(parents=True, exist_ok=True)
    tag = f"{name}__{'multipod' if multi_pod else 'pod'}"
    (out_dir / f"{tag}.json").write_text(json.dumps(result, indent=2))
    print(f"[dryrun] {tag}: compile={t_compile:.0f}s "
          f"coll/dev={coll['total']:.3e}B "
          f"halo/dev={analytic['halo_bytes_per_device']:.3e}B")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--conv", default=None,
                    help="compile a sharded_conv2d cell instead of an LM "
                         f"cell: one of {sorted(CONV_CELLS)} or 'all'")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out", default=str(RESULTS))
    args = ap.parse_args()
    out_dir = pathlib.Path(args.out)

    if args.conv:
        names = sorted(CONV_CELLS) if args.conv == "all" else [args.conv]
        meshes = [False, True] if args.both_meshes else [args.multi_pod]
        failures = []
        for name in names:
            for mp in meshes:
                tag = f"{name}__{'multipod' if mp else 'pod'}"
                try:
                    run_conv_cell(name, mp, out_dir)
                except Exception as e:
                    failures.append((tag, repr(e)))
                    print(f"[dryrun] {tag}: FAILED {e}")
                    traceback.print_exc()
        if failures:
            raise SystemExit(f"{len(failures)} conv dry-run cells failed: "
                             + ", ".join(t for t, _ in failures))
        print(f"[dryrun] all {len(names) * len(meshes)} conv cells OK")
        return

    cells = []
    archs = list(ARCHS) if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for arch in archs:
        for shape in shapes:
            if not cell_applicable(arch, shape):
                continue
            for mp in meshes:
                cells.append((arch, shape, mp))

    failures = []
    for arch, shape, mp in cells:
        tag = f"{arch}__{shape}__{'multipod' if mp else 'pod'}"
        if args.skip_existing and (out_dir / f"{tag}.json").exists():
            print(f"[dryrun] {tag}: cached")
            continue
        try:
            run_cell(arch, shape, mp, out_dir)
        except Exception as e:
            failures.append((tag, repr(e)))
            print(f"[dryrun] {tag}: FAILED {e}")
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} dry-run cells failed: "
                         + ", ".join(t for t, _ in failures))
    print(f"[dryrun] all {len(cells)} cells OK")


if __name__ == "__main__":
    main()
