"""Analytic whole-step cost model (FLOPs / HBM bytes / collective bytes).

Why this exists: XLA's ``compiled.cost_analysis()`` counts each ``while``
body ONCE, not x trip-count (verified on this jax build: a 10-iteration
scan of matmuls reports the same flops as one matmul).  Our stacks scan
over layers, so raw HLO numbers under-count by ~n_layers.  The roofline
table therefore uses this explicit, auditable model for the three terms;
the raw per-device HLO numbers from the dry-run are kept alongside as a
lower bound (they remain useful for comparing collective *mixes*).

Conventions/assumptions (all documented in EXPERIMENTS.md):
* matmul flops = 2*M*N*K; attention runs the full S^2 (the streaming
  kernel computes masked upper chunks too — counted, since the machine
  executes them).
* train = fwd + remat-refwd + bwd(2x) = 4x block fwd flops; logits 3x.
* HBM traffic: every weight byte read once per fwd/refwd/bwd pass and
  read+written once by the optimizer (f32 moments); activations cross HBM
  ~8x hidden bytes per block per pass (reads+writes of residual/attn/mlp
  streams) — a calibrated coefficient, not a fiction: see EXPERIMENTS.md
  S Roofline notes.
* collectives (per chip, operand-size convention):
  TP: 2 hidden all-reduces per block fwd (x3 passes with remat-refwd);
  EP: 2 all_to_alls of the local dispatch buffer per MoE block per pass;
  DP: one gradient all-reduce of the model-sharded param bytes (f32).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict

from repro.models.config import ModelConfig

BF16 = 2
F32 = 4


@dataclasses.dataclass(frozen=True)
class MeshShape:
    pod: int = 1
    data: int = 16
    model: int = 16

    @property
    def chips(self) -> int:
        return self.pod * self.data * self.model

    @property
    def dp(self) -> int:
        return self.pod * self.data


def _attn_flops_fwd(cfg, b, s, s_kv=None) -> float:
    s_kv = s_kv or s
    hd, h, kv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    d = cfg.d_model
    proj = 2 * b * s * d * hd * (h + 2 * kv) + 2 * b * s * h * hd * d
    # qk^T + av; the triangular kernel (attn_skip_masked) visits only the
    # causal half of the chunk grid
    factor = 2 if getattr(cfg, "attn_skip_masked", False) else 4
    scores = factor * b * h * s * s_kv * hd
    return proj + scores


def _block_flops_fwd(cfg, b, s) -> Dict[str, float]:
    d = cfg.d_model
    out = {"attn": 0.0, "mlp": 0.0, "ssm": 0.0}
    fam = cfg.family
    if fam in ("dense", "vlm", "moe", "audio"):
        out["attn"] = _attn_flops_fwd(cfg, b, s)
        f = cfg.moe_d_ff if fam == "moe" else cfg.d_ff
        mult = cfg.top_k + cfg.n_shared_experts if fam == "moe" else 1
        out["mlp"] = 3 * 2 * b * s * d * f * mult
        if fam == "moe":
            out["mlp"] += 2 * b * s * d * cfg.n_experts     # router
    if fam == "hybrid":
        d_in = cfg.ssm_expand * d
        n = cfg.ssm_state
        h = d_in // cfg.ssm_head_dim
        proj = 2 * b * s * d * (2 * d_in + 2 * n + h) + 2 * b * s * d_in * d
        conv = 2 * b * s * cfg.conv_width * (d_in + 2 * n)
        # SSD: intra-chunk quadratic (chunk=128) + state updates
        chunk = 128
        ssd = (2 * b * s * chunk * n            # C B^T within chunk
               + 2 * b * s * chunk * h * cfg.ssm_head_dim
               + 4 * b * s * h * cfg.ssm_head_dim * n)
        out["ssm"] = proj + conv + ssd
    if fam == "ssm":
        d_in = 2 * d
        proj = 2 * b * s * d * 2 * d_in + 3 * 2 * b * s * d_in * d_in \
            + 2 * b * s * d_in * d
        quad = 4 * b * cfg.n_heads * s * s * (d_in // cfg.n_heads)
        out["ssm"] = proj + quad
    return out


def _layer_multiplier(cfg) -> float:
    return cfg.n_layers + (cfg.encoder_layers if cfg.family == "audio" else 0)


def flops_fwd(cfg, b, s) -> float:
    blk = _block_flops_fwd(cfg, b, s)
    per_layer = sum(blk.values())
    total = per_layer * cfg.n_layers
    if cfg.family == "audio":
        enc = _attn_flops_fwd(cfg, b, cfg.encoder_len) + \
            2 * 2 * b * cfg.encoder_len * cfg.d_model * cfg.d_ff
        total += enc * cfg.encoder_layers
        total += _attn_flops_fwd(cfg, b, s, cfg.encoder_len) * cfg.n_layers
    if cfg.family == "hybrid":
        n_apps = cfg.n_layers // max(1, cfg.attn_every)
        shared = _attn_flops_fwd(cfg, b, s) + 3 * 2 * b * s * cfg.d_model * cfg.d_ff
        total += shared * n_apps - 0  # shared block applied n_apps times
    return total


def logits_flops(cfg, b, s) -> float:
    return 2 * b * s * cfg.d_model * cfg.vocab


def params_bytes(cfg, dtype_bytes=BF16) -> float:
    return cfg.param_count() * dtype_bytes


# --------------------------------------------------------------------- train
def train_cost(cfg: ModelConfig, b: int, s: int, mesh: MeshShape) -> Dict:
    fwd = flops_fwd(cfg, b, s)
    lg = logits_flops(cfg, b, s)
    # remat_policy="dots": matmul outputs are saved, the recompute pass
    # re-runs only elementwise ops (~15% of fwd flops) and NO collectives
    remat = 0.0 if not cfg.remat else \
        (0.15 if cfg.remat_policy == "dots" else 1.0)
    flops = fwd * (3 + remat) + lg * 3
    # HBM: weights (3+remat passes) + optimizer (read m,v,p + write) + acts
    w = params_bytes(cfg) / mesh.chips
    opt = cfg.param_count() * (3 * F32 * 2) / mesh.chips     # m,v,master rw
    act = (8 * _layer_multiplier(cfg) * (b / mesh.dp) * s * cfg.d_model
           * BF16 * (3 + remat))
    # NOTE (refuted hypothesis, EXPERIMENTS §Perf zamba2 iter 1): we first
    # charged the 'lowered' conv1d dataflow k_w x conv-channel bytes for a
    # materialized L per block, but the compiled HLO shows XLA fuses the
    # gather into the reduction — no L buffer exists and bytes-accessed are
    # ~equal for both dataflows.  The term is therefore NOT charged; the
    # fused Pallas kernel remains the *guaranteed* no-L path on TPU.
    hbm = w * (3 + remat) + opt + act
    # collectives per chip (operand-size convention)
    hid = (b / mesh.dp) * s * cfg.d_model * BF16
    passes = 2 + (1 if remat == 1.0 else 0)   # dots policy: no refwd colls
    tp_ar = _tp_ars_per_stack(cfg) * hid * passes
    ep = 0.0
    if cfg.family == "moe":
        # int8 dispatch: 1 byte/elem + one bf16 scale per row
        elem = (1 + 2.0 / cfg.d_model) if getattr(
            cfg, "moe_dispatch_int8", False) else BF16
        tok_bytes = (b / mesh.dp) * (s / mesh.model) * cfg.top_k \
            * cfg.d_model * elem * cfg.capacity_factor
        ep = 2 * cfg.n_layers * tok_bytes * passes
    # gradient all-reduce over DP: grads carry the param dtype (bf16);
    # int8-EF compression gathers 1 byte/elem instead (conservative 2x in
    # the operand-bytes convention; the real ring-AR wire saving is ~8x)
    grad_byte = 1 if getattr(cfg, "grad_compress_int8", False) else BF16
    dp_ar = cfg.param_count() / mesh.model * grad_byte if mesh.dp > 1 else 0.0
    coll = tp_ar + ep + dp_ar
    return {"flops": flops, "hbm_bytes_chip": hbm, "coll_bytes_chip": coll,
            "model_flops": 6 * cfg.param_count(active_only=True) * b * s}


def _tp_ars_per_stack(cfg) -> float:
    """Hidden-sized TP all-reduces per forward pass of the whole stack.

    Dense/attention block: 2 (attn out-proj + MLP down-proj row-parallel).
    With sequence-parallel residual segments (cfg.seq_parallel) the pair
    becomes RS+AG at half the operand bytes each -> counts as 1.
    Mamba2 block: 1 (out_proj).  xLSTM: 1 (down).  MoE block: 1 attn AR +
    SP gather/scatter around the a2a (~1).
    """
    sp = 0.5 if getattr(cfg, "seq_parallel", False) else 1.0
    if cfg.family in ("dense", "vlm"):
        return 2 * cfg.n_layers * sp
    if cfg.family == "moe":
        return (1 + 1) * cfg.n_layers * sp
    if cfg.family == "hybrid":
        n_apps = cfg.n_layers // max(1, cfg.attn_every)
        return (1 * cfg.n_layers + 2 * n_apps) * sp
    if cfg.family == "ssm":
        return 1 * cfg.n_layers
    if cfg.family == "audio":
        return 2 * (cfg.n_layers + cfg.encoder_layers) + cfg.n_layers
    return 2 * cfg.n_layers


# ------------------------------------------------------------------- prefill
def prefill_cost(cfg, b, s, mesh: MeshShape) -> Dict:
    fwd = flops_fwd(cfg, b, s)
    flops = fwd + 2 * b * cfg.d_model * cfg.vocab   # last-token logits
    w = params_bytes(cfg) / mesh.chips
    act = 8 * _layer_multiplier(cfg) * (b / mesh.dp) * s * cfg.d_model * BF16
    cache = (_layer_multiplier(cfg) * (b / mesh.dp) * s * 2
             * cfg.n_kv_heads * cfg.head_dim * BF16)
    hbm = w + act + cache
    hid = (b / mesh.dp) * s * cfg.d_model * BF16
    tp_ar = _tp_ars_per_stack(cfg) * hid
    ep = 0.0
    if cfg.family == "moe":
        ep = 2 * cfg.n_layers * (b / mesh.dp) * (s / mesh.model) \
            * cfg.top_k * cfg.d_model * BF16 * cfg.capacity_factor
    return {"flops": flops, "hbm_bytes_chip": hbm, "coll_bytes_chip": tp_ar + ep,
            "model_flops": 2 * cfg.param_count(active_only=True) * b * s}


# -------------------------------------------------------------------- decode
def decode_cost(cfg, b: int, s_cache: int, mesh: MeshShape) -> Dict:
    n_act = cfg.param_count(active_only=True)
    flops = 2 * n_act * b
    kv_layers = (cfg.n_layers if cfg.family in ("dense", "vlm", "moe", "audio")
                 else cfg.n_layers // max(1, cfg.attn_every)
                 if cfg.family == "hybrid" else 0)
    kv_elem = ((1 + 2.0 / cfg.head_dim)
               if getattr(cfg, "kv_cache_int8", False) else BF16)
    cache_bytes = (kv_layers * b * s_cache * 2 * cfg.n_kv_heads
                   * cfg.head_dim * kv_elem)
    flops += 2 * kv_layers * b * cfg.n_heads * s_cache * cfg.head_dim * 2
    # every live weight byte + the whole cache cross HBM once per token
    hbm = params_bytes(cfg) / mesh.chips + cache_bytes / mesh.chips
    if cfg.family == "moe":
        # only routed experts' weights are touched per token batch
        live = (cfg.param_count(active_only=True)
                + 3 * cfg.d_model * cfg.moe_d_ff
                * min(cfg.n_experts, b * cfg.top_k)) * BF16
        hbm = live / mesh.chips + cache_bytes / mesh.chips
    hid = max(b / mesh.dp, 1) * cfg.d_model * BF16
    tp_ar = _tp_ars_per_stack(cfg) * hid
    logits_ag = max(b / mesh.dp, 1) * cfg.vocab / mesh.model * F32
    return {"flops": flops,
            "hbm_bytes_chip": hbm,
            "coll_bytes_chip": tp_ar + logits_ag,
            "model_flops": 2 * n_act * b}


def cell_cost(cfg, kind: str, b: int, s: int, mesh: MeshShape) -> Dict:
    if kind == "train":
        return train_cost(cfg, b, s, mesh)
    if kind == "prefill":
        return prefill_cost(cfg, b, s, mesh)
    return decode_cost(cfg, b, s, mesh)


# ----------------------------------------------------- conv2d algorithm choice
# Consulted by repro.core.conv_api.conv2d(algorithm="auto"); the scoring
# combines the paper's analytic memory overheads (§3.4, repro.core.memory)
# with mult-add counts.  Full rules documented in DESIGN.md §1; the fitted
# correction layer (repro.plan.calibrate) in DESIGN.md §10.

def conv2d_algorithm_costs(spec, calibration=None) -> Dict[str, Dict[str, float]]:
    """Per-eligible-algorithm {flops, overhead_elems} for one ConvSpec.

    With a ``repro.plan.calibrate.Calibration``, each entry additionally
    carries the fitted view: ``calibrated_overhead_elems`` (Eq. 2-3
    scaled by the measured/predicted byte ratio), ``measured_us`` (this
    cell's own autotune evidence, None without it) and ``time_us_est``
    (the fitted Eq. 2-4 time model, None for unfitted algorithms).  The
    default (None) keeps the paper's uncalibrated constants — bench
    reports gate these fields exactly, so they must stay deterministic.
    """
    from repro.core import memory
    base = memory.conv_flops(spec)
    costs: Dict[str, Dict[str, float]] = {}
    for alg, overhead in memory.ALL_OVERHEADS.items():
        if alg == "winograd" and \
                (spec.k_h, spec.k_w, spec.s_h, spec.s_w) != (3, 3, 1, 1):
            continue
        flops = float(base)
        if alg == "winograd":
            flops = base * 4.0 / 9.0      # F(2x2,3x3): 16 mults per 36
        if alg == "fft":
            hw = spec.i_h * spec.i_w
            planes = spec.i_n * spec.i_c + spec.i_c * spec.k_c \
                + spec.i_n * spec.k_c
            flops = 5.0 * hw * math.log2(max(hw, 2)) * planes \
                + 8.0 * spec.i_n * hw * spec.i_c * spec.k_c
        costs[alg] = {"flops": flops,
                      "overhead_elems": float(overhead(spec))}
    if calibration is not None:
        cell = calibration.cell_times(spec)
        constants = calibration.time_constants()
        for alg, entry in costs.items():
            entry["calibrated_overhead_elems"] = \
                entry["overhead_elems"] * calibration.mem_ratio_for(alg)
            entry["measured_us"] = cell.get(alg)
            entry["time_us_est"] = calibration.time_estimate(
                spec, alg, constants)
    return costs


# ------------------------------------------------- conv2d partition choice
# Consulted by repro.parallel.conv.sharded_conv2d(partition="auto") and the
# bench `dist` suite.  Per-device terms follow the paper's Eq. 2-4 memory
# model applied to the *local* geometry each device sees, plus the bytes
# that cross the interconnect (halo exchange forward, psum transposes
# backward).  DESIGN.md §6 documents the protocol.

def _halo_rows(spec) -> int:
    # The executor's halo protocol owns this formula; reusing it keeps
    # the gated analytic halo bytes equal to what ppermute ships.
    from repro.parallel.conv import spatial_halo_rows
    return spatial_halo_rows(spec.k_h, spec.s_h)


def conv_partition_costs(spec, n_dev, dtype_bytes: int = 4,
                         calibration=None) -> Dict:
    """Per-partition per-device cost terms for an ``n_dev``-way split.

    ``n_dev`` as an int evaluates the three 1-D modes (keys ``"batch"``/
    ``"channel"``/``"spatial"``); a ``(n0, n1)`` tuple evaluates the
    composite modes (keys from ``parallel.conv.COMPOSITE_PARTITIONS``,
    component ``i`` split ``n_dev[i]``-ways).  Every mode is reported
    (with ``viable`` flagging whether the geometry actually divides) so
    analytic benchmark fields stay defined on non-divisible cells:

    * ``per_device_overhead_elems`` — MEC's compact L (Eq. 3) on the
      local geometry (note: ``channel`` does not shrink L — it splits
      only the kernel/output);
    * ``per_device_im2col_elems``   — Eq. 2 on the same local geometry;
    * ``halo_bytes_per_device``     — spatial halo, ``(k_h - s_h)`` input
      rows per exchange on the *local* batch shard (0 when no spatial
      component);
    * ``comm_bytes_fwd/bwd_per_device`` — interconnect bytes per device:
      spatial pays the halo each way, batch psums the kernel cotangent,
      channel psums the input cotangent.  Composites sum their
      components' terms, each psum operand taken at the size the *other*
      component leaves local (e.g. batch x channel psums a ``k_c/n1``
      kernel shard and an ``i_n/n0`` input shard);
    * ``flops_per_device``.

    A ``repro.plan.calibrate.Calibration`` scales the two per-device
    Eq. 2-3 memory predictions by the memaudit-fitted byte ratios
    (comm-byte and flops terms are geometric, not fitted).  Default None
    keeps the gated analytic fields deterministic.
    """
    import dataclasses as _dc

    from repro.core import memory
    from repro.parallel.conv import COMPOSITE_PARTITIONS

    halo = _halo_rows(spec)
    mec_ratio = 1.0 if calibration is None \
        else calibration.mem_ratio_for("mec")
    im2col_ratio = 1.0 if calibration is None \
        else calibration.mem_ratio_for("im2col")

    def ceil_div(a, b):
        return -(-a // b)

    def halo_row_bytes(i_n_local):
        return i_n_local * halo * spec.i_w * spec.i_c * dtype_bytes

    def one_mode(parts, sizes):
        """Cost entry for a 1- or 2-component partition."""
        by = dict(zip(parts, sizes))
        n_b, n_s, n_c = by.get("batch", 1), by.get("spatial", 1), \
            by.get("channel", 1)
        i_n_loc = max(1, ceil_div(spec.i_n, n_b))
        k_c_loc = max(1, ceil_div(spec.k_c, n_c))
        lspec = _dc.replace(
            spec, i_n=i_n_loc,
            i_h=min(spec.i_h, ceil_div(spec.i_h, n_s) + halo),
            k_c=k_c_loc)
        # Spatial halo on the local batch shard; psum operands at the
        # size the other component leaves local (ceil-sized, matching
        # lspec, so analytics stay self-consistent on non-divisible
        # cells).
        halo_bytes = halo_row_bytes(i_n_loc) if "spatial" in by else 0
        fwd = halo_bytes
        bwd = halo_bytes
        if "batch" in by or "spatial" in by:
            # kernel cotangent psum'd over the input-sharding axes;
            # operand is the (possibly channel-sharded) local kernel.
            bwd += spec.k_h * spec.k_w * spec.i_c * k_c_loc * dtype_bytes
        if "channel" in by:
            # input cotangent psum'd over the channel axis; operand is
            # the (possibly batch/spatially-sharded) local input.
            bwd += i_n_loc * ceil_div(spec.i_h, max(n_s, 1)) \
                * spec.i_w * spec.i_c * dtype_bytes
        n_total = math.prod(max(n, 1) for n in sizes)
        return {
            "viable": bool(min(sizes) > 0
                           and _viable(spec, parts if len(parts) > 1
                                       else parts[0],
                                       tuple(sizes) if len(parts) > 1
                                       else sizes[0])),
            "n_dev": int(n_total),
            "n_dev_axes": [int(n) for n in sizes],
            "per_device_overhead_elems":
                float(memory.mec_overhead(lspec)) * mec_ratio,
            "per_device_im2col_elems":
                float(memory.im2col_overhead(lspec)) * im2col_ratio,
            "halo_bytes_per_device": float(halo_bytes),
            "comm_bytes_fwd_per_device": float(fwd),
            "comm_bytes_bwd_per_device": float(bwd),
            "flops_per_device": float(memory.conv_flops(spec) / n_total),
        }

    out: Dict = {}
    if isinstance(n_dev, int):
        for part in ("batch", "channel", "spatial"):
            out[part] = one_mode((part,), (n_dev,))
    else:
        sizes = tuple(int(n) for n in n_dev)
        if len(sizes) != 2:
            raise ValueError(f"composite n_dev must be a 2-tuple, got "
                             f"{n_dev!r}")
        for comp in COMPOSITE_PARTITIONS:
            out[comp] = one_mode(comp, sizes)
    return out


def _viable(spec, partition, n_dev) -> bool:
    from repro.parallel.conv import partition_viable
    return partition_viable(spec, partition, n_dev)


def pick_conv_partition(spec, axis_sizes: Dict,
                        dtype_bytes: int = 4, calibration=None):
    """Cheapest viable partition for ``sharded_conv2d(partition='auto')``.

    axis_sizes maps a candidate — a partition name, or a composite tuple
    from ``parallel.conv.COMPOSITE_PARTITIONS`` — to the size of the
    mesh axis (axes tuple, for composites) it would run over.  Returns
    the winning key, or None when no mode can split the geometry over
    more than one device (caller falls back to single-device execution).
    Ranking: fewest fwd+bwd interconnect bytes per device; ties go to
    the lowest *calibrated* per-device Eq. 3 overhead when a
    ``repro.plan.calibrate.Calibration`` is supplied (comm bytes are
    geometric — the memory fit is the only measured term a partition
    choice can consult), then to ``batch`` (embarrassingly parallel),
    then ``spatial``, then ``channel`` — the paper's preference order
    for keeping the lowered buffer, not the activations, on the wire —
    then to 1-D modes over composites (fewer axes on the wire for the
    same comm bytes).  Without a calibration the overhead tie-break term
    is constant, so the committed dist picks are unchanged.
    """
    from repro.parallel.conv import COMPOSITE_PARTITIONS, PARTITIONS
    order = ("batch", "spatial", "channel") + COMPOSITE_PARTITIONS
    unknown = [k for k in axis_sizes
               if k not in PARTITIONS + COMPOSITE_PARTITIONS]
    if unknown:
        # A misspelled or non-canonical key would otherwise be silently
        # skipped and parallelism lost — same loud-error stance as
        # sharded_conv2d's explicit-axis validation.
        raise ValueError(
            f"unknown partition candidate(s) {unknown!r}; expected keys "
            f"from {PARTITIONS + COMPOSITE_PARTITIONS}")
    best, best_cost = None, None
    for part in order:
        n = axis_sizes.get(part)
        if n is None:
            continue
        if isinstance(part, str):
            if isinstance(n, (tuple, list)):
                raise ValueError(f"candidate {part!r} takes one axis "
                                 f"size, got {n!r}")
            n = int(n)
            if n <= 1 or not _viable(spec, part, n):
                continue
        else:
            if not isinstance(n, (tuple, list)) or len(n) != len(part):
                raise ValueError(f"candidate {part!r} takes {len(part)} "
                                 f"axis sizes, got {n!r}")
            n = tuple(int(v) for v in n)
            # A composite with a 1-way sub-axis is just its other
            # component, which is enumerated separately.
            if min(n) <= 1 or not _viable(spec, part, n):
                continue
        c = conv_partition_costs(spec, n, dtype_bytes,
                                 calibration=calibration)[part]
        cost = (c["comm_bytes_fwd_per_device"]
                + c["comm_bytes_bwd_per_device"],
                c["per_device_overhead_elems"] if calibration is not None
                else 0.0)
        if best_cost is None or cost < best_cost:
            best, best_cost = part, cost
    return best


def pick_conv2d_algorithm(spec, backend: str | None = None,
                          calibration="ambient") -> str:
    """Dispatch rule for conv2d(algorithm='auto') — DESIGN.md §1, §10.

    * 1x1 kernels: lowering is a no-op, direct wins outright.
    * TPU backend: the fused Pallas kernel (no L in HBM at all) is the
      whole point of this codebase — always.
    * elsewhere (CPU/GPU via XLA): MEC whenever its compact L actually
      saves memory over im2col (k_h > s_h row overlap, Eq. 4), else
      direct — never im2col/fft/winograd, which only trade memory away
      for speed XLA already gets from its direct conv.

    Calibration (DESIGN.md §10): ``calibration="ambient"`` consults the
    fitted store for this environment ($REPRO_CALIBRATION or the
    fingerprinted file beside the plan cache) when one exists; pass
    ``None`` to force the paper's uncalibrated constants (what bench
    reports gate), or an explicit ``Calibration``.  A calibration whose
    backend differs from ``backend`` is ignored.  Two corrections apply:
    the Eq. 4 memory comparison runs on byte-ratio-scaled overheads, and
    — only where this exact cell has measured evidence covering the
    analytic pick and at least one rival — the pick defers to the
    measurements through ``pick_measured``'s noise margin.  Fitted
    global constants alone never flip a cell: three smoke measurements
    must not rewrite Table 2.
    """
    import jax

    backend = backend or jax.default_backend()
    if spec.k_h == 1 and spec.k_w == 1:
        return "direct"
    if backend == "tpu":
        return "mec_fused"
    from repro.plan.calibrate import resolve_calibration
    calib = resolve_calibration(calibration, backend)
    costs = conv2d_algorithm_costs(spec, calibration=calib)
    # MEC pays for itself iff its compact L is strictly smaller than the
    # im2col lowering it replaces (equivalent to Eq. 4 saving > 0) —
    # both sides scaled by the memaudit-fitted byte ratios when
    # calibrated (measured mec temps run >1x Eq. 3 on CPU, im2col 1.00x).
    mec_ovh = costs["mec"].get("calibrated_overhead_elems",
                               costs["mec"]["overhead_elems"])
    im2col_ovh = costs["im2col"].get("calibrated_overhead_elems",
                                     costs["im2col"]["overhead_elems"])
    analytic = "mec" if mec_ovh < im2col_ovh else "direct"
    if calib is not None:
        cell = calib.cell_times(spec)
        if analytic in cell and len(cell) >= 2:
            from repro.plan.convplan import pick_measured
            return pick_measured(cell, analytic)
    return analytic
