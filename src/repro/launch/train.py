"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --smoke \
      --steps 50 --ckpt-dir /tmp/ckpt

Fault-tolerance loop: the step loop runs under a watchdog; on a crash or
watchdog timeout the process restarts from the newest atomic checkpoint
(exact data resume included).  ``--mesh host`` runs on whatever devices
exist (CPU smoke); on a pod, the production mesh + sharding rules from
repro.parallel are used unchanged.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.ckpt.manager import CheckpointManager
from repro.configs.archs import ARCHS, smoke_config
from repro.data.pipeline import DataState, SyntheticLMData
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.lm import LM
from repro.optim.adamw import AdamWConfig
from repro.parallel import sharding
from repro.parallel.axes import default_rules
from repro.training import steps
from repro.training.watchdog import StepWatchdog


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--mesh", choices=["host", "production", "multipod"],
                    default="host")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--compress-grads", action="store_true",
                    help="int8 error-feedback DP gradient all-reduce "
                         "(pure-DP meshes)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else ARCHS[args.arch]
    model = LM(cfg)

    if args.mesh == "host":
        mesh = make_host_mesh()
    else:
        mesh = make_production_mesh(multi_pod=args.mesh == "multipod")
    rules = default_rules(mesh)

    data = SyntheticLMData(cfg, args.global_batch, args.seq_len)
    opt_cfg = AdamWConfig(lr=args.lr, total_steps=args.steps,
                          warmup_steps=max(2, args.steps // 20))

    with mesh:
        params = model.init(jax.random.key(0))
        p_specs = sharding.param_specs(params, mesh)
        p_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs,
                               is_leaf=lambda x: isinstance(x, P))
        params = jax.tree.map(jax.device_put, params, p_shard)
        opt_state = steps.init_opt_state(params,
                                         compressed=args.compress_grads)
        if args.compress_grads:
            step_fn = steps.make_compressed_train_step(model, opt_cfg, rules)
        else:
            step_fn = steps.make_train_step(model, opt_cfg, rules)
        step_fn = jax.jit(step_fn, donate_argnums=(0, 1))

        mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
        start = 0
        if mgr is not None and mgr.latest_step() is not None:
            start = mgr.latest_step()
            restored = mgr.restore(start, {
                "params": params, "opt": opt_state,
                "data": data.state.to_dict()})
            params, opt_state = restored["params"], restored["opt"]
            data.state = DataState.from_dict(restored["data"])
            print(f"[train] resumed from step {start}")

        dog = StepWatchdog(hard_timeout_s=None)
        for step in range(start, args.steps):
            dog.start_step()
            batch = data.next_batch()
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            dt = dog.end_step()
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"[train] step {step} loss {loss:.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):.2f} {dt*1e3:.0f}ms")
            if mgr is not None and (step + 1) % args.ckpt_every == 0:
                mgr.save_async(step + 1, {
                    "params": params, "opt": opt_state,
                    "data": data.state.to_dict()})
        if mgr is not None:
            mgr.wait()
            mgr.save(args.steps, {"params": params, "opt": opt_state,
                                  "data": data.state.to_dict()})
    print(f"[train] done: {args.steps} steps, median step "
          f"{dog.median*1e3:.0f}ms, stragglers {dog.straggler_events}")
    return float(metrics["loss"])


if __name__ == "__main__":
    main()
