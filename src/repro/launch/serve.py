"""Serving launcher: batched prefill + decode loop.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --smoke \
      --batch 4 --prompt-len 32 --gen 16

``--warm-plans`` resolves ConvPlans for the ``--shape-classes`` buckets
at startup (repro.serving.conv_service, DESIGN.md §9) and routes the
vlm/audio conv frontend through the warmed services, printing the
per-class resolved-plan table before the first request.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.archs import ARCHS, smoke_config
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import serve as serve_lib
from repro.models.lm import LM
from repro.parallel.axes import default_rules, use_rules


def _warm_frontend(cfg, classes):
    """(frontend, services) for the family's conv encoder, warmed over
    ``classes``; (None, []) when the family has no conv frontend."""
    from repro.serving.conv_service import (patch_embed_service,
                                            whisper_frontend_service)
    key = jax.random.key(2)
    if cfg.family == "vlm":
        # ViT-style patch embed: classes are (batch, H, W) image buckets.
        frontend, svc = patch_embed_service(key, 3, cfg.d_model, 4, classes,
                                            cfg.prefix_len)
        return frontend, [svc]
    if cfg.family == "audio":
        # Mel frontend: classes are (batch, T, 1) time buckets; stride-2
        # conv halves T, so serve mel at 2 * encoder_len.
        frontend, services = whisper_frontend_service(
            key, 80, cfg.d_model, classes)
        return frontend, services
    return None, []


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--mesh", choices=["host", "production", "multipod"],
                    default="host")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--warm-plans", action="store_true",
                    help="resolve ConvPlans for --shape-classes at "
                         "startup and serve the conv frontend through "
                         "them (DESIGN.md §9)")
    ap.add_argument("--shape-classes", default=None,
                    help="comma-separated NxHxW padded classes for "
                         "--warm-plans, e.g. 4x32x32,4x64x64 (vlm: "
                         "image buckets; audio: 4xTx1 time buckets)")
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else ARCHS[args.arch]
    model = LM(cfg)

    frontend, services = None, []
    if args.warm_plans:
        from repro.serving.conv_service import parse_shape_classes
        if args.shape_classes:
            classes = parse_shape_classes(args.shape_classes)
        elif cfg.family == "audio":
            classes = [(args.batch, 2 * cfg.encoder_len, 1)]
        else:
            classes = [(args.batch, 16, 16), (args.batch, 32, 32)]
        t0 = time.monotonic()
        frontend, services = _warm_frontend(cfg, classes)
        for svc in services:
            print(svc.warmup.render())
        if services:
            print(f"[serve] warmed {len(services)} conv service(s) in "
                  f"{time.monotonic() - t0:.2f}s")
        else:
            print(f"[serve] --warm-plans: family {cfg.family!r} has no "
                  "conv frontend; nothing to warm")
    mesh = (make_host_mesh() if args.mesh == "host"
            else make_production_mesh(multi_pod=args.mesh == "multipod"))
    rules = default_rules(mesh)
    max_len = args.prompt_len + args.gen + (
        cfg.prefix_len if cfg.family == "vlm" else 0)

    with mesh:
        params = model.init(jax.random.key(0))
        key = jax.random.key(1)
        batch = {"tokens": jax.random.randint(
            key, (args.batch, args.prompt_len), 0, cfg.vocab, jnp.int32)}
        if cfg.family == "vlm":
            if frontend is not None:
                # Dummy images through the warmed patch-embed service:
                # sized to the smallest class so bucketing is exercised.
                cls = services[0].classes[0] if services else None
                img = jnp.zeros((args.batch, cls.h, cls.w, 3), jnp.float32)
                batch["vision"] = frontend(img)
            else:
                batch["vision"] = jnp.zeros(
                    (args.batch, cfg.prefix_len, cfg.d_model), jnp.float32)
        if cfg.family == "audio":
            if frontend is not None:
                from repro.serving.conv_service import fit_prefix
                cls = services[0].classes[0] if services else None
                mel = jnp.zeros((args.batch, cls.h, 80), jnp.float32)
                batch["frames"] = fit_prefix(frontend(mel), cfg.encoder_len)
            else:
                batch["frames"] = jnp.zeros(
                    (args.batch, cfg.encoder_len, cfg.d_model), jnp.float32)

        prefill = jax.jit(lambda p, b: serve_lib.prefill(model, p, b, max_len))
        decode = jax.jit(lambda p, c, t: serve_lib.decode_step(model, p, c, t))

        with use_rules(rules):
            t0 = time.monotonic()
            logits, cache = jax.block_until_ready(prefill(params, batch))
            t_prefill = time.monotonic() - t0

            def sample(logits, key):
                if args.temperature <= 0:
                    return jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
                return jax.random.categorical(
                    key, logits / args.temperature)[:, None].astype(jnp.int32)

            tok = sample(logits, key)
            out = [tok]
            t0 = time.monotonic()
            for i in range(args.gen - 1):
                key, sub = jax.random.split(key)
                logits, cache = decode(params, cache, tok)
                tok = sample(logits, sub)
                out.append(tok)
            jax.block_until_ready(tok)
            t_decode = time.monotonic() - t0

    gen = jnp.concatenate(out, axis=1)
    tps = args.batch * (args.gen - 1) / max(t_decode, 1e-9)
    print(f"[serve] prefill {args.batch}x{args.prompt_len} in "
          f"{t_prefill*1e3:.0f}ms; decode {args.gen-1} steps @ "
          f"{tps:.1f} tok/s (incl. first-step compile)")
    print("[serve] sample tokens:", gen[0, :10].tolist())
    return gen


if __name__ == "__main__":
    main()
