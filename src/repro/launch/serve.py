"""Serving launcher: batched prefill + decode loop.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --smoke \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.archs import ARCHS, smoke_config
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import serve as serve_lib
from repro.models.lm import LM
from repro.parallel.axes import default_rules, use_rules


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--mesh", choices=["host", "production", "multipod"],
                    default="host")
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else ARCHS[args.arch]
    model = LM(cfg)
    mesh = (make_host_mesh() if args.mesh == "host"
            else make_production_mesh(multi_pod=args.mesh == "multipod"))
    rules = default_rules(mesh)
    max_len = args.prompt_len + args.gen + (
        cfg.prefix_len if cfg.family == "vlm" else 0)

    with mesh:
        params = model.init(jax.random.key(0))
        key = jax.random.key(1)
        batch = {"tokens": jax.random.randint(
            key, (args.batch, args.prompt_len), 0, cfg.vocab, jnp.int32)}
        if cfg.family == "vlm":
            batch["vision"] = jnp.zeros(
                (args.batch, cfg.prefix_len, cfg.d_model), jnp.float32)
        if cfg.family == "audio":
            batch["frames"] = jnp.zeros(
                (args.batch, cfg.encoder_len, cfg.d_model), jnp.float32)

        prefill = jax.jit(lambda p, b: serve_lib.prefill(model, p, b, max_len))
        decode = jax.jit(lambda p, c, t: serve_lib.decode_step(model, p, c, t))

        with use_rules(rules):
            t0 = time.monotonic()
            logits, cache = jax.block_until_ready(prefill(params, batch))
            t_prefill = time.monotonic() - t0

            def sample(logits, key):
                if args.temperature <= 0:
                    return jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
                return jax.random.categorical(
                    key, logits / args.temperature)[:, None].astype(jnp.int32)

            tok = sample(logits, key)
            out = [tok]
            t0 = time.monotonic()
            for i in range(args.gen - 1):
                key, sub = jax.random.split(key)
                logits, cache = decode(params, cache, tok)
                tok = sample(logits, sub)
                out.append(tok)
            jax.block_until_ready(tok)
            t_decode = time.monotonic() - t0

    gen = jnp.concatenate(out, axis=1)
    tps = args.batch * (args.gen - 1) / max(t_decode, 1e-9)
    print(f"[serve] prefill {args.batch}x{args.prompt_len} in "
          f"{t_prefill*1e3:.0f}ms; decode {args.gen-1} steps @ "
          f"{tps:.1f} tok/s (incl. first-step compile)")
    print("[serve] sample tokens:", gen[0, :10].tolist())
    return gen


if __name__ == "__main__":
    main()
