"""Post-SPMD HLO analysis: collective-bytes accounting + roofline terms.

``collective_bytes`` parses the optimized (partitioned) HLO text and sums
the operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute.  Hardware constants are TPU v5e
(assignment): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from typing import Dict

# v5e per-chip constants
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link

# Every storage type the current jax/XLA matrix can print in an HLO
# shape.  Sub-byte types (s2/u2/s4/u4/f4) are conservatively counted at
# their packed-in-one-byte size.  An UNKNOWN type raises — a silent
# 4-byte default would let the memory/collective auditors under- or
# over-count new dtypes invisibly (repro.analysis, ISSUE 6).
_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "s2": 1, "u2": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "f8e4m3fnuz": 1, "f8e4m3b11fnuz": 1, "f8e5m2fnuz": 1, "f8e8m0fnu": 1,
    "f4e2m1fn": 1, "token": 0,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  bf16[8,128,2048]{2,1,0}
_TYPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s+(.*?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")
# replica_groups=[16,16]<=... (iota form) or ={{0,1},{2,3}} (explicit form)
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        raise ValueError(
            f"unknown HLO dtype {dtype!r}: add its byte size to "
            "repro.launch.hlo_analysis._DTYPE_BYTES (refusing the old "
            "silent 4-byte default — it would mis-count collective and "
            "memory-audit bytes invisibly)")
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_EXPL_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device operand bytes per collective kind, from partitioned HLO.

    Operand types are not printed inline in optimized HLO dumps, so operand
    bytes are derived from the result type: all-gather operand is
    result/group_size, reduce-scatter operand is result*group_size, the
    rest move result-sized operands.
    """
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        result_types, kind, variant = m.group(1), m.group(2), m.group(3)
        if variant == "-done":        # async pair: count only the -start
            continue
        types = _TYPE_RE.findall(result_types)
        if variant == "-start" and len(types) > 1:
            # (operand, result) tuple: keep the result element(s)
            types = types[len(types) // 2:]
        total = sum(_shape_bytes(t, d) for t, d in types)
        g = _group_size(line)
        if kind == "all-gather":
            total //= max(g, 1)
        elif kind == "reduce-scatter":
            total *= g
        out[kind] += total
        out["count"] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def hlo_flops_bytes(compiled) -> Dict[str, float]:
    """HLO-derived {flops, bytes_accessed} of a compiled executable.

    Uses the version-normalized ``repro.core.compat.cost_analysis``; both
    fields are 0.0 on backends without a cost model.  NOTE the while-body
    caveat in ``repro.launch.costmodel``: scan bodies are counted once.
    """
    from repro.core.compat import cost_analysis
    cost = cost_analysis(compiled)
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0))}


def roofline_terms(flops: float, hbm_bytes: float, coll_bytes: float,
                   n_chips: int) -> Dict[str, float]:
    """Three roofline terms in seconds (assignment §Roofline).

    flops/hbm_bytes are whole-program HLO totals (cost_analysis of the
    partitioned module is per-device; see dryrun.py for which is passed).
    """
    t_compute = flops / (n_chips * PEAK_FLOPS)
    t_memory = hbm_bytes / (n_chips * HBM_BW)
    t_coll = coll_bytes / (n_chips * ICI_BW)
    dominant = max((t_compute, "compute"), (t_memory, "memory"),
                   (t_coll, "collective"))[1]
    return {"t_compute_s": t_compute, "t_memory_s": t_memory,
            "t_collective_s": t_coll, "dominant": dominant}
