"""Production mesh construction (DESIGN.md §6).

Defined as functions (never module-level constants) so importing this
module does not touch jax device state.  Single pod: 16x16 = 256 chips
(data x model).  Multi-pod: 2 x 16 x 16 = 512 chips (pod x data x model);
the 'pod' axis is data-parallel by default and carries only the gradient
all-reduce across the slow inter-pod links.
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    if len(devices) > n:
        # dry-run host platform exposes 512 devices; single-pod uses 256
        return Mesh(np.asarray(devices[:n]).reshape(shape), axes)
    raise ValueError(
        f"need {n} devices for mesh {shape}, have {len(devices)} "
        "(set XLA_FLAGS=--xla_force_host_platform_device_count=512 for the "
        "dry-run)")


def make_host_mesh(shape=None, axes=None) -> Mesh:
    """Small mesh over whatever devices exist (tests, examples).

    shape=None uses every device on a 1-D "data" axis.  An explicit
    shape without axes gets generated axis names ("ax0", "ax1", ...) —
    passing axes=None through to Mesh() used to crash.
    """
    devices = jax.devices()
    if shape is None:
        shape = (len(devices),)
        axes = axes or ("data",)
    shape = tuple(shape)
    if axes is None:
        axes = tuple(f"ax{i}" for i in range(len(shape)))
    axes = tuple(axes)
    if len(axes) != len(shape):
        raise ValueError(f"mesh shape {shape} needs {len(shape)} axis "
                         f"names, got {axes}")
    n = int(np.prod(shape))
    if n > len(devices):
        raise ValueError(f"need {n} devices for host mesh {shape}, "
                         f"have {len(devices)}")
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)
