"""Pallas TPU kernels for the paper's compute hot-spot: MEC convolution.

mec_conv.py   — compact lowering + shifted-window GEMM (+ fused variant)
mec_conv1d.py — fused causal depthwise conv1d (Mamba2/xLSTM blocks)
ops.py        — jit'd public wrappers (block-size selection, interpret auto)
ref.py        — pure-jnp oracles
"""
from repro.kernels.ops import mec_conv1d_tpu, mec_conv2d_tpu

__all__ = ["mec_conv2d_tpu", "mec_conv1d_tpu"]
