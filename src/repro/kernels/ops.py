"""Public jit'd entry points for the MEC Pallas kernels.

``interpret`` defaults to True when the backend has no TPU (this container
is CPU-only; on a real TPU pod pass interpret=False or rely on the
auto-detection).  Block sizes are chosen for v5e VMEM (~16 MiB/core):
the fused kernel's working set is
``i_w*i_c + k_w*i_c*k_c + w_blk*k_c`` floats per step.
"""
from __future__ import annotations

import functools
import os
import warnings

import jax
import jax.numpy as jnp

from repro.kernels.mec_conv import (mec_conv_fused2_pallas,
                                    mec_conv_fused_pallas, mec_gemm_pallas,
                                    mec_lower_pallas)
from repro.kernels.mec_conv1d import mec_conv1d_pallas

# Accumulator budget override for non-v5e targets (bytes; decimal or hex).
ACC_BYTES_ENV = "REPRO_MEC_ACC_BYTES"

# Per-core VMEM by device kind (substring match against
# jax.Device.device_kind).  v2-v5 generations all carry ~16 MiB/core;
# Trillium doubles it.  Unknown kinds (and CPU/GPU interpret runs) fall
# back to the v5e figure.
_VMEM_BYTES_BY_KIND = (
    ("v6", 32 << 20),
    ("v5", 16 << 20),
    ("v4", 16 << 20),
    ("v3", 16 << 20),
    ("v2", 16 << 20),
)
_DEFAULT_VMEM = 16 << 20
# The f32 accumulator gets 1/8 of VMEM; the rest holds the input strip,
# kernel block, and Mosaic's double buffering.
_ACC_FRACTION = 8


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def vmem_bytes() -> int:
    """Per-core VMEM of the queried device kind (v5e figure when the
    kind is unknown or the query fails — CPU/GPU interpret runs).  The
    static checker (``repro.analysis.pallas_check``) sizes whole-kernel
    working sets against this; :func:`accumulator_budget` carves the
    accumulator's fraction out of it."""
    try:
        kind = jax.devices()[0].device_kind.lower()
    except Exception:
        return _DEFAULT_VMEM
    for tag, vmem in _VMEM_BYTES_BY_KIND:
        if tag in kind:
            return vmem
    return _DEFAULT_VMEM


def accumulator_budget(*, _warn_env: bool = True) -> int:
    """VMEM bytes the f32 output accumulator may fill.

    Resolution order: the REPRO_MEC_ACC_BYTES env override, else
    VMEM/8 for the queried device kind, else the ~2 MiB v5e heuristic —
    so non-v5e targets tune block sizes without editing source.

    The env override is deprecated outside the planner: tuned block
    sizes belong in a :class:`repro.plan.ConvPlan` (``plan.w_blk``,
    produced by ``repro.plan.plan_conv2d`` and threaded to the kernels
    by the ``conv2d`` executor).  Reads of the env var on the kwargs
    fallback path emit a DeprecationWarning; behaviour is unchanged.
    """
    env = os.environ.get(ACC_BYTES_ENV)  # lint-ignore: deprecated-acc-bytes-env, raw-environ-read-outside-compat (this IS the deprecation shim for the env var)
    if env:
        if _warn_env:
            warnings.warn(
                f"{ACC_BYTES_ENV} is deprecated outside the plan path: "
                "put tuned accumulator budgets in a ConvPlan instead "
                "(repro.plan.plan_conv2d resolves ConvPlan.w_blk once; "
                "conv2d(plan=...) threads it to the kernels)",
                DeprecationWarning, stacklevel=2)
        budget = int(env, 0)
        if budget <= 0:
            raise ValueError(f"{ACC_BYTES_ENV} must be positive, got {env!r}")
        return budget
    return vmem_bytes() // _ACC_FRACTION


def pick_w_blk(o_w: int, k_c: int, target_bytes: int | None = None, *,
               _warn_env: bool = True) -> int:
    """Output-column block: fill the accumulator budget (device-queried /
    env-tunable via :func:`accumulator_budget`, ~2 MiB on v5e) with the
    f32 accumulator, rounded down to a multiple of 8 (sublane) and capped
    at o_w.

    The 8-column sublane floor applies only to the *implicit* device
    budget; an explicit ``target_bytes`` is a hard cap — the block never
    exceeds it (down to the 1-column minimum, the smallest accumulator
    that exists).  ``_warn_env=False`` is the planner's entry
    (``repro.plan``): the env override still applies there without the
    deprecation warning, since a plan *is* the supported place for the
    tuned value to land.
    """
    explicit = target_bytes is not None
    if not explicit:
        target_bytes = accumulator_budget(_warn_env=_warn_env)
    blk = min(512, target_bytes // max(1, 4 * k_c))
    if not explicit:
        blk = max(8, blk)
    if blk >= 8:
        blk = (blk // 8) * 8
    return max(1, min(blk, o_w))


def mec_conv2d_tpu(inp: jnp.ndarray, kernel: jnp.ndarray, stride=1,
                   mode: str = "fused", interpret=None,
                   precision=None, w_blk: int | None = None) -> jnp.ndarray:
    """MEC convolution with Pallas kernels.

    mode='lowered' is the paper-faithful path (L materialized in HBM,
    Eq. 3 memory observable); mode='fused' is the beyond-paper fused path.
    precision reaches the in-kernel GEMMs (matters for bf16 operands on
    the MXU; accumulation is f32 regardless).  w_blk is normally supplied
    by the resolved :class:`repro.plan.ConvPlan`; when None (bare kwargs
    path) it falls back to :func:`pick_w_blk` — device-queried VMEM with
    the deprecated REPRO_MEC_ACC_BYTES env override.
    """
    if interpret is None:
        interpret = _default_interpret()
    s_h, s_w = (stride, stride) if isinstance(stride, int) else stride
    i_n, i_h, i_w, i_c = inp.shape
    k_h, k_w, _, k_c = kernel.shape
    o_w = (i_w - k_w) // s_w + 1
    if w_blk is None:
        w_blk = pick_w_blk(o_w, k_c)
    elif not 1 <= w_blk <= max(o_w, 1):
        raise ValueError(f"w_blk must be in [1, o_w={o_w}], got {w_blk}")
    if mode == "fused":
        return mec_conv_fused_pallas(inp, kernel, (s_h, s_w), w_blk=w_blk,
                                     interpret=interpret,
                                     precision=precision)
    if mode == "fused2":   # h-blocked + halo: ~1x input fetch (EXPERIMENTS)
        return mec_conv_fused2_pallas(inp, kernel, (s_h, s_w), w_blk=w_blk,
                                      interpret=interpret,
                                      precision=precision)
    if mode == "lowered":
        low = mec_lower_pallas(inp, k_w, s_w, interpret=interpret)
        kernel_mat = kernel.reshape(k_h, k_w * i_c, k_c)
        out = mec_gemm_pallas(low, kernel_mat, k_h, s_h, w_blk=w_blk,
                              interpret=interpret, precision=precision)
        return out.astype(inp.dtype)
    raise ValueError(f"unknown mode {mode!r}")


def mec_conv1d_tpu(x: jnp.ndarray, kernel: jnp.ndarray,
                   interpret=None) -> jnp.ndarray:
    """Fused causal depthwise conv1d (Mamba2 / xLSTM blocks)."""
    if interpret is None:
        interpret = _default_interpret()
    return mec_conv1d_pallas(x, kernel, interpret=interpret)
