"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.direct import direct_conv2d
from repro.core.mec import mec_conv1d_depthwise, mec_lower


def conv2d_ref(inp: jnp.ndarray, kernel: jnp.ndarray, stride=1) -> jnp.ndarray:
    """Oracle for mec_gemm_pallas / mec_conv_fused_pallas."""
    return direct_conv2d(inp, kernel, stride)


def lower_ref(inp: jnp.ndarray, k_w: int, s_w: int) -> jnp.ndarray:
    """Oracle for mec_lower_pallas: L (n, o_w, i_h, k_w*i_c)."""
    low = mec_lower(inp, k_w, s_w)  # (n, o_w, i_h, k_w, i_c)
    n, o_w, i_h, kw, i_c = low.shape
    return low.reshape(n, o_w, i_h, kw * i_c)


def conv1d_ref(x: jnp.ndarray, kernel: jnp.ndarray) -> jnp.ndarray:
    """Oracle for mec_conv1d_pallas (causal depthwise)."""
    return mec_conv1d_depthwise(x, kernel, causal=True)
