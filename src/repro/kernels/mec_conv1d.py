"""Pallas TPU kernel: fused causal depthwise conv1d via MEC.

Used by the Mamba2 (zamba2) and xLSTM blocks.  In 1-D the MEC compact
lowering coincides with im2col (DESIGN.md §5), so the win is the *fused*
form: no lowered matrix at all.  The causal halo (k_w-1 steps of history)
is fetched through a second BlockSpec view of the same input pointing at
the previous time-block — BlockSpec index maps again standing in for the
paper's aliased BLAS views.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conv1d_kernel(x_ref, prev_ref, k_ref, o_ref, *, k_w: int):
    # x_ref/prev_ref: (1, t_blk, c_blk); k_ref: (k_w, c_blk)
    i = pl.program_id(1)
    x = x_ref[0]                            # (t_blk, c_blk)
    tail = prev_ref[0, -(k_w - 1):, :]      # halo from previous block
    tail = jnp.where(i == 0, jnp.zeros_like(tail), tail)  # causal left pad
    xx = jnp.concatenate([tail, x], axis=0)  # (t_blk + k_w - 1, c_blk)
    t_blk = x.shape[0]
    acc = jnp.zeros(x.shape, jnp.float32)
    for j in range(k_w):
        acc += xx[j:j + t_blk, :].astype(jnp.float32) * k_ref[j][None, :]
    o_ref[0] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("t_blk", "c_blk", "interpret"))
def mec_conv1d_pallas(x: jnp.ndarray, kernel: jnp.ndarray,
                      t_blk: int = 512, c_blk: int = 128,
                      interpret: bool = True) -> jnp.ndarray:
    """Causal depthwise conv1d.  x: (n, t, c); kernel: (k_w, c)."""
    n, t, c = x.shape
    k_w, kc = kernel.shape
    assert kc == c, (kernel.shape, x.shape)
    t_blk = min(t_blk, t)
    c_blk = min(c_blk, c)
    pad_t, pad_c = (-t) % t_blk, (-c) % c_blk
    if pad_t or pad_c:
        x = jnp.pad(x, ((0, 0), (0, pad_t), (0, pad_c)))
        kernel = jnp.pad(kernel, ((0, 0), (0, pad_c)))
    t_p, c_p = t + pad_t, c + pad_c
    assert t_blk >= k_w - 1, "time block must cover the causal halo"
    grid = (n, t_p // t_blk, c_p // c_blk)
    out = pl.pallas_call(
        functools.partial(_conv1d_kernel, k_w=k_w),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, t_blk, c_blk), lambda n, i, cc: (n, i, cc)),
            # halo view: previous time block (clamped at 0; masked in-kernel)
            pl.BlockSpec((1, t_blk, c_blk),
                         lambda n, i, cc: (n, jnp.maximum(i - 1, 0), cc)),
            pl.BlockSpec((k_w, c_blk), lambda n, i, cc: (0, cc)),
        ],
        out_specs=pl.BlockSpec((1, t_blk, c_blk), lambda n, i, cc: (n, i, cc)),
        out_shape=jax.ShapeDtypeStruct((n, t_p, c_p), x.dtype),
        interpret=interpret,
    )(x, x, kernel)
    return out[:, :t, :c]
