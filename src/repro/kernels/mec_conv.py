"""Pallas TPU kernels for MEC convolution (Cho & Brand, ICML 2017).

TPU adaptation (see DESIGN.md §2): the paper's BLAS ``ld``-aliased
overlapping sub-matrix views become BlockSpec *index maps*.  The key
observation making the shifted-window GEMM expressible with non-overlapping
BlockSpec blocks is the k_h-decomposition::

    O[n, h, :, :] = sum_{r=0}^{k_h-1}  L[n, :, h*s_h + r, :] @ K[r]

With block size 1 on the i_h axis of L, the index ``h*s_h + r`` is a plain
block index — the grid dimension ``r`` walks the kernel rows and the output
block accumulates in VMEM.  Three kernels:

* ``mec_lower``    — Algorithm 2 lines 4-6 (build compact L in HBM).
* ``mec_gemm``     — the o_h shifted GEMMs over a materialized L
                     (paper-faithful mode: Eq. 3 memory is observable).
* ``mec_conv_fused`` — beyond-paper: lowering happens in VMEM inside the
                     GEMM pipeline, L never exists in HBM.  HBM traffic is
                     I (k_h/s_h x) + K + O, vs. the lowered path's
                     additional |L| write + (k_h/s_h)|L| read.

All kernels accumulate in f32 regardless of input dtype.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


# ---------------------------------------------------------------------------
# Lowering kernel: I (n, i_h, i_w, i_c) -> L (n, o_w, i_h, k_w*i_c)
# ---------------------------------------------------------------------------

def _lower_kernel(i_ref, l_ref, *, k_w: int, s_w: int, o_w: int):
    # i_ref: (1, h_blk, i_w, i_c); l_ref: (1, o_w, h_blk, k_w*i_c)
    x = i_ref[0]  # (h_blk, i_w, i_c)
    h_blk, _, i_c = x.shape
    # Column-strip windows: strip[j] = x[:, j : j + s_w*o_w : s_w, :]
    cols = [
        lax.slice(x, (0, j, 0), (h_blk, j + s_w * (o_w - 1) + 1, i_c),
                  (1, s_w, 1))
        for j in range(k_w)
    ]
    strip = jnp.stack(cols, axis=2)            # (h_blk, o_w, k_w, i_c)
    strip = jnp.transpose(strip, (1, 0, 2, 3))  # (o_w, h_blk, k_w, i_c)
    l_ref[0] = strip.reshape(o_w, h_blk, k_w * i_c).astype(l_ref.dtype)


@functools.partial(jax.jit, static_argnames=("k_w", "s_w", "h_blk", "interpret"))
def mec_lower_pallas(inp: jnp.ndarray, k_w: int, s_w: int,
                     h_blk: int = 8, interpret: bool = True) -> jnp.ndarray:
    """Compact MEC lowering on TPU.  Returns L (n, o_w, i_h, k_w*i_c)."""
    i_n, i_h, i_w, i_c = inp.shape
    o_w = (i_w - k_w) // s_w + 1
    h_blk = min(h_blk, i_h)
    pad_h = (-i_h) % h_blk
    if pad_h:
        inp = jnp.pad(inp, ((0, 0), (0, pad_h), (0, 0), (0, 0)))
    i_h_p = i_h + pad_h
    grid = (i_n, i_h_p // h_blk)
    out = pl.pallas_call(
        functools.partial(_lower_kernel, k_w=k_w, s_w=s_w, o_w=o_w),
        grid=grid,
        in_specs=[pl.BlockSpec((1, h_blk, i_w, i_c), lambda n, h: (n, h, 0, 0))],
        out_specs=pl.BlockSpec((1, o_w, h_blk, k_w * i_c),
                               lambda n, h: (n, 0, h, 0)),
        out_shape=jax.ShapeDtypeStruct((i_n, o_w, i_h_p, k_w * i_c), inp.dtype),
        interpret=interpret,
    )(inp)
    return out[:, :, :i_h, :]


# ---------------------------------------------------------------------------
# Shifted GEMM kernel over materialized L (paper-faithful)
# ---------------------------------------------------------------------------

def _gemm_kernel(l_ref, k_ref, o_ref, *, precision):
    # l_ref: (1, w_blk, 1, kwic); k_ref: (1, kwic, k_c); o_ref: (1,1,w_blk,k_c)
    r = pl.program_id(3)

    @pl.when(r == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    acc = jnp.dot(l_ref[0, :, 0, :], k_ref[0], precision=precision,
                  preferred_element_type=jnp.float32)
    o_ref[0, 0] += acc.astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("k_h", "s_h", "w_blk", "interpret",
                                    "precision"))
def mec_gemm_pallas(low: jnp.ndarray, kernel_mat: jnp.ndarray,
                    k_h: int, s_h: int, w_blk: int = 128,
                    interpret: bool = True,
                    precision=None) -> jnp.ndarray:
    """The o_h shifted GEMMs:  O[n,h] = sum_r L[n,:,h*s_h+r,:] @ K[r].

    low: (n, o_w, i_h, k_w*i_c)  (from mec_lower_pallas)
    kernel_mat: (k_h, k_w*i_c, k_c)
    Returns O (n, o_h, o_w, k_c) f32.
    """
    i_n, o_w, i_h, kwic = low.shape
    _, _, k_c = kernel_mat.shape
    o_h = (i_h - k_h) // s_h + 1
    w_blk = min(w_blk, o_w)
    pad_w = (-o_w) % w_blk
    if pad_w:
        low = jnp.pad(low, ((0, 0), (0, pad_w), (0, 0), (0, 0)))
    o_w_p = o_w + pad_w
    grid = (i_n, o_h, o_w_p // w_blk, k_h)
    out = pl.pallas_call(
        functools.partial(_gemm_kernel, precision=precision),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, w_blk, 1, kwic),
                         lambda n, h, w, r, s_h=s_h: (n, w, h * s_h + r, 0)),
            pl.BlockSpec((1, kwic, k_c), lambda n, h, w, r: (r, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, w_blk, k_c),
                               lambda n, h, w, r: (n, h, w, 0)),
        out_shape=jax.ShapeDtypeStruct((i_n, o_h, o_w_p, k_c), jnp.float32),
        interpret=interpret,
    )(low, kernel_mat)
    return out[:, :, :o_w, :]


# ---------------------------------------------------------------------------
# Fused kernel: lowering in VMEM, no L in HBM (beyond-paper)
# ---------------------------------------------------------------------------

def _fused_kernel(i_ref, k_ref, o_ref, *, k_w: int, s_w: int, w_blk: int,
                  precision):
    # i_ref: (1, 1, i_w, i_c) — one input row (h*s_h + r) in VMEM
    # k_ref: (1, kwic, k_c); o_ref: (1, 1, w_blk, k_c)
    r = pl.program_id(3)
    w = pl.program_id(2)

    @pl.when(r == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = i_ref[0, 0]                     # (i_w, i_c)
    i_c = x.shape[1]
    base = w * (s_w * w_blk)            # input col of first window in block
    span = s_w * (w_blk - 1) + 1
    cols = []
    for j in range(k_w):
        seg = lax.dynamic_slice(x, (base + j, 0), (span, i_c))
        cols.append(seg[::s_w])         # (w_blk, i_c)
    strip = jnp.stack(cols, axis=1).reshape(w_blk, k_w * i_c)
    acc = jnp.dot(strip, k_ref[0], precision=precision,
                  preferred_element_type=jnp.float32)
    o_ref[0, 0] += acc.astype(o_ref.dtype)


# ---------------------------------------------------------------------------
# Fused v2: h-blocked with halo (beyond-paper, DESIGN §2 / EXPERIMENTS §Perf)
# v1 fetches each input row k_h/s_h times (once per output row using it).
# v2 processes oh_blk output rows per grid step; the input block is the
# oh_blk*s_h rows it owns plus a (k_h - s_h)-row halo fetched through a
# SECOND BlockSpec view of the same input pointing at the next block —
# each input row now crosses HBM ~(1 + halo/block) times.
# ---------------------------------------------------------------------------

def _fused2_kernel(i_ref, halo_ref, k_ref, o_ref, *, k_w: int, s_w: int,
                   s_h: int, w_blk: int, oh_blk: int, halo: int,
                   precision):
    r = pl.program_id(3)
    w = pl.program_id(2)

    @pl.when(r == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    rows = i_ref[0]                        # (oh_blk*s_h, i_w, i_c)
    if halo > 0:                           # first rows of the next block
        rows = jnp.concatenate([rows, halo_ref[0][:halo]], axis=0)
    i_c = rows.shape[-1]
    base = w * (s_w * w_blk)
    span = s_w * (w_blk - 1) + 1
    acc = jnp.zeros((oh_blk, w_blk, k_ref.shape[-1]), jnp.float32)
    for dh in range(oh_blk):               # output rows in this block
        row = lax.dynamic_slice(rows, (dh * s_h + r, 0, 0),
                                (1, rows.shape[1], i_c))[0]
        cols = []
        for j in range(k_w):
            seg = lax.dynamic_slice(row, (base + j, 0), (span, i_c))
            cols.append(seg[::s_w])
        strip = jnp.stack(cols, axis=1).reshape(w_blk, k_w * i_c)
        acc = acc.at[dh].set(
            jnp.dot(strip, k_ref[0], precision=precision,
                    preferred_element_type=jnp.float32))
    o_ref[0] += acc.astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("stride", "w_blk", "oh_blk", "interpret",
                                    "precision"))
def mec_conv_fused2_pallas(inp: jnp.ndarray, kernel: jnp.ndarray, stride=1,
                           w_blk: int = 128, oh_blk: int = 8,
                           interpret: bool = True,
                           precision=None) -> jnp.ndarray:
    """h-blocked fused MEC conv (halo via second BlockSpec view)."""
    s_h, s_w = (stride, stride) if isinstance(stride, int) else stride
    i_n, i_h, i_w, i_c = inp.shape
    k_h, k_w, _, k_c = kernel.shape
    o_h = (i_h - k_h) // s_h + 1
    o_w = (i_w - k_w) // s_w + 1
    halo = k_h - s_h
    if halo < 0 or halo > s_h * oh_blk:
        # non-overlapping kernels (or giant halo): fall back to v1
        return mec_conv_fused_pallas(inp, kernel, (s_h, s_w), w_blk=w_blk,
                                     interpret=interpret,
                                     precision=precision)
    oh_blk = min(oh_blk, o_h)
    w_blk = min(w_blk, o_w)
    pad_h = (-o_h) % oh_blk
    pad_w = (-o_w) % w_blk
    o_h_p, o_w_p = o_h + pad_h, o_w + pad_w
    rows_blk = s_h * oh_blk
    n_hblocks = o_h_p // oh_blk
    # one extra zero block so the h+1 halo view is always in bounds
    need_h = (n_hblocks + 1) * rows_blk
    need_w = s_w * (o_w_p - 1) + k_w
    inp = jnp.pad(inp, ((0, 0), (0, max(0, need_h - i_h)),
                        (0, max(0, need_w - i_w)), (0, 0)))
    kernel_mat = kernel.reshape(k_h, k_w * i_c, k_c)
    grid = (i_n, n_hblocks, o_w_p // w_blk, k_h)
    out = pl.pallas_call(
        functools.partial(_fused2_kernel, k_w=k_w, s_w=s_w, s_h=s_h,
                          w_blk=w_blk, oh_blk=oh_blk, halo=halo,
                          precision=precision),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, rows_blk, inp.shape[2], i_c),
                         lambda n, h, w, r: (n, h, 0, 0)),
            # halo: the NEXT h-block of the same input (always in bounds
            # thanks to the extra zero block)
            pl.BlockSpec((1, rows_blk, inp.shape[2], i_c),
                         lambda n, h, w, r: (n, h + 1, 0, 0)),
            pl.BlockSpec((1, k_w * i_c, k_c), lambda n, h, w, r: (r, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, oh_blk, w_blk, k_c),
                               lambda n, h, w, r: (n, h, w, 0)),
        out_shape=jax.ShapeDtypeStruct((i_n, o_h_p, o_w_p, k_c), jnp.float32),
        interpret=interpret,
    )(inp, inp, kernel_mat)
    return out[:, :o_h, :o_w, :].astype(inp.dtype)


@functools.partial(jax.jit,
                   static_argnames=("stride", "w_blk", "interpret",
                                    "precision"))
def mec_conv_fused_pallas(inp: jnp.ndarray, kernel: jnp.ndarray, stride=1,
                          w_blk: int = 128,
                          interpret: bool = True,
                          precision=None) -> jnp.ndarray:
    """Fused MEC convolution: implicit lowering inside the GEMM pipeline.

    inp: (n, i_h, i_w, i_c) pre-padded; kernel: (k_h, k_w, i_c, k_c).
    Returns (n, o_h, o_w, k_c) in inp.dtype (f32 accumulation).
    """
    s_h, s_w = (stride, stride) if isinstance(stride, int) else stride
    i_n, i_h, i_w, i_c = inp.shape
    k_h, k_w, _, k_c = kernel.shape
    o_h = (i_h - k_h) // s_h + 1
    o_w = (i_w - k_w) // s_w + 1
    w_blk = min(w_blk, o_w)
    pad_w = (-o_w) % w_blk
    o_w_p = o_w + pad_w
    # Pad input width so the last window block is in-bounds.
    need_w = s_w * (o_w_p - 1) + k_w
    if need_w > i_w:
        inp = jnp.pad(inp, ((0, 0), (0, 0), (0, need_w - i_w), (0, 0)))
    kernel_mat = kernel.reshape(k_h, k_w * i_c, k_c)
    grid = (i_n, o_h, o_w_p // w_blk, k_h)
    out = pl.pallas_call(
        functools.partial(_fused_kernel, k_w=k_w, s_w=s_w, w_blk=w_blk,
                          precision=precision),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, inp.shape[2], i_c),
                         lambda n, h, w, r, s_h=s_h: (n, h * s_h + r, 0, 0)),
            pl.BlockSpec((1, k_w * i_c, k_c), lambda n, h, w, r: (r, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, w_blk, k_c),
                               lambda n, h, w, r: (n, h, w, 0)),
        out_shape=jax.ShapeDtypeStruct((i_n, o_h, o_w_p, k_c), jnp.float32),
        interpret=interpret,
    )(inp, kernel_mat)
    return out[:, :, :o_w, :].astype(inp.dtype)
