"""Step builders: training (with optional int8-compressed DP gradients)
and serving (prefill / decode).  All steps are pure functions suitable for
jax.jit with in/out shardings from repro.parallel.sharding.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.compat import shard_map
from repro.models import serve
from repro.models.lm import LM
from repro.optim import adamw
from repro.parallel import compression
from repro.parallel.axes import ShardingRules, use_rules
from repro.training.loss import chunked_softmax_xent


def make_loss_fn(model: LM):
    def loss_fn(params, batch):
        h, aux = model.forward(params, batch)
        loss, metrics = chunked_softmax_xent(
            h, model.head_weights(params), batch["labels"])
        return loss + aux, dict(metrics, aux=aux)
    return loss_fn


def make_train_step(model: LM, opt_cfg: adamw.AdamWConfig,
                    rules: Optional[ShardingRules] = None):
    loss_fn = make_loss_fn(model)

    def train_step(params, opt_state, batch):
        with use_rules(rules):
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        new_params, new_opt, om = adamw.update(opt_cfg, grads, opt_state,
                                               params)
        return new_params, new_opt, dict(metrics, loss=loss, **om)

    return train_step


def make_compressed_train_step(model: LM, opt_cfg: adamw.AdamWConfig,
                               rules: ShardingRules):
    """Training with int8 error-feedback gradient all-reduce over the DP
    axes.

    The shard_map is *manual over the DP axes only* (``axis_names``):
    tensor-parallel sharding over the model axis stays with GSPMD inside
    the body, so this composes with TP meshes.  (Expert-parallel MoE's
    internal shard_map does not nest under partial-manual yet — use
    ``moe_impl='local'`` or plain training for EP models; see
    EXPERIMENTS.md kimi iter-5 note.)
    """
    mesh = rules.mesh
    dp_axes = tuple(rules.dp_axes) or tuple(mesh.axis_names)
    manual = set(dp_axes)
    loss_fn = make_loss_fn(model)
    rep = P()

    def train_step(params, opt_state, batch):
        def shard_fn(params, ef, batch):
            # params replicated w.r.t. the manual DP axes -> grads arrive
            # un-reduced per DP shard; we own the reduction (quantized).
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            grads, new_ef = compression.compressed_psum(grads, ef, dp_axes)
            loss = jax.lax.pmean(loss, dp_axes)
            metrics = jax.tree.map(lambda m: jax.lax.pmean(m, dp_axes),
                                   metrics)
            return loss, metrics, grads, new_ef

        pspec = jax.tree.map(lambda _: rep, params)
        espec = jax.tree.map(lambda _: rep, opt_state["ef"])
        bspec = jax.tree.map(
            lambda _: P(dp_axes if len(dp_axes) > 1 else dp_axes[0]), batch)
        loss, metrics, grads, new_ef = shard_map(
            shard_fn, mesh=mesh,
            in_specs=(pspec, espec, bspec),
            out_specs=(rep, jax.tree.map(lambda _: rep, metrics_shape(model)),
                       pspec, espec),
            axis_names=manual,
            check_vma=False,
        )(params, opt_state["ef"], batch)
        inner = {k: opt_state[k] for k in ("m", "v", "step")}
        new_params, new_inner, om = adamw.update(opt_cfg, grads, inner, params)
        new_opt = dict(new_inner, ef=new_ef)
        return new_params, new_opt, dict(metrics, loss=loss, **om)

    return train_step


def metrics_shape(model: LM):  # lint-ignore: accepted-kwarg-not-forwarded (metrics schema is model-independent today; signature is the extension point)
    return {"nll": 0.0, "tokens": 0.0, "aux": 0.0}


def init_opt_state(params, compressed: bool = False):
    state = adamw.init(params)
    if compressed:
        state["ef"] = compression.init_ef(params)
    return state


def make_prefill_step(model: LM, max_len: int,
                      rules: Optional[ShardingRules] = None):
    def prefill_step(params, batch):
        with use_rules(rules):
            return serve.prefill(model, params, batch, max_len)
    return prefill_step


def make_decode_step(model: LM, rules: Optional[ShardingRules] = None):
    def decode_step(params, cache, tokens):
        with use_rules(rules):
            return serve.decode_step(model, params, cache, tokens)
    return decode_step
