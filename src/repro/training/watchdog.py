"""Straggler/step-time watchdog.

On a real pod every host runs this around its step loop; a host whose step
time exceeds ``threshold x median`` is flagged (logged + counted) so the
orchestrator can preempt/replace it.  Hangs are caught by a hard deadline:
``check_deadline`` raises if a step exceeds ``hard_timeout_s``, letting the
surrounding retry loop checkpoint-restart the job (tested on CPU by
simulation in tests/test_fault_tolerance.py).
"""
from __future__ import annotations

import statistics
import time
from typing import List, Optional


class StepWatchdog:
    def __init__(self, threshold: float = 2.0, window: int = 50,
                 hard_timeout_s: Optional[float] = None,
                 warmup_steps: int = 2):
        self.threshold = threshold
        self.window = window
        self.hard_timeout_s = hard_timeout_s
        self.warmup_steps = warmup_steps
        self.times: List[float] = []
        self.straggler_events = 0
        self._t0: Optional[float] = None
        self._steps_seen = 0

    def start_step(self) -> None:
        self._t0 = time.monotonic()

    def check_deadline(self) -> None:
        if (self.hard_timeout_s is not None and self._t0 is not None
                and time.monotonic() - self._t0 > self.hard_timeout_s):
            raise TimeoutError(
                f"step exceeded hard timeout {self.hard_timeout_s}s")

    def end_step(self) -> float:
        dt = time.monotonic() - self._t0
        self._steps_seen += 1
        if self._steps_seen > self.warmup_steps:   # skip compile steps
            self.times.append(dt)
            self.times = self.times[-self.window:]
            if len(self.times) >= 5:
                med = statistics.median(self.times)
                if dt > self.threshold * med:
                    self.straggler_events += 1
        return dt

    @property
    def median(self) -> float:
        return statistics.median(self.times) if self.times else 0.0
