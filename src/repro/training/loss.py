"""Sequence-chunked softmax cross-entropy.

Materializing (B, S, V) f32 logits for a 152k vocab costs ~10 GB per
device at our shapes, so the loss scans over sequence chunks: each chunk
projects (B, c, d) -> (B, c, V) (vocab-sharded under TP), reduces, and
discards.  Gradients flow through the scan; peak memory is one chunk.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax


def chunked_softmax_xent(h: jnp.ndarray, w_head: jnp.ndarray,
                         labels: jnp.ndarray, chunk: int = 512,
                         z_loss: float = 1e-4):
    """h (B, S, d); w_head (d, V); labels (B, S) int32 (-1 = ignore).

    Returns (mean_nll, metrics dict).
    """
    b, s, d = h.shape
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    nc = (s + pad) // chunk
    hc = h.reshape(b, nc, chunk, d).swapaxes(0, 1)        # (nc, B, c, d)
    lc = labels.reshape(b, nc, chunk).swapaxes(0, 1)

    def step(carry, inputs):
        nll_sum, z_sum, n_tok = carry
        h_i, l_i = inputs
        logits = jnp.einsum("bcd,dv->bcv", h_i.astype(jnp.float32),
                            w_head.astype(jnp.float32))
        lse = jax.nn.logsumexp(logits, axis=-1)           # (B, c)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(l_i, 0)[..., None], axis=-1)[..., 0]
        valid = (l_i >= 0).astype(jnp.float32)
        nll_sum = nll_sum + jnp.sum((lse - gold) * valid)
        z_sum = z_sum + jnp.sum(jnp.square(lse) * valid)
        return (nll_sum, z_sum, n_tok + valid.sum()), None

    init = (jnp.zeros((), jnp.float32),) * 3
    (nll, z, n), _ = lax.scan(step, init, (hc, lc))
    n = jnp.maximum(n, 1.0)
    loss = nll / n + z_loss * z / n
    return loss, {"nll": nll / n, "tokens": n}
