"""AdamW with global-norm clipping and warmup-cosine schedule (no optax in
this environment).  Optimizer moments are f32 regardless of param dtype;
under the production mesh the moment tree additionally gets ZeRO-1
sharding (see repro.parallel.sharding.zero1_specs).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(1.0, cfg.warmup_steps)
    t = (step - cfg.warmup_steps) / jnp.maximum(
        1.0, cfg.total_steps - cfg.warmup_steps)
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init(params) -> Dict[str, Any]:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"m": zeros, "v": jax.tree.map(jnp.copy, zeros),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def update(cfg: AdamWConfig, grads, opt_state, params
           ) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat, vhat = m2 / b1c, v2 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    out = jax.tree.map(upd, grads, opt_state["m"], opt_state["v"], params)
    new_params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}
