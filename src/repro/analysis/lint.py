"""Repo-invariant AST lint (``repro.analysis``, DESIGN.md §8).

A small, deliberately non-configurable ``ast`` pass enforcing invariants
this repo has already been bitten by — each rule is named after the bug
class it prevents:

``accepted-kwarg-not-forwarded``
    A ``def`` accepts a named parameter that its body never reads or
    passes through.  This is the PR 4 bug class: ``precision=`` accepted
    by the MEC paths and silently dropped on the floor.  Parameters
    named ``self``/``cls``/``_*`` and pure interface stubs
    (``pass``/``...``/``raise NotImplementedError`` bodies) are exempt.

``raw-environ-read-outside-compat``
    ``os.environ[...]`` / ``os.environ.get`` / ``os.getenv`` read
    anywhere but ``core/compat.py``, the plan cache (``plan/cache.py``),
    and the calibration store (``plan/calibrate.py``).  Env reads are
    version/deployment surface; one module owning them is what lets the
    jax-matrix CI leg work.

``shard-map-import-outside-compat``
    ``shard_map`` imported from jax anywhere but ``core/compat.py`` —
    the shim owns the moved-module / renamed-kwarg differences; a direct
    import silently bypasses them on one side of the version matrix.

``deprecated-acc-bytes-env``
    Any read of the deprecated ``REPRO_MEC_ACC_BYTES`` override outside
    its one sanctioned accessor; tuned accumulator budgets belong in a
    :class:`repro.plan.ConvPlan`.

``no-bare-dot-precision``
    A ``jnp.dot`` / ``jnp.einsum`` / ``lax.dot_general`` (any attribute
    call named ``dot``/``einsum``/``dot_general``) inside the numeric
    core (``src/repro/core``, ``src/repro/kernels``,
    ``src/repro/parallel``) without an explicit ``precision=`` or
    ``preferred_element_type=`` keyword.  A bare GEMM silently runs at
    the backend default — the exact silent-downcast class the
    shardcheck precision-flow pass catches after lowering; this rule
    catches it at the call site.

Suppression: append ``# lint-ignore: <rule>[, <rule>...]`` (or a bare
``# lint-ignore`` for every rule) to the flagged line — for the kwarg
rule, to the ``def`` line.  Pre-existing findings are grandfathered in a
committed baseline (``benchmarks/baselines/lint_baseline.json``) keyed
by ``rule:path:symbol`` — line-number free, so unrelated edits never
churn it.  Any finding not in the baseline fails the run; fixing a
grandfathered finding and regenerating (``python -m repro.analysis
--suite lint --update-lint-baseline``) shrinks the baseline
monotonically.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import pathlib
import re
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

LINT_BASELINE_VERSION = 1

RULES = (
    "accepted-kwarg-not-forwarded",
    "raw-environ-read-outside-compat",
    "shard-map-import-outside-compat",
    "deprecated-acc-bytes-env",
    "no-bare-dot-precision",
)

# Directories whose GEMM call sites must pin their numerics (the rule
# scope, not the scan scope — bench/examples glue may use defaults).
# PR 10 widened the scope from the numeric core to everything that
# executes on the serving/training path and burned the grandfathered
# baseline to zero — new findings fail outright now.
_DOT_PRECISION_DIRS = ("src/repro/core/", "src/repro/kernels/",
                       "src/repro/parallel/", "src/repro/models/",
                       "src/repro/serving/", "src/repro/plan/")
_DOT_CALLEES = ("dot", "einsum", "dot_general")

# Files allowed to read the environment raw: the version-compat shim and
# the plan cache + calibration store (whose directory/file overrides ARE
# their public configuration).
_ENVIRON_ALLOWED = ("core/compat.py", "plan/cache.py", "plan/calibrate.py")
_SHARD_MAP_ALLOWED = ("core/compat.py",)
_ACC_BYTES_ENV = "REPRO_MEC_ACC_BYTES"

# Directories scanned relative to the repo root; tests are out of scope
# (fixtures deliberately contain violations).
DEFAULT_SCAN_DIRS = ("src/repro", "benchmarks", "examples")

_SUPPRESS_RE = re.compile(r"#\s*lint-ignore(?::\s*(?P<rules>[\w\-, ]+))?")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One lint violation.  ``key()`` is the line-stable identity the
    baseline stores: rule + file + symbol, never the line number."""

    rule: str
    path: str                  # repo-relative, forward slashes
    symbol: str                # enclosing def/import detail
    lineno: int
    message: str

    def key(self) -> str:
        return f"{self.rule}:{self.path}:{self.symbol}"

    def render(self) -> str:
        return f"{self.path}:{self.lineno}: [{self.rule}] {self.message}"


def _suppressed(source_lines: Sequence[str], lineno: int,
                rule: str) -> bool:
    if not 1 <= lineno <= len(source_lines):
        return False
    m = _SUPPRESS_RE.search(source_lines[lineno - 1])
    if not m:
        return False
    rules = m.group("rules")
    if rules is None:
        return True
    return rule in {r.strip() for r in rules.split(",")}


def _is_stub_body(body: Sequence[ast.stmt]) -> bool:
    """Interface stubs legitimately ignore their parameters."""
    stmts = list(body)
    if stmts and isinstance(stmts[0], ast.Expr) and \
            isinstance(stmts[0].value, ast.Constant) and \
            isinstance(stmts[0].value.value, str):
        stmts = stmts[1:]                      # docstring
    if not stmts:
        return True
    if len(stmts) > 1:
        return False
    s = stmts[0]
    if isinstance(s, ast.Pass):
        return True
    if isinstance(s, ast.Expr) and isinstance(s.value, ast.Constant) \
            and s.value.value is Ellipsis:
        return True
    if isinstance(s, ast.Raise) and s.exc is not None:
        name = s.exc.func if isinstance(s.exc, ast.Call) else s.exc
        return getattr(name, "id", None) == "NotImplementedError"
    return False


def _check_unused_params(tree: ast.AST, path: str,
                         lines: Sequence[str]) -> List[Finding]:
    rule = "accepted-kwarg-not-forwarded"
    out: List[Finding] = []
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if any(isinstance(d, ast.Name) and d.id in ("overload",)
               for d in node.decorator_list):
            continue
        if _is_stub_body(node.body):
            continue
        args = node.args
        params = [a.arg for a in (args.posonlyargs + args.args
                                  + args.kwonlyargs)]
        names_read = {n.id for stmt in node.body
                      for n in ast.walk(stmt) if isinstance(n, ast.Name)}
        # A nested def/lambda re-binding the name still counts via Name
        # nodes; ``**kwargs`` forwarding reads the kwargs Name itself.
        for p in params:
            if p in ("self", "cls") or p.startswith("_"):
                continue
            if p in names_read:
                continue
            if _suppressed(lines, node.lineno, rule):
                continue
            out.append(Finding(
                rule=rule, path=path, symbol=f"{node.name}:{p}",
                lineno=node.lineno,
                message=f"def {node.name}(...) accepts {p!r} but its body "
                        f"never reads or forwards it (PR-4 dropped-kwarg "
                        f"class)"))
    return out


def _environ_read_calls(tree: ast.AST) -> Iterable[Tuple[ast.AST, str,
                                                         Optional[ast.expr]]]:
    """Yield (node, kind, key_expr) for every raw environment *read*:
    ``os.environ.get/setdefault(k)``, ``os.environ[k]`` loads, and
    ``os.getenv(k)``.  Writes (``os.environ[k] = v``) are not reads."""
    def is_os_environ(n: ast.AST) -> bool:
        return (isinstance(n, ast.Attribute) and n.attr == "environ"
                and isinstance(n.value, ast.Name) and n.value.id == "os")

    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            f = node.func
            if isinstance(f, ast.Attribute) and \
                    f.attr in ("get", "setdefault") and \
                    is_os_environ(f.value):
                yield node, f"os.environ.{f.attr}", \
                    node.args[0] if node.args else None
            elif isinstance(f, ast.Attribute) and f.attr == "getenv" and \
                    isinstance(f.value, ast.Name) and f.value.id == "os":
                yield node, "os.getenv", node.args[0] if node.args else None
        elif isinstance(node, ast.Subscript) and \
                is_os_environ(node.value) and \
                isinstance(node.ctx, ast.Load):
            yield node, "os.environ[...]", node.slice


def _check_environ_reads(tree: ast.AST, path: str,
                         lines: Sequence[str]) -> List[Finding]:
    out: List[Finding] = []
    allowed = any(path.endswith(a) for a in _ENVIRON_ALLOWED)
    for node, kind, key in _environ_read_calls(tree):
        key_name = None
        if isinstance(key, ast.Constant) and isinstance(key.value, str):
            key_name = key.value
        elif isinstance(key, ast.Name):
            key_name = key.id
        deprecated = key_name in (_ACC_BYTES_ENV, "ACC_BYTES_ENV")
        if not allowed and not _suppressed(
                lines, node.lineno, "raw-environ-read-outside-compat"):
            out.append(Finding(
                rule="raw-environ-read-outside-compat", path=path,
                symbol=f"{kind}:{key_name or '<dynamic>'}",
                lineno=node.lineno,
                message=f"{kind}({key_name or '...'}) outside "
                        f"{_ENVIRON_ALLOWED}: route environment surface "
                        f"through repro.core.compat or the plan cache"))
        if deprecated and not _suppressed(
                lines, node.lineno, "deprecated-acc-bytes-env"):
            out.append(Finding(
                rule="deprecated-acc-bytes-env", path=path,
                symbol=f"{kind}:{key_name}", lineno=node.lineno,
                message=f"read of deprecated {_ACC_BYTES_ENV}: tuned "
                        f"accumulator budgets belong in a ConvPlan "
                        f"(repro.plan.plan_conv2d -> plan.w_blk)"))
    return out


def _check_shard_map_imports(tree: ast.AST, path: str,
                             lines: Sequence[str]) -> List[Finding]:
    rule = "shard-map-import-outside-compat"
    if any(path.endswith(a) for a in _SHARD_MAP_ALLOWED):
        return []
    out: List[Finding] = []
    for node in ast.walk(tree):
        detail = None
        if isinstance(node, ast.ImportFrom):
            mod = node.module or ""
            if mod.startswith("jax") and (
                    "shard_map" in mod
                    or any(a.name == "shard_map" for a in node.names)):
                detail = f"from {mod} import " + \
                    ", ".join(a.name for a in node.names)
        elif isinstance(node, ast.Import):
            for a in node.names:
                if a.name.startswith("jax") and "shard_map" in a.name:
                    detail = f"import {a.name}"
        if detail and not _suppressed(lines, node.lineno, rule):
            out.append(Finding(
                rule=rule, path=path, symbol=detail, lineno=node.lineno,
                message=f"{detail}: import shard_map from "
                        f"repro.core.compat (the shim owns the "
                        f"moved-module and renamed-kwarg differences)"))
    return out


def _check_bare_dot_precision(tree: ast.AST, path: str,
                              lines: Sequence[str]) -> List[Finding]:
    rule = "no-bare-dot-precision"
    if not any(path.startswith(d) for d in _DOT_PRECISION_DIRS):
        return []
    out: List[Finding] = []

    def visit(node: ast.AST, scope: str) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                visit(child, child.name)
                continue
            if isinstance(child, ast.Call):
                f = child.func
                kws = {k.arg for k in child.keywords}
                if isinstance(f, ast.Attribute) \
                        and f.attr in _DOT_CALLEES \
                        and "precision" not in kws \
                        and "preferred_element_type" not in kws \
                        and None not in kws \
                        and not _suppressed(lines, child.lineno, rule):
                    # a **kwargs splat (None in kws) may carry
                    # precision; shardcheck's flow pass still audits
                    # what actually lowers.
                    base = getattr(f.value, "id",
                                   getattr(f.value, "attr", "?"))
                    out.append(Finding(
                        rule=rule, path=path,
                        symbol=f"{scope}:{base}.{f.attr}",
                        lineno=child.lineno,
                        message=f"{base}.{f.attr}(...) in {scope} without "
                                f"explicit precision= or "
                                f"preferred_element_type= — a bare GEMM "
                                f"runs at the backend default "
                                f"(silent-downcast class; see "
                                f"shardcheck's precision-flow pass)"))
            visit(child, scope)

    visit(tree, "<module>")
    return out


def lint_file(path: pathlib.Path, rel: str) -> List[Finding]:
    source = path.read_text()
    try:
        tree = ast.parse(source, filename=str(path))
    except SyntaxError as e:
        return [Finding(rule="accepted-kwarg-not-forwarded", path=rel,
                        symbol="<syntax-error>", lineno=e.lineno or 0,
                        message=f"file does not parse: {e.msg}")]
    lines = source.splitlines()
    out: List[Finding] = []
    out += _check_unused_params(tree, rel, lines)
    out += _check_environ_reads(tree, rel, lines)
    out += _check_shard_map_imports(tree, rel, lines)
    out += _check_bare_dot_precision(tree, rel, lines)
    return out


def repo_root() -> pathlib.Path:
    """The checkout root (three levels above this file's package)."""
    return pathlib.Path(__file__).resolve().parents[3]


def lint_tree(root: Optional[pathlib.Path] = None,
              scan_dirs: Sequence[str] = DEFAULT_SCAN_DIRS) -> List[Finding]:
    root = pathlib.Path(root) if root is not None else repo_root()
    findings: List[Finding] = []
    for d in scan_dirs:
        base = root / d
        if not base.exists():
            continue
        for py in sorted(base.rglob("*.py")):
            rel = py.relative_to(root).as_posix()
            findings.extend(lint_file(py, rel))
    return sorted(findings, key=lambda f: (f.path, f.lineno, f.rule))


# ---------------------------------------------------------------- baseline

def load_baseline(path) -> List[str]:
    doc = json.loads(pathlib.Path(path).read_text())
    if doc.get("lint_baseline_version") != LINT_BASELINE_VERSION:
        raise ValueError(
            f"lint baseline {path} has version "
            f"{doc.get('lint_baseline_version')!r}, expected "
            f"{LINT_BASELINE_VERSION}")
    keys = doc.get("findings")
    if not isinstance(keys, list) or \
            not all(isinstance(k, str) for k in keys):
        raise ValueError(f"lint baseline {path}: findings must be a list "
                         "of rule:path:symbol strings")
    return keys


def write_baseline(findings: Sequence[Finding], path) -> None:
    doc = {
        "lint_baseline_version": LINT_BASELINE_VERSION,
        "findings": sorted({f.key() for f in findings}),
    }
    pathlib.Path(path).write_text(
        json.dumps(doc, indent=2, sort_keys=True) + "\n")


def apply_baseline(findings: Sequence[Finding],
                   baseline_keys: Sequence[str]) -> Dict[str, List]:
    """Split findings into new failures vs. grandfathered, and report
    baseline entries that no longer fire (fixed — shrink the file)."""
    baseline = set(baseline_keys)
    new = [f for f in findings if f.key() not in baseline]
    grandfathered = [f for f in findings if f.key() in baseline]
    fixed = sorted(baseline - {f.key() for f in findings})
    return {"new": new, "grandfathered": grandfathered, "fixed": fixed}
