"""Numerics contract checker: dtype-flow + accumulation audit
(DESIGN.md §8.5).

MEC's Table 2 claim is *result-preserving* memory/speed trades: im2col,
FFT, Winograd, the compact-L GEMMs and the Pallas kernels must all
compute the same convolution.  memaudit verifies the memory leg and
shardcheck the distributed leg; this module closes the numerics leg.
For one backend x dtype it extracts the computation's **numeric
signature** from the jaxpr — every ``dot_general`` /
``conv_general_dilated``'s operand dtypes, ``preferred_element_type``
and ``precision``, and every ``convert_element_type`` edge classified
as widen / narrow / complexify — recursing into Pallas kernels,
``custom_vjp`` branches and ``shard_map`` bodies, and checks it against
the backend's declared :class:`repro.core.numerics.NumericContract`:

* **disallowed-dtype** — a float/complex dtype outside the contract's
  allowed set ({input dtype, f32} + complex64 for FFT); catches both a
  stray mid-chain downcast (an ``astype(bf16)`` in an f32 program) and
  any f64/complex128 leak.
* **accumulation** — a contraction with sub-f32 operands whose output
  is also sub-f32 accumulated below the contract width (a dropped
  ``preferred_element_type``, the PR 4/PR 5 bug class).
* **pallas-accum** — the in-kernel variant, checked symbolically on the
  kernel jaxpr beside ``pallas_check.check_geometry``: Pallas dots must
  *carry* ``preferred_element_type=f32`` for sub-f32 inputs (MXU
  accumulation width is set per dot, not recovered by a later cast).
* **narrow-widen** — a value narrowed then widened again (silent
  precision loss); taint propagates through structural ops only
  (reshape/transpose/slice/...), so a forward output legitimately
  consumed by arithmetic in the backward pass never false-positives.
* **output-cast-count** — the forward program narrows back to the
  input dtype through *exactly* ``fwd_output_narrows`` cast edges (one
  everywhere today; two would be double rounding).
* **error-budget** — a measured probe: fwd + ``value_and_grad`` of a
  quadratic loss vs an f64 numpy reference on fixed seeds, gated by the
  per-algorithm tolerances the contract declares (never the test file).

The precision-flow pass that shipped inside shardcheck (PR 9) now lives
here — :func:`jaxpr_dot_precisions`, :func:`hlo_precision_tally`,
:func:`precision_flow_findings` — and shardcheck re-imports them, so
the partitioned contract keeps working unchanged.

Wired at the same three layers as shardcheck: ``plan_conv2d`` asserts
the static contract (:func:`assert_plan_numerics`, memoized) before
returning any plan; bench cells record a reduced ``numcheck`` field
(:func:`cell_numcheck`) gated by ``bench.check``; ``python -m
repro.analysis --suite numcheck`` sweeps every backend x {f32, bf16,
f16} x {fwd, grad} into the CI-gated ``BENCH_numcheck.json``.

Layering: never imports ``repro.plan`` (plans are duck-typed); jax is
imported lazily so contract data is usable before backend init.
"""
from __future__ import annotations

import contextlib
import dataclasses
import re
import warnings
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.numerics import (CONTRACT_DTYPES, NumericContract,
                                 contract_for, float_bits)

DIRECTIONS = ("fwd", "grad")

#: executor backends the CLI suite sweeps (ALGORITHMS minus "auto").
NUMCHECK_ALGORITHMS = ("direct", "im2col", "fft", "winograd", "mec",
                       "mec_lowered", "mec_fused", "mec_fused2")
NUMCHECK_DTYPES = CONTRACT_DTYPES


def probe_spec():
    """The fixed geometry every contract budget is measured on: 3x3
    stride-1 (so winograd participates), small enough that the 24-cell
    f64 sweep stays in CI budget.  Matches shardcheck's probe spec."""
    from repro.core.convspec import ConvSpec
    return ConvSpec(2, 16, 16, 3, 3, 3, 4, 1, 1)

_DOT_PRIMS = ("dot_general", "conv_general_dilated")


@contextlib.contextmanager
def _quiet_trace():
    """The checker's internal traces go through the kwargs dispatch path
    (no ConvPlan), which may cross deprecation shims; those warnings are
    about the *caller's* API choice, not this audit — keep them out of
    planners and bench runs."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        yield

# Data-movement primitives that preserve a value's rounding history —
# the only edges narrow-widen taint flows through.  Arithmetic consumes
# the value (a terminal narrow followed by downstream compute is the
# normal sub-f32 output path, not double rounding).
_STRUCTURAL_PRIMS = frozenset({
    "reshape", "transpose", "broadcast_in_dim", "squeeze", "slice",
    "dynamic_slice", "dynamic_update_slice", "rev", "concatenate", "pad",
    "gather", "copy",
})

_COMPLEX_BITS = {"complex64": 64, "complex128": 128}

_HLO_DOT_RE = re.compile(r"=\s*\S+\s+(?:dot|convolution)\(")
# `%x = bf16[2,14,14,4]{3,2,1,0} convert(f32[2,14,14,4]{3,2,1,0} %y)`
_HLO_CONVERT_RE = re.compile(
    r"=\s*([a-z0-9]+)\[[^\]]*\](?:\{[^}]*\})?\s*convert\(([a-z0-9]+)\[")


class NumCheckError(AssertionError):
    """A backend's lowering broke its declared numeric contract."""


@dataclasses.dataclass(frozen=True)
class ContractViolation:
    rule: str          # disallowed-dtype | f64-leak | accumulation |
    #                    pallas-accum | narrow-widen | output-cast-count |
    #                    error-budget | precision-flow | (shardcheck's
    #                    collective rules reuse this class)
    direction: str     # 'fwd' | 'grad' | 'static'
    message: str

    def render(self) -> str:
        return f"[{self.rule}] {self.direction}: {self.message}"


# ---------------------------------------------------------------------------
# jaxpr walking (shared with shardcheck)
# ---------------------------------------------------------------------------

def _subjaxprs(value):
    """Jaxprs reachable from one eqn param (ClosedJaxpr, raw Jaxpr, or
    containers of either — pallas_call kernels, custom_vjp branches,
    shard_map bodies all hide theirs differently)."""
    if hasattr(value, "eqns"):                       # raw Jaxpr
        yield value
    elif hasattr(value, "jaxpr") and hasattr(value.jaxpr, "eqns"):
        yield value.jaxpr                            # ClosedJaxpr
    elif isinstance(value, (tuple, list)):
        for v in value:
            yield from _subjaxprs(v)
    elif isinstance(value, dict):
        for v in value.values():
            yield from _subjaxprs(v)


def _iter_jaxprs(closed):
    """Every (sub-)jaxpr reachable from ``closed``, each yielded once."""
    stack = [closed.jaxpr if hasattr(closed, "jaxpr") else closed]
    seen = set()
    while stack:
        j = stack.pop()
        if id(j) in seen:
            continue
        seen.add(id(j))
        yield j
        for eqn in j.eqns:
            for v in eqn.params.values():
                stack.extend(_subjaxprs(v))


def _walk_eqns(closed):
    """``(eqn, in_pallas)`` for every eqn reachable through nested
    sub-jaxprs; ``in_pallas`` is True inside a ``pallas_call`` kernel
    body (where the in-kernel accumulator audit applies)."""
    stack = [(closed.jaxpr if hasattr(closed, "jaxpr") else closed, False)]
    seen = set()
    while stack:
        j, in_pallas = stack.pop()
        if id(j) in seen:
            continue
        seen.add(id(j))
        for eqn in j.eqns:
            yield eqn, in_pallas
            child = in_pallas or eqn.primitive.name == "pallas_call"
            for v in eqn.params.values():
                for sub in _subjaxprs(v):
                    stack.append((sub, child))


def jaxpr_dot_precisions(closed) -> List[Tuple[str, object]]:
    """``(primitive_name, precision_param)`` for every dot/convolution
    eqn reachable through nested sub-jaxprs."""
    out: List[Tuple[str, object]] = []
    for j in _iter_jaxprs(closed):
        for eqn in j.eqns:
            if eqn.primitive.name in _DOT_PRIMS:
                out.append((eqn.primitive.name,
                            eqn.params.get("precision")))
    return out


def _precision_matches(param, declared: str) -> bool:
    import jax
    want = getattr(jax.lax.Precision, declared)
    if param is None:
        return False
    vals = param if isinstance(param, tuple) else (param,)
    return all(p == want for p in vals)


def hlo_precision_tally(hlo_text: str,
                        declared: Optional[str]) -> Dict[str, int]:
    """dot/convolution ops in the (optimized) HLO, and how many lack
    the declared ``operand_precision`` marker.  With no declared
    precision nothing is required (XLA's default annotation is fine)."""
    dots = 0
    unannotated = 0
    marker = None if declared is None else \
        "operand_precision={" + declared.lower()
    for line in hlo_text.splitlines():
        if not _HLO_DOT_RE.search(line):
            continue
        dots += 1
        if marker is not None and marker not in line:
            unannotated += 1
    return {"dots": dots, "unannotated": unannotated}


def precision_flow_findings(closed_jaxprs: Sequence,
                            hlo_texts: Sequence[str],
                            declared: Optional[str]
                            ) -> Tuple[Dict, List[ContractViolation]]:
    """The precision-flow pass over one cell's lowerings.

    ``declared`` is the plan's canonical precision name ('HIGHEST' /
    'HIGH' / 'DEFAULT') or None (nothing declared — trivially clean).
    The jaxpr walk is the primary evidence (it sees inside Pallas
    kernels and custom-VJP branches, which HLO fusions can hide); the
    HLO scan is the backstop that the annotation *survived* lowering.
    """
    tally = {"declared": declared, "dot_ops": 0, "unannotated_dot_ops": 0,
             "hlo_dots": 0, "hlo_unannotated": 0}
    violations: List[ContractViolation] = []
    for closed in closed_jaxprs:
        for name, param in jaxpr_dot_precisions(closed):
            tally["dot_ops"] += 1
            if declared not in (None, "DEFAULT") and \
                    not _precision_matches(param, declared):
                tally["unannotated_dot_ops"] += 1
    for text in hlo_texts:
        t = hlo_precision_tally(
            text, None if declared in (None, "DEFAULT") else declared)
        tally["hlo_dots"] += t["dots"]
        tally["hlo_unannotated"] += t["unannotated"]
    if tally["unannotated_dot_ops"]:
        violations.append(ContractViolation(
            "precision-flow", "static",
            f"{tally['unannotated_dot_ops']}/{tally['dot_ops']} "
            f"dot/convolution op(s) in the jaxpr lack the declared "
            f"precision={declared} — a kwargs path dropped precision= "
            f"before the GEMM (the PR 4/5 silent-downcast bug class)"))
    if tally["hlo_unannotated"]:
        violations.append(ContractViolation(
            "precision-flow", "static",
            f"{tally['hlo_unannotated']}/{tally['hlo_dots']} "
            f"dot/convolution op(s) in the optimized HLO lack "
            f"operand_precision={{{str(declared).lower()},...}} — the "
            f"declared precision did not survive lowering"))
    return tally, violations


# ---------------------------------------------------------------------------
# numeric signature
# ---------------------------------------------------------------------------

def _is_complex(name: str) -> bool:
    return str(name) in _COMPLEX_BITS


def _is_inexact(name: str) -> bool:
    return float_bits(name) is not None or _is_complex(name)


def cast_kind(src: str, dst: str) -> str:
    """Classify one convert edge: narrow / widen / reformat (same-width
    float, e.g. bf16<->f16) / complexify / realify / complex-narrow /
    complex-widen / other (integer/bool)."""
    src, dst = str(src), str(dst)
    sb, db = float_bits(src), float_bits(dst)
    if sb is not None and db is not None:
        if db < sb:
            return "narrow"
        if db > sb:
            return "widen"
        return "same" if src == dst else "reformat"
    sc, dc = _is_complex(src), _is_complex(dst)
    if dc and not sc:
        return "complexify"
    if sc and not dc:
        return "realify"
    if sc and dc:
        s, d = _COMPLEX_BITS[src], _COMPLEX_BITS[dst]
        return "complex-narrow" if d < s else \
            "complex-widen" if d > s else "same"
    return "other"


def _dtype_name(value) -> Optional[str]:
    if value is None:
        return None
    import numpy as np
    try:
        return str(np.dtype(value))
    except TypeError:
        return str(value)


def extract_signature(closed) -> Dict:
    """The numeric signature of one traced program: every contraction
    (operand dtypes, accumulation dtype, precision, Pallas context) and
    every cast edge, classified."""
    dots: List[Dict] = []
    casts: List[Dict] = []
    for eqn, in_pallas in _walk_eqns(closed):
        name = eqn.primitive.name
        if name in _DOT_PRIMS:
            operands = [str(v.aval.dtype) for v in eqn.invars
                        if hasattr(v.aval, "dtype")]
            dots.append({
                "op": name,
                "operands": operands,
                "out": str(eqn.outvars[0].aval.dtype),
                "preferred_element_type":
                    _dtype_name(eqn.params.get("preferred_element_type")),
                "precision": eqn.params.get("precision"),
                "pallas": in_pallas,
            })
        elif name == "convert_element_type":
            src = str(eqn.invars[0].aval.dtype)
            dst = str(eqn.outvars[0].aval.dtype)
            casts.append({"op": name, "src": src, "dst": dst,
                          "kind": cast_kind(src, dst), "pallas": in_pallas})
    return {"dots": dots, "casts": casts}


def _render_dot(d: Dict) -> str:
    return (f"{d['op']}({' x '.join(d['operands'])} -> {d['out']}"
            + (", in-kernel" if d["pallas"] else "") + ")")


def signature_findings(sig: Dict, contract: NumericContract,
                       direction: str,
                       input_dtype: str) -> List[ContractViolation]:
    """Static detectors over one direction's numeric signature."""
    out: List[ContractViolation] = []
    allowed = set(contract.allowed_dtypes(input_dtype))
    accum_bits = float_bits(contract.accum_dtype) or 32
    flagged = set()

    def check_dtype(name: str, where: str):
        if name in allowed or not _is_inexact(name):
            return
        key = (where, name)
        if key in flagged:
            return
        flagged.add(key)
        if name in ("float64", "complex128") and not contract.allow_f64:
            out.append(ContractViolation(
                "f64-leak", direction,
                f"{where} touches {name} — the contract bans f64 "
                f"everywhere (an unintended promotion, not extra "
                f"accuracy the backend claims)"))
        else:
            out.append(ContractViolation(
                "disallowed-dtype", direction,
                f"{where} touches {name}; a {input_dtype} "
                f"{contract.algorithm} program may only use "
                f"{sorted(allowed)} — a stray mid-chain cast "
                f"silently re-rounds the value"))

    for d in sig["dots"]:
        where = _render_dot(d)
        for o in d["operands"] + [d["out"]]:
            check_dtype(o, where)
        sub = [o for o in d["operands"]
               if (float_bits(o) or 99) < accum_bits]
        out_bits = float_bits(d["out"])
        if sub and out_bits is not None and out_bits < accum_bits:
            out.append(ContractViolation(
                "accumulation", direction,
                f"{where} accumulates below {contract.accum_dtype}: "
                f"sub-{contract.accum_dtype} operands must carry "
                f"preferred_element_type={contract.accum_dtype} "
                f"(got {d['preferred_element_type']})"))
        if d["pallas"] and sub:
            p = d["preferred_element_type"]
            if p is None or (float_bits(p) or 0) < accum_bits:
                out.append(ContractViolation(
                    "pallas-accum", direction,
                    f"in-kernel {d['op']}"
                    f"({' x '.join(d['operands'])}) must carry "
                    f"preferred_element_type={contract.accum_dtype} for "
                    f"sub-f32 inputs — MXU accumulation width is set "
                    f"per dot, a later cast cannot recover it "
                    f"(got {p})"))
    for c in sig["casts"]:
        where = f"{c['op']}({c['src']} -> {c['dst']})"
        check_dtype(c["src"], where)
        check_dtype(c["dst"], where)
    in_bits = float_bits(input_dtype)
    if direction == "fwd" and in_bits is not None and in_bits < accum_bits:
        narrows = [c for c in sig["casts"]
                   if c["kind"] == "narrow" and c["dst"] == input_dtype]
        if len(narrows) != contract.fwd_output_narrows:
            srcs = ", ".join(f"{c['src']}->{c['dst']}" for c in narrows) \
                or "none"
            out.append(ContractViolation(
                "output-cast-count", direction,
                f"forward program narrows to {input_dtype} "
                f"{len(narrows)} time(s) ({srcs}); the contract says "
                f"exactly {contract.fwd_output_narrows} — fewer means "
                f"the accumulator never narrowed (dropped "
                f"preferred_element_type upstream), more means double "
                f"rounding through an intermediate {input_dtype}"))
    return out


def narrow_widen_findings(closed, direction: str) -> List[ContractViolation]:
    """A value narrowed then widened again = silent precision loss.

    Taint is per-jaxpr (never crosses sub-jaxpr boundaries) and flows
    only through :data:`_STRUCTURAL_PRIMS`; arithmetic consumes it, so
    the legitimate pattern — a sub-f32 forward output fed to backward
    compute that widens its *own* operands — never fires."""
    out: List[ContractViolation] = []
    for j in _iter_jaxprs(closed):
        taint: Dict[int, Tuple[str, str]] = {}
        for eqn in j.eqns:
            name = eqn.primitive.name
            if name == "convert_element_type":
                src_v = eqn.invars[0]
                if not hasattr(src_v, "count"):
                    # Literal operand: constants carry no history.
                    continue
                src = str(src_v.aval.dtype)
                dst = str(eqn.outvars[0].aval.dtype)
                kind = cast_kind(src, dst)
                hist = taint.get(id(src_v))
                if hist is not None and kind == "widen":
                    orig, narrowed = hist
                    out.append(ContractViolation(
                        "narrow-widen", direction,
                        f"a value narrowed {orig}->{narrowed} is widened "
                        f"back to {dst} by convert_element_type without "
                        f"intervening compute — the narrow rounded away "
                        f"precision the widen cannot restore (the "
                        f"PR 4/PR 5 silent-loss class)"))
                if kind == "narrow":
                    taint[id(eqn.outvars[0])] = (src, dst)
                elif kind in ("same", "reformat") and hist is not None:
                    taint[id(eqn.outvars[0])] = hist
            elif name in _STRUCTURAL_PRIMS:
                hist = None
                for v in eqn.invars:
                    if hasattr(v, "count") and id(v) in taint:
                        hist = taint[id(v)]
                        break
                if hist is not None:
                    for ov in eqn.outvars:
                        taint[id(ov)] = hist
    return out


def hlo_convert_counts(hlo_text: str) -> Dict[Tuple[str, str], int]:
    """(src_dtype, dst_dtype) -> count over every ``convert`` op in the
    optimized HLO text (fusion bodies included) — the lowered-cast
    evidence behind the output-cast-count regression tests."""
    counts: Dict[Tuple[str, str], int] = {}
    for line in hlo_text.splitlines():
        m = _HLO_CONVERT_RE.search(line)
        if m:
            key = (m.group(2), m.group(1))   # (operand, result)
            counts[key] = counts.get(key, 0) + 1
    return counts


# ---------------------------------------------------------------------------
# f64 reference + error probe
# ---------------------------------------------------------------------------

def f64_conv2d(x64, k64, s_h: int, s_w: int):
    """The f64 numpy oracle (jax's x64 flag stays untouched): direct
    valid convolution, NHWC x HWIO -> NHWC."""
    import numpy as np
    i_h, i_w = x64.shape[1], x64.shape[2]
    k_h, k_w = k64.shape[0], k64.shape[1]
    o_h = (i_h - k_h) // s_h + 1
    o_w = (i_w - k_w) // s_w + 1
    out = np.zeros((x64.shape[0], o_h, o_w, k64.shape[3]), np.float64)
    for r in range(k_h):
        for c in range(k_w):
            xs = x64[:, r:r + s_h * (o_h - 1) + 1:s_h,
                     c:c + s_w * (o_w - 1) + 1:s_w, :]
            out += np.einsum("nhwc,co->nhwo", xs, k64[r, c])
    return out


def f64_conv2d_grads(x64, k64, g64, s_h: int, s_w: int):
    """``(dL/dx, dL/dk)`` for cotangent ``g64``, same oracle."""
    import numpy as np
    k_h, k_w = k64.shape[0], k64.shape[1]
    o_h, o_w = g64.shape[1], g64.shape[2]
    dx = np.zeros_like(x64)
    dk = np.zeros_like(k64)
    for r in range(k_h):
        for c in range(k_w):
            sl_h = slice(r, r + s_h * (o_h - 1) + 1, s_h)
            sl_w = slice(c, c + s_w * (o_w - 1) + 1, s_w)
            xs = x64[:, sl_h, sl_w, :]
            dk[r, c] = np.einsum("nhwc,nhwo->co", xs, g64)
            dx[:, sl_h, sl_w, :] += np.einsum("nhwo,co->nhwc", g64, k64[r, c])
    return dx, dk


def _rel_err(got, ref) -> float:
    import numpy as np
    got = np.asarray(got).astype(np.float64)
    denom = max(float(np.max(np.abs(ref))), 1e-30)
    return float(np.max(np.abs(got - ref)) / denom)


def error_probe(spec, algorithm: str, dtype: str = "float32", *,
                solution: str = "auto", precision: Optional[str] = None,
                interpret: Optional[bool] = None, seed: int = 0) -> Dict:
    """Measured fwd + grad error vs the f64 oracle on fixed seeds.

    The reference consumes the *dtype-quantized* inputs widened to f64,
    so the measured error is the backend's compute error, not input
    rounding.  The grad probe is ``value_and_grad`` of ``sum(out^2)``
    — its cotangent is quantized at the input dtype, the honest
    training-time error the budgets must cover."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core.conv_api import conv2d
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(spec.i_n, spec.i_h, spec.i_w, spec.i_c)
                    .astype(np.float32), dtype)
    k = jnp.asarray(rng.randn(spec.k_h, spec.k_w, spec.i_c, spec.k_c)
                    .astype(np.float32), dtype)
    x64 = np.asarray(x).astype(np.float64)
    k64 = np.asarray(k).astype(np.float64)
    prec = None if precision is None else \
        getattr(jax.lax.Precision, precision)
    stride = (spec.s_h, spec.s_w)

    def fwd(xv, kv):
        return conv2d(xv, kv, stride=stride, algorithm=algorithm,
                      solution=solution, interpret=interpret,
                      precision=prec, partition="none")

    def loss(xv, kv):
        o = fwd(xv, kv)
        return jnp.sum(o * o)

    with _quiet_trace():
        out = jax.jit(fwd)(x, k)
        din, dk = jax.jit(jax.grad(loss, argnums=(0, 1)))(x, k)
    out64 = f64_conv2d(x64, k64, spec.s_h, spec.s_w)
    dx64, dk64 = f64_conv2d_grads(x64, k64, 2.0 * out64, spec.s_h, spec.s_w)
    return {"seed": seed,
            "fwd_err": _rel_err(out, out64),
            "din_err": _rel_err(din, dx64),
            "dk_err": _rel_err(dk, dk64)}


# ---------------------------------------------------------------------------
# the checker
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class NumCheck:
    """Verdict of one (algorithm, dtype) numeric-contract check.

    ``record`` is the JSON-able evidence bench/CLI reports embed;
    ``skipped`` carries the reason when the cell cannot be checked here
    (no contract for the backend or dtype, geometry the backend
    refuses) — a skip is not a pass and not a failure."""

    algorithm: str
    dtype: str
    violations: List[ContractViolation]
    record: Dict
    skipped: Optional[str] = None

    @property
    def ok(self) -> bool:
        return not self.violations

    def render(self) -> str:
        head = (f"numcheck {self.algorithm}/{self.dtype}: "
                f"{self.record.get('verdict')}")
        lines = [head]
        if self.skipped:
            lines.append(f"  skipped: {self.skipped}")
        lines += [f"  {v.render()}" for v in self.violations]
        return "\n".join(lines)


def check_numerics(spec, algorithm: str, dtype: str = "float32", *,
                   solution: str = "auto",
                   precision: Optional[str] = None,
                   interpret: Optional[bool] = None,
                   directions: Sequence[str] = DIRECTIONS,
                   probe: bool = True, seed: int = 0) -> NumCheck:
    """Full numeric-contract check of one backend x dtype cell.

    Traces ``conv2d`` on ``spec`` (fwd and ``value_and_grad`` of the
    quadratic probe loss — tracing only, no compile), runs the static
    detectors over each direction's numeric signature, the per-jaxpr
    narrow-widen taint pass, the precision-flow pass when a precision
    is declared, and — with ``probe=True`` — the measured error-budget
    probe (this one jit-compiles and executes, so the plan hook turns
    it off)."""
    contract = contract_for(algorithm)
    record: Dict = {
        "algorithm": algorithm,
        "dtype": dtype,
        "contract": None if contract is None else contract.to_dict(),
        "directions": {},
        "precision_flow": None,
        "probe": None,
        "verdict": "pass",
        "skipped_reason": None,
        "violations": [],
    }

    def skipped(reason: str) -> NumCheck:
        record["verdict"] = "skipped"
        record["skipped_reason"] = reason
        return NumCheck(algorithm, dtype, [], record, skipped=reason)

    if contract is None:
        return skipped(f"no numeric contract declared for {algorithm!r} "
                       f"(repro.core.numerics.CONTRACTS — every backend "
                       f"must declare one before entering the plan "
                       f"candidate set)")
    if dtype not in CONTRACT_DTYPES:
        return skipped(f"no contract dtype {dtype!r} (contract dtypes: "
                       f"{CONTRACT_DTYPES})")
    if algorithm == "winograd" and \
            (spec.k_h, spec.k_w, spec.s_h, spec.s_w) != (3, 3, 1, 1):
        return skipped("winograd F(2x2,3x3) requires a 3x3 kernel and "
                       "stride 1")
    if algorithm in ("mec_lowered", "mec_fused", "mec_fused2"):
        from repro.analysis.pallas_check import check_geometry
        geo = check_geometry(spec, algorithm, None, dtype)
        if not geo.ok:
            return skipped(f"pallas geometry rejected: {geo.render()}")

    import jax
    import jax.numpy as jnp
    from repro.core.conv_api import conv2d
    prec = None if precision is None else \
        getattr(jax.lax.Precision, precision)
    stride = (spec.s_h, spec.s_w)

    def fwd(xv, kv):
        return conv2d(xv, kv, stride=stride, algorithm=algorithm,
                      solution=solution, interpret=interpret,
                      precision=prec, partition="none")

    def loss(xv, kv):
        o = fwd(xv, kv)
        return jnp.sum(o * o)

    fns = {"fwd": fwd, "grad": jax.value_and_grad(loss, argnums=(0, 1))}
    x_s = jax.ShapeDtypeStruct((spec.i_n, spec.i_h, spec.i_w, spec.i_c),
                               dtype)
    k_s = jax.ShapeDtypeStruct((spec.k_h, spec.k_w, spec.i_c, spec.k_c),
                               dtype)
    violations: List[ContractViolation] = []
    jaxprs = []
    for direction in directions:
        with _quiet_trace():
            closed = jax.make_jaxpr(fns[direction])(x_s, k_s)
        jaxprs.append(closed)
        sig = extract_signature(closed)
        violations += signature_findings(sig, contract, direction, dtype)
        violations += narrow_widen_findings(closed, direction)
        record["directions"][direction] = {
            "dots": len(sig["dots"]),
            "pallas_dots": sum(1 for d in sig["dots"] if d["pallas"]),
            "casts": len(sig["casts"]),
            "narrows_to_input": sum(
                1 for c in sig["casts"]
                if c["kind"] == "narrow" and c["dst"] == dtype),
        }
    if precision not in (None, "DEFAULT"):
        tally, pviol = precision_flow_findings(jaxprs, [], precision)
        violations += pviol
        record["precision_flow"] = tally
    if probe:
        errs = error_probe(spec, algorithm, dtype, solution=solution,
                           precision=precision, interpret=interpret,
                           seed=seed)
        tol_fwd = contract.tolerance(dtype, "fwd")
        tol_grad = contract.tolerance(dtype, "grad")
        record["probe"] = dict(errs, budget_fwd=tol_fwd,
                               budget_grad=tol_grad)
        for label, err, tol in (("fwd", errs["fwd_err"], tol_fwd),
                                ("grad(d_input)", errs["din_err"], tol_grad),
                                ("grad(d_kernel)", errs["dk_err"],
                                 tol_grad)):
            if tol is not None and err > tol:
                direction = "fwd" if label == "fwd" else "grad"
                violations.append(ContractViolation(
                    "error-budget", direction,
                    f"{label} error {err:.3e} vs the f64 reference "
                    f"exceeds the contract budget {tol:.0e} for "
                    f"{algorithm}/{dtype} (seed {errs['seed']})"))
    record["violations"] = [v.render() for v in violations]
    record["verdict"] = "pass" if not violations else "fail"
    return NumCheck(algorithm, dtype, violations, record)


# ---------------------------------------------------------------------------
# bench + plan wiring (duck-typed; repro.plan imports us, never the
# reverse)
# ---------------------------------------------------------------------------

_CELL_CACHE: Dict[Tuple, Dict] = {}
_CELL_CACHE_MAX = 256


def cell_numcheck(spec, algorithm: str, dtype: str, *,
                  solution: str = "auto",
                  interpret: Optional[bool] = None) -> Dict:
    """Reduced, memoized static verdict for one bench cell (no probe —
    the bench harness must not pay an extra execution per cell).  The
    reduced field is version-robust: verdict + rendered violations; the
    full evidence lives in BENCH_numcheck.json."""
    key = (spec, algorithm, solution, dtype)
    hit = _CELL_CACHE.get(key)
    if hit is not None:
        return dict(hit)
    chk = check_numerics(spec, algorithm, dtype, solution=solution,
                         interpret=interpret, probe=False)
    reduced = {"verdict": chk.record["verdict"],
               "skipped_reason": chk.record["skipped_reason"],
               "violations": chk.record["violations"]}
    if len(_CELL_CACHE) >= _CELL_CACHE_MAX:
        _CELL_CACHE.clear()
    _CELL_CACHE[key] = reduced
    return dict(reduced)


# plan_conv2d calls the hook once per contract identity; layers
# resolving the same plan per construction must not re-pay two traces
# each time.
_HOOK_CACHE: Dict[Tuple, Tuple[bool, str]] = {}
_HOOK_CACHE_MAX = 256


def assert_plan_numerics(plan) -> None:
    """The ``plan_conv2d`` hook: raise :class:`NumCheckError` when the
    resolved backend x dtype breaks its static numeric contract.
    Static-only (tracing, no compile, no probe) so planning stays
    cheap; skipped checks (unregistered backend or dtype) pass silently
    — the CLI suite is where skips are visible.  Memoized by contract
    identity (spec, dtype, algorithm, solution, precision)."""
    algorithm = getattr(plan, "algorithm", None)
    if algorithm in (None, "auto"):
        return
    dtype = str(getattr(plan, "dtype", "float32"))
    solution = getattr(plan, "solution", "auto")
    precision = getattr(plan, "precision", None)
    key = (plan.spec, dtype, algorithm, solution, precision)
    hit = _HOOK_CACHE.get(key)
    if hit is not None:
        ok, rendered = hit
        if not ok:
            raise NumCheckError(rendered)
        return
    result = check_numerics(plan.spec, algorithm, dtype, solution=solution,
                            precision=precision, probe=False)
    if len(_HOOK_CACHE) >= _HOOK_CACHE_MAX:
        _HOOK_CACHE.clear()
    _HOOK_CACHE[key] = (result.ok, result.render())
    if not result.ok:
        raise NumCheckError(result.render())
