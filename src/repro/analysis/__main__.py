"""CLI: ``python -m repro.analysis --suite
memaudit|pallas|lint|shardcheck|numcheck|all``.

Exit status is non-zero on any violation — this is what the CI
``static-analysis`` job runs on every push.  ``--update-lint-baseline``
regenerates the grandfathered-findings file (use only to *shrink* it
after fixing a finding, or to adopt a deliberate new suppression the
baseline should own).

The ``shardcheck`` suite forces a host platform with
:data:`SHARDCHECK_FORCED_DEVICES` devices (the env must be set before
jax initializes, so ``main`` does it up front) and writes the full
collective-contract evidence to ``BENCH_shardcheck.json``.

The ``numcheck`` suite (DESIGN.md §8.5) sweeps every conv backend x
{f32, bf16, f16}: static dtype-flow signature checks on fwd + grad plus
the measured f64 error-budget probe, written to ``BENCH_numcheck.json``
(CI gates the deterministic fields against the committed baseline).
"""
from __future__ import annotations

import argparse
import json
import math
import os
import pathlib
import sys

SUITES = ("memaudit", "pallas", "lint", "shardcheck", "numcheck", "all")

# Enough forced host devices for every committed dist-baseline mesh
# except the 256-way pod cells (those record an explicit skip — a CLI
# that forced 256 devices would spend CI minutes compiling what the
# slow-dryrun workflow already covers).
SHARDCHECK_FORCED_DEVICES = 8

DEFAULT_DIST = "benchmarks/baselines/dist.json"


def _run_memaudit(args) -> int:
    from repro.analysis.memaudit import write_audit
    out, failures = write_audit(
        plans_path=args.plans, out_path=args.out,
        calibration_store=True if args.record_calibration else None)
    print(f"memaudit: report written to {out}")
    if args.record_calibration:
        print("memaudit: gated ratios recorded to the calibration store "
              "(repro.plan.calibrate)")
    if failures:
        print(f"memaudit: {len(failures)} gate failure(s):")
        for f in failures:
            print(f"  {f}")
        return 1
    print("memaudit: all gated cells within tolerance")
    return 0


def _run_pallas(args) -> int:
    """Check every baseline plan as-committed, then every Pallas variant
    of each baseline geometry with the planner-derived w_blk — the
    committed plans are mostly reference-path, so the variants are what
    actually exercises the kernel mirror."""
    from repro.analysis.memaudit import DEFAULT_PLANS, load_plans
    from repro.analysis.pallas_check import (PALLAS_ALGORITHMS,
                                             check_geometry, check_plan)
    root = pathlib.Path(__file__).resolve().parents[3]
    plans = load_plans(args.plans or root / DEFAULT_PLANS)
    bad = 0
    pallas_cells = 0
    for name, plan in plans.items():
        result = check_plan(plan)
        if not result.ok:
            bad += 1
            print(f"pallas: {name} (as committed): {result.render()}")
        for alg in PALLAS_ALGORITHMS:
            variant = check_geometry(plan.spec, alg, None, plan.dtype)
            pallas_cells += 1
            if not variant.ok:
                bad += 1
                print(f"pallas: {name} as {alg}: {variant.render()}")
    # Tuned non-default w_blk coverage: every stage-2 grid candidate the
    # measured autotuner actually trialed (BENCH_autotune.json) must have
    # been geometry-admissible — incl. the committed w520 cell, whose
    # tuned w_blk=520 exceeds pick_w_blk's 512 default cap.
    trial_cells = 0
    autotune = root / "BENCH_autotune.json"
    if autotune.exists():
        from repro.core.convspec import ConvSpec
        doc = json.loads(autotune.read_text())
        for r in doc.get("results", []):
            tuning = r.get("tuning")
            if not tuning or \
                    tuning.get("algorithm") not in PALLAS_ALGORITHMS:
                continue
            spec = ConvSpec(**r["run_spec"])
            for label, t in tuning["trials"].items():
                res = check_geometry(spec, tuning["algorithm"],
                                     t.get("w_blk"), r["dtype"])
                trial_cells += 1
                if not res.ok:
                    bad += 1
                    print(f"pallas: {r['scenario']} trialed w_blk={label}: "
                          f"{res.render()}")
    if bad:
        print(f"pallas: {bad} rejected geometry(ies)")
        return 1
    print(f"pallas: {len(plans)} plan(s) + {pallas_cells} Pallas "
          f"variant geometries + {trial_cells} autotune trial "
          f"geometries accepted")
    return 0


def _run_shardcheck(args) -> int:
    """Contract-check every partitioned cell of the committed baselines.

    Cells come from two sources: the dist baseline (every partitioned
    record, deduplicated by executed geometry) and any partitioned plans
    in the plans baseline (checked under a minimal 2-way-per-axis forced
    mesh — a plan records mesh *axes*, not sizes).  Writes the full
    evidence report and fails on any ``fail`` verdict; skips (e.g. the
    256-way pod cells) are recorded, never silently dropped.
    """
    from repro.analysis.memaudit import DEFAULT_PLANS, load_plans
    from repro.analysis.shardcheck import check_sharding
    from repro.bench.report import make_report, write_report
    from repro.bench.scenarios import ALGORITHM_VARIANTS
    from repro.core.convspec import ConvSpec
    root = pathlib.Path(__file__).resolve().parents[3]
    dist_path = pathlib.Path(args.dist or root / DEFAULT_DIST)
    results = []
    n_fail = n_skip = 0

    def one(scenario, variant, spec, partition, sizes, dtype, source,
            *, algorithm, solution="auto", precision=None):
        # `variant` is the bench cell key (e.g. "mecB"); `algorithm` is
        # the resolved executor algorithm it maps to (e.g. "mec").
        nonlocal n_fail, n_skip
        chk = check_sharding(spec, partition, sizes, dtype=dtype,
                             algorithm=algorithm, solution=solution,
                             precision=precision)
        rec = dict(chk.record)
        rec.update({
            "scenario": scenario,
            "algorithm": variant,
            "dtype": dtype,
            "spec": {f: getattr(spec, f) for f in
                     ("i_n", "i_h", "i_w", "i_c", "k_h", "k_w", "k_c",
                      "s_h", "s_w")},
            "source": source,
            "n_dev": int(math.prod(sizes)),
        })
        # solution/precision ride inside `directions`-level evidence
        # already; the report schema keys the canonical fields only.
        rec.pop("solution", None)
        results.append(rec)
        if chk.record["verdict"] == "fail":
            n_fail += 1
            print(f"shardcheck: FAIL {scenario}/{variant}:")
            for v in chk.record["violations"]:
                print(f"  {v}")
        elif chk.record["verdict"] == "skipped":
            n_skip += 1
            print(f"shardcheck: skip {scenario}/{variant}: "
                  f"{chk.record['skipped_reason']}")

    if dist_path.exists():
        dist = json.loads(dist_path.read_text())
        for r in dist.get("results", []):
            if "partition" not in r:
                continue
            spec = ConvSpec(**r["run_spec"])
            kw = ALGORITHM_VARIANTS.get(r["algorithm"],
                                        {"algorithm": r["algorithm"]})
            one(r["scenario"], r["algorithm"], spec, r["partition"],
                tuple(r.get("n_dev_axes") or [r["n_dev"]]), r["dtype"],
                "dist-baseline",
                algorithm=kw.get("algorithm", r["algorithm"]),
                solution=kw.get("solution", "auto"))
    else:
        print(f"shardcheck: no dist baseline at {dist_path} "
              f"(checking plans only)")
    plans = load_plans(args.plans or root / DEFAULT_PLANS)
    for name, plan in plans.items():
        if plan.partition is None:
            continue
        one(name, plan.algorithm, plan.spec, plan.partition,
            (2,) * len(plan.partition), plan.dtype, "plans-baseline",
            algorithm=plan.algorithm, solution=plan.solution,
            precision=plan.precision)
    out = pathlib.Path(args.shardcheck_out or root / "BENCH_shardcheck.json")
    if results:
        doc = make_report("shardcheck", results,
                          harness={"forced_devices":
                                   SHARDCHECK_FORCED_DEVICES,
                                   "dist_baseline": str(dist_path),
                                   "directions": ["fwd", "grad"]})
        write_report(doc, out)
        print(f"shardcheck: report written to {out}")
    verified = len(results) - n_fail - n_skip
    if n_fail:
        print(f"shardcheck: {n_fail} cell(s) broke the collective/"
              f"precision contract")
        return 1
    print(f"shardcheck: {verified} cell(s) verified, {n_skip} skipped, "
          f"0 contract violations")
    return 0


def _run_numcheck(args) -> int:
    """Numeric-contract check of every backend x contract dtype
    (DESIGN.md §8.5): static signature detectors on fwd + grad, then the
    measured error-budget probe vs the f64 reference.  Skips (winograd
    off-geometry, Pallas-rejected cells, unregistered backends) are
    recorded, never silently dropped.  Writes the full evidence to
    ``BENCH_numcheck.json``."""
    from repro.analysis.numcheck import (NUMCHECK_ALGORITHMS,
                                         NUMCHECK_DTYPES, check_numerics,
                                         probe_spec)
    from repro.bench.report import make_report, write_report
    from repro.bench.scenarios import ALGORITHM_VARIANTS
    root = pathlib.Path(__file__).resolve().parents[3]
    spec = probe_spec()
    results = []
    n_fail = n_skip = 0
    for variant in NUMCHECK_ALGORITHMS:
        kw = ALGORITHM_VARIANTS.get(variant, {"algorithm": variant})
        for dtype in NUMCHECK_DTYPES:
            chk = check_numerics(spec, kw.get("algorithm", variant), dtype,
                                 solution=kw.get("solution", "auto"),
                                 interpret=True)
            rec = dict(chk.record)
            rec.update({
                "scenario": f"numprobe_{dtype}",
                "algorithm": variant,
                "spec": {f: getattr(spec, f) for f in
                         ("i_n", "i_h", "i_w", "i_c", "k_h", "k_w", "k_c",
                          "s_h", "s_w")},
                "source": "probe-spec",
            })
            results.append(rec)
            if chk.record["verdict"] == "fail":
                n_fail += 1
                print(f"numcheck: FAIL {variant}/{dtype}:")
                for v in chk.record["violations"]:
                    print(f"  {v}")
            elif chk.record["verdict"] == "skipped":
                n_skip += 1
                print(f"numcheck: skip {variant}/{dtype}: "
                      f"{chk.record['skipped_reason']}")
    out = pathlib.Path(args.numcheck_out or root / "BENCH_numcheck.json")
    doc = make_report("numcheck", results,
                      harness={"directions": ["fwd", "grad"],
                               "probe_seed": 0,
                               "reference": "numpy-f64"})
    write_report(doc, out)
    print(f"numcheck: report written to {out}")
    verified = len(results) - n_fail - n_skip
    if n_fail:
        print(f"numcheck: {n_fail} cell(s) broke their numeric contract")
        return 1
    print(f"numcheck: {verified} cell(s) verified, {n_skip} skipped, "
          f"0 contract violations")
    return 0


def _run_lint(args) -> int:
    from repro.analysis.lint import (apply_baseline, lint_tree,
                                     load_baseline, repo_root,
                                     write_baseline)
    root = repo_root()
    findings = lint_tree(root)
    baseline_path = pathlib.Path(
        args.lint_baseline or root / "benchmarks/baselines/lint_baseline.json")
    if args.update_lint_baseline:
        write_baseline(findings, baseline_path)
        print(f"lint: baseline rewritten with {len(findings)} finding(s) "
              f"-> {baseline_path}")
        return 0
    baseline = load_baseline(baseline_path) if baseline_path.exists() else []
    split = apply_baseline(findings, baseline)
    for f in split["new"]:
        print(f"lint: NEW {f.render()}")
    if split["fixed"]:
        print(f"lint: {len(split['fixed'])} baseline entry(ies) no longer "
              f"fire — shrink the baseline with --update-lint-baseline:")
        for key in split["fixed"]:
            print(f"  fixed: {key}")
    if split["new"]:
        print(f"lint: {len(split['new'])} new finding(s) "
              f"({len(split['grandfathered'])} grandfathered)")
        return 1
    print(f"lint: clean ({len(split['grandfathered'])} grandfathered)")
    return 0


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static analysis suites (DESIGN.md §8)")
    parser.add_argument("--suite", choices=SUITES, default="all")
    parser.add_argument("--plans", default=None,
                        help="plans baseline JSON (default: "
                             "benchmarks/baselines/plans.json)")
    parser.add_argument("--out", default=None,
                        help="memaudit report path "
                             "(default: BENCH_memaudit.json)")
    parser.add_argument("--record-calibration", action="store_true",
                        help="record gated measured/predicted ratios "
                             "into the fitted-costmodel store "
                             "(repro.plan.calibrate, DESIGN.md §10)")
    parser.add_argument("--lint-baseline", default=None,
                        help="lint baseline JSON (default: "
                             "benchmarks/baselines/lint_baseline.json)")
    parser.add_argument("--update-lint-baseline", action="store_true",
                        help="rewrite the lint baseline from the current "
                             "tree (shrink-only workflow)")
    parser.add_argument("--dist", default=None,
                        help="dist baseline JSON feeding the shardcheck "
                             "suite (default: benchmarks/baselines/"
                             "dist.json)")
    parser.add_argument("--shardcheck-out", default=None,
                        help="shardcheck report path "
                             "(default: BENCH_shardcheck.json)")
    parser.add_argument("--numcheck-out", default=None,
                        help="numcheck report path "
                             "(default: BENCH_numcheck.json)")
    args = parser.parse_args(argv)
    if args.suite in ("shardcheck", "all"):
        # Must happen before anything imports-and-initializes jax (the
        # other suites do), or the process is stuck with one device and
        # every multi-way cell records a skip instead of a verdict.
        # The raw read is sanctioned: XLA_FLAGS is jax bootstrap
        # surface, not repo configuration.
        flags = os.environ.get("XLA_FLAGS", "")  # lint-ignore: raw-environ-read-outside-compat
        if "xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                flags + f" --xla_force_host_platform_device_count="
                        f"{SHARDCHECK_FORCED_DEVICES}").strip()
    rc = 0
    if args.suite in ("lint", "all"):
        rc |= _run_lint(args)
    if args.suite in ("pallas", "all"):
        rc |= _run_pallas(args)
    if args.suite in ("memaudit", "all"):
        rc |= _run_memaudit(args)
    if args.suite in ("numcheck", "all"):
        rc |= _run_numcheck(args)
    if args.suite in ("shardcheck", "all"):
        rc |= _run_shardcheck(args)
    return rc


if __name__ == "__main__":
    sys.exit(main())
