"""CLI: ``python -m repro.analysis --suite memaudit|pallas|lint|all``.

Exit status is non-zero on any violation — this is what the CI
``static-analysis`` job runs on every push.  ``--update-lint-baseline``
regenerates the grandfathered-findings file (use only to *shrink* it
after fixing a finding, or to adopt a deliberate new suppression the
baseline should own).
"""
from __future__ import annotations

import argparse
import pathlib
import sys

SUITES = ("memaudit", "pallas", "lint", "all")


def _run_memaudit(args) -> int:
    from repro.analysis.memaudit import write_audit
    out, failures = write_audit(
        plans_path=args.plans, out_path=args.out,
        calibration_store=True if args.record_calibration else None)
    print(f"memaudit: report written to {out}")
    if args.record_calibration:
        print("memaudit: gated ratios recorded to the calibration store "
              "(repro.plan.calibrate)")
    if failures:
        print(f"memaudit: {len(failures)} gate failure(s):")
        for f in failures:
            print(f"  {f}")
        return 1
    print("memaudit: all gated cells within tolerance")
    return 0


def _run_pallas(args) -> int:
    """Check every baseline plan as-committed, then every Pallas variant
    of each baseline geometry with the planner-derived w_blk — the
    committed plans are mostly reference-path, so the variants are what
    actually exercises the kernel mirror."""
    from repro.analysis.memaudit import DEFAULT_PLANS, load_plans
    from repro.analysis.pallas_check import (PALLAS_ALGORITHMS,
                                             check_geometry, check_plan)
    root = pathlib.Path(__file__).resolve().parents[3]
    plans = load_plans(args.plans or root / DEFAULT_PLANS)
    bad = 0
    pallas_cells = 0
    for name, plan in plans.items():
        result = check_plan(plan)
        if not result.ok:
            bad += 1
            print(f"pallas: {name} (as committed): {result.render()}")
        for alg in PALLAS_ALGORITHMS:
            variant = check_geometry(plan.spec, alg, None, plan.dtype)
            pallas_cells += 1
            if not variant.ok:
                bad += 1
                print(f"pallas: {name} as {alg}: {variant.render()}")
    if bad:
        print(f"pallas: {bad} rejected geometry(ies)")
        return 1
    print(f"pallas: {len(plans)} plan(s) + {pallas_cells} Pallas "
          f"variant geometries accepted")
    return 0


def _run_lint(args) -> int:
    from repro.analysis.lint import (apply_baseline, lint_tree,
                                     load_baseline, repo_root,
                                     write_baseline)
    root = repo_root()
    findings = lint_tree(root)
    baseline_path = pathlib.Path(
        args.lint_baseline or root / "benchmarks/baselines/lint_baseline.json")
    if args.update_lint_baseline:
        write_baseline(findings, baseline_path)
        print(f"lint: baseline rewritten with {len(findings)} finding(s) "
              f"-> {baseline_path}")
        return 0
    baseline = load_baseline(baseline_path) if baseline_path.exists() else []
    split = apply_baseline(findings, baseline)
    for f in split["new"]:
        print(f"lint: NEW {f.render()}")
    if split["fixed"]:
        print(f"lint: {len(split['fixed'])} baseline entry(ies) no longer "
              f"fire — shrink the baseline with --update-lint-baseline:")
        for key in split["fixed"]:
            print(f"  fixed: {key}")
    if split["new"]:
        print(f"lint: {len(split['new'])} new finding(s) "
              f"({len(split['grandfathered'])} grandfathered)")
        return 1
    print(f"lint: clean ({len(split['grandfathered'])} grandfathered)")
    return 0


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static analysis suites (DESIGN.md §8)")
    parser.add_argument("--suite", choices=SUITES, default="all")
    parser.add_argument("--plans", default=None,
                        help="plans baseline JSON (default: "
                             "benchmarks/baselines/plans.json)")
    parser.add_argument("--out", default=None,
                        help="memaudit report path "
                             "(default: BENCH_memaudit.json)")
    parser.add_argument("--record-calibration", action="store_true",
                        help="record gated measured/predicted ratios "
                             "into the fitted-costmodel store "
                             "(repro.plan.calibrate, DESIGN.md §10)")
    parser.add_argument("--lint-baseline", default=None,
                        help="lint baseline JSON (default: "
                             "benchmarks/baselines/lint_baseline.json)")
    parser.add_argument("--update-lint-baseline", action="store_true",
                        help="rewrite the lint baseline from the current "
                             "tree (shrink-only workflow)")
    args = parser.parse_args(argv)
    rc = 0
    if args.suite in ("lint", "all"):
        rc |= _run_lint(args)
    if args.suite in ("pallas", "all"):
        rc |= _run_pallas(args)
    if args.suite in ("memaudit", "all"):
        rc |= _run_memaudit(args)
    return rc


if __name__ == "__main__":
    sys.exit(main())
