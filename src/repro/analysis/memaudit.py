"""HLO memory auditor: XLA's bytes vs. the paper's Eqs. 2-4 (DESIGN.md §8).

For every plan in the committed decision baseline
(``benchmarks/baselines/plans.json``), AOT-lower the convolution through
the public ``conv2d(plan=...)`` executor against
``jax.ShapeDtypeStruct`` operands (no real arrays — cv4 alone would be
100+ MB), pull the compiled executable's peak temporary-buffer bytes via
the version-shimmed :func:`repro.core.compat.memory_analysis`, and gate
the measurement against the analytic model
(``repro.core.memory.algorithm_overhead`` x dtype size) within a
per-algorithm tolerance band.

Tolerance policy (bands measured on the jax 0.4.37 CPU backend across
all 15 baseline cells plus winograd/fft probes; see DESIGN.md §8):

* ``direct``   predicts zero overhead — gated on an absolute slack
  (XLA may keep a small reshape/copy temp).
* ``im2col``   XLA materializes exactly the Toeplitz patch matrix;
  measured/predicted was 1.000 on every cell, band [0.98, 1.15].
* ``mec``      XLA holds L plus an f32 accumulator / fusion temps;
  measured 1.03-1.51, band [0.95, 1.9].
* ``winograd`` / ``fft``  looser ([0.95, 2.0] / [0.95, 2.1]): XLA keeps
  transform temps alive across the element-wise product.
* Pallas algorithms (``mec_lowered``/``mec_fused*``) are **recorded but
  not gated** off-TPU: interpret-mode compiles materialize the lowering
  as XLA temps, so CPU numbers say nothing about the TPU VMEM story —
  that is ``repro.analysis.pallas_check``'s job.

A band failure means either the analytic model or the implementation
drifted — exactly the regression Table 2's memory claims rest on.  Each
mec cell also carries a crosscheck: measured mec temp bytes must stay
*below* measured im2col temp bytes whenever Eq. 4 predicts a positive
saving — the paper's core claim, machine-checked end to end.

Output is a schema-validated ``BENCH_memaudit.json`` via the
``repro.bench.report`` machinery (suite ``memaudit``).
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import memory
from repro.core.compat import memory_analysis
from repro.core.convspec import ConvSpec

# Per-algorithm measured-vs-predicted gates, keyed by the *base* model
# name (repro.core.memory._DISPATCH_BASE resolves mecA/mec_lowered/...).
# ratio = measured_temp_bytes / predicted_overhead_bytes.
TOLERANCES: Dict[str, Dict[str, float]] = {
    "direct": {"abs_slack": 4096},
    "im2col": {"lo": 0.98, "hi": 1.15},
    "mec": {"lo": 0.95, "hi": 1.9},
    "winograd": {"lo": 0.95, "hi": 2.0},
    "fft": {"lo": 0.95, "hi": 2.1},
}

DEFAULT_PLANS = "benchmarks/baselines/plans.json"
DEFAULT_REPORT = "BENCH_memaudit.json"


def _base_algorithm(algorithm: str) -> str:
    return memory._DISPATCH_BASE.get(algorithm, algorithm)


def pallas_gated() -> bool:
    """Pallas cells are tolerance-gated only where the kernels actually
    run as kernels (TPU); interpret-mode temps are recorded only."""
    import jax
    return jax.default_backend() == "tpu"


def lower_plan(plan):
    """AOT-compile ``conv2d(plan=...)`` on ShapeDtypeStruct operands."""
    import jax
    from repro.core.conv_api import conv2d
    s = plan.spec
    inp = jax.ShapeDtypeStruct((s.i_n, s.i_h, s.i_w, s.i_c), plan.dtype)
    ker = jax.ShapeDtypeStruct((s.k_h, s.k_w, s.i_c, s.k_c), plan.dtype)
    fn = jax.jit(lambda i, k: conv2d(i, k, stride=(s.s_h, s.s_w),
                                     plan=plan))
    return fn.lower(inp, ker).compile()


def audit_plan(scenario: str, plan) -> Tuple[Dict, List[str]]:
    """One audit record (bench-report shape) + its gate failures."""
    import numpy as np
    s = plan.spec
    base = _base_algorithm(plan.algorithm)
    dtype_bytes = int(np.dtype(plan.dtype).itemsize)
    predicted_elems = memory.algorithm_overhead(s, plan.algorithm)
    predicted_bytes = predicted_elems * dtype_bytes

    compiled = lower_plan(plan)
    stats = memory_analysis(compiled)
    measured = None if stats is None else stats.get("temp_bytes")
    source = None if stats is None else stats.get("source")

    is_pallas = plan.algorithm in ("mec_lowered", "mec_fused", "mec_fused2")
    policy = "recorded" if (is_pallas and not pallas_gated()) else "gated"
    tol = TOLERANCES[base]
    ratio = None
    slack = None
    failures: List[str] = []
    if measured is None:
        verdict = "recorded"        # no memory stats on this backend
        policy = "recorded"
    elif policy == "recorded":
        verdict = "recorded"
        if predicted_bytes:
            ratio = measured / predicted_bytes
        slack = measured - predicted_bytes
    elif "abs_slack" in tol:
        slack = measured - predicted_bytes
        verdict = "pass" if slack <= tol["abs_slack"] else "fail"
    else:
        slack = measured - predicted_bytes
        if predicted_bytes <= 0:
            verdict = "fail"
            failures.append(
                f"{scenario}/{plan.algorithm}: model predicts no overhead "
                f"but algorithm is ratio-gated")
        else:
            ratio = measured / predicted_bytes
            verdict = "pass" if tol["lo"] <= ratio <= tol["hi"] else "fail"
    if verdict == "fail" and not failures:
        failures.append(
            f"{scenario}/{plan.algorithm}: measured temp {measured}B vs "
            f"predicted {predicted_bytes}B "
            f"(ratio={'n/a' if ratio is None else f'{ratio:.3f}'}, "
            f"slack={slack}B) outside {tol}")

    record = {
        "scenario": scenario,
        "algorithm": plan.algorithm,
        "dtype": plan.dtype,
        "spec": dataclasses.asdict(s),
        "predicted_overhead_elems": predicted_elems,
        "predicted_overhead_bytes": predicted_bytes,
        "measured_temp_bytes": measured,
        "measured_argument_bytes": None if stats is None
        else stats.get("argument_bytes"),
        "measured_output_bytes": None if stats is None
        else stats.get("output_bytes"),
        "ratio": ratio,
        "slack_bytes": slack,
        "tolerance": dict(tol),
        "policy": policy,
        "source": source,
        "verdict": verdict,
    }
    return record, failures


def _companion_plan(plan, algorithm: str):
    """Same cell, different algorithm — for the mec-vs-im2col crosscheck."""
    return dataclasses.replace(plan, algorithm=algorithm, solution="auto",
                               w_blk=None)


def load_plans(path) -> Dict[str, object]:
    from repro.plan.convplan import ConvPlan
    doc = json.loads(pathlib.Path(path).read_text())
    return {name: ConvPlan.from_dict(d)
            for name, d in sorted(doc["plans"].items())}


def record_calibration(records: Sequence[Dict], store=None) -> int:
    """Feed the memory-side fit (DESIGN.md §10): every tolerance-gated
    measured/predicted ratio becomes a memory sample in the calibration
    store.  ``recorded``-policy cells (Pallas off-TPU, absent memory
    stats) never train the fit — their temps are XLA interpret-mode
    artifacts, not the algorithm's memory story.  Returns the number of
    samples added; flushes (best-effort) when it created the store.
    """
    from repro.plan.calibrate import CalibrationStore
    own = store is None
    store = store or CalibrationStore()
    n = 0
    for rec in records:
        if rec.get("policy") != "gated" or rec.get("ratio") is None:
            continue
        store.add_memory(ConvSpec(**rec["spec"]), rec["dtype"],
                         _base_algorithm(rec["algorithm"]),
                         float(rec["ratio"]))
        n += 1
    if own and n:
        store.flush()
    return n


def run_audit(plans_path=None,
              plans: Optional[Dict[str, object]] = None,
              calibration_store=None) -> Tuple[Dict, List[str]]:
    """Audit every baseline plan (+ an im2col companion per mec cell).

    Returns ``(report_doc, failures)`` — the doc validates against the
    bench-report ``memaudit`` suite schema; failures is the flat list of
    gate violations (empty == audit passed).  Pass a
    ``repro.plan.calibrate.CalibrationStore`` (or ``True`` for the
    ambient one) to additionally record the gated ratios as memory
    samples for the fitted costmodel — opt-in, so a plain audit never
    mutates planner state.
    """
    from repro.bench.report import make_report
    if plans is None:
        root = pathlib.Path(__file__).resolve().parents[3]
        plans_path = pathlib.Path(plans_path or root / DEFAULT_PLANS)
        plans = load_plans(plans_path)
    results: List[Dict] = []
    crosscheck: List[Dict] = []
    failures: List[str] = []
    measured_by_cell: Dict[Tuple[str, str], Optional[int]] = {}
    for scenario, plan in plans.items():
        rec, fails = audit_plan(scenario, plan)
        results.append(rec)
        failures.extend(fails)
        measured_by_cell[(scenario, _base_algorithm(plan.algorithm))] = \
            rec["measured_temp_bytes"]
        if _base_algorithm(plan.algorithm) == "mec":
            comp, comp_fails = audit_plan(
                scenario, _companion_plan(plan, "im2col"))
            results.append(comp)
            failures.extend(comp_fails)
            saving = memory.mec_saving(plan.spec)
            mec_b = rec["measured_temp_bytes"]
            im2col_b = comp["measured_temp_bytes"]
            ok = (mec_b is None or im2col_b is None or saving <= 0
                  or mec_b < im2col_b)
            crosscheck.append({
                "scenario": scenario,
                "mec_temp_bytes": mec_b,
                "im2col_temp_bytes": im2col_b,
                "mec_saving_elems": saving,
                "ok": "yes" if ok else "no",
            })
            if not ok:
                failures.append(
                    f"{scenario}: Eq. 4 predicts a {saving}-element "
                    f"saving but measured mec temp {mec_b}B >= "
                    f"im2col temp {im2col_b}B")
    if calibration_store is not None and calibration_store is not False:
        record_calibration(
            results, None if calibration_store is True else calibration_store)
    doc = make_report(
        "memaudit", results,
        harness={
            "plans_path": str(plans_path) if plans_path else "<in-memory>",
            "tolerances": TOLERANCES,
            "pallas_gated": "yes" if pallas_gated() else "no",
        },
        crosscheck=crosscheck)
    return doc, failures


def write_audit(plans_path=None, out_path=None,
                calibration_store=None) -> Tuple[pathlib.Path, List[str]]:
    from repro.bench.report import write_report
    root = pathlib.Path(__file__).resolve().parents[3]
    doc, failures = run_audit(plans_path, calibration_store=calibration_store)
    out = pathlib.Path(out_path or root / DEFAULT_REPORT)
    write_report(doc, out)
    return out, failures
