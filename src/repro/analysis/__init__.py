"""repro.analysis — static verification of the repo's memory claims
(DESIGN.md §8).  Three CI-gated suites:

* :mod:`repro.analysis.memaudit` — XLA peak-temp bytes vs. the paper's
  Eq. 2-4 analytic model, for every committed baseline plan.
* :mod:`repro.analysis.pallas_check` — symbolic grid/BlockSpec/VMEM
  checking of the Pallas kernel geometries, no compile needed.
* :mod:`repro.analysis.lint` — AST invariants for bug classes this repo
  has already shipped (dropped kwargs, stray env reads, shard_map
  imports bypassing the compat shim).

Run all three: ``python -m repro.analysis --suite all``.

Layering: analysis may import ``core``/``kernels``/``bench`` freely but
never ``repro.plan`` at module level — the planner calls *into*
``pallas_check`` (lazily), so plans are duck-typed here.
"""
from repro.analysis.lint import Finding, lint_file, lint_tree
from repro.analysis.memaudit import TOLERANCES, audit_plan, run_audit
from repro.analysis.pallas_check import (PallasCheckError, PlanCheck,
                                         assert_plan, check_geometry,
                                         check_plan)

__all__ = [
    "Finding", "lint_file", "lint_tree",
    "TOLERANCES", "audit_plan", "run_audit",
    "PallasCheckError", "PlanCheck", "assert_plan", "check_geometry",
    "check_plan",
]
