"""repro.analysis — static verification of the repo's memory claims
(DESIGN.md §8).  Four CI-gated suites:

* :mod:`repro.analysis.memaudit` — XLA peak-temp bytes vs. the paper's
  Eq. 2-4 analytic model, for every committed baseline plan.
* :mod:`repro.analysis.pallas_check` — symbolic grid/BlockSpec/VMEM
  checking of the Pallas kernel geometries, no compile needed.
* :mod:`repro.analysis.shardcheck` — the distributed-conv collective
  contract (halo permute / psum all-reduce bytes vs. the costmodel,
  zero accidental resharding) over every partitioned lowering.
* :mod:`repro.analysis.numcheck` — the numeric contract (DESIGN.md
  §8.5): dtype-flow signature extraction (accumulation widths, cast
  edges, in-kernel Pallas accumulators), the narrow-then-widen
  detector, the precision-flow pass (promoted from shardcheck), and
  the measured f64 error-budget probe, for every backend x dtype.
* :mod:`repro.analysis.lint` — AST invariants for bug classes this repo
  has already shipped (dropped kwargs, stray env reads, shard_map
  imports bypassing the compat shim, bare un-annotated GEMMs).

Run all five: ``python -m repro.analysis --suite all``.

Layering: analysis may import ``core``/``kernels``/``bench`` freely but
never ``repro.plan`` at module level — the planner calls *into*
``pallas_check``/``shardcheck`` (lazily), so plans are duck-typed here.

Exports resolve lazily (PEP 562): importing this package must not drag
in the submodules' jax dependency chain, because the ``shardcheck`` CLI
needs to force the host device count *after* ``import repro.analysis``
but *before* anything initializes a jax backend.
"""
import importlib

_EXPORTS = {
    "Finding": "repro.analysis.lint",
    "lint_file": "repro.analysis.lint",
    "lint_tree": "repro.analysis.lint",
    "TOLERANCES": "repro.analysis.memaudit",
    "audit_plan": "repro.analysis.memaudit",
    "run_audit": "repro.analysis.memaudit",
    "PallasCheckError": "repro.analysis.pallas_check",
    "PlanCheck": "repro.analysis.pallas_check",
    "assert_plan": "repro.analysis.pallas_check",
    "check_geometry": "repro.analysis.pallas_check",
    "check_plan": "repro.analysis.pallas_check",
    "ContractViolation": "repro.analysis.numcheck",
    "NumCheck": "repro.analysis.numcheck",
    "NumCheckError": "repro.analysis.numcheck",
    "assert_plan_numerics": "repro.analysis.numcheck",
    "cell_numcheck": "repro.analysis.numcheck",
    "check_numerics": "repro.analysis.numcheck",
    "error_probe": "repro.analysis.numcheck",
    "extract_signature": "repro.analysis.numcheck",
    "precision_flow_findings": "repro.analysis.numcheck",
    "ShardCheck": "repro.analysis.shardcheck",
    "ShardCheckError": "repro.analysis.shardcheck",
    "assert_plan_contract": "repro.analysis.shardcheck",
    "check_plan_contract": "repro.analysis.shardcheck",
    "check_sharding": "repro.analysis.shardcheck",
    "expected_collectives": "repro.analysis.shardcheck",
    "verify_collectives": "repro.analysis.shardcheck",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name):
    if name in _EXPORTS:
        return getattr(importlib.import_module(_EXPORTS[name]), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_EXPORTS))
