"""Shard contract & precision-flow static analysis (DESIGN.md §8).

Partitioned convolutions promise a *predictable* interconnect footprint:
the costmodel (``repro.launch.costmodel.conv_partition_costs``) states
exactly which bytes cross the mesh — the spatial halo rides a
``collective-permute``, the backward psums ride ``all-reduce``, and
nothing else moves.  This module turns that promise into a statically
checkable **collective contract**: it lowers a partitioned convolution
(forward, and ``value_and_grad`` of a quadratic probe loss) under a
forced host mesh with pinned in/out shardings, parses the partitioned
HLO with ``repro.launch.hlo_analysis.collective_bytes``, and verifies

* ``collective-permute`` bytes/device == ``halo_bytes_per_device`` plus
  the output-trim reshard (see :func:`trim_permute_bytes`) — x2 in the
  grad program (forward halo + transposed cotangent), exact;
* ``all-reduce`` bytes/device == the predicted psum operand bytes
  (``comm_bytes_bwd - halo``), within ``SCALAR_REDUCE_ALLOWANCE_BYTES``
  for the scalar partial-sum reduction the probe loss itself adds;
* **zero** ``all-gather`` / ``all-to-all`` / ``reduce-scatter`` — any
  of these means GSPMD reshard traffic the costmodel never priced
  (an accidental resharding, typically an unpinned sharding boundary).

Tolerances are *exact*, not relative: the only admitted slack is the
scalar probe-loss all-reduce, and — for sub-f32 dtypes on backends
whose XLA hoists the upcast above the collective (CPU does) — a
collective may move its bytes at f32 width instead of the declared
width.  Both admissible widths are exact; anything else fails.

A **precision-flow pass** rides the same lowering: it walks the jaxpr —
recursing into ``pallas_call`` kernels, ``custom_vjp`` branches and
``shard_map`` bodies — and asserts the plan's declared precision
annotates every ``dot_general``/``conv_general_dilated``, then scans
the optimized HLO for ``dot``/``convolution`` ops missing the matching
``operand_precision``.  This catches a silently-dropped ``precision=``
(the PR 4/5 bug class) statically, for every backend at once.

Registering a new backend: a backend whose partitioned execution moves
different collectives (e.g. an all-gather-based halo) overrides
:func:`expected_collectives` — the contract is *derived*, not
hard-coded per call site, so one function is the single source of
truth for dryrun, the bench ``dist`` suite, the planner hook and the
``--suite shardcheck`` CLI.

Layering: ``repro.analysis`` never imports ``repro.plan`` at module
level — plans are duck-typed (``spec``/``dtype``/``algorithm``/
``solution``/``precision``/``partition``/``partition_axes``).  jax is
imported lazily so contract *derivation* works without a live backend.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple, Union

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

DIRECTIONS = ("fwd", "grad")

# Tolerance model (DESIGN.md §8): every collective kind is gated EXACTLY
# except the grad-direction all-reduce, which may exceed the predicted
# psum operand bytes by this allowance — the probe loss (sum(out^2)) adds
# one scalar partial-sum reduction per mesh axis group, bytes the
# costmodel rightly never priced (they belong to the probe, not the
# convolution).
SCALAR_REDUCE_ALLOWANCE_BYTES = 64

# ContractViolation (and the whole precision-flow pass further down) was
# promoted to repro.analysis.numcheck in PR 10; shardcheck's collective
# rules reuse the same violation type so mixed reports render uniformly.
from repro.analysis.numcheck import _HLO_DOT_RE  # noqa: F401
from repro.analysis.numcheck import ContractViolation  # noqa: F401


class ShardCheckError(AssertionError):
    """A partitioned lowering broke its collective/precision contract."""


@dataclasses.dataclass
class ShardCheck:
    """Verdict of one partitioned-cell contract check.

    ``record`` is the JSON-able evidence (expected/observed bytes per
    direction + the precision-flow tally) that bench/dryrun/CLI reports
    embed; ``skipped`` carries the reason when the cell could not be
    lowered here (not enough forced devices, non-viable geometry,
    degenerate 1-way mesh) — a skip is not a pass and not a failure.
    """

    partition: str
    n_dev_axes: Tuple[int, ...]
    violations: List[ContractViolation]
    record: Dict
    skipped: Optional[str] = None

    @property
    def ok(self) -> bool:
        return not self.violations

    def render(self) -> str:
        head = (f"shardcheck {self.partition} x{list(self.n_dev_axes)}: "
                f"{self.record.get('verdict')}")
        lines = [head]
        if self.skipped:
            lines.append(f"  skipped: {self.skipped}")
        lines += [f"  {v.render()}" for v in self.violations]
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# the contract
# ---------------------------------------------------------------------------

def trim_reshard(spec, parts, sizes,
                 dtype_bytes: int) -> Tuple[Optional[str], float]:
    """Price the ``out[:, :o_h]`` trim reshard: ``(fwd_unmodeled_reason,
    optional_permute_bytes)``.

    A spatially partitioned ``sharded_conv2d`` emits ``r = h_loc/s_h``
    output rows per device and trims the global result to ``o_h``.
    When ``o_h`` splits evenly over the ``n_s`` spatial ways, GSPMD
    *may* rebalance by shifting ``f = (n_s*r - o_h)/n_s`` rows to the
    successor device — one extra collective-permute of
    ``i_n_loc * f * o_w * k_c_loc`` output elements, which the contract
    admits as *optional* traffic (whether the rebalance materializes,
    and in which direction's program, is GSPMD's choice; the halo bytes
    underneath stay exact either way).  Two lowerings cannot be priced
    as a uniform permute and return a non-None reason instead:

    * ``o_h % n_s != 0`` — GSPMD resolves the uneven output boundary of
      the *standalone forward* program with a gather+slice; the grad
      program never exposes that boundary (its outputs are the scalar
      probe loss and input-shaped gradients), so only ``fwd`` is
      unverifiable;
    * ``n_s > 2`` with ``f > 0`` — the shift spans multiple source
      devices; neither direction lowers to a single uniform permute.
    """
    if "spatial" not in parts:
        return None, 0.0
    n_s = sizes[parts.index("spatial")]
    if n_s <= 1:
        return None, 0.0
    r = (spec.i_h // n_s) // spec.s_h
    trimmed = n_s * r - spec.o_h
    if trimmed <= 0:
        return None, 0.0
    f = r - (-(-spec.o_h // n_s))  # per-device shift: r - ceil(o_h/n_s)
    if n_s > 2 and f > 0:
        return (f"{n_s}-way spatial trim shifts {f} row(s) per device "
                f"across multiple sources; the reshard lowering is not "
                f"a single uniform collective-permute"), math.nan
    slab = 0.0
    if f > 0:
        n_b = sizes[parts.index("batch")] if "batch" in parts else 1
        n_c = sizes[parts.index("channel")] if "channel" in parts else 1
        i_n_loc = max(1, -(-spec.i_n // n_b))
        k_c_loc = max(1, -(-spec.k_c // n_c))
        slab = float(i_n_loc * f * spec.o_w * k_c_loc * dtype_bytes)
    if spec.o_h % n_s:
        return (f"trimmed output (o_h={spec.o_h}) does not split evenly "
                f"over the {n_s}-way spatial axis; GSPMD lowers the "
                f"standalone-forward output boundary as gather+slice "
                f"(unpriced probe traffic) — the grad program verifies "
                f"both VJP directions instead"), slab
    return None, slab


def replica_combine_bytes(spec, parts, sizes, dtype_bytes: int) -> float:
    """Per-device bytes of the gradient-combine all-reduce GSPMD may add
    when the deployment mesh is *larger* than the partition (free axes
    replicate the cell ``replicated_ways``-fold — the production-mesh
    dry-run, not the exact-size host meshes).

    GSPMD is free to shard the backward computation over the unused
    axes and combine the partial gradients with one all-reduce.  A
    gradient whose VJP already carries a modeled psum merges into that
    op (same operand bytes, wider replica groups — no new traffic); the
    one gradient *without* a modeled psum pays its local shard bytes:
    the input gradient when the partition has no channel component
    (its cotangent arrives via the permute transpose), the kernel
    gradient for the pure-channel partition (computed locally per k_c
    shard).  At most one term is ever non-zero.
    """
    n = dict(zip(parts, sizes))
    if "channel" not in parts:
        x_loc = (-(-spec.i_n // n.get("batch", 1))) * \
            (spec.i_h // max(1, n.get("spatial", 1))) * spec.i_w * spec.i_c
        return float(x_loc * dtype_bytes)
    if parts == ("channel",):
        k_loc = spec.k_h * spec.k_w * spec.i_c * \
            (-(-spec.k_c // n["channel"]))
        return float(k_loc * dtype_bytes)
    return 0.0


def expected_collectives(spec, partition, n_dev, dtype_bytes: int,
                         direction: str, *, replicated_ways: int = 1
                         ) -> Tuple[Dict[str, float], Dict[str, float],
                                    Optional[str]]:
    """``(required, optional, unmodeled_reason)`` for one direction.

    ``required`` is the per-device operand bytes each collective kind
    must move, derived from ``conv_partition_costs`` — the same
    Eq.-level terms the bench ``dist`` suite gates — so the contract
    can never drift from the costmodel.  ``optional`` is traffic GSPMD
    may add or elide at its discretion (the output-trim rebalance
    permute; with ``replicated_ways > 1``, the free-axis gradient
    combine of :func:`replica_combine_bytes`); an observed total
    matches if it equals the required bytes alone or required+optional.
    A non-None ``unmodeled_reason`` means this direction's reshard
    lowering cannot be priced and must be recorded as unverified —
    never as a pass.  ``direction='fwd'`` is the forward program alone;
    ``'grad'`` is ``value_and_grad`` of the probe loss (forward halo +
    transposed halo cotangent on the permute, every backward psum on
    the all-reduce).  ``replicated_ways`` is how many copies of the
    cell the deployment mesh's unused axes carry (1 on an exact-size
    mesh).
    """
    if direction not in DIRECTIONS:
        raise ValueError(f"unknown direction {direction!r}; expected one "
                         f"of {DIRECTIONS}")
    from repro.launch.costmodel import conv_partition_costs
    from repro.parallel.conv import normalize_partition
    parts = normalize_partition(partition)
    sizes = tuple(int(n) for n in n_dev) \
        if isinstance(n_dev, (tuple, list)) else (int(n_dev),)
    if len(sizes) != len(parts):
        raise ValueError(f"partition {partition!r} has {len(parts)} "
                         f"component(s) but n_dev {n_dev!r} has "
                         f"{len(sizes)}")
    entry = conv_partition_costs(
        spec, sizes if len(parts) > 1 else sizes[0], dtype_bytes)[
            parts if len(parts) > 1 else parts[0]]
    halo = float(entry["halo_bytes_per_device"])
    psum = float(entry["comm_bytes_bwd_per_device"]) - halo
    reason, trim = trim_reshard(spec, parts, sizes, dtype_bytes)
    # A NaN optional marks a trim no direction can price; a reason with
    # a finite optional only disqualifies the standalone-forward probe.
    unmodeled = reason if reason is not None and \
        (direction == "fwd" or math.isnan(trim)) else None
    if math.isnan(trim):
        trim = 0.0
    mult = 1.0 if direction == "fwd" else 2.0
    required = {k: 0.0 for k in COLLECTIVE_KINDS}
    optional = {k: 0.0 for k in COLLECTIVE_KINDS}
    required["collective-permute"] = mult * halo
    optional["collective-permute"] = mult * trim
    if direction == "grad":
        required["all-reduce"] = psum
        if replicated_ways > 1:
            optional["all-reduce"] = replica_combine_bytes(
                spec, parts, sizes, dtype_bytes)
    return required, optional, unmodeled


def verify_collectives(observed: Dict, expected: Dict[str, float],
                       direction: str, label: str = "",
                       dtype_bytes: int = 4,
                       optional: Optional[Dict[str, float]] = None
                       ) -> List[ContractViolation]:
    """Compare ``collective_bytes`` output against the contract.

    Exact on every kind — the admissible totals per kind are the
    required bytes alone or required+optional (GSPMD-discretionary
    traffic such as the trim rebalance), each also accepted at f32
    width for sub-f32 dtypes when the backend hoists the upcast above
    the collective (CPU does — the convert fuses into the permute
    operand); the grad all-reduce may additionally run over by the
    scalar probe-loss allowance.  Messages name the breach, both byte
    counts, and the mechanism that should have produced the traffic —
    a missing halo permute is an actionable bug report, not a number.
    """
    where = f"{label}: " if label else ""
    widths = (1.0,) if dtype_bytes >= 4 else (1.0, 4.0 / dtype_bytes)
    out: List[ContractViolation] = []
    for kind in COLLECTIVE_KINDS:
        got = float(observed.get(kind, 0))
        base = float(expected.get(kind, 0.0))
        opt = float((optional or {}).get(kind, 0.0))
        allowance = SCALAR_REDUCE_ALLOWANCE_BYTES \
            if kind == "all-reduce" and direction == "grad" else 0.0
        matched = False
        for total in {base, base + opt}:
            for w in widths:
                want = total * w
                if want <= got <= want + allowance:
                    matched = True
        if matched:
            continue
        want = base  # report at declared width, required bytes
        hi = base + allowance
        if got < want:
            hint = ""
            if kind == "collective-permute":
                hint = (" — the spatial halo exchange (lax.ppermute in "
                        "repro.parallel.conv.sharded_conv2d"
                        + (", or its VJP transpose" if direction == "grad"
                           else "")
                        + ") is missing or undersized in the lowered HLO")
            elif kind == "all-reduce":
                hint = (" — a backward psum (kernel cotangent over the "
                        "batch/spatial axes, input cotangent over the "
                        "channel axis) is missing from the VJP")
            out.append(ContractViolation(
                "missing-collective", direction,
                f"{where}{kind} moved {got:.0f} bytes/device, contract "
                f"expects {want:.0f}{hint}"))
        elif want == 0.0:
            out.append(ContractViolation(
                "unexpected-collective", direction,
                f"{where}{kind} moved {got:.0f} bytes/device but the "
                f"contract expects none — GSPMD reshard traffic the "
                f"costmodel never priced (check the pinned in/out "
                f"shardings against parallel.conv.conv_partition_specs)"))
        else:
            hint = ""
            if kind == "collective-permute":
                hint = (" — halo/trim permute bytes are off: check the "
                        "halo exchange and its VJP transpose in "
                        "repro.parallel.conv.sharded_conv2d")
            out.append(ContractViolation(
                "collective-bytes-mismatch", direction,
                f"{where}{kind} moved {got:.0f} bytes/device, contract "
                f"expects {want:.0f}"
                + (f"+{opt:.0f} optional" if opt else "")
                + f" (allowance {hi - want:.0f}){hint}"))
    return out


# ---------------------------------------------------------------------------
# precision flow — promoted to repro.analysis.numcheck (PR 10), where it
# joined the full numeric-signature pass; re-exported here so the
# partitioned contract (and its callers) keep one import surface.
# ---------------------------------------------------------------------------

from repro.analysis.numcheck import (_subjaxprs,  # noqa: F401,E402
                                     _precision_matches,
                                     hlo_precision_tally,
                                     jaxpr_dot_precisions,
                                     precision_flow_findings)


# ---------------------------------------------------------------------------
# lowering
# ---------------------------------------------------------------------------

def _lower_partitioned(spec, parts, axes, mesh, dtype, direction, *,
                       algorithm, solution, precision, interpret):
    """AOT-lower one direction under pinned shardings; returns
    ``(closed_jaxpr, optimized_hlo_text)``.

    In/out shardings are pinned to ``conv_partition_specs`` — the
    contract is about what the *convolution* moves, so GSPMD must not
    be given reshard freedom at the jit boundary (an unpinned entry
    would add all-gathers the executor never asked for).
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.parallel.conv import conv_partition_specs, sharded_conv2d
    part_arg = parts if len(parts) > 1 else parts[0]
    axis_arg = tuple(axes) if len(axes) > 1 else axes[0]
    x_spec, k_spec, o_spec = conv_partition_specs(part_arg, axis_arg)
    x_sh = NamedSharding(mesh, x_spec)
    k_sh = NamedSharding(mesh, k_spec)
    x = jax.ShapeDtypeStruct((spec.i_n, spec.i_h, spec.i_w, spec.i_c),
                             dtype)
    k = jax.ShapeDtypeStruct((spec.k_h, spec.k_w, spec.i_c, spec.k_c),
                             dtype)
    stride = (spec.s_h, spec.s_w)

    def fwd(xv, kv):
        return sharded_conv2d(xv, kv, stride=stride, padding="VALID",
                              algorithm=algorithm, solution=solution,
                              partition=part_arg, axis=axis_arg,
                              mesh=mesh, interpret=interpret,
                              precision=precision)

    o_sh = NamedSharding(mesh, o_spec)

    if direction == "fwd":
        # Pin the output to the executor's own layout: left free, GSPMD
        # sometimes resolves the uneven output-trim slice with a full
        # all-gather — traffic the contract would (rightly) reject, but
        # caused by the probe boundary, not the convolution.  A sharding
        # *constraint* (not out_shardings=) because the trimmed o_h is
        # generally not divisible by the spatial ways.
        def fn(xv, kv):
            return jax.lax.with_sharding_constraint(fwd(xv, kv), o_sh)

        out_shardings = None
    else:
        def loss(xv, kv):
            out = fwd(xv, kv)
            return jnp.sum(out * out)

        fn = jax.value_and_grad(loss, argnums=(0, 1))
        # Pin the gradients to the input shardings (they fall out of the
        # shard_map transpose already sharded that way) and the scalar
        # loss replicated — reshard freedom here would hide breaches.
        out_shardings = (NamedSharding(mesh, P()), (x_sh, k_sh))
    closed = jax.make_jaxpr(fn)(x, k)
    jitted = jax.jit(fn, in_shardings=(x_sh, k_sh),
                     out_shardings=out_shardings)
    compiled = jitted.lower(x, k).compile()
    return closed, compiled.as_text()


# ---------------------------------------------------------------------------
# the checker
# ---------------------------------------------------------------------------

def check_sharding(spec, partition, n_dev=None, *, dtype: str = "float32",
                   algorithm: str = "mec", solution: str = "auto",
                   precision: Optional[str] = None,
                   interpret: Optional[bool] = None,
                   axes: Optional[Sequence[str]] = None,
                   mesh=None,
                   directions: Sequence[str] = DIRECTIONS) -> ShardCheck:
    """Full contract check of one partitioned cell.

    Lowers the cell under ``mesh`` (or a fresh host mesh of shape
    ``n_dev``) in every requested direction, verifies the collective
    contract, and runs the precision-flow pass over all lowerings.
    Returns a skipped (non-failing, non-passing) verdict when the cell
    cannot be lowered in this process: 1-way meshes (nothing crosses
    the interconnect), non-viable geometry (the executor would refuse),
    or more devices than the process was forced to host.
    """
    import jax
    from repro.parallel.conv import (normalize_partition, partition_name,
                                     partition_viable)
    parts = normalize_partition(partition)
    if mesh is not None:
        if axes is None:
            raise ValueError("check_sharding(mesh=...) needs axes= naming "
                             "the mesh axes the partition runs over")
        axes = tuple(axes)
        sizes = tuple(int(mesh.shape[a]) for a in axes)
    else:
        if n_dev is None:
            raise ValueError("check_sharding needs n_dev= (axis sizes) "
                             "or an explicit mesh=")
        sizes = tuple(int(n) for n in n_dev) \
            if isinstance(n_dev, (tuple, list)) else (int(n_dev),)
    if len(sizes) != len(parts):
        raise ValueError(f"partition {partition!r} has {len(parts)} "
                         f"component(s) but got {len(sizes)} axis "
                         f"size(s)")
    name = partition_name(parts)
    n_total = math.prod(sizes)
    import jax.numpy as jnp
    dtype_bytes = jnp.dtype(dtype).itemsize

    record: Dict = {
        "partition": name,
        "n_dev_axes": [int(n) for n in sizes],
        "dtype": dtype,
        "algorithm": algorithm,
        "solution": solution,
        "precision": precision,
        "directions": {},
        "precision_flow": None,
        "verdict": "pass",
        "skipped_reason": None,
        "violations": [],
    }

    def skipped(reason: str) -> ShardCheck:
        record["verdict"] = "skipped"
        record["skipped_reason"] = reason
        return ShardCheck(name, sizes, [], record, skipped=reason)

    if n_total <= 1:
        return skipped("1-way partition: nothing crosses the interconnect")
    if not partition_viable(spec, parts, sizes if len(parts) > 1
                            else sizes[0]):
        return skipped(f"partition {name!r} cannot split {spec} "
                       f"{sizes}-ways (parallel.conv.partition_viable)")
    if mesh is None:
        if n_total > jax.device_count():
            return skipped(
                f"needs {n_total} devices, process has "
                f"{jax.device_count()} (force more with XLA_FLAGS="
                f"--xla_force_host_platform_device_count=N before jax "
                f"initializes)")
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh(shape=sizes, axes=tuple(axes) if axes
                              else None)
        axes = tuple(mesh.axis_names)

    precision_value = None
    if precision is not None:
        precision_value = getattr(jax.lax.Precision, precision)

    violations: List[ContractViolation] = []
    jaxprs = []
    hlo_texts = []
    unmodeled_reasons = []
    verified = []
    for direction in directions:
        required, optional, unmodeled = expected_collectives(
            spec, parts, sizes, dtype_bytes, direction)
        if unmodeled is not None:
            record["directions"][direction] = {"unmodeled": unmodeled}
            unmodeled_reasons.append(f"{direction}: {unmodeled}")
            continue
        closed, hlo_text = _lower_partitioned(
            spec, parts, axes, mesh, dtype, direction,
            algorithm=algorithm, solution=solution,
            precision=precision_value, interpret=interpret)
        jaxprs.append(closed)
        hlo_texts.append(hlo_text)
        from repro.launch.hlo_analysis import collective_bytes
        observed = collective_bytes(hlo_text)
        violations += verify_collectives(
            observed, required, direction,
            label=f"{name} x{list(sizes)} {algorithm}/{dtype}",
            dtype_bytes=dtype_bytes, optional=optional)
        record["directions"][direction] = {
            "expected": {k: required[k] for k in COLLECTIVE_KINDS},
            "optional": {k: optional[k] for k in COLLECTIVE_KINDS},
            "observed": {k: int(observed.get(k, 0))
                         for k in COLLECTIVE_KINDS},
        }
        verified.append(direction)
    if not verified:
        return skipped("no direction verifiable — "
                       + "; ".join(unmodeled_reasons))
    tally, pviol = precision_flow_findings(jaxprs, hlo_texts, precision)
    violations += pviol
    record["precision_flow"] = tally
    record["violations"] = [v.render() for v in violations]
    record["verdict"] = "pass" if not violations else "fail"
    return ShardCheck(name, sizes, violations, record)


# ---------------------------------------------------------------------------
# plan wiring (duck-typed; repro.plan imports us, never the reverse)
# ---------------------------------------------------------------------------

def check_plan_contract(plan, mesh=None,
                        directions: Sequence[str] = ("grad",)
                        ) -> ShardCheck:
    """Contract-check one (duck-typed) ConvPlan.

    Partition-free plans trivially pass.  The mesh defaults to the
    installed ``parallel.axes`` rules mesh — the same mesh the plan's
    axes were resolved against; with no live mesh carrying the plan's
    axes the check is recorded as skipped (the plan cannot execute
    there either).  The default direction is ``grad`` alone: the
    ``value_and_grad`` program contains the forward halo too, so one
    lowering audits both sides at plan time.
    """
    partition = getattr(plan, "partition", None)
    if partition is None:
        rec = {"partition": None, "verdict": "skipped",
               "skipped_reason": "no partition"}
        return ShardCheck("none", (), [], rec, skipped="no partition")
    if mesh is None:
        from repro.parallel.axes import current_rules
        rules = current_rules()
        mesh = rules.mesh if rules is not None else None
    axes = tuple(plan.partition_axes)
    if mesh is None or any(a not in mesh.axis_names for a in axes):
        rec = {"partition": "+".join(partition), "verdict": "skipped",
               "skipped_reason": "no installed mesh carrying the plan's "
                                 f"axes {axes!r}"}
        return ShardCheck("+".join(partition), (), [], rec,
                          skipped=rec["skipped_reason"])
    return check_sharding(
        plan.spec, partition, dtype=plan.dtype,
        algorithm=plan.algorithm, solution=plan.solution,
        precision=getattr(plan, "precision", None),
        axes=axes, mesh=mesh, directions=directions)


# plan_conv2d calls the hook once per (contract identity); layers
# resolving the same partitioned plan per construction must not re-pay
# two AOT compiles each time.
_HOOK_CACHE: Dict[Tuple, Tuple[bool, str]] = {}
_HOOK_CACHE_MAX = 256


def assert_plan_contract(plan, mesh=None) -> None:
    """The ``plan_conv2d`` hook: raise :class:`ShardCheckError` when a
    partitioned plan's lowering breaks the collective or precision
    contract.  Skipped checks (no/1-way mesh, not enough devices) pass
    silently — the planner must stay usable on a laptop; CI's forced
    meshes are where skips become failures.  Memoized by contract
    identity (spec, dtype, algorithm, solution, precision, partition,
    axes, sizes)."""
    partition = getattr(plan, "partition", None)
    if partition is None:
        return
    if mesh is None:
        from repro.parallel.axes import current_rules
        rules = current_rules()
        mesh = rules.mesh if rules is not None else None
    if mesh is None:
        return
    axes = tuple(plan.partition_axes)
    sizes = tuple(int(mesh.shape[a]) for a in axes
                  if a in mesh.axis_names)
    key = (plan.spec, plan.dtype, plan.algorithm, plan.solution,
           getattr(plan, "precision", None), tuple(partition), axes,
           sizes)
    hit = _HOOK_CACHE.get(key)
    if hit is not None:
        ok, rendered = hit
        if not ok:
            raise ShardCheckError(rendered)
        return
    result = check_plan_contract(plan, mesh=mesh)
    if len(_HOOK_CACHE) >= _HOOK_CACHE_MAX:
        _HOOK_CACHE.clear()
    _HOOK_CACHE[key] = (result.ok, result.render())
    if not result.ok:
        raise ShardCheckError(result.render())
