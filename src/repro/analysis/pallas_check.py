"""Static VMEM/BlockSpec checker for the MEC Pallas kernels (DESIGN.md §8).

Given a resolved plan (anything with ``.spec``, ``.algorithm``,
``.w_blk``, ``.dtype`` — duck-typed so this module never imports
``repro.plan``), mirror the grid / BlockSpec / padding arithmetic of
``repro.kernels.mec_conv`` *symbolically* — no compile, no tracing — and
reject geometries that would fault or silently overrun VMEM on a real
TPU before anything is timed or cached:

``w-blk-out-of-range``        w_blk outside [1, o_w] (the executor's own
                              precondition, checked without running it).
``block-index-out-of-bounds`` a BlockSpec index map addresses a block
                              past the (padded) array extent — e.g. the
                              shifted-GEMM row ``h*s_h + r`` or the
                              fused2 ``h+1`` halo view.
``grid-not-covering``         the output grid leaves part of the (padded)
                              output unwritten.
``vmem-budget-overrun``       the double-buffered per-step working set
                              (blocks + in-kernel scratch) exceeds the
                              device VMEM (``repro.kernels.ops.vmem_bytes``).
``accumulator-overrun``       the f32 accumulator block alone exceeds the
                              :func:`~repro.kernels.ops.accumulator_budget`
                              carve-out ``pick_w_blk`` sizes against
                              (single-output-row kernels only; fused2's
                              oh_blk-row accumulator is governed by the
                              whole-set budget above).

The index-map checks exploit that every map in ``mec_conv`` is monotone
non-decreasing in each grid coordinate, so evaluating at the grid's max
corner bounds every step.  ``plan_conv2d`` refuses to return a Pallas
plan that fails (:func:`assert_plan`), and ``measure_candidates`` skips
rejected candidates instead of timing them.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

PALLAS_ALGORITHMS = ("mec_lowered", "mec_fused", "mec_fused2")

# Mosaic double-buffers every HBM<->VMEM block stream.
_DOUBLE_BUFFER = 2
_F32 = 4


class PallasCheckError(ValueError):
    """A plan failed the static Pallas geometry check."""


@dataclasses.dataclass(frozen=True)
class Violation:
    rule: str
    kernel: str
    message: str

    def render(self) -> str:
        return f"[{self.rule}] {self.kernel}: {self.message}"


@dataclasses.dataclass(frozen=True)
class KernelGeometry:
    """One pallas_call, mirrored: its grid, block shapes (elements), and
    estimated per-step VMEM bytes (double-buffered blocks + scratch)."""

    name: str
    grid: Tuple[int, ...]
    blocks: Dict[str, Tuple[int, ...]]
    vmem_bytes: int


@dataclasses.dataclass(frozen=True)
class PlanCheck:
    algorithm: str
    pallas: bool                     # False => trivially accepted
    w_blk: Optional[int]
    kernels: Tuple[KernelGeometry, ...]
    vmem_budget: int
    acc_budget: int
    violations: Tuple[Violation, ...]

    @property
    def ok(self) -> bool:
        return not self.violations

    @property
    def vmem_bytes(self) -> int:
        """Peak per-step VMEM estimate across the plan's kernels."""
        return max((k.vmem_bytes for k in self.kernels), default=0)

    def render(self) -> str:
        head = (f"{self.algorithm} w_blk={self.w_blk} "
                f"vmem={self.vmem_bytes}/{self.vmem_budget}B: "
                f"{'ok' if self.ok else 'REJECTED'}")
        return "\n".join([head] + ["  " + v.render()
                                   for v in self.violations])


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


def _blocks_bytes(blocks: Dict[str, Tuple[Tuple[int, ...], int]]) -> int:
    """Double-buffered bytes of named (shape, itemsize) block streams."""
    return _DOUBLE_BUFFER * sum(
        math.prod(shape) * itemsize for shape, itemsize in blocks.values())


def _index_bounds(name: str, kernel: str, block: Sequence[int],
                  padded: Sequence[int],
                  index_map: Callable[..., Sequence[int]],
                  grid: Sequence[int],
                  out: List[Violation]) -> None:
    """Flag any axis where the max-corner block index over-runs the
    padded array (maps are monotone in every grid coordinate)."""
    max_idx = index_map(*[g - 1 for g in grid])
    for axis, (idx, blk, ext) in enumerate(zip(max_idx, block, padded)):
        if (idx + 1) * blk > ext:
            out.append(Violation(
                "block-index-out-of-bounds", kernel,
                f"{name} axis {axis}: max block index {idx} x block "
                f"{blk} over-runs padded extent {ext}"))
        if idx < 0:
            out.append(Violation(
                "block-index-out-of-bounds", kernel,
                f"{name} axis {axis}: negative block index {idx}"))


def _coverage(kernel: str, out_block: Sequence[int],
              out_padded: Sequence[int], written_blocks: Sequence[int],
              out: List[Violation]) -> None:
    for axis, (blk, ext, n) in enumerate(
            zip(out_block, out_padded, written_blocks)):
        if n * blk < ext:
            out.append(Violation(
                "grid-not-covering", kernel,
                f"output axis {axis}: grid writes {n} x {blk} "
                f"< padded extent {ext}"))


def check_geometry(spec, algorithm: str, w_blk: Optional[int],
                   dtype: str = "float32", *,
                   vmem_budget: Optional[int] = None,
                   acc_budget: Optional[int] = None) -> PlanCheck:
    """Statically check one (spec, algorithm, w_blk) Pallas geometry.

    ``spec`` needs the ConvSpec fields (``i_n..s_w`` + ``o_h``/``o_w``).
    Non-Pallas algorithms are trivially accepted (``pallas=False``).
    """
    from repro.kernels.ops import accumulator_budget, pick_w_blk, vmem_bytes
    if vmem_budget is None:
        vmem_budget = vmem_bytes()
    if acc_budget is None:
        acc_budget = accumulator_budget(_warn_env=False)
    if algorithm not in PALLAS_ALGORITHMS:
        return PlanCheck(algorithm=algorithm, pallas=False, w_blk=w_blk,
                         kernels=(), vmem_budget=vmem_budget,
                         acc_budget=acc_budget, violations=())

    db = int(np.dtype(dtype).itemsize)
    i_n, i_h, i_w, i_c = spec.i_n, spec.i_h, spec.i_w, spec.i_c
    k_h, k_w, k_c = spec.k_h, spec.k_w, spec.k_c
    s_h, s_w = spec.s_h, spec.s_w
    o_h, o_w = spec.o_h, spec.o_w
    kwic = k_w * i_c
    if w_blk is None:                       # the executor's own fallback
        w_blk = pick_w_blk(o_w, k_c, _warn_env=False)

    viol: List[Violation] = []
    kernels: List[KernelGeometry] = []
    if not 1 <= w_blk <= max(o_w, 1):
        viol.append(Violation(
            "w-blk-out-of-range", algorithm,
            f"w_blk={w_blk} outside [1, o_w={o_w}]"))
        return PlanCheck(algorithm=algorithm, pallas=True, w_blk=w_blk,
                         kernels=(), vmem_budget=vmem_budget,
                         acc_budget=acc_budget, violations=tuple(viol))

    def add(name: str, grid, blocks, scratch_bytes: int,
            acc_shape: Optional[Tuple[int, ...]] = None) -> None:
        est = _blocks_bytes(blocks) + scratch_bytes
        kernels.append(KernelGeometry(
            name=name, grid=tuple(grid),
            blocks={k: s for k, (s, _) in blocks.items()},
            vmem_bytes=est))
        if est > vmem_budget:
            viol.append(Violation(
                "vmem-budget-overrun", name,
                f"per-step working set ~{est}B exceeds VMEM "
                f"{vmem_budget}B"))
        if acc_shape is not None:
            acc = math.prod(acc_shape) * _F32
            if acc > acc_budget:
                viol.append(Violation(
                    "accumulator-overrun", name,
                    f"f32 accumulator {acc_shape} = {acc}B exceeds "
                    f"budget {acc_budget}B (shrink w_blk)"))

    if algorithm == "mec_lowered":
        # --- mec_lower_pallas: grid (i_n, i_h_p/h_blk)
        h_blk = min(8, i_h)
        i_h_p = _ceil_to(i_h, h_blk)
        grid = (i_n, i_h_p // h_blk)
        in_pad = (i_n, i_h_p, i_w, i_c)
        l_shape = (i_n, o_w, i_h_p, kwic)
        in_blk = (1, h_blk, i_w, i_c)
        l_blk = (1, o_w, h_blk, kwic)
        _index_bounds("input", "mec_lower", in_blk, in_pad,
                      lambda n, h: (n, h, 0, 0), grid, viol)
        _index_bounds("L", "mec_lower", l_blk, l_shape,
                      lambda n, h: (n, 0, h, 0), grid, viol)
        _coverage("mec_lower", l_blk, l_shape,
                  (grid[0], 1, grid[1], 1), viol)
        # scratch: the stacked/transposed strip is another L block
        add("mec_lower", grid,
            {"input": (in_blk, db), "L": (l_blk, db)},
            scratch_bytes=math.prod(l_blk) * db)

        # --- mec_gemm_pallas over L (n, o_w, i_h, kwic)
        g_wblk = min(w_blk, o_w)
        o_w_p = _ceil_to(o_w, g_wblk)
        grid = (i_n, o_h, o_w_p // g_wblk, k_h)
        l_pad = (i_n, o_w_p, i_h, kwic)
        out_shape = (i_n, o_h, o_w_p, k_c)
        l_blk = (1, g_wblk, 1, kwic)
        k_blk = (1, kwic, k_c)
        o_blk = (1, 1, g_wblk, k_c)
        # THE load-bearing map: L row h*s_h + r must stay inside i_h.
        _index_bounds("L", "mec_gemm", l_blk, l_pad,
                      lambda n, h, w, r: (n, w, h * s_h + r, 0), grid, viol)
        _index_bounds("kernel", "mec_gemm", k_blk, (k_h, kwic, k_c),
                      lambda n, h, w, r: (r, 0, 0), grid, viol)
        _index_bounds("output", "mec_gemm", o_blk, out_shape,
                      lambda n, h, w, r: (n, h, w, 0), grid, viol)
        _coverage("mec_gemm", o_blk, out_shape,
                  (grid[0], grid[1], grid[2], 1), viol)
        add("mec_gemm", grid,
            {"L": (l_blk, db), "kernel": (k_blk, db),
             "output": (o_blk, _F32)},
            scratch_bytes=0, acc_shape=(g_wblk, k_c))

    elif algorithm == "mec_fused":
        _check_fused_v1(spec, w_blk, db, viol, add)

    elif algorithm == "mec_fused2":
        halo = k_h - s_h
        oh_blk = min(8, o_h)
        if halo < 0 or halo > s_h * 8:
            # the executor falls back to v1 on these geometries
            _check_fused_v1(spec, w_blk, db, viol, add)
        else:
            f_wblk = min(w_blk, o_w)
            pad_h = (-o_h) % oh_blk
            pad_w = (-o_w) % f_wblk
            o_h_p, o_w_p = o_h + pad_h, o_w + pad_w
            rows_blk = s_h * oh_blk
            n_hblocks = o_h_p // oh_blk
            need_h = (n_hblocks + 1) * rows_blk   # extra zero halo block
            need_w = s_w * (o_w_p - 1) + k_w
            in_pad = (i_n, max(i_h, need_h), max(i_w, need_w), i_c)
            grid = (i_n, n_hblocks, o_w_p // f_wblk, k_h)
            in_blk = (1, rows_blk, in_pad[2], i_c)
            k_blk = (1, kwic, k_c)
            o_blk = (1, oh_blk, f_wblk, k_c)
            out_shape = (i_n, o_h_p, o_w_p, k_c)
            _index_bounds("input", "mec_fused2", in_blk, in_pad,
                          lambda n, h, w, r: (n, h, 0, 0), grid, viol)
            # the h+1 halo view — in bounds only thanks to the extra block
            _index_bounds("halo", "mec_fused2", in_blk, in_pad,
                          lambda n, h, w, r: (n, h + 1, 0, 0), grid, viol)
            _index_bounds("kernel", "mec_fused2", k_blk, (k_h, kwic, k_c),
                          lambda n, h, w, r: (r, 0, 0), grid, viol)
            _index_bounds("output", "mec_fused2", o_blk, out_shape,
                          lambda n, h, w, r: (n, h, w, 0), grid, viol)
            _coverage("mec_fused2", o_blk, out_shape,
                      (grid[0], grid[1], grid[2], 1), viol)
            # in-kernel: max dynamic_slice row dh*s_h+r + halo concat
            max_row = (oh_blk - 1) * s_h + (k_h - 1)
            if max_row >= rows_blk + halo:
                viol.append(Violation(
                    "block-index-out-of-bounds", "mec_fused2",
                    f"in-kernel row {max_row} over-runs the "
                    f"{rows_blk}+{halo}-row block+halo window"))
            max_col = (grid[2] - 1) * s_w * f_wblk + (k_w - 1) \
                + s_w * (f_wblk - 1)
            if max_col >= in_pad[2]:
                viol.append(Violation(
                    "block-index-out-of-bounds", "mec_fused2",
                    f"in-kernel column {max_col} over-runs padded "
                    f"width {in_pad[2]}"))
            scratch = ((rows_blk + halo) * in_pad[2] * i_c * db   # concat
                       + f_wblk * kwic * db                       # strip
                       + oh_blk * f_wblk * k_c * _F32)            # acc
            add("mec_fused2", grid,
                {"input": (in_blk, db), "halo": (in_blk, db),
                 "kernel": (k_blk, db), "output": (o_blk, _F32)},
                scratch_bytes=scratch)

    return PlanCheck(algorithm=algorithm, pallas=True, w_blk=w_blk,
                     kernels=tuple(kernels), vmem_budget=vmem_budget,
                     acc_budget=acc_budget, violations=tuple(viol))


def _check_fused_v1(spec, w_blk: int, db: int, viol: List[Violation],
                    add) -> None:
    i_n, i_h, i_w, i_c = spec.i_n, spec.i_h, spec.i_w, spec.i_c
    k_h, k_w, k_c = spec.k_h, spec.k_w, spec.k_c
    s_h, s_w = spec.s_h, spec.s_w
    o_h, o_w = spec.o_h, spec.o_w
    kwic = k_w * i_c
    f_wblk = min(w_blk, o_w)
    o_w_p = _ceil_to(o_w, f_wblk)
    need_w = max(i_w, s_w * (o_w_p - 1) + k_w)
    in_pad = (i_n, i_h, need_w, i_c)
    grid = (i_n, o_h, o_w_p // f_wblk, k_h)
    in_blk = (1, 1, need_w, i_c)
    k_blk = (1, kwic, k_c)
    o_blk = (1, 1, f_wblk, k_c)
    out_shape = (i_n, o_h, o_w_p, k_c)
    # input row h*s_h + r — the fused shifted-window walk
    _index_bounds("input", "mec_fused", in_blk, in_pad,
                  lambda n, h, w, r: (n, h * s_h + r, 0, 0), grid, viol)
    _index_bounds("kernel", "mec_fused", k_blk, (k_h, kwic, k_c),
                  lambda n, h, w, r: (r, 0, 0), grid, viol)
    _index_bounds("output", "mec_fused", o_blk, out_shape,
                  lambda n, h, w, r: (n, h, w, 0), grid, viol)
    _coverage("mec_fused", o_blk, out_shape,
              (grid[0], grid[1], grid[2], 1), viol)
    max_col = (grid[2] - 1) * s_w * f_wblk + (k_w - 1) + s_w * (f_wblk - 1)
    if max_col >= need_w:
        viol.append(Violation(
            "block-index-out-of-bounds", "mec_fused",
            f"in-kernel column {max_col} over-runs padded width {need_w}"))
    scratch = f_wblk * kwic * db + f_wblk * k_c * _F32
    add("mec_fused", grid,
        {"input": (in_blk, db), "kernel": (k_blk, db),
         "output": (o_blk, _F32)},
        scratch_bytes=scratch, acc_shape=(f_wblk, k_c))


def check_plan(plan, *, vmem_budget: Optional[int] = None,
               acc_budget: Optional[int] = None) -> PlanCheck:
    """Check a resolved plan (duck-typed: ``.spec``, ``.algorithm``,
    ``.w_blk``, ``.dtype``)."""
    return check_geometry(plan.spec, plan.algorithm, plan.w_blk,
                          plan.dtype, vmem_budget=vmem_budget,
                          acc_budget=acc_budget)


def assert_plan(plan, *, vmem_budget: Optional[int] = None,
                acc_budget: Optional[int] = None) -> PlanCheck:
    """:func:`check_plan`, raising :class:`PallasCheckError` on rejection
    — what ``plan_conv2d`` calls so measured-mode never times (and the
    cache never stores) a kernel geometry the checker rejects."""
    result = check_plan(plan, vmem_budget=vmem_budget,
                        acc_budget=acc_budget)
    if not result.ok:
        raise PallasCheckError(
            "static Pallas check rejected the plan:\n" + result.render())
    return result
