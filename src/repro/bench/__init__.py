"""Machine-readable benchmark subsystem (DESIGN.md §3).

The paper's entire claim is a measured trade-off — Eq. 3's compact
lowering vs. im2col's k_h*k_w blow-up, *and* a speedup from better
memory-subsystem behaviour — so benchmark results must be comparable
across runs, machines, and jax versions.  This package owns that:

* :mod:`repro.bench.scenarios` — the scenario registry: paper Table 2
  (``cv1``–``cv12``), the Table 3 ResNet-101 weighted set, the Fig 4(a)
  k/s sweep, batch/channel/dtype diversity suites, and the CI ``smoke``
  subset, all routed through ``repro.core.conv_api.conv2d``.
* :mod:`repro.bench.harness` — warmup/steady-state timing of
  pre-compiled calls, analytic memory overhead (``repro.core.memory``),
  HLO-derived flops/bytes (``repro.launch.hlo_analysis`` via
  ``repro.core.compat.cost_analysis``), and costmodel cross-validation.
* :mod:`repro.bench.report` — the ``BENCH_<suite>.json`` schema,
  environment fingerprint, validation, and legacy-CSV rendering.
* :mod:`repro.bench.check` — baseline comparison with per-metric
  tolerances; non-zero exit on regression (the CI perf gate).

CLI::

  PYTHONPATH=src python -m repro.bench --suite smoke --out BENCH_smoke.json
  PYTHONPATH=src python -m repro.bench.check BENCH_smoke.json \\
      --baseline benchmarks/baselines/smoke.json --schema-only-on-timing
"""
from repro.bench.harness import run_autotune, run_serve, run_suite
from repro.bench.report import render_csv, validate_report, write_report
from repro.bench.scenarios import (ALGORITHM_VARIANTS, CV_LAYERS,
                                   RESNET101_WEIGHTS, SUITES, Scenario,
                                   resolve_suite)

__all__ = [
    "ALGORITHM_VARIANTS", "CV_LAYERS", "RESNET101_WEIGHTS", "SUITES",
    "Scenario", "render_csv", "resolve_suite", "run_autotune", "run_serve",
    "run_suite", "validate_report", "write_report",
]
