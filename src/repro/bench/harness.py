"""Benchmark harness: one measurement protocol for every scenario.

Protocol (DESIGN.md §3): each (scenario, algorithm) cell is lowered and
compiled ahead of time (``jax.jit(...).lower(...).compile()``); the
pre-compiled executable is called ``warmup`` times to reach steady
state, then ``iters`` times under ``time.perf_counter`` with
``block_until_ready``; ``us_per_call`` is the median.  Alongside the
measured timing every record carries *deterministic* analytic fields —
memory overhead (``repro.core.memory``, paper Eqs. 2–4, on the exact
paper spec) and flops (``repro.launch.costmodel``) — plus the
HLO-derived flops/bytes of the compiled executable
(``repro.launch.hlo_analysis.hlo_flops_bytes``).  The deterministic
fields are what ``repro.bench.check`` gates on; timing is tolerance- or
schema-only checked.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.bench.scenarios import (ALGORITHM_VARIANTS, Scenario,
                                   resolve_suite)
from repro.core.conv_api import conv2d
from repro.core.convspec import ConvSpec
from repro.core.memory import algorithm_overhead
from repro.launch.costmodel import (conv2d_algorithm_costs,
                                    conv_partition_costs,
                                    pick_conv2d_algorithm,
                                    pick_conv_partition)
from repro.launch.hlo_analysis import hlo_flops_bytes

# Variant name -> key into conv2d_algorithm_costs for the flops model
# (all MEC executions compute the same mult-adds as the reference).
_FLOPS_BASE = {"mecA": "mec", "mecB": "mec", "mec_lowered": "mec",
               "mec_fused": "mec", "mec_fused2": "mec"}


def make_arrays(s: ConvSpec, dtype: str = "float32", seed: int = 0):
    """Deterministic NHWC input + HWIO kernel for a spec."""
    rng = np.random.RandomState(seed)
    inp = rng.randn(s.i_n, s.i_h, s.i_w, s.i_c).astype(np.float32)
    ker = rng.randn(s.k_h, s.k_w, s.i_c, s.k_c).astype(np.float32)
    return jnp.asarray(inp, dtype), jnp.asarray(ker, dtype)


def time_compiled(call, iters: int = 3, warmup: int = 1) -> Dict:
    """Steady-state wall-clock stats (microseconds) of a nullary call.

    ``us_std`` / ``us_rel_spread`` (std over median) quantify the
    run-to-run jitter of the timed iterations — the data behind the
    planner's ``MEASURED_NOISE_MARGIN``: a measured flip is only
    trustworthy when the margin dominates the observed spread.
    """
    for _ in range(max(warmup, 1)):
        jax.block_until_ready(call())
    us: List[float] = []
    for _ in range(max(iters, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(call())
        us.append((time.perf_counter() - t0) * 1e6)
    median = float(np.median(us))
    std = float(np.std(us))
    return {"iters": max(iters, 1), "warmup": max(warmup, 1),
            "us_median": median, "us_min": float(min(us)),
            "us_mean": float(np.mean(us)), "us_std": std,
            "us_rel_spread": (std / median if median > 0 else None)}


def _analytic_flops(spec: ConvSpec, algorithm: str) -> float:
    costs = conv2d_algorithm_costs(spec)
    base = _FLOPS_BASE.get(algorithm, algorithm)
    return float(costs[base]["flops"])


def _resolved_plan_dict(sc: Scenario) -> Dict:
    """The resolved ConvPlan (repro.plan, analytic policy) for the
    scenario's paper geometry — recorded per cell so a report shows the
    full decision, not just the algorithm name.  Lazy import: bench sits
    below plan in the layer order."""
    from repro.plan import plan_conv2d
    return plan_conv2d(sc.spec, dtype=sc.dtype, mode="analytic",
                       partition="none").to_dict()


def measure(sc: Scenario, algorithm: str, iters: int = 3, warmup: int = 1,
            interpret: Optional[bool] = None, with_hlo: bool = True,
            with_timing: bool = True,
            plan_dict: Optional[Dict] = None) -> Dict:
    """One result record for a (scenario, algorithm) cell.  plan_dict
    lets run_suite derive the (per-scenario, algorithm-independent)
    resolved plan once instead of per cell."""
    kwargs = dict(ALGORITHM_VARIANTS[algorithm])
    stride = (sc.run_spec.s_h, sc.run_spec.s_w)
    dtype_bytes = jnp.zeros((), sc.dtype).dtype.itemsize
    record = {
        "scenario": sc.name,
        "algorithm": algorithm,
        "dtype": sc.dtype,
        "weight": sc.weight,
        "spec": dataclasses.asdict(sc.spec),
        "run_spec": dataclasses.asdict(sc.run_spec),
        # Deterministic analytics on the exact paper spec (check gates on
        # these) ...
        "overhead_elems": int(algorithm_overhead(sc.spec, algorithm)),
        "overhead_bytes": int(algorithm_overhead(sc.spec, algorithm)
                              * dtype_bytes),
        "flops": _analytic_flops(sc.spec, algorithm),
        # ... and on the (possibly channel-capped) spec actually executed,
        # so HLO numbers have an apples-to-apples analytic partner.
        "run_flops": _analytic_flops(sc.run_spec, algorithm),
        "auto_algorithm": pick_conv2d_algorithm(sc.spec),
        "plan": plan_dict if plan_dict is not None
        else _resolved_plan_dict(sc),
        "out_shape": list(sc.run_spec.out_shape),
        "us_per_call": None,
        "timing": None,
        "hlo_flops": None,
        "hlo_bytes": None,
    }
    mesh = None
    mesh_axis = None
    if sc.partition is not None:
        # Distributed cell: per-device/halo analytics (DESIGN.md §6) are
        # always emitted; execution additionally needs enough devices.
        # Composite cells carry a component tuple + per-sub-axis device
        # tuple; records serialize them via partition_name / n_dev_axes.
        from repro.parallel.conv import (normalize_partition,
                                         partition_name, partition_viable)
        parts = normalize_partition(sc.partition)
        composite = len(parts) > 1
        sizes = tuple(sc.n_dev) if composite else (int(sc.n_dev),)
        n_total = math.prod(sizes)
        dist = conv_partition_costs(
            sc.spec, sizes if composite else sizes[0], dtype_bytes)
        entry = dist[parts if composite else parts[0]]
        record["partition"] = partition_name(parts)
        record["n_dev"] = int(n_total)
        record["n_dev_axes"] = [int(n) for n in sizes]
        record["halo_bytes_per_device"] = entry["halo_bytes_per_device"]
        record["per_device_overhead_elems"] = \
            entry["per_device_overhead_elems"]
        record["comm_bytes_per_device"] = (
            entry["comm_bytes_fwd_per_device"]
            + entry["comm_bytes_bwd_per_device"])
        candidates = {p: n_total for p in ("batch", "channel", "spatial")}
        if composite:
            from repro.parallel.conv import COMPOSITE_PARTITIONS
            candidates.update({c: sizes for c in COMPOSITE_PARTITIONS})
        auto = pick_conv_partition(sc.spec, candidates, dtype_bytes)
        record["auto_partition"] = \
            None if auto is None else partition_name(auto)
        if n_total > jax.device_count() or \
                not partition_viable(sc.run_spec, parts, sc.n_dev):
            with_hlo = with_timing = False
        else:
            from repro.launch.mesh import make_host_mesh
            mesh = make_host_mesh(shape=sizes)
            mesh_axis = mesh.axis_names if composite else None
    if not (with_hlo or with_timing):
        return record

    inp, ker = make_arrays(sc.run_spec, sc.dtype)
    if mesh is not None:
        from repro.parallel.conv import sharded_conv2d
        fn = jax.jit(lambda i, k: sharded_conv2d(
            i, k, stride=stride, partition=sc.partition, mesh=mesh,
            axis=mesh_axis, interpret=interpret, **kwargs))
    else:
        fn = jax.jit(lambda i, k: conv2d(i, k, stride=stride,
                                         interpret=interpret, **kwargs))
    compiled = fn.lower(inp, ker).compile()
    if with_hlo:
        hlo = hlo_flops_bytes(compiled)
        record["hlo_flops"] = hlo["flops"]
        record["hlo_bytes"] = hlo["bytes_accessed"]
    if mesh is not None and with_hlo:
        # Collective-contract verdict for the executed dist cell
        # (repro.analysis.shardcheck, DESIGN.md §8).  The gated field is
        # the version-robust reduction — verdict, per-direction status,
        # and the costmodel-side expected bytes — because the observed
        # HLO byte evidence may shift with the jax/XLA version matrix
        # while the contract still holds; the full evidence lives in
        # BENCH_shardcheck.json (python -m repro.analysis --suite
        # shardcheck).
        from repro.analysis.shardcheck import check_sharding
        chk = check_sharding(
            sc.run_spec, sc.partition, dtype=sc.dtype,
            algorithm=kwargs.get("algorithm", "auto"),
            solution=kwargs.get("solution", "auto"),
            interpret=interpret, mesh=mesh,
            axes=tuple(mesh.axis_names)).record
        record["shardcheck"] = {
            "verdict": chk["verdict"],
            "skipped_reason": chk["skipped_reason"],
            "directions": {
                d: ("unmodeled" if "unmodeled" in info else "verified")
                for d, info in chk["directions"].items()},
            "expected": {
                d: {"required": info["expected"],
                    "optional": info["optional"]}
                for d, info in chk["directions"].items()
                if "expected" in info},
            "violations": chk["violations"],
        }
    if with_hlo and mesh is None:
        # Numeric-contract verdict for the single-device cell
        # (repro.analysis.numcheck, DESIGN.md §8.5).  Static-only (no
        # probe — the harness must not pay an extra execution per cell)
        # and memoized across cells sharing a (spec, algorithm, dtype);
        # the reduced field is the version-robust verdict, the full
        # signature + probe evidence lives in BENCH_numcheck.json
        # (python -m repro.analysis --suite numcheck).
        from repro.analysis.numcheck import cell_numcheck
        record["numcheck"] = cell_numcheck(
            sc.run_spec, kwargs.get("algorithm", "auto"), sc.dtype,
            solution=kwargs.get("solution", "auto"), interpret=interpret)
    if with_timing:
        timing = time_compiled(lambda: compiled(inp, ker),
                               iters=iters, warmup=warmup)
        record["timing"] = timing
        record["us_per_call"] = timing["us_median"]
    return record


def crosscheck_scenario(records: Sequence[Dict]) -> Dict:
    """Costmodel-vs-measurement cross-validation for one scenario.

    * ``auto_matches_best`` — did ``pick_conv2d_algorithm`` choose the
      algorithm that actually timed fastest here?
    * ``auto_overhead_ok`` — is auto's pick also no worse on analytic
      memory overhead than the measured-fastest one (the paper's point:
      you should not have to pay memory for speed)?
    * ``flops_ratio_hlo`` — per-algorithm HLO flops / analytic flops on
      the executed spec; ~1 means the costmodel predicts what XLA built.
    """
    timed = [r for r in records if r["us_per_call"] is not None]
    out = {"scenario": records[0]["scenario"],
           "auto_algorithm": records[0]["auto_algorithm"],
           "measured_best": None, "auto_matches_best": None,
           "auto_overhead_ok": None, "flops_ratio_hlo": {}}
    for r in records:
        if r["hlo_flops"] and r["run_flops"]:
            out["flops_ratio_hlo"][r["algorithm"]] = \
                round(r["hlo_flops"] / r["run_flops"], 3)
    if not timed:
        return out
    best = min(timed, key=lambda r: r["us_per_call"])
    out["measured_best"] = best["algorithm"]
    auto = out["auto_algorithm"]
    # auto names a conv2d algorithm; bench variants mecA/mecB both map to it
    base_of = {n: kw["algorithm"] for n, kw in ALGORITHM_VARIANTS.items()}
    out["auto_matches_best"] = base_of[best["algorithm"]] == auto
    auto_recs = [r for r in records if base_of[r["algorithm"]] == auto]
    if auto_recs:
        out["auto_overhead_ok"] = \
            auto_recs[0]["overhead_elems"] <= best["overhead_elems"]
    return out


def run_suite(suite: str, iters: int = 3, warmup: int = 1,
              interpret: Optional[bool] = None, with_hlo: bool = True,
              with_timing: bool = True, crosscheck: bool = False,
              progress=None) -> Dict:
    """Run a registered suite and return the report document."""
    from repro.bench.report import make_report
    scenarios = resolve_suite(suite)
    results: List[Dict] = []
    checks: List[Dict] = []
    for sc in scenarios:
        recs = []
        plan_dict = _resolved_plan_dict(sc)   # algorithm-independent
        for alg in sc.algorithms:
            if progress:
                progress(f"[bench] {suite}/{sc.name}/{alg}")
            recs.append(measure(sc, alg, iters=iters, warmup=warmup,
                                interpret=interpret, with_hlo=with_hlo,
                                with_timing=with_timing,
                                plan_dict=plan_dict))
        results.extend(recs)
        if crosscheck:
            checks.append(crosscheck_scenario(recs))
    harness = {"iters": iters, "warmup": warmup,
               "interpret": interpret, "with_hlo": with_hlo,
               "with_timing": with_timing}
    return make_report(suite, results, harness,
                       crosscheck=checks if crosscheck else None)


SERVE_MODES = ("warm", "cold", "auto")


def _serve_requests(cell, kernel_dtype):
    """The cell's deterministic request stream, arrays prebuilt (array
    construction must not pollute the latency measurement)."""
    i_c = cell.kernel_shape[2]
    reqs = []
    for i in range(cell.n_requests):
        n, h, w = cell.requests[i % len(cell.requests)]
        rng = np.random.RandomState(1000 + i)
        x = jnp.asarray(rng.randn(n, h, w, i_c).astype(np.float32),
                        kernel_dtype)
        reqs.append(x)
    jax.block_until_ready(reqs)
    return reqs


def run_serve(progress=None) -> Dict:
    """The ``serve`` suite (DESIGN.md §9): every registered
    :class:`~repro.bench.scenarios.ServeScenario` served under the three
    policies in :data:`SERVE_MODES`, one record per (shape class, mode).

    Latencies are end-to-end request wall-clock *including* each mode's
    real setup profile — warm pays plan resolution + AOT compile before
    the stream starts, cold pays it inside the first request of each
    class (visible as ``first_request_us``/p99), and auto pays eager
    per-call dispatch on every request.  ``us_per_call`` is the p50, so
    the generic timing tolerance of ``repro.bench.check`` applies; the
    analytic fields are the paper's Eq. 3 MEC overhead on the padded
    class spec (backend-independent, gated exactly).
    """
    from repro.bench.report import make_report
    from repro.bench.scenarios import serve_cells
    from repro.plan import plan_conv2d
    from repro.serving.conv_service import ConvService
    results: List[Dict] = []
    for cell in serve_cells():
        rng = np.random.RandomState(7)
        k_h, k_w, i_c, k_c = cell.kernel_shape
        kernel = jnp.asarray(rng.randn(k_h, k_w, i_c, k_c)
                             .astype(np.float32), cell.dtype)
        reqs = _serve_requests(cell, cell.dtype)
        for mode in SERVE_MODES:
            if progress:
                progress(f"[bench] serve/{cell.name}/{mode}")
            svc = ConvService(kernel, stride=cell.stride,
                              padding=cell.padding, classes=cell.classes,
                              plan_mode="cached")
            warmed = svc.warm() if mode == "warm" else None
            per_class: Dict = {cls: [] for cls in svc.classes}
            t_all = time.perf_counter()
            for x in reqs:
                cls = svc.bucket(x.shape)
                t0 = time.perf_counter()
                if mode == "auto":
                    # The pre-planner serving baseline: every request
                    # re-enters conv2d's dispatch eagerly (same padding
                    # work, no frozen plan, no AOT executable).
                    out = conv2d(svc.pad_to_class(x, cls), kernel,
                                 stride=cell.stride, padding=cell.padding,
                                 algorithm="auto")
                    o_n, o_h, o_w, _ = svc.request_out_shape(x.shape)
                    out = out[:o_n, :o_h, :o_w, :]
                else:
                    out = svc.execute(x)
                jax.block_until_ready(out)
                per_class[cls].append((time.perf_counter() - t0) * 1e6)
            total_s = max(time.perf_counter() - t_all, 1e-9)
            throughput = len(reqs) / total_s
            for cls in svc.classes:
                spec = svc.class_spec(cls)
                lat = per_class[cls]
                record = {
                    "scenario": f"{cell.name}_c{cls.tag()}",
                    "algorithm": mode,
                    "dtype": cell.dtype,
                    "weight": 1,
                    "spec": dataclasses.asdict(spec),
                    "run_spec": dataclasses.asdict(spec),
                    # Eq. 3 on the padded class spec: the memory the
                    # serving layer's MEC lowering costs per class
                    # request — backend-independent, exact-gated.
                    "overhead_elems": int(algorithm_overhead(spec, "mec")),
                    "overhead_bytes": int(
                        algorithm_overhead(spec, "mec")
                        * jnp.dtype(cell.dtype).itemsize),
                    "flops": _analytic_flops(spec, "mec"),
                    "run_flops": _analytic_flops(spec, "mec"),
                    "auto_algorithm": pick_conv2d_algorithm(spec),
                    "plan": plan_conv2d(spec, dtype=cell.dtype,
                                        mode="analytic",
                                        partition="none").to_dict(),
                    "out_shape": list(spec.out_shape),
                    "us_per_call": (float(np.percentile(lat, 50))
                                    if lat else None),
                    "timing": ({"n": len(lat),
                                "us_p50": float(np.percentile(lat, 50)),
                                "us_p99": float(np.percentile(lat, 99)),
                                "us_mean": float(np.mean(lat)),
                                "us_min": float(min(lat)),
                                "us_max": float(max(lat))}
                               if lat else None),
                    "hlo_flops": None,
                    "hlo_bytes": None,
                    "serve_mode": mode,
                    "shape_class": cls.tag(),
                    "n_classes": len(svc.classes),
                    "n_requests": len(lat),
                    "p50_us": (float(np.percentile(lat, 50))
                               if lat else None),
                    "p99_us": (float(np.percentile(lat, 99))
                               if lat else None),
                    "first_request_us": float(lat[0]) if lat else None,
                    "throughput_rps": float(throughput),
                    "warmup_warnings": (warmed.warning_count
                                        if warmed else 0),
                    "plan_cache_io_errors": (warmed.plan_cache_io_errors
                                             if warmed else 0),
                }
                results.append(record)
    harness = {"modes": list(SERVE_MODES),
               "latency": "end-to-end request wall-clock incl. each "
                          "mode's setup profile"}
    return make_report("serve", results, harness)


def run_autotune(base_suite: str = "smoke", iters: int = 3, warmup: int = 1,
                 interpret: Optional[bool] = None, progress=None) -> Dict:
    """Analytic-vs-measured pick quality (the ``autotune`` scenario).

    For every scenario in ``base_suite``, derive the analytic plan on
    the *timed* geometry (``run_spec`` — both picks must be judged on
    the shapes actually measured), then run the full measured policy
    (``repro.plan.tune_measured`` — the same staged race + knob grid
    ``plan_conv2d(mode="measured")`` uses, so these numbers ARE the
    planner's numbers) and record both picks with their steady-state
    times.  ``speedup`` > 1 means measured autotuning beat the analytic
    costmodel on that cell.

    Schema v2 additions (DESIGN.md §10): per-candidate full timing
    stats including spread (``candidate_stats``) — the evidence behind
    the 5%% noise margin; candidates that could not be timed with their
    reasons (``skipped``/``n_skipped`` — nothing is dropped silently);
    the stage-2 knob grid (``tuning``) and final measured ``plan``; and
    the active calibration's provenance (every trial here feeds the
    calibration store, so autotune runs are the fitted costmodel's
    training data).
    """
    from repro.bench.report import environment_fingerprint
    from repro.plan import pick_measured, plan_conv2d, tune_measured
    from repro.plan.calibrate import calibration_info
    from repro.plan.convplan import MEASURED_NOISE_MARGIN
    results: List[Dict] = []
    for sc in resolve_suite(base_suite):
        if progress:
            progress(f"[bench] autotune/{sc.name}")
        analytic = plan_conv2d(sc.run_spec, dtype=sc.dtype, mode="analytic",
                               partition="none")
        plan, detail = tune_measured(sc.run_spec, sc.dtype, iters=iters,
                                     warmup=warmup, interpret=interpret,
                                     candidates=sc.tune_candidates)
        times = detail["candidate_us"]
        # The planner's own decision rule: noise-margin tie to analytic,
        # margin widened to each candidate's observed rel spread (§10).
        measured_alg = pick_measured(times, analytic.algorithm, spreads={
            a: s.get("us_rel_spread")
            for a, s in detail["candidate_stats"].items()})
        analytic_us = times.get(analytic.algorithm)
        measured_us = times[measured_alg]
        spreads = [s.get("us_rel_spread")
                   for s in detail["candidate_stats"].values()
                   if s.get("us_rel_spread") is not None]
        results.append({
            "scenario": sc.name,
            "dtype": sc.dtype,
            "run_spec": dataclasses.asdict(sc.run_spec),
            "analytic_algorithm": analytic.algorithm,
            "analytic_us": analytic_us,
            "measured_algorithm": measured_alg,
            "measured_us": measured_us,
            "candidate_us": {a: times[a] for a in sorted(times)},
            "candidate_stats": {a: detail["candidate_stats"][a]
                                for a in sorted(detail["candidate_stats"])},
            "skipped": dict(sorted(detail["skipped"].items())),
            "n_skipped": len(detail["skipped"]),
            "max_rel_spread": (round(max(spreads), 4) if spreads else None),
            "tuning": detail["tuning"],
            "plan": plan.to_dict(),
            "speedup": (None if not analytic_us
                        else round(analytic_us / measured_us, 3)),
            "pick_agrees": measured_alg == analytic.algorithm,
        })
    return {
        "autotune_schema_version": 2,
        "suite": "autotune",
        "base_suite": base_suite,
        "environment": environment_fingerprint(),
        "calibration": calibration_info(),
        "harness": {"iters": iters, "warmup": warmup,
                    "interpret": interpret,
                    "noise_margin": MEASURED_NOISE_MARGIN},
        "results": results,
    }
