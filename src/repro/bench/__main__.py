"""CLI entry point:  PYTHONPATH=src python -m repro.bench --suite smoke \\
    --out BENCH_smoke.json [--format csv] [--crosscheck]"""
from __future__ import annotations

import argparse
import sys

from repro.bench.harness import run_suite
from repro.bench.report import render_csv, write_report
from repro.bench.scenarios import SUITES


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro.bench", description=__doc__)
    ap.add_argument("--suite", required=True, choices=sorted(SUITES))
    ap.add_argument("--out", default=None,
                    help="write BENCH_<suite>.json here (default: "
                         "BENCH_<suite>.json in the cwd for json format)")
    ap.add_argument("--format", choices=("json", "csv"), default="json",
                    help="csv prints the legacy table,name,us,derived lines")
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--warmup", type=int, default=1)
    ap.add_argument("--interpret", choices=("auto", "true", "false"),
                    default="auto",
                    help="Pallas interpret mode for mec_* kernels "
                         "(auto: interpret everywhere but real TPU)")
    ap.add_argument("--no-hlo", action="store_true",
                    help="skip cost_analysis of the compiled executables")
    ap.add_argument("--no-timing", action="store_true",
                    help="analytic + HLO fields only (fast, deterministic)")
    ap.add_argument("--crosscheck", action="store_true",
                    help="cross-validate costmodel predictions against "
                         "measurements (adds a 'crosscheck' section)")
    args = ap.parse_args(argv)

    interpret = {"auto": None, "true": True, "false": False}[args.interpret]
    doc = run_suite(args.suite, iters=args.iters, warmup=args.warmup,
                    interpret=interpret, with_hlo=not args.no_hlo,
                    with_timing=not args.no_timing,
                    crosscheck=args.crosscheck,
                    progress=lambda msg: print(msg, file=sys.stderr))
    if args.format == "csv":
        for line in render_csv(doc):
            print(line)
        if args.out:
            write_report(doc, args.out)
        return 0
    out = args.out or f"BENCH_{args.suite}.json"
    write_report(doc, out)
    print(f"[bench] {args.suite}: {len(doc['results'])} cells -> {out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
