"""CLI entry point:  PYTHONPATH=src python -m repro.bench --suite smoke \\
    --out BENCH_smoke.json [--format csv] [--crosscheck]

``--suite autotune`` is special: it runs the analytic-vs-measured pick
comparison (``harness.run_autotune``, DESIGN.md §7) over the scenarios
of ``--base-suite`` and writes its own document (BENCH_autotune.json)
rather than a standard suite report.

``--suite serve`` runs the conv-serving cells (``harness.run_serve``,
DESIGN.md §9): warm-plan vs cold-plan vs per-call ``algorithm="auto"``
over the registered shape-class services, emitted as a standard report
so ``repro.bench.check`` gates it against
``benchmarks/baselines/serve.json``."""
from __future__ import annotations

import argparse
import json
import sys

from repro.bench.harness import run_autotune, run_serve, run_suite
from repro.bench.report import render_csv, write_report
from repro.bench.scenarios import SUITES


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro.bench", description=__doc__)
    ap.add_argument("--suite", required=True,
                    choices=sorted(SUITES) + ["autotune", "serve"])
    ap.add_argument("--base-suite", default="smoke", choices=sorted(SUITES),
                    help="scenarios the autotune comparison runs over")
    ap.add_argument("--out", default=None,
                    help="write BENCH_<suite>.json here (default: "
                         "BENCH_<suite>.json in the cwd for json format)")
    ap.add_argument("--format", choices=("json", "csv"), default="json",
                    help="csv prints the legacy table,name,us,derived lines")
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--warmup", type=int, default=1)
    ap.add_argument("--interpret", choices=("auto", "true", "false"),
                    default="auto",
                    help="Pallas interpret mode for mec_* kernels "
                         "(auto: interpret everywhere but real TPU)")
    ap.add_argument("--no-hlo", action="store_true",
                    help="skip cost_analysis of the compiled executables")
    ap.add_argument("--no-timing", action="store_true",
                    help="analytic + HLO fields only (fast, deterministic)")
    ap.add_argument("--crosscheck", action="store_true",
                    help="cross-validate costmodel predictions against "
                         "measurements (adds a 'crosscheck' section)")
    args = ap.parse_args(argv)

    interpret = {"auto": None, "true": True, "false": False}[args.interpret]
    if args.suite == "autotune":
        doc = run_autotune(args.base_suite, iters=args.iters,
                           warmup=args.warmup, interpret=interpret,
                           progress=lambda m: print(m, file=sys.stderr))
        out = args.out or "BENCH_autotune.json"
        with open(out, "w") as f:
            json.dump(doc, f, indent=2)
            f.write("\n")
        wins = sum(1 for r in doc["results"]
                   if r["speedup"] and r["speedup"] >= 1.0)
        print(f"[bench] autotune over {args.base_suite}: "
              f"{len(doc['results'])} cells, measured pick <= analytic on "
              f"{wins} -> {out}")
        return 0
    if args.suite == "serve":
        doc = run_serve(progress=lambda m: print(m, file=sys.stderr))
        out = args.out or "BENCH_serve.json"
        write_report(doc, out)
        by_key = {(r["scenario"], r["serve_mode"]): r
                  for r in doc["results"]}
        cells = sorted({r["scenario"] for r in doc["results"]})
        warm_wins = sum(
            1 for c in cells
            if (by_key[(c, "warm")]["p50_us"] or 0)
            <= (by_key[(c, "auto")]["p50_us"] or 0))
        print(f"[bench] serve: {len(doc['results'])} records over "
              f"{len(cells)} class cells; warm p50 <= per-call auto p50 "
              f"on {warm_wins}/{len(cells)} -> {out}")
        return 0
    doc = run_suite(args.suite, iters=args.iters, warmup=args.warmup,
                    interpret=interpret, with_hlo=not args.no_hlo,
                    with_timing=not args.no_timing,
                    crosscheck=args.crosscheck,
                    progress=lambda msg: print(msg, file=sys.stderr))
    if args.format == "csv":
        for line in render_csv(doc):
            print(line)
        if args.out:
            write_report(doc, args.out)
        return 0
    out = args.out or f"BENCH_{args.suite}.json"
    write_report(doc, out)
    print(f"[bench] {args.suite}: {len(doc['results'])} cells -> {out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
