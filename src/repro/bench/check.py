"""Regression gate: compare a BENCH_<suite>.json against a baseline.

Per-metric policy (DESIGN.md §3):

* **exact** — ``overhead_elems``, ``overhead_bytes``, ``flops``,
  ``run_flops``, ``out_shape``, ``spec``, ``run_spec``, ``dtype``,
  ``auto_algorithm`` (skipped when the two backends differ — the auto
  dispatch branches on backend): analytic/deterministic; any drift is a real
  behaviour change (e.g. the Eq. 3 model or the auto dispatch rule
  changed) and fails the check.
* **tolerance** — ``us_per_call``: fails only when slower than baseline
  by more than ``--timing-rtol`` (default 1.0, i.e. 2x — CI machines are
  noisy).  ``--schema-only-on-timing`` skips timing comparison entirely
  (the CI perf-smoke job uses this: cross-runner wall-clock is not
  comparable, schema + exact fields still are).
* **informational** — ``hlo_flops``/``hlo_bytes``: printed when they
  drift (XLA version changes move them) but never fail the check.

Every baseline scenario/algorithm cell must be present in the new
report; missing cells fail (a suite silently losing coverage is itself
a regression).  Extra cells in the new report are fine.

Autotune documents (``BENCH_autotune.json``, detected by their
``autotune_schema_version``) get their own policy: exact on the
decision fields (``analytic_algorithm``, ``run_spec``, ``dtype``),
loud on newly-``skipped`` candidates, tolerance on the measured us
fields, and never-failing notes on the spread fields.

Exit status: 0 clean, 1 regression/schema failure, 2 usage error.

  PYTHONPATH=src python -m repro.bench.check BENCH_smoke.json \\
      --baseline benchmarks/baselines/smoke.json --schema-only-on-timing
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
from typing import Dict, List, Tuple

from repro.bench.report import result_key, validate_report

EXACT_FIELDS = ("dtype", "spec", "run_spec", "out_shape", "overhead_elems",
                "overhead_bytes", "flops", "run_flops", "auto_algorithm")

# Distributed-cell analytics (suite ``dist``): exact, but only gated when
# the baseline record carries them (schema_version 1 baselines predate
# these fields; ``n_dev_axes`` additionally predates composite 2-D cells).
# The serve-suite structural fields (DESIGN.md §9) gate the same way:
# the class set and request-stream bucketing are deterministic, so a
# drifted shape_class / request count is a behaviour change, while the
# serve latency fields (p50_us etc.) stay under the timing policy.
OPTIONAL_EXACT_FIELDS = ("partition", "n_dev", "n_dev_axes",
                         "halo_bytes_per_device",
                         "per_device_overhead_elems",
                         "comm_bytes_per_device", "auto_partition",
                         "serve_mode", "shape_class", "n_classes",
                         "n_requests", "shardcheck", "numcheck")

# Reports whose suite carries its own record schema (DESIGN.md §8) gate
# exactly on their *deterministic* fields only — verdicts, contracts,
# rendered violations — never on the measured/version-sensitive ones
# (probe errors move at the ulp level across jax/XLA releases, jaxpr
# dot/cast tallies move when jax changes its lowering).  These fields
# have no us_per_call/hlo_* either, so the timing policy is skipped.
SUITE_EXACT_FIELDS = {
    "numcheck": ("dtype", "spec", "source", "contract", "verdict",
                 "skipped_reason", "violations"),
    "shardcheck": ("dtype", "spec", "source", "partition", "n_dev",
                   "n_dev_axes", "verdict", "skipped_reason",
                   "violations"),
    "memaudit": ("dtype", "spec", "predicted_overhead_elems",
                 "predicted_overhead_bytes", "policy", "verdict"),
}


def _load(path) -> Dict:
    p = pathlib.Path(path)
    try:
        return json.loads(p.read_text())
    except FileNotFoundError:
        raise SystemExit(f"[bench.check] no such file: {p}")
    except json.JSONDecodeError as e:
        raise SystemExit(f"[bench.check] {p} is not valid JSON: {e}")


# Autotune documents (repro.bench.harness.run_autotune) carry their own
# schema; per cell these fields are deterministic given an environment +
# calibration and gate exactly, while measured decisions and anything
# us-valued follow the timing policy (noted / tolerance-checked).
AUTOTUNE_EXACT_FIELDS = ("dtype", "run_spec", "analytic_algorithm")
AUTOTUNE_SCHEMA_VERSIONS = (1, 2)


def _compare_autotune(new: Dict, baseline: Dict, timing_rtol: float,
                      schema_only_on_timing: bool
                      ) -> Tuple[List[str], List[str]]:
    """Autotune-report diff: exact on the decision fields, tolerance on
    the measured/spread fields, and — the satellite of DESIGN.md §10 —
    loud on coverage: a candidate newly ``skipped`` relative to the
    baseline is a real loss of the race, not noise."""
    failures: List[str] = []
    notes: List[str] = []
    for label, doc in (("new report", new), ("baseline", baseline)):
        v = doc.get("autotune_schema_version")
        if v not in AUTOTUNE_SCHEMA_VERSIONS:
            failures.append(f"schema ({label}): autotune_schema_version "
                            f"{v!r} not in {AUTOTUNE_SCHEMA_VERSIONS}")
        if not isinstance(doc.get("results"), list) or not doc.get("results"):
            failures.append(f"schema ({label}): results must be a "
                            "non-empty list")
    if failures:
        return failures, notes
    if new.get("base_suite") != baseline.get("base_suite"):
        failures.append(f"base_suite mismatch: new={new.get('base_suite')!r} "
                        f"baseline={baseline.get('base_suite')!r}")
        return failures, notes
    backend_differs = (new["environment"]["backend"]
                       != baseline["environment"]["backend"])
    exact = AUTOTUNE_EXACT_FIELDS
    if backend_differs:
        notes.append(f"backend differs: new={new['environment']['backend']} "
                     f"baseline={baseline['environment']['backend']} "
                     "(analytic_algorithm not compared)")
        exact = tuple(f for f in exact if f != "analytic_algorithm")
    if (new.get("calibration") or {}).get("active") != \
            (baseline.get("calibration") or {}).get("active"):
        notes.append(
            f"calibration active differs: new="
            f"{(new.get('calibration') or {}).get('active')!r} baseline="
            f"{(baseline.get('calibration') or {}).get('active')!r} "
            "(analytic picks may legitimately move)")
        exact = tuple(f for f in exact if f != "analytic_algorithm")
    key = lambda r: f"{r['scenario']}/{r.get('dtype')}"  # noqa: E731
    new_by_key = {key(r): r for r in new["results"]}
    for base in baseline["results"]:
        k = key(base)
        rec = new_by_key.get(k)
        if rec is None:
            failures.append(f"{k}: missing from new report "
                            "(coverage regression)")
            continue
        for f in exact:
            if rec.get(f) != base.get(f):
                failures.append(f"{k}: {f} changed {base.get(f)!r} -> "
                                f"{rec.get(f)!r}")
        for f in ("measured_algorithm", "pick_agrees"):
            if rec.get(f) != base.get(f):
                notes.append(f"{k}: {f} drifted {base.get(f)!r} -> "
                             f"{rec.get(f)!r} (measured; informational)")
        new_skips = set(rec.get("skipped") or {}) \
            - set(base.get("skipped") or {})
        if new_skips:
            failures.append(
                f"{k}: candidate(s) newly skipped vs baseline: "
                + ", ".join(f"{a} ({(rec.get('skipped') or {})[a]})"
                            for a in sorted(new_skips)))
        if schema_only_on_timing:
            continue
        for f in ("measured_us", "analytic_us"):
            b_us, n_us = base.get(f), rec.get(f)
            if b_us is None or n_us is None:
                continue
            if n_us > b_us * (1.0 + timing_rtol):
                failures.append(f"{k}: {f} regressed {b_us:.0f} -> "
                                f"{n_us:.0f} (> {1.0 + timing_rtol:.1f}x "
                                "baseline)")
        b_sp, n_sp = base.get("max_rel_spread"), rec.get("max_rel_spread")
        if b_sp is not None and n_sp is not None and n_sp > b_sp * 4 \
                and n_sp > 0.25:
            notes.append(f"{k}: max_rel_spread grew {b_sp} -> {n_sp} "
                         "(noisy run; spread fields never fail)")
    extra = set(new_by_key) - {key(r) for r in baseline["results"]}
    if extra:
        notes.append(f"{len(extra)} cells not in baseline (new coverage): "
                     + ", ".join(sorted(extra)[:5])
                     + ("..." if len(extra) > 5 else ""))
    return failures, notes


def compare(new: Dict, baseline: Dict, timing_rtol: float = 1.0,
            schema_only_on_timing: bool = False) -> Tuple[List[str], List[str]]:
    """(failures, notes) from diffing ``new`` against ``baseline``."""
    failures: List[str] = []
    notes: List[str] = []
    if "autotune_schema_version" in new \
            or "autotune_schema_version" in baseline:
        return _compare_autotune(new, baseline, timing_rtol,
                                 schema_only_on_timing)
    for label, doc in (("new report", new), ("baseline", baseline)):
        for err in validate_report(doc):
            failures.append(f"schema ({label}): {err}")
    if failures:
        return failures, notes
    if new["suite"] != baseline["suite"]:
        failures.append(f"suite mismatch: new={new['suite']!r} "
                        f"baseline={baseline['suite']!r}")
        return failures, notes
    if new["environment"]["jax"] != baseline["environment"]["jax"]:
        notes.append(f"jax version differs: new="
                     f"{new['environment']['jax']} baseline="
                     f"{baseline['environment']['jax']}")
    suite_schema = new["suite"] in SUITE_EXACT_FIELDS
    exact_fields = SUITE_EXACT_FIELDS.get(new["suite"], EXACT_FIELDS)
    if new["environment"]["backend"] != baseline["environment"]["backend"]:
        # auto dispatch branches on the backend (DESIGN.md §1), so across
        # backends its pick is expected to differ — don't gate on it.
        exact_fields = tuple(f for f in exact_fields
                             if f != "auto_algorithm")
        notes.append(f"backend differs: new="
                     f"{new['environment']['backend']} baseline="
                     f"{baseline['environment']['backend']} "
                     "(auto_algorithm not compared)")

    new_by_key = {result_key(r): r for r in new["results"]}
    for base in baseline["results"]:
        key = result_key(base)
        rec = new_by_key.get(key)
        if rec is None:
            failures.append(f"{key}: missing from new report "
                            "(coverage regression)")
            continue
        for f in exact_fields:
            if rec.get(f) != base.get(f):
                failures.append(f"{key}: {f} changed "
                                f"{base.get(f)!r} -> {rec.get(f)!r}")
        if suite_schema:
            # Suite-schema records carry no optional dist/serve block
            # and no timing fields — the exact set above is the whole
            # gate.
            continue
        for f in OPTIONAL_EXACT_FIELDS:
            if f in base and rec.get(f) != base[f]:
                failures.append(f"{key}: {f} changed "
                                f"{base[f]!r} -> {rec.get(f)!r}")
        for f in ("hlo_flops", "hlo_bytes"):
            if rec[f] != base[f]:
                notes.append(f"{key}: {f} drifted {base[f]!r} -> {rec[f]!r} "
                             "(informational)")
        if schema_only_on_timing:
            continue
        b_us, n_us = base["us_per_call"], rec["us_per_call"]
        if b_us is None or n_us is None:
            if (b_us is None) != (n_us is None):
                failures.append(f"{key}: us_per_call presence changed "
                                f"{b_us!r} -> {n_us!r}")
            continue
        if n_us > b_us * (1.0 + timing_rtol):
            failures.append(f"{key}: us_per_call regressed "
                            f"{b_us:.0f} -> {n_us:.0f} "
                            f"(> {1.0 + timing_rtol:.1f}x baseline)")
    extra = set(new_by_key) - {result_key(r) for r in baseline["results"]}
    if extra:
        notes.append(f"{len(extra)} cells not in baseline (new coverage): "
                     + ", ".join(sorted(extra)[:5])
                     + ("..." if len(extra) > 5 else ""))
    return failures, notes


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro.bench.check",
                                 description=__doc__.splitlines()[0])
    ap.add_argument("result", help="BENCH_<suite>.json to check")
    ap.add_argument("--baseline", required=True,
                    help="committed baseline to compare against")
    ap.add_argument("--timing-rtol", type=float, default=1.0,
                    help="allowed relative us_per_call slowdown "
                         "(default 1.0 == 2x)")
    ap.add_argument("--schema-only-on-timing", action="store_true",
                    help="skip timing comparison; schema + exact "
                         "(memory/flops) fields still gate")
    args = ap.parse_args(argv)

    new, baseline = _load(args.result), _load(args.baseline)
    failures, notes = compare(new, baseline, timing_rtol=args.timing_rtol,
                              schema_only_on_timing=args.schema_only_on_timing)
    for n in notes:
        print(f"[bench.check] note: {n}")
    if failures:
        for f in failures:
            print(f"[bench.check] FAIL: {f}", file=sys.stderr)
        print(f"[bench.check] {args.result}: {len(failures)} regression(s) "
              f"vs {args.baseline}", file=sys.stderr)
        return 1
    n_cells = len(baseline["results"])
    print(f"[bench.check] OK: {args.result} matches {args.baseline} "
          f"({n_cells} cells"
          + (", timing schema-only" if args.schema_only_on_timing else "")
          + ")")
    return 0


if __name__ == "__main__":
    sys.exit(main())
