"""Regression gate: compare a BENCH_<suite>.json against a baseline.

Per-metric policy (DESIGN.md §3):

* **exact** — ``overhead_elems``, ``overhead_bytes``, ``flops``,
  ``run_flops``, ``out_shape``, ``spec``, ``run_spec``, ``dtype``,
  ``auto_algorithm`` (skipped when the two backends differ — the auto
  dispatch branches on backend): analytic/deterministic; any drift is a real
  behaviour change (e.g. the Eq. 3 model or the auto dispatch rule
  changed) and fails the check.
* **tolerance** — ``us_per_call``: fails only when slower than baseline
  by more than ``--timing-rtol`` (default 1.0, i.e. 2x — CI machines are
  noisy).  ``--schema-only-on-timing`` skips timing comparison entirely
  (the CI perf-smoke job uses this: cross-runner wall-clock is not
  comparable, schema + exact fields still are).
* **informational** — ``hlo_flops``/``hlo_bytes``: printed when they
  drift (XLA version changes move them) but never fail the check.

Every baseline scenario/algorithm cell must be present in the new
report; missing cells fail (a suite silently losing coverage is itself
a regression).  Extra cells in the new report are fine.

Exit status: 0 clean, 1 regression/schema failure, 2 usage error.

  PYTHONPATH=src python -m repro.bench.check BENCH_smoke.json \\
      --baseline benchmarks/baselines/smoke.json --schema-only-on-timing
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
from typing import Dict, List, Tuple

from repro.bench.report import result_key, validate_report

EXACT_FIELDS = ("dtype", "spec", "run_spec", "out_shape", "overhead_elems",
                "overhead_bytes", "flops", "run_flops", "auto_algorithm")

# Distributed-cell analytics (suite ``dist``): exact, but only gated when
# the baseline record carries them (schema_version 1 baselines predate
# these fields; ``n_dev_axes`` additionally predates composite 2-D cells).
# The serve-suite structural fields (DESIGN.md §9) gate the same way:
# the class set and request-stream bucketing are deterministic, so a
# drifted shape_class / request count is a behaviour change, while the
# serve latency fields (p50_us etc.) stay under the timing policy.
OPTIONAL_EXACT_FIELDS = ("partition", "n_dev", "n_dev_axes",
                         "halo_bytes_per_device",
                         "per_device_overhead_elems",
                         "comm_bytes_per_device", "auto_partition",
                         "serve_mode", "shape_class", "n_classes",
                         "n_requests")


def _load(path) -> Dict:
    p = pathlib.Path(path)
    try:
        return json.loads(p.read_text())
    except FileNotFoundError:
        raise SystemExit(f"[bench.check] no such file: {p}")
    except json.JSONDecodeError as e:
        raise SystemExit(f"[bench.check] {p} is not valid JSON: {e}")


def compare(new: Dict, baseline: Dict, timing_rtol: float = 1.0,
            schema_only_on_timing: bool = False) -> Tuple[List[str], List[str]]:
    """(failures, notes) from diffing ``new`` against ``baseline``."""
    failures: List[str] = []
    notes: List[str] = []
    for label, doc in (("new report", new), ("baseline", baseline)):
        for err in validate_report(doc):
            failures.append(f"schema ({label}): {err}")
    if failures:
        return failures, notes
    if new["suite"] != baseline["suite"]:
        failures.append(f"suite mismatch: new={new['suite']!r} "
                        f"baseline={baseline['suite']!r}")
        return failures, notes
    if new["environment"]["jax"] != baseline["environment"]["jax"]:
        notes.append(f"jax version differs: new="
                     f"{new['environment']['jax']} baseline="
                     f"{baseline['environment']['jax']}")
    exact_fields = EXACT_FIELDS
    if new["environment"]["backend"] != baseline["environment"]["backend"]:
        # auto dispatch branches on the backend (DESIGN.md §1), so across
        # backends its pick is expected to differ — don't gate on it.
        exact_fields = tuple(f for f in EXACT_FIELDS
                             if f != "auto_algorithm")
        notes.append(f"backend differs: new="
                     f"{new['environment']['backend']} baseline="
                     f"{baseline['environment']['backend']} "
                     "(auto_algorithm not compared)")

    new_by_key = {result_key(r): r for r in new["results"]}
    for base in baseline["results"]:
        key = result_key(base)
        rec = new_by_key.get(key)
        if rec is None:
            failures.append(f"{key}: missing from new report "
                            "(coverage regression)")
            continue
        for f in exact_fields:
            if rec[f] != base[f]:
                failures.append(f"{key}: {f} changed "
                                f"{base[f]!r} -> {rec[f]!r}")
        for f in OPTIONAL_EXACT_FIELDS:
            if f in base and rec.get(f) != base[f]:
                failures.append(f"{key}: {f} changed "
                                f"{base[f]!r} -> {rec.get(f)!r}")
        for f in ("hlo_flops", "hlo_bytes"):
            if rec[f] != base[f]:
                notes.append(f"{key}: {f} drifted {base[f]!r} -> {rec[f]!r} "
                             "(informational)")
        if schema_only_on_timing:
            continue
        b_us, n_us = base["us_per_call"], rec["us_per_call"]
        if b_us is None or n_us is None:
            if (b_us is None) != (n_us is None):
                failures.append(f"{key}: us_per_call presence changed "
                                f"{b_us!r} -> {n_us!r}")
            continue
        if n_us > b_us * (1.0 + timing_rtol):
            failures.append(f"{key}: us_per_call regressed "
                            f"{b_us:.0f} -> {n_us:.0f} "
                            f"(> {1.0 + timing_rtol:.1f}x baseline)")
    extra = set(new_by_key) - {result_key(r) for r in baseline["results"]}
    if extra:
        notes.append(f"{len(extra)} cells not in baseline (new coverage): "
                     + ", ".join(sorted(extra)[:5])
                     + ("..." if len(extra) > 5 else ""))
    return failures, notes


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro.bench.check",
                                 description=__doc__.splitlines()[0])
    ap.add_argument("result", help="BENCH_<suite>.json to check")
    ap.add_argument("--baseline", required=True,
                    help="committed baseline to compare against")
    ap.add_argument("--timing-rtol", type=float, default=1.0,
                    help="allowed relative us_per_call slowdown "
                         "(default 1.0 == 2x)")
    ap.add_argument("--schema-only-on-timing", action="store_true",
                    help="skip timing comparison; schema + exact "
                         "(memory/flops) fields still gate")
    args = ap.parse_args(argv)

    new, baseline = _load(args.result), _load(args.baseline)
    failures, notes = compare(new, baseline, timing_rtol=args.timing_rtol,
                              schema_only_on_timing=args.schema_only_on_timing)
    for n in notes:
        print(f"[bench.check] note: {n}")
    if failures:
        for f in failures:
            print(f"[bench.check] FAIL: {f}", file=sys.stderr)
        print(f"[bench.check] {args.result}: {len(failures)} regression(s) "
              f"vs {args.baseline}", file=sys.stderr)
        return 1
    n_cells = len(baseline["results"])
    print(f"[bench.check] OK: {args.result} matches {args.baseline} "
          f"({n_cells} cells"
          + (", timing schema-only" if args.schema_only_on_timing else "")
          + ")")
    return 0


if __name__ == "__main__":
    sys.exit(main())
