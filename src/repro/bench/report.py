"""BENCH_<suite>.json: schema, environment fingerprint, validation, CSV.

The schema is deliberately flat — one record per (scenario, algorithm)
cell — so ``repro.bench.check`` can diff two reports key-by-key and CI
artifacts stay greppable.  Validation is hand-rolled (the container
ships no ``jsonschema``) but strict: unknown suites, missing fields, or
wrongly-typed metrics all fail loudly *before* a report is written, so
a committed baseline can never be malformed.
"""
from __future__ import annotations

import json
import pathlib
import platform
from typing import Dict, List, Optional, Sequence

SCHEMA_VERSION = 1

_NUM = (int, float)
_OPT_NUM = (int, float, type(None))

# field -> allowed types; every result record must carry all of them.
RESULT_FIELDS = {
    "scenario": str,
    "algorithm": str,
    "dtype": str,
    "weight": int,
    "spec": dict,
    "run_spec": dict,
    "overhead_elems": int,
    "overhead_bytes": int,
    "flops": _NUM,
    "run_flops": _NUM,
    "auto_algorithm": str,
    "out_shape": list,
    "us_per_call": _OPT_NUM,
    "timing": (dict, type(None)),
    "hlo_flops": _OPT_NUM,
    "hlo_bytes": _OPT_NUM,
}

# Distributed-cell fields (suite ``dist``, DESIGN.md §6): optional so
# schema_version 1 baselines stay valid, but type-checked when present
# and emitted as a block (partition present => all present).  Composite
# 2-D cells serialize the component tuple as "batch+spatial" and their
# per-sub-axis split as n_dev_axes (n_dev stays the device product).
OPTIONAL_RESULT_FIELDS = {
    "partition": str,
    "n_dev": int,
    "n_dev_axes": list,
    "halo_bytes_per_device": _NUM,
    "per_device_overhead_elems": _NUM,
    "comm_bytes_per_device": _NUM,
    "auto_partition": (str, type(None)),
    # The resolved ConvPlan for the cell's scenario (repro.plan,
    # DESIGN.md §7) — informational here; the committed
    # benchmarks/baselines/plans.json gates the decision fields.
    "plan": dict,
    # Serve-suite fields (repro.serving.conv_service, DESIGN.md §9):
    # one record per (shape class, serving mode).  The structural
    # fields (serve_mode, shape_class, n_classes, n_requests) are
    # deterministic and exact-gated by check.py; the latency/throughput
    # fields follow the timing policy (schema-only on CI).
    "serve_mode": str,
    "shape_class": str,
    "n_classes": int,
    "n_requests": int,
    "p50_us": _OPT_NUM,
    "p99_us": _OPT_NUM,
    "first_request_us": _OPT_NUM,
    "throughput_rps": _OPT_NUM,
    "warmup_warnings": int,
    "plan_cache_io_errors": int,
    # Collective-contract verdict for a partitioned cell
    # (repro.analysis.shardcheck, DESIGN.md §8): the full check record —
    # per-direction expected/observed collective bytes, precision-flow
    # tally, verdict, rendered violations.  Exact-gated by check.py when
    # the baseline carries it.
    "shardcheck": dict,
    # Numeric-contract verdict for any with-HLO cell
    # (repro.analysis.numcheck, DESIGN.md §8.5): the reduced static
    # record — verdict, skipped_reason, rendered violations.  Exact-gated
    # by check.py when the baseline carries it.
    "numcheck": dict,
}

# Fields newer than the first dist baselines: type-checked when present
# but NOT required by the partition-present block rule, so a
# pre-composite baseline still validates (and check.py can gate it
# leniently as promised).  The serve-suite fields are likewise outside
# the partition block (they form their own serve_mode-keyed block).
_BLOCK_EXEMPT_FIELDS = ("n_dev_axes", "plan", "serve_mode", "shape_class",
                        "n_classes", "n_requests", "p50_us", "p99_us",
                        "first_request_us", "throughput_rps",
                        "warmup_warnings", "plan_cache_io_errors",
                        "shardcheck", "numcheck")

# Suite "memaudit" (repro.analysis.memaudit, DESIGN.md §8): one record
# per audited (scenario, algorithm) cell — XLA's measured temp bytes vs.
# the Eq. 2-4 prediction.  measured_*/ratio/slack are None when the
# backend exposes no memory stats; verdict is "pass"/"fail"/"recorded"
# and policy says whether the cell was tolerance-gated at all.
MEMAUDIT_RESULT_FIELDS = {
    "scenario": str,
    "algorithm": str,
    "dtype": str,
    "spec": dict,
    "predicted_overhead_elems": int,
    "predicted_overhead_bytes": int,
    "measured_temp_bytes": _OPT_NUM,
    "measured_argument_bytes": _OPT_NUM,
    "measured_output_bytes": _OPT_NUM,
    "ratio": _OPT_NUM,
    "slack_bytes": _OPT_NUM,
    "tolerance": dict,
    "policy": str,
    "source": (str, type(None)),
    "verdict": str,
}

# Suite "shardcheck" (repro.analysis.shardcheck, DESIGN.md §8): one
# record per partitioned (scenario, algorithm) cell of the committed
# dist/plans baselines — the collective contract (expected vs observed
# per-collective bytes, both VJP directions) plus the precision-flow
# tally.  verdict is "pass"/"fail"/"skipped"; skipped cells say why
# (e.g. the baseline mesh needs more devices than the checker forces).
SHARDCHECK_RESULT_FIELDS = {
    "scenario": str,
    "algorithm": str,
    "dtype": str,
    "spec": dict,
    "source": str,
    "partition": str,
    "n_dev": int,
    "n_dev_axes": list,
    "directions": dict,
    "precision_flow": (dict, type(None)),
    "verdict": str,
    "skipped_reason": (str, type(None)),
    "violations": list,
}

# Suite "numcheck" (repro.analysis.numcheck, DESIGN.md §8.5): one
# record per (backend variant, dtype) cell on the fixed probe spec —
# the backend's declared numeric contract, per-direction signature
# counts (dots, in-kernel dots, casts, narrows back to the input
# dtype), the precision-flow tally when a precision was declared, and
# the measured fwd/grad error vs the f64 reference beside its contract
# budget.  verdict is "pass"/"fail"/"skipped"; skipped cells say why
# (winograd off-geometry, Pallas-rejected, unregistered backend/dtype).
NUMCHECK_RESULT_FIELDS = {
    "scenario": str,
    "algorithm": str,
    "dtype": str,
    "spec": dict,
    "source": str,
    "contract": (dict, type(None)),
    "directions": dict,
    "precision_flow": (dict, type(None)),
    "probe": (dict, type(None)),
    "verdict": str,
    "skipped_reason": (str, type(None)),
    "violations": list,
}

# suite name -> required per-record fields; unknown suites use the
# default timing schema above.
RESULT_FIELDS_BY_SUITE = {"memaudit": MEMAUDIT_RESULT_FIELDS,
                          "shardcheck": SHARDCHECK_RESULT_FIELDS,
                          "numcheck": NUMCHECK_RESULT_FIELDS}

SPEC_FIELDS = ("i_n", "i_h", "i_w", "i_c", "k_h", "k_w", "k_c", "s_h", "s_w")

ENV_FIELDS = ("jax", "numpy", "python", "backend", "device_count", "platform")


def environment_fingerprint() -> Dict:
    """Everything needed to judge whether two reports are comparable."""
    import jax
    import numpy as np
    return {
        "jax": jax.__version__,
        "numpy": np.__version__,
        "python": platform.python_version(),
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "platform": platform.platform(),
    }


def make_report(suite: str, results: Sequence[Dict], harness: Dict,
                crosscheck: Optional[List[Dict]] = None) -> Dict:
    doc = {
        "schema_version": SCHEMA_VERSION,
        "suite": suite,
        "environment": environment_fingerprint(),
        "harness": harness,
        "results": list(results),
    }
    if crosscheck is not None:
        doc["crosscheck"] = crosscheck
    errors = validate_report(doc)
    if errors:
        raise ValueError("refusing to emit invalid report:\n  "
                         + "\n  ".join(errors))
    return doc


def validate_report(doc: Dict) -> List[str]:
    """All schema violations (empty list == valid)."""
    errs: List[str] = []
    if not isinstance(doc, dict):
        return ["report is not a JSON object"]
    if doc.get("schema_version") != SCHEMA_VERSION:
        errs.append(f"schema_version must be {SCHEMA_VERSION}, "
                    f"got {doc.get('schema_version')!r}")
    if not isinstance(doc.get("suite"), str) or not doc.get("suite"):
        errs.append("suite must be a non-empty string")
    env = doc.get("environment")
    if not isinstance(env, dict):
        errs.append("environment must be an object")
    else:
        for k in ENV_FIELDS:
            if k not in env:
                errs.append(f"environment missing {k!r}")
    if not isinstance(doc.get("harness"), dict):
        errs.append("harness must be an object")
    results = doc.get("results")
    if not isinstance(results, list) or not results:
        return errs + ["results must be a non-empty list"]
    fields = RESULT_FIELDS_BY_SUITE.get(doc.get("suite"), RESULT_FIELDS)
    seen = set()
    for i, rec in enumerate(results):
        where = f"results[{i}]"
        if not isinstance(rec, dict):
            errs.append(f"{where} is not an object")
            continue
        for field, types in fields.items():
            if field not in rec:
                errs.append(f"{where} missing {field!r}")
            elif not isinstance(rec[field], types) \
                    or isinstance(rec[field], bool):
                errs.append(f"{where}.{field} has type "
                            f"{type(rec[field]).__name__}")
        if fields is RESULT_FIELDS:
            # The optional-field types and the dist-block rule are about
            # the default timing schema; suites with their own schema
            # (memaudit, shardcheck) define field semantics above.
            for field, types in OPTIONAL_RESULT_FIELDS.items():
                if field in rec and (not isinstance(rec[field], types)
                                     or isinstance(rec[field], bool)):
                    errs.append(f"{where}.{field} has type "
                                f"{type(rec[field]).__name__}")
            if "partition" in rec:
                missing = [f for f in OPTIONAL_RESULT_FIELDS
                           if f not in rec and f not in _BLOCK_EXEMPT_FIELDS]
                if missing:
                    errs.append(f"{where}: distributed cell missing "
                                f"{missing}")
        if "serve_mode" in rec:
            missing = [f for f in ("shape_class", "n_classes", "n_requests",
                                   "warmup_warnings",
                                   "plan_cache_io_errors")
                       if f not in rec]
            if missing:
                errs.append(f"{where}: serve cell missing {missing}")
        for sf in ("spec", "run_spec"):
            spec = rec.get(sf)
            if isinstance(spec, dict):
                missing = [k for k in SPEC_FIELDS
                           if not isinstance(spec.get(k), int)]
                if missing:
                    errs.append(f"{where}.{sf} missing int fields {missing}")
        key = (rec.get("scenario"), rec.get("algorithm"))
        if key in seen:
            errs.append(f"{where}: duplicate (scenario, algorithm) {key}")
        seen.add(key)
    return errs


def result_key(rec: Dict) -> str:
    return f"{rec['scenario']}/{rec['algorithm']}"


def write_report(doc: Dict, path) -> pathlib.Path:
    path = pathlib.Path(path)
    errors = validate_report(doc)
    if errors:
        raise ValueError("refusing to write invalid report:\n  "
                         + "\n  ".join(errors))
    path.write_text(json.dumps(doc, indent=2, sort_keys=False) + "\n")
    return path


def render_csv(doc: Dict) -> List[str]:
    """Legacy ``table,name,us_per_call,derived`` lines (benchmarks/run.py
    printed exactly this shape before the registry existed)."""
    lines = ["table,name,us_per_call,derived"]
    for rec in doc["results"]:
        us = rec["us_per_call"]
        derived = (f"overhead_bytes={rec['overhead_bytes']};"
                   f"flops={rec['flops']:.3e};auto={rec['auto_algorithm']}")
        if rec["hlo_flops"] is not None:
            derived += f";hlo_flops={rec['hlo_flops']:.3e}"
        lines.append(f"{doc['suite']},{result_key(rec)},"
                     f"{0 if us is None else us:.0f},{derived}")
    return lines
