"""Scenario registry: the paper's evaluation as first-class data.

A :class:`Scenario` names one convolution geometry and the set of
``conv2d`` algorithm variants to run on it.  Each carries two specs:

* ``spec``      — the exact paper geometry; analytic metrics (memory
  overhead, flops) are always computed on this, so they stay comparable
  to the paper regardless of how the scenario is *timed*;
* ``run_spec``  — the geometry actually timed.  On this single-core
  container the full-channel paper layers take minutes, so timed runs
  cap channels (geometry preserved) exactly as ``benchmarks/
  conv_runtime.py`` always did; ``run_spec == spec`` where affordable.

Suites (resolve with :func:`resolve_suite`):

===============  ===========================================================
``table2``       paper Table 2, ``cv1``–``cv12``, every algorithm
``resnet101``    Table 3's ResNet-101 layers with occurrence weights
``ks_sweep``     Fig 4(a): cv1 geometry, stride swept 1..10, MEC vs im2col
``batch``        batch-size diversity (cv9 at n = 1/4/16)
``channels``     channel-count diversity (cv12 geometry, widths 32..512)
``dtype``        dtype diversity (cv9 in f32 and bf16)
``smoke``        CI subset: 3 small layers x all algorithms plus a
                 ``w_blk``-tuning Pallas cell, < 2 min
``dist``         distributed execution (DESIGN.md §6): per-device
                 overhead + halo-bytes analytics on 2/8/256-way spatial
                 partitions of cv1-cv12 and on composite 2-D partitions
                 (batch x spatial / batch x channel / spatial x channel
                 over two mesh axes), plus 2-device smoke cells (one per
                 1-D partition mode) and 2x2-device composite smoke
                 cells that are actually timed when the process has
                 enough devices
===============  ===========================================================
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Tuple, Union

from repro.core.convspec import ConvSpec

# Paper Table 2: name -> (i_h, i_w, i_c, k_h, k_w, o_c, stride).
# This is the canonical copy; benchmarks/convbench.py re-exports it.
CV_LAYERS = {
    "cv1": (227, 227, 3, 11, 11, 96, 4),
    "cv2": (231, 231, 3, 11, 11, 96, 4),
    "cv3": (227, 227, 3, 7, 7, 64, 2),
    "cv4": (224, 224, 64, 7, 7, 64, 2),
    "cv5": (24, 24, 96, 5, 5, 256, 1),
    "cv6": (12, 12, 256, 3, 3, 512, 1),
    "cv7": (224, 224, 3, 3, 3, 64, 1),
    "cv8": (112, 112, 64, 3, 3, 128, 1),
    "cv9": (56, 56, 64, 3, 3, 64, 1),
    "cv10": (28, 28, 128, 3, 3, 128, 1),
    "cv11": (14, 14, 256, 3, 3, 256, 1),
    "cv12": (7, 7, 512, 3, 3, 512, 1),
}

# Paper Table 3: ResNet-101 layer occurrence counts.
RESNET101_WEIGHTS = {"cv4": 1, "cv9": 3, "cv10": 4, "cv11": 23, "cv12": 3}

# conv2d dispatch variants: bench name -> conv2d(**kwargs).  mecA/mecB are
# the paper's Solution A/B of the reference Algorithm 2; the mec_* names
# are the Pallas kernels (DESIGN.md §2).
ALGORITHM_VARIANTS: Dict[str, Dict[str, str]] = {
    "direct": {"algorithm": "direct"},
    "im2col": {"algorithm": "im2col"},
    "fft": {"algorithm": "fft"},
    "winograd": {"algorithm": "winograd"},
    "mecA": {"algorithm": "mec", "solution": "A"},
    "mecB": {"algorithm": "mec", "solution": "B"},
    "mec_lowered": {"algorithm": "mec_lowered"},
    "mec_fused": {"algorithm": "mec_fused"},
    "mec_fused2": {"algorithm": "mec_fused2"},
}

ALL_VARIANTS = tuple(ALGORITHM_VARIANTS)
# Cheap cross-section for the diversity suites (reference + one Pallas).
CORE_VARIANTS = ("direct", "im2col", "mecA", "mec_fused")


def eligible_algorithms(spec: ConvSpec, names=ALL_VARIANTS) -> Tuple[str, ...]:
    """Filter variant names by geometry (winograd is 3x3/stride-1 only)."""
    ok = []
    for n in names:
        if n == "winograd" and \
                (spec.k_h, spec.k_w, spec.s_h, spec.s_w) != (3, 3, 1, 1):
            continue
        ok.append(n)
    return tuple(ok)


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One geometry x algorithm-set cell of a suite."""

    name: str
    spec: ConvSpec                 # exact paper geometry (analytic metrics)
    run_spec: ConvSpec             # geometry actually timed
    algorithms: Tuple[str, ...]
    dtype: str = "float32"
    weight: int = 1                # Table-3 occurrence count (else 1)
    # Distributed cells (suite ``dist``): partition mode + device count.
    # Composite 2-D cells carry a component tuple (from
    # ``parallel.conv.COMPOSITE_PARTITIONS``) and a matching per-sub-axis
    # device tuple.  Analytic per-device/halo fields are always emitted
    # for these; timing additionally needs prod(n_dev) <=
    # jax.device_count().
    partition: Union[str, Tuple[str, ...], None] = None
    n_dev: Union[int, Tuple[int, ...]] = 1
    # Measured-mode candidate restriction (DESIGN.md §10): when set, the
    # autotune suite races exactly these ``conv2d`` algorithm names
    # instead of every eligible candidate.  Kernel-tuning cells use it
    # to keep the stage-1 race inside the Pallas variants so the stage-2
    # knob grid (``w_blk``) is what the cell exercises.  Names here are
    # executor algorithm names ("mec", "mec_fused", ...), not the
    # mecA/mecB bench-variant names.
    tune_candidates: Union[Tuple[str, ...], None] = None


def layer_spec(name: str, batch: int = 1,
               channel_cap: int | None = None) -> ConvSpec:
    """ConvSpec for a Table 2 layer, optionally channel-capped."""
    ih, iw, ic, kh, kw, oc, s = CV_LAYERS[name]
    if channel_cap:
        ic, oc = min(ic, channel_cap), min(oc, channel_cap)
    return ConvSpec(batch, ih, iw, ic, kh, kw, oc, s, s)


def _layer_scenario(name: str, batch: int = 1, channel_cap: int | None = 16,
                    algorithms=ALL_VARIANTS, dtype: str = "float32",
                    weight: int = 1, tag: str = "") -> Scenario:
    spec = layer_spec(name, batch=batch)
    return Scenario(name=name + tag, spec=spec,
                    run_spec=layer_spec(name, batch=batch,
                                        channel_cap=channel_cap),
                    algorithms=eligible_algorithms(spec, algorithms),
                    dtype=dtype, weight=weight)


def _table2() -> Tuple[Scenario, ...]:
    return tuple(_layer_scenario(n) for n in CV_LAYERS)


def _resnet101() -> Tuple[Scenario, ...]:
    return tuple(_layer_scenario(n, weight=w, algorithms=CORE_VARIANTS
                                 + ("mecB",))
                 for n, w in RESNET101_WEIGHTS.items())


def _ks_sweep() -> Tuple[Scenario, ...]:
    # Fig 4(a): cv1's 11x11 kernel, stride 1..10 — the k/s ratio drives
    # both the Eq. 4 memory saving and the runtime gap vs im2col.
    out = []
    for s in range(1, 11):
        spec = ConvSpec(1, 227, 227, 3, 11, 11, 96, s, s)
        run = ConvSpec(1, 227, 227, 3, 11, 11, 8, s, s)
        out.append(Scenario(name=f"cv1_s{s}", spec=spec, run_spec=run,
                            algorithms=("mecA", "im2col")))
    return tuple(out)


def _batch() -> Tuple[Scenario, ...]:
    return tuple(_layer_scenario("cv9", batch=b, tag=f"_b{b}")
                 for b in (1, 4, 16))


def _channels() -> Tuple[Scenario, ...]:
    # cv12's 7x7 plane is small enough to run the paper's channel widths
    # un-capped; sweep width to see where each lowering pays off.
    out = []
    for c in (32, 128, 512):
        spec = ConvSpec(1, 7, 7, c, 3, 3, c, 1, 1)
        out.append(Scenario(name=f"cv12_c{c}", spec=spec, run_spec=spec,
                            algorithms=eligible_algorithms(spec)))
    return tuple(out)


def _dtype() -> Tuple[Scenario, ...]:
    return tuple(_layer_scenario("cv9", dtype=d, tag=f"_{tag}",
                                 algorithms=CORE_VARIANTS)
                 for d, tag in (("float32", "f32"), ("bfloat16", "bf16")))


def _smoke() -> Tuple[Scenario, ...]:
    # Three small layers x every algorithm plus one kernel-tuning cell,
    # sized so the full suite (including interpret-mode Pallas) stays
    # well under 2 minutes on one CPU core: a winograd-eligible 3x3/s1,
    # a strided 5x5, a cv1-shaped 11x11/s4, and a wide row whose o_w
    # exceeds the w_blk accumulator cap.
    shapes = {
        "s3x3": ConvSpec(1, 14, 14, 4, 3, 3, 8, 1, 1),
        "s5x5": ConvSpec(1, 16, 16, 3, 5, 5, 8, 2, 2),
        "s11x11": ConvSpec(1, 23, 23, 3, 11, 11, 8, 4, 4),
    }
    cells = [Scenario(name=n, spec=s, run_spec=s,
                      algorithms=eligible_algorithms(s))
             for n, s in shapes.items()]
    # Kernel-tuning cell (DESIGN.md §10): o_w=520 sits just above
    # pick_w_blk's 512-column accumulator cap, so the planner default
    # splits the row into two grid steps while the stage-2 grid's
    # min(o_w, 2*default)=520 trial covers it in one — a structural
    # (grid-step count) gap the measured tuner must find, independent of
    # timer jitter.  The race is restricted to the Pallas variants so
    # stage 2 tunes w_blk rather than re-litigating the algorithm pick.
    w520 = ConvSpec(1, 3, 522, 3, 3, 3, 8, 1, 1)
    cells.append(Scenario(
        name="w520", spec=w520, run_spec=w520,
        algorithms=("mec_lowered", "mec_fused", "mec_fused2"),
        tune_candidates=("mec_lowered", "mec_fused", "mec_fused2")))
    return tuple(cells)


def _dist() -> Tuple[Scenario, ...]:
    # Analytic sweep: every Table-2 layer under 2/8/256-way spatial
    # partitioning (mecB — the paper's parallel Solution — is the
    # algorithm the per-device Eq. 3 overhead describes).  These cells
    # are never timed at 8/256-way on CI; their per-device overhead,
    # halo-bytes and comm-bytes fields are the deliverable and are gated
    # exactly by repro.bench.check.
    out = []
    for n_dev in (2, 8, 256):
        for layer in CV_LAYERS:
            spec = layer_spec(layer)
            out.append(Scenario(
                name=f"{layer}_d{n_dev}", spec=spec,
                run_spec=layer_spec(layer, channel_cap=16),
                algorithms=("mecB",), partition="spatial", n_dev=n_dev))
    # Composite 2-D analytic sweep (DESIGN.md §6 "composite partitions"):
    # batch x spatial for every Table-2 layer at batch 8 over a 2x2 mesh
    # tile, plus batch x channel on the channel-heavy layers and
    # spatial x channel on the large-plane layers.  Like the 1-D sweep
    # these are never timed at scale on CI — the per-device overhead /
    # halo / comm analytics are the deliverable, gated exactly.
    for layer in CV_LAYERS:
        out.append(Scenario(
            name=f"{layer}_bs2x2", spec=layer_spec(layer, batch=8),
            run_spec=layer_spec(layer, batch=8, channel_cap=16),
            algorithms=("mecB",), partition=("batch", "spatial"),
            n_dev=(2, 2)))
    for layer, n_dev in (("cv5", (2, 4)), ("cv6", (2, 4)),
                         ("cv12", (2, 4))):
        out.append(Scenario(
            name=f"{layer}_bc{n_dev[0]}x{n_dev[1]}",
            spec=layer_spec(layer, batch=8),
            run_spec=layer_spec(layer, batch=8, channel_cap=16),
            algorithms=("mecB",), partition=("batch", "channel"),
            n_dev=n_dev))
    for layer in ("cv4", "cv8"):
        out.append(Scenario(
            name=f"{layer}_sc2x2", spec=layer_spec(layer),
            run_spec=layer_spec(layer, channel_cap=16),
            algorithms=("mecB",), partition=("spatial", "channel"),
            n_dev=(2, 2)))
    # CI-affordable 2-device smoke cells: tiny geometry every partition
    # mode can split, actually executed + timed when the process has two
    # devices, plus 2x2 composite smoke cells timed under four (CI
    # forces --xla_force_host_platform_device_count=4).
    small = ConvSpec(2, 16, 16, 4, 3, 3, 8, 1, 1)
    for part in ("batch", "channel", "spatial"):
        out.append(Scenario(
            name=f"smoke2_{part}", spec=small, run_spec=small,
            algorithms=("mecB", "mec_fused"), partition=part, n_dev=2))
    for comp in (("batch", "spatial"), ("batch", "channel"),
                 ("spatial", "channel")):
        out.append(Scenario(
            name=f"smoke4_{comp[0]}_{comp[1]}", spec=small, run_spec=small,
            algorithms=("mecB", "mec_fused"), partition=comp, n_dev=(2, 2)))
    return tuple(out)


# ---------------------------------------------------------------------------
# serve suite (repro.serving.conv_service, DESIGN.md §9)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ServeScenario:
    """One conv-serving cell: a fixed kernel geometry, a bounded set of
    padded shape classes, and a deterministic mixed-shape request stream
    cycled ``n_requests`` times.  ``harness.run_serve`` serves the same
    stream under three policies — ``warm`` (plans resolved + executors
    AOT-compiled at startup), ``cold`` (lazy per-class resolution on
    first hit), and ``auto`` (per-call eager ``algorithm="auto"``
    dispatch, the pre-planner serving baseline) — and emits one record
    per (shape class, policy)."""

    name: str
    kernel_shape: Tuple[int, int, int, int]     # (k_h, k_w, i_c, k_c)
    stride: Tuple[int, int]
    padding: Union[str, Tuple]                  # size-independent only
    classes: Tuple[Tuple[int, int, int], ...]   # (n, h, w) padded classes
    requests: Tuple[Tuple[int, int, int], ...]  # request shapes, cycled
    n_requests: int = 24
    dtype: str = "float32"


def serve_cells() -> Tuple[ServeScenario, ...]:
    # Three smoke-sized services, each exercising a distinct frontend
    # shape: a whisper-style conv1d (h = time), a ViT patch embed, and a
    # general strided 2-D conv with batch diversity.  Sized so all three
    # policies x the full stream stay well inside the serve-smoke CI
    # budget on one CPU core.
    return (
        ServeScenario(
            name="mel1d", kernel_shape=(3, 1, 8, 16), stride=(1, 1),
            padding=((1, 1), (0, 0)),
            classes=((1, 16, 1), (1, 32, 1)),
            requests=((1, 10, 1), (1, 16, 1), (1, 23, 1), (1, 32, 1))),
        ServeScenario(
            name="patch4", kernel_shape=(4, 4, 3, 8), stride=(4, 4),
            padding="VALID",
            classes=((1, 16, 16), (1, 32, 32)),
            requests=((1, 12, 12), (1, 16, 16), (1, 24, 20), (1, 32, 32))),
        ServeScenario(
            name="s3x3", kernel_shape=(3, 3, 4, 8), stride=(2, 2),
            padding=1,
            classes=((1, 12, 12), (2, 16, 16)),
            requests=((1, 9, 11), (1, 12, 12), (2, 13, 16), (2, 16, 16))),
    )


SUITES: Dict[str, Callable[[], Tuple[Scenario, ...]]] = {
    "table2": _table2,
    "resnet101": _resnet101,
    "ks_sweep": _ks_sweep,
    "batch": _batch,
    "channels": _channels,
    "dtype": _dtype,
    "smoke": _smoke,
    "dist": _dist,
}


def resolve_suite(name: str) -> Tuple[Scenario, ...]:
    if name not in SUITES:
        raise KeyError(f"unknown suite {name!r}; expected one of "
                       f"{sorted(SUITES)}")
    scenarios = SUITES[name]()
    seen = set()
    for sc in scenarios:
        if sc.name in seen:
            raise ValueError(f"suite {name!r}: duplicate scenario {sc.name!r}")
        seen.add(sc.name)
        if not sc.algorithms:
            raise ValueError(f"suite {name!r}: {sc.name!r} has no algorithms")
    return scenarios
