"""Plan-cache CLI: build the resolved plans for bench suites and diff
them against a committed baseline (CI's costmodel-drift gate).

  PYTHONPATH=src python -m repro.plan --suites smoke,table2 \\
      --out plans.json [--baseline benchmarks/baselines/plans.json] \\
      [--calibration benchmarks/baselines/calibration.json]

  PYTHONPATH=src python -m repro.plan calibrate --report|--check|--fit

The baseline diff is exact on the *decision* fields — ``algorithm``,
``solution``, ``partition``, ``partition_axes`` — mirroring
``repro.bench.check``'s stance on analytic fields: a costmodel change
that flips any pick fails loudly and the baseline must be regenerated
on purpose.  ``w_blk`` is device-dependent and only noted.
``--calibration`` pins the fitted costmodel (DESIGN.md §10) the picks
consult, so a committed calibrated baseline reproduces on machines with
an empty store; the default is the ambient store.  The ``calibrate``
subcommand (``repro.plan.calibrate``) reports/gates/builds the
coefficient file itself.  Exit status: 0 clean, 1 drift/schema failure,
2 usage error.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
from typing import Dict, List, Tuple

PLANS_SCHEMA_VERSION = 1

# Decision fields gated exactly; everything else in the plan dict is
# context (spec/dtype/backend identify the cell) or device-tuned (w_blk).
EXACT_PLAN_FIELDS = ("algorithm", "solution", "partition", "partition_axes")
NOTE_PLAN_FIELDS = ("w_blk", "precision")


def build_plans(suites, mode: str = "analytic",
                calibration="ambient", calibration_path=None) -> Dict:
    from repro.bench.report import environment_fingerprint
    from repro.bench.scenarios import resolve_suite
    from repro.plan import current_calibration, plan_conv2d
    active = (current_calibration() is not None
              if calibration == "ambient" else calibration is not None)
    plans: Dict[str, Dict] = {}
    for suite in suites:
        for sc in resolve_suite(suite):
            key = f"{suite}/{sc.name}"
            if key in plans:
                continue
            # Paper geometry, single-device: the committed baseline must
            # not depend on how many host devices CI forces.
            plans[key] = plan_conv2d(sc.spec, dtype=sc.dtype, mode=mode,
                                     partition="none",
                                     calibration=calibration).to_dict()
    return {
        "plans_schema_version": PLANS_SCHEMA_VERSION,
        "suites": list(suites),
        "mode": mode,
        "environment": environment_fingerprint(),
        "calibration": {
            "path": None if calibration_path is None
            else str(calibration_path),
            "active": active,
        },
        "plans": plans,
    }


def compare_plans(new: Dict, baseline: Dict) -> Tuple[List[str], List[str]]:
    failures: List[str] = []
    notes: List[str] = []
    for label, doc in (("new", new), ("baseline", baseline)):
        if doc.get("plans_schema_version") != PLANS_SCHEMA_VERSION:
            failures.append(f"{label}: plans_schema_version is "
                            f"{doc.get('plans_schema_version')!r}, expected "
                            f"{PLANS_SCHEMA_VERSION}")
        if not isinstance(doc.get("plans"), dict) or not doc.get("plans"):
            failures.append(f"{label}: plans must be a non-empty object")
    if failures:
        return failures, notes
    exact = EXACT_PLAN_FIELDS
    new_backend = new.get("environment", {}).get("backend")
    base_backend = baseline.get("environment", {}).get("backend")
    if new_backend != base_backend:
        # The analytic pick branches on backend (DESIGN.md §1); across
        # backends algorithm drift is expected, not a regression.
        exact = tuple(f for f in exact if f != "algorithm")
        notes.append(f"backend differs: new={new_backend} "
                     f"baseline={base_backend} (algorithm not compared)")
    for key, base_plan in baseline["plans"].items():
        new_plan = new["plans"].get(key)
        if new_plan is None:
            failures.append(f"{key}: missing from new plans "
                            "(coverage regression)")
            continue
        for f in exact:
            if new_plan.get(f) != base_plan.get(f):
                failures.append(f"{key}: {f} changed "
                                f"{base_plan.get(f)!r} -> "
                                f"{new_plan.get(f)!r}")
        for f in NOTE_PLAN_FIELDS:
            if new_plan.get(f) != base_plan.get(f):
                notes.append(f"{key}: {f} drifted {base_plan.get(f)!r} -> "
                             f"{new_plan.get(f)!r} (informational)")
    extra = set(new["plans"]) - set(baseline["plans"])
    if extra:
        notes.append(f"{len(extra)} plan(s) not in baseline (new "
                     "coverage): " + ", ".join(sorted(extra)[:5])
                     + ("..." if len(extra) > 5 else ""))
    return failures, notes


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else list(argv)
    if argv and argv[0] == "calibrate":
        from repro.plan.calibrate import calibrate_main
        return calibrate_main(argv[1:])
    ap = argparse.ArgumentParser(prog="repro.plan",
                                 description=__doc__.splitlines()[0])
    ap.add_argument("--suites", default="smoke,table2",
                    help="comma-separated bench suites to plan "
                         "(default: smoke,table2)")
    ap.add_argument("--mode", choices=("analytic", "measured"),
                    default="analytic")
    ap.add_argument("--out", default=None,
                    help="write the plans document here")
    ap.add_argument("--baseline", default=None,
                    help="committed plans.json to diff against "
                         "(exact on algorithm/solution/partition fields)")
    ap.add_argument("--calibration", default=None,
                    help="calibration JSON the picks consult (fitted "
                         "costmodel, DESIGN.md §10); default: the "
                         "ambient store ($REPRO_CALIBRATION or the "
                         "fingerprinted file beside the plan cache)")
    args = ap.parse_args(argv)
    suites = [s for s in args.suites.split(",") if s]
    calibration = "ambient"
    if args.calibration:
        from repro.plan.calibrate import _load_file
        calibration = _load_file(pathlib.Path(args.calibration),
                                 strict_fingerprint=False)
        if calibration is None:
            # A named calibration that cannot apply here must be loud:
            # the whole point of pinning the file is reproducibility.
            print(f"[plan] --calibration {args.calibration} is missing, "
                  "unreadable, or fitted for another backend/device "
                  "kind", file=sys.stderr)
            return 2
    doc = build_plans(suites, mode=args.mode, calibration=calibration,
                      calibration_path=args.calibration)
    if args.out:
        pathlib.Path(args.out).write_text(
            json.dumps(doc, indent=2, sort_keys=True) + "\n")
        print(f"[plan] {len(doc['plans'])} plans ({args.mode}) -> "
              f"{args.out}")
    if args.baseline:
        try:
            baseline = json.loads(pathlib.Path(args.baseline).read_text())
        except FileNotFoundError:
            print(f"[plan] no such baseline: {args.baseline}",
                  file=sys.stderr)
            return 2
        except json.JSONDecodeError as e:
            print(f"[plan] {args.baseline} is not valid JSON: {e}",
                  file=sys.stderr)
            return 2
        failures, notes = compare_plans(doc, baseline)
        for n in notes:
            print(f"[plan] note: {n}")
        if failures:
            for f in failures:
                print(f"[plan] FAIL: {f}", file=sys.stderr)
            print(f"[plan] {len(failures)} plan regression(s) vs "
                  f"{args.baseline}", file=sys.stderr)
            return 1
        print(f"[plan] OK: plans match {args.baseline} "
              f"({len(baseline['plans'])} cells)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
