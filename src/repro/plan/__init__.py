"""Planner / autotune / plan cache (DESIGN.md §7).

``plan_conv2d`` turns a :class:`~repro.core.convspec.ConvSpec` into a
frozen :class:`ConvPlan` under one of three policies (analytic /
measured / cached); ``conv2d(..., plan=)`` executes it exactly.  The
process+disk plan cache lives in :mod:`repro.plan.cache`; the CLI
(``python -m repro.plan``) builds and diffs plan baselines.
"""
from repro.plan.cache import (PlanCache, global_plan_cache, plan_cache_dir,
                              reset_global_plan_cache)
from repro.plan.calibrate import (CALIBRATION_ENV, Calibration,
                                  CalibrationStore, calibration_path,
                                  current_calibration,
                                  reset_calibration_cache)
from repro.plan.convplan import (MEASURED_NOISE_MARGIN, PLAN_MODES,
                                 PLAN_VERSION, ConvPlan,
                                 MeasuredCandidates, eligible_candidates,
                                 measure_candidates,
                                 measure_candidates_detailed, pick_measured,
                                 plan_cache_key, plan_conv2d,
                                 resolve_cached_plan, spec_key,
                                 tune_measured)

__all__ = [
    "ConvPlan", "plan_conv2d", "resolve_cached_plan", "measure_candidates",
    "measure_candidates_detailed", "MeasuredCandidates", "tune_measured",
    "pick_measured", "eligible_candidates", "spec_key", "plan_cache_key",
    "MEASURED_NOISE_MARGIN", "PLAN_MODES", "PLAN_VERSION",
    "PlanCache", "global_plan_cache", "plan_cache_dir",
    "reset_global_plan_cache",
    "Calibration", "CalibrationStore", "CALIBRATION_ENV",
    "calibration_path", "current_calibration", "reset_calibration_cache",
]
