"""Persistent plan cache (DESIGN.md §7): process-level LRU in front of an
on-disk JSON file, so a tuned decision survives the process — "tune
once, serialize, serve from cache".

Layout: one JSON file per *environment fingerprint* under the cache
directory (``$REPRO_PLAN_CACHE_DIR``, else ``$XDG_CACHE_HOME/repro/
plans``, else ``~/.cache/repro/plans``), named
``<fingerprint-hash>.json``.  The fingerprint hashes the plan schema
version, jax version, backend, and device kind — any of those changing
silently switches to a fresh file, which IS the invalidation rule: a
plan tuned on one stack never leaks onto another.  Inside the file,
plans are keyed by ``spec|dtype|backend`` (:meth:`ConvPlan.cache_key`).
Partitioned plans are the one exception to persistence: the
fingerprint does not cover mesh topology, so they stay in the process
LRU and never reach disk.

Disk I/O is strictly best-effort: an unreadable/unwritable cache
directory degrades to memory-only (the LRU), never to an error — the
planner must work in read-only containers and sandboxes.
"""
from __future__ import annotations

import collections
import hashlib
import json
import os
import pathlib
import tempfile
import threading
from typing import Dict, Optional

from repro.plan.convplan import PLAN_VERSION, ConvPlan

CACHE_DIR_ENV = "REPRO_PLAN_CACHE_DIR"
CACHE_FILE_VERSION = 1

_DEFAULT_MAX_ENTRIES = 4096


def environment_fingerprint() -> str:
    """Short stable hash of everything that invalidates cached plans."""
    import jax
    try:
        kind = jax.devices()[0].device_kind
    except Exception:
        kind = "unknown"
    raw = (f"plan{PLAN_VERSION}|jax{jax.__version__}|"
           f"{jax.default_backend()}|{kind}")
    return hashlib.sha256(raw.encode()).hexdigest()[:16]


def plan_cache_dir() -> pathlib.Path:
    env = os.environ.get(CACHE_DIR_ENV)
    if env:
        return pathlib.Path(env)
    xdg = os.environ.get("XDG_CACHE_HOME")
    base = pathlib.Path(xdg) if xdg else pathlib.Path.home() / ".cache"
    return base / "repro" / "plans"


class PlanCache:
    """LRU of :class:`ConvPlan` backed by one fingerprinted JSON file.

    ``path=None`` resolves the default per-environment file lazily (so
    importing this module never touches jax or the filesystem);
    ``path=False``-y values other than None are taken literally.
    """

    def __init__(self, path: Optional[pathlib.Path] = None,
                 max_entries: int = _DEFAULT_MAX_ENTRIES):
        self._explicit_path = pathlib.Path(path) if path is not None else None
        self._path: Optional[pathlib.Path] = self._explicit_path
        self._mem: "collections.OrderedDict[str, ConvPlan]" = \
            collections.OrderedDict()
        self._max_entries = max_entries
        self._disk_loaded = False
        self._lock = threading.Lock()
        # Swallowed disk failures (unreadable, corrupt, read-only).  The
        # degradation stays silent per call, but operators need to see
        # it: the conv-service warmup surfaces this counter in the serve
        # report (DESIGN.md §9) instead of crashing — or hiding it.
        self.io_errors = 0

    # ----------------------------------------------------------- resolution

    def path(self) -> pathlib.Path:
        if self._path is None:
            self._path = plan_cache_dir() / f"{environment_fingerprint()}.json"
        return self._path

    def _load_disk_locked(self) -> None:
        if self._disk_loaded:
            return
        self._disk_loaded = True
        try:
            text = self.path().read_text()
        except FileNotFoundError:
            return            # a cache that simply isn't there yet is fine
        except OSError:
            self.io_errors += 1
            return
        try:
            doc = json.loads(text)
        except ValueError:
            self.io_errors += 1  # corrupt file: degrade, but count it
            return
        if doc.get("plan_cache_version") != CACHE_FILE_VERSION:
            return
        for key, plan_doc in doc.get("plans", {}).items():
            if key in self._mem:
                continue  # memory (newer) wins over disk
            try:
                self._mem[key] = ConvPlan.from_dict(plan_doc)
            except (ValueError, KeyError, TypeError):
                continue  # one stale entry never poisons the rest
        self._trim_locked()

    def _trim_locked(self) -> None:
        while len(self._mem) > self._max_entries:
            self._mem.popitem(last=False)

    def _flush_locked(self) -> None:
        # Partitioned plans never reach disk: the file's environment
        # fingerprint does not cover mesh topology, so a plan recording
        # mesh axes from one job must not resurface in another whose
        # mesh names differ.  They live in the process LRU only.
        doc = {
            "plan_cache_version": CACHE_FILE_VERSION,
            "plans": {k: p.to_dict() for k, p in self._mem.items()
                      if p.partition is None},
        }
        path = self.path()
        try:
            path.parent.mkdir(parents=True, exist_ok=True)
            fd, tmp = tempfile.mkstemp(dir=str(path.parent),
                                       prefix=path.name, suffix=".tmp")
            with os.fdopen(fd, "w") as f:
                json.dump(doc, f, indent=1, sort_keys=True)
            os.replace(tmp, path)
        except OSError:
            self.io_errors += 1  # read-only environment: memory-only now

    # ------------------------------------------------------------------ api

    def get(self, key: str) -> Optional[ConvPlan]:
        with self._lock:
            if key not in self._mem:
                self._load_disk_locked()
            plan = self._mem.get(key)
            if plan is not None:
                self._mem.move_to_end(key)
            return plan

    def put(self, key: str, plan: ConvPlan) -> None:
        with self._lock:
            self._load_disk_locked()  # merge before rewrite, not clobber
            self._mem[key] = plan
            self._mem.move_to_end(key)
            self._trim_locked()
            self._flush_locked()

    def clear(self) -> None:
        """Drop the memory tier and delete the disk file (tests; and the
        documented answer to 'my costmodel changed, flush the plans')."""
        with self._lock:
            self._mem.clear()
            self._disk_loaded = False
            try:
                self.path().unlink()
            except OSError:
                pass

    def __len__(self) -> int:
        with self._lock:
            return len(self._mem)


_global_cache: Optional[PlanCache] = None
_global_lock = threading.Lock()


def global_plan_cache() -> PlanCache:
    """The process-level cache ``plan_conv2d(mode="cached")`` and the
    ``conv2d`` auto path share."""
    global _global_cache
    with _global_lock:
        if _global_cache is None:
            _global_cache = PlanCache()
        return _global_cache


def reset_global_plan_cache() -> None:
    """Forget the process-level cache object (tests point the cache at a
    fresh tmpdir by resetting + setting REPRO_PLAN_CACHE_DIR)."""
    global _global_cache
    with _global_lock:
        _global_cache = None
