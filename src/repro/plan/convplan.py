"""ConvPlan — the frozen planner/executor decision record (DESIGN.md §7).

MEC's win is choosing the right lowering per shape (paper §3-4, Table 2:
no single algorithm wins every cv1-cv12 cell).  A :class:`ConvPlan`
captures the *entire* decision for one convolution — geometry
(:class:`~repro.core.convspec.ConvSpec`), dtype, algorithm, MEC
solution, Pallas ``w_blk``, GEMM precision, and the distributed
partition (components + mesh axes) — so it can be inspected
(:meth:`ConvPlan.explain`), serialized (:meth:`ConvPlan.to_json`),
cached (``repro.plan.cache``), and executed exactly by the thin
``conv2d(..., plan=)`` executor.

:func:`plan_conv2d` produces plans under three policies:

``analytic``  the costmodel pick (``repro.launch.costmodel``), exactly
              what the pre-planner ``conv2d(algorithm="auto")`` derived
              per call — now derived once.
``measured``  AOT-compile every candidate algorithm and time it through
              the ``repro.bench.harness`` steady-state protocol; the
              wall-clock winner becomes the plan.  A second stage then
              tunes the winner's knobs — the MEC solution (§3.2
              Solutions 1-2: h- vs w-direction lowering) or the Pallas
              ``w_blk`` — over a small measured grid, and every trial
              is recorded into the calibration store
              (``repro.plan.calibrate``, DESIGN.md §10): autotune runs
              are the fitted costmodel's training data.
``cached``    process-level LRU backed by an on-disk JSON cache keyed
              by spec+dtype+backend (env-fingerprinted file); a miss
              falls back to ``analytic`` and populates both tiers.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Optional, Sequence, Tuple, Union

from repro.core.convspec import ConvSpec
from repro.core.mec import SOLUTIONS, pick_solution

PLAN_VERSION = 1

# Canonical names for jax.lax.Precision members (plan JSON stores the
# name, never the enum, so reports stay readable and version-stable).
PRECISION_NAMES = ("DEFAULT", "HIGH", "HIGHEST")

_SINGLE_DEVICE_ALGOS = ("direct", "im2col", "fft", "winograd", "mec",
                        "mec_lowered", "mec_fused", "mec_fused2")
# Pallas variants: the only algorithms whose plan carries a w_blk.
_PALLAS_ALGOS = ("mec_lowered", "mec_fused", "mec_fused2")

PLAN_MODES = ("analytic", "measured", "cached")


def _precision_name(precision) -> Optional[str]:
    """None | 'highest' | lax.Precision.HIGHEST -> canonical name/None."""
    if precision is None:
        return None
    if isinstance(precision, str):
        name = precision.upper()
    elif isinstance(precision, tuple):
        raise ValueError(
            f"per-operand precision tuples are not plannable: {precision!r}")
    else:
        name = getattr(precision, "name", None)
        if name is None:
            raise ValueError(f"unknown precision {precision!r}")
    if name not in PRECISION_NAMES:
        raise ValueError(f"unknown precision {precision!r}; expected one "
                         f"of {PRECISION_NAMES} (or None)")
    return name


def _dtype_name(dtype) -> str:
    import jax.numpy as jnp
    return jnp.dtype(dtype).name


def spec_key(spec: ConvSpec) -> str:
    """Readable, order-stable spec identity used in cache keys."""
    return (f"{spec.i_n}x{spec.i_h}x{spec.i_w}x{spec.i_c}"
            f"-k{spec.k_h}x{spec.k_w}x{spec.k_c}"
            f"-s{spec.s_h}x{spec.s_w}")


def plan_cache_key(spec: ConvSpec, dtype: str, backend: str) -> str:
    """The one cache-key format — ``ConvPlan.cache_key()`` and the
    cached policy's lookup both build it here, so they can never
    drift apart."""
    return f"{spec_key(spec)}|{dtype}|{backend}"


@dataclasses.dataclass(frozen=True)
class ConvPlan:
    """One fully-resolved convolution decision.  Frozen: a plan is a
    value — compare, hash, serialize, and replay it; never mutate it."""

    spec: ConvSpec
    dtype: str
    algorithm: str                         # resolved; never "auto"
    solution: str = "auto"                 # 'A'/'B' for mec, else 'auto'
    w_blk: Optional[int] = None            # Pallas output-column block
    precision: Optional[str] = None        # canonical Precision name
    partition: Optional[Tuple[str, ...]] = None
    partition_axes: Optional[Tuple[str, ...]] = None
    backend: str = "cpu"
    mode: str = "analytic"                 # policy that produced the plan

    def __post_init__(self):
        if self.algorithm not in _SINGLE_DEVICE_ALGOS:
            raise ValueError(f"plan algorithm {self.algorithm!r} is not a "
                             f"resolved algorithm {_SINGLE_DEVICE_ALGOS}")
        if self.solution not in SOLUTIONS:
            raise ValueError(f"unknown MEC solution {self.solution!r}")
        if self.precision is not None and \
                self.precision not in PRECISION_NAMES:
            raise ValueError(f"unknown precision {self.precision!r}")
        if (self.partition is None) != (self.partition_axes is None):
            raise ValueError("partition and partition_axes must be set "
                             "together")
        if self.partition is not None:
            from repro.parallel.conv import normalize_partition
            parts = normalize_partition(self.partition)
            object.__setattr__(self, "partition", parts)
            axes = tuple(self.partition_axes)
            if len(axes) != len(parts):
                raise ValueError(
                    f"partition {parts!r} needs {len(parts)} axis(es), "
                    f"got {axes!r}")
            object.__setattr__(self, "partition_axes", axes)

    # ------------------------------------------------------------- identity

    def cache_key(self) -> str:
        """spec + dtype + backend — what the plan cache indexes on."""
        return plan_cache_key(self.spec, self.dtype, self.backend)

    def precision_value(self):
        """The jax.lax.Precision the executor passes to the GEMMs."""
        if self.precision is None:
            return None
        import jax
        return getattr(jax.lax.Precision, self.precision)

    # ------------------------------------------------------------ execution

    def check_executable(self, spec: ConvSpec, dtype) -> None:
        """Raise unless this plan was made for exactly this call: the
        executor refuses to run a stale plan on drifted geometry — or
        on a different backend, where the recorded pick may be wildly
        wrong (e.g. a TPU Pallas plan interpreting on CPU)."""
        if spec != self.spec:
            raise ValueError(
                f"plan/call geometry mismatch: plan was made for "
                f"{self.spec}, call resolves to {spec}")
        got = _dtype_name(dtype)
        if got != self.dtype:
            raise ValueError(
                f"plan/call dtype mismatch: plan was made for "
                f"{self.dtype!r}, call carries {got!r}")
        import jax
        live = jax.default_backend()
        if live != self.backend:
            raise ValueError(
                f"plan/backend mismatch: plan was made for "
                f"{self.backend!r}, this process runs {live!r}; "
                f"re-plan with plan_conv2d(spec, backend={live!r})")

    # -------------------------------------------------------- serialization

    def to_dict(self) -> Dict:
        return {
            "plan_version": PLAN_VERSION,
            "spec": dataclasses.asdict(self.spec),
            "dtype": self.dtype,
            "algorithm": self.algorithm,
            "solution": self.solution,
            "w_blk": self.w_blk,
            "precision": self.precision,
            "partition": (None if self.partition is None
                          else list(self.partition)),
            "partition_axes": (None if self.partition_axes is None
                               else list(self.partition_axes)),
            "backend": self.backend,
            "mode": self.mode,
        }

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    @classmethod
    def from_dict(cls, doc: Dict) -> "ConvPlan":
        version = doc.get("plan_version")
        if version != PLAN_VERSION:
            raise ValueError(f"plan_version {version!r} is not "
                             f"{PLAN_VERSION}; regenerate the plan")
        return cls(
            spec=ConvSpec(**doc["spec"]),
            dtype=doc["dtype"],
            algorithm=doc["algorithm"],
            solution=doc.get("solution", "auto"),
            w_blk=doc.get("w_blk"),
            precision=doc.get("precision"),
            partition=(None if doc.get("partition") is None
                       else tuple(doc["partition"])),
            partition_axes=(None if doc.get("partition_axes") is None
                            else tuple(doc["partition_axes"])),
            backend=doc.get("backend", "cpu"),
            mode=doc.get("mode", "analytic"),
        )

    @classmethod
    def from_json(cls, text: str) -> "ConvPlan":
        return cls.from_dict(json.loads(text))

    # -------------------------------------------------------------- explain

    def explain(self) -> str:
        """Human-readable *why*: the paper's Eq. 2-4 memory overheads and
        flops for every eligible algorithm (winner marked), plus the
        predicted per-device communication bytes of the partition."""
        from repro.core import memory
        from repro.launch.costmodel import conv2d_algorithm_costs
        s = self.spec
        lines = [
            f"ConvPlan[{self.mode}] {spec_key(s)} dtype={self.dtype} "
            f"backend={self.backend}",
            f"  algorithm={self.algorithm} solution={self.solution} "
            f"w_blk={self.w_blk} precision={self.precision}",
            f"  out_shape={tuple(s.out_shape)}  "
            f"mec saving vs im2col (Eq. 4): {memory.mec_saving(s)} elems",
            "  candidate costs (Eq. 2-4 overhead elems / flops):",
        ]
        costs = conv2d_algorithm_costs(s)
        base = {"mec_lowered": "mec", "mec_fused": "direct",
                "mec_fused2": "direct"}.get(self.algorithm, self.algorithm)
        for alg in sorted(costs):
            mark = " <- plan" if alg == base else ""
            c = costs[alg]
            lines.append(f"    {alg:8s} overhead={c['overhead_elems']:.3e} "
                         f"flops={c['flops']:.3e}{mark}")
        if self.algorithm in _PALLAS_ALGOS:
            lines.append("  (Pallas kernel: lowering stays in VMEM; "
                         "HBM overhead is the direct conv's)")
        if self.partition is None:
            lines.append("  partition: none (single device)")
        else:
            from repro.launch.costmodel import conv_partition_costs
            from repro.parallel.conv import partition_name
            import jax.numpy as jnp
            dtype_bytes = jnp.dtype(self.dtype).itemsize
            lines.append(f"  partition: {partition_name(self.partition)} "
                         f"over mesh axes {self.partition_axes}")
            try:
                n_dev = self._partition_sizes()
                entry = conv_partition_costs(
                    s, n_dev, dtype_bytes)[
                        self.partition if len(self.partition) > 1
                        else self.partition[0]]
                lines.append(
                    f"    predicted comm bytes/device: "
                    f"fwd={entry['comm_bytes_fwd_per_device']:.3e} "
                    f"bwd={entry['comm_bytes_bwd_per_device']:.3e} "
                    f"(halo {entry['halo_bytes_per_device']:.3e}); "
                    f"per-device L overhead "
                    f"{entry['per_device_overhead_elems']:.3e} elems")
            except Exception:  # no live mesh to size the axes from
                lines.append("    (no live mesh: per-device comm bytes "
                             "need the axis sizes)")
        return "\n".join(lines)

    def _partition_sizes(self) -> Union[int, Tuple[int, ...]]:
        """Axis sizes of the plan's partition on the *installed* mesh."""
        from repro.parallel.axes import current_rules
        rules = current_rules()
        if rules is None:
            raise ValueError("no installed mesh")
        sizes = tuple(int(rules.mesh.shape[a]) for a in self.partition_axes)
        return sizes[0] if len(sizes) == 1 else sizes


# ---------------------------------------------------------------------------
# planning policies
# ---------------------------------------------------------------------------

def _resolve_partition(spec: ConvSpec, partition, partition_axis,
                       dtype_bytes: int):
    """(components, axes) or (None, None), mirroring the executor's
    rules-aware routing (DESIGN.md §6) — but resolved once, at plan
    time, via the same candidate enumeration the distributed layer
    uses."""
    from repro.parallel.axes import current_rules
    rules = current_rules()
    if partition == "none":
        return None, None
    if rules is None:
        if partition not in (None, "auto"):
            raise ValueError(f"partition {partition!r} needs an installed "
                             "mesh (parallel.axes.use_rules)")
        return None, None
    mesh = rules.mesh
    from repro.launch.costmodel import pick_conv_partition
    from repro.parallel.conv import (enumerate_partition_candidates,
                                     normalize_partition, partition_viable)
    candidates = enumerate_partition_candidates(mesh, rules, partition_axis)
    if partition is None or partition == "auto":
        picked = pick_conv_partition(
            spec, {p: n for p, (_, n) in candidates.items()}, dtype_bytes)
        if picked is None:
            return None, None
        return normalize_partition(picked), candidates[picked][0]
    parts = normalize_partition(partition)
    key = parts if len(parts) > 1 else parts[0]
    if key not in candidates:
        raise ValueError(f"partition {partition!r} resolves no mesh axis "
                         f"on {mesh.axis_names}; pass partition_axis=")
    axes, n_dev = candidates[key]
    if not partition_viable(spec, parts, n_dev):
        raise ValueError(f"partition {partition!r} cannot split "
                         f"{spec} over {n_dev} device(s)")
    return parts, axes


def _hit_satisfies(hit: ConvPlan, precision_name: Optional[str],
                   partition, partition_axis) -> bool:
    """Would serving this cached plan honour the caller's request?

    The cache key is spec|dtype|backend only, so precision, the
    partition intent (components AND explicit axes), and the current
    accumulator-budget derivation must be checked against the hit — a
    plan resolved without HIGHEST (or without a partition, or under a
    different REPRO_MEC_ACC_BYTES / device budget) must never silently
    answer a call that asked otherwise.
    """
    if hit.precision != precision_name:
        return False
    if hit.w_blk != _pallas_w_blk(hit.spec, hit.algorithm):
        return False              # env/device budget changed since tuning
    if partition_axis is not None and hit.partition_axes is not None:
        axes = (partition_axis,) if isinstance(partition_axis, str) \
            else tuple(partition_axis)
        if hit.partition_axes != axes:
            return False
    if partition == "none":
        return hit.partition is None
    if partition not in (None, "auto"):
        from repro.parallel.conv import normalize_partition
        return hit.partition == normalize_partition(partition)
    # Rules-aware request: the hit must make sense on the *currently*
    # installed mesh — a partitioned plan recorded under other rules,
    # or a partition-free plan now that a mesh is up, is recomputed
    # (if the recompute agrees, the caller below skips the re-store).
    from repro.parallel.axes import current_rules
    rules = current_rules()
    if rules is None:
        return hit.partition is None
    return hit.partition is not None and all(
        a in rules.mesh.axis_names for a in hit.partition_axes)


def _pallas_w_blk(spec: ConvSpec, algorithm: str) -> Optional[int]:
    if algorithm not in _PALLAS_ALGOS:
        return None
    from repro.kernels.ops import pick_w_blk
    # The planner is the supported home for the accumulator budget; the
    # env override applies here without the deprecation warning.
    return pick_w_blk(spec.o_w, spec.k_c, _warn_env=False)


# A measured flip needs to clear this margin over the analytic pick —
# sub-5% deltas are timer jitter at bench iteration counts, and a pick
# that flips run-to-run on noise is worse than a stable analytic one.
MEASURED_NOISE_MARGIN = 0.05


def pick_measured(times: Dict[str, float], analytic: str,
                  margin: float = MEASURED_NOISE_MARGIN,
                  spreads: Optional[Dict[str, float]] = None) -> str:
    """The measured policy's decision rule (shared with the autotune
    bench suite): fastest candidate, except the analytic pick is kept
    whenever it is within the noise margin of the fastest — a flip must
    have timing evidence beyond run-to-run noise.

    ``spreads`` (algorithm -> ``us_rel_spread`` from the same timed
    iterations, DESIGN.md §10) widens the margin to the observed jitter
    of the two candidates being compared: the 5%% convention is the
    *floor*, and on a host whose medians wobble 30%% run-to-run a 30%%
    "win" is not evidence.  Without spread data the floor applies
    unchanged (pre-v2 reports, calibration cell medians)."""
    best = min(times, key=lambda a: times[a])
    if analytic not in times:
        return best
    eff = margin
    for alg in (analytic, best):
        sp = (spreads or {}).get(alg)
        if sp is not None:
            eff = max(eff, min(float(sp), 1.0))
    if times[analytic] <= times[best] * (1 + eff):
        return analytic
    return best


def eligible_candidates(spec: ConvSpec) -> Tuple[str, ...]:
    """conv2d algorithm names the measured policy may time on a spec."""
    algs = []
    for alg in _SINGLE_DEVICE_ALGOS:
        if alg == "winograd" and \
                (spec.k_h, spec.k_w, spec.s_h, spec.s_w) != (3, 3, 1, 1):
            continue
        algs.append(alg)
    return tuple(algs)


@dataclasses.dataclass
class MeasuredCandidates:
    """Everything one measured sweep learned: per-candidate steady-state
    timings + full iteration stats, and — the part that used to vanish
    silently — every candidate that could not be timed, with the reason
    (same surfacing stance as ``PlanCache.io_errors``)."""

    times: Dict[str, float]            # alg -> us_median (timeable only)
    stats: Dict[str, Dict]             # alg -> full time_compiled stats
    skipped: Dict[str, str]            # alg -> why it was not timed


def _time_trial(trial: ConvPlan, inp, ker, iters: int, warmup: int,
                interpret: Optional[bool]) -> Dict:
    """AOT-compile one trial plan and run the harness timing protocol."""
    import jax
    from repro.bench.harness import time_compiled
    from repro.core.conv_api import conv2d
    spec = trial.spec
    fn = jax.jit(lambda i, k, _p=trial: conv2d(
        i, k, stride=(spec.s_h, spec.s_w), plan=_p, interpret=interpret))
    compiled = fn.lower(inp, ker).compile()
    return time_compiled(lambda: compiled(inp, ker),
                         iters=iters, warmup=warmup)


def _record_time_trials(spec: ConvSpec, dtype: str, trials) -> None:
    """Fold measured trials into the calibration store (DESIGN.md §10).

    Strictly best-effort: the store already degrades silently on disk
    trouble, and a calibration failure must never fail a measurement.
    """
    try:
        from repro.plan.calibrate import CalibrationStore
        store = CalibrationStore()
        for alg, solution, w_blk, us in trials:
            store.add_time(spec, dtype, alg, us,
                           solution=solution, w_blk=w_blk)
        store.flush()
    except Exception:
        pass


def measure_candidates_detailed(
        spec: ConvSpec, dtype: str = "float32",
        candidates: Optional[Sequence[str]] = None,
        iters: int = 3, warmup: int = 1,
        interpret: Optional[bool] = None,
        precision=None, record: bool = True) -> MeasuredCandidates:
    """Steady-state ``us_per_call`` per candidate algorithm, via the
    bench harness protocol (AOT compile -> warmup -> median of timed
    calls).  This IS the measured policy's inner loop; the autotune
    bench suite reuses it so its numbers are the planner's numbers.

    Each candidate is timed *through a ConvPlan executor call* — the
    measurement exercises exactly what the winning plan will later run
    (resolved solution, planner-derived w_blk, named precision), and
    the planner's w_blk derivation stays on the warning-free path.

    Candidates that cannot be timed — the Pallas geometry checker
    rejects the trial plan, or compilation/execution raises — are never
    dropped silently: each lands in ``.skipped`` with its reason (and a
    warning), so the autotune report can show exactly what the race was
    missing.  With ``record=True`` every successful trial is added to
    the calibration store.
    """
    import warnings

    import jax
    from repro.bench.harness import make_arrays
    candidates = tuple(candidates) if candidates else \
        eligible_candidates(spec)
    dtype = _dtype_name(dtype)
    precision_name = _precision_name(precision)
    inp, ker = make_arrays(spec, dtype)
    out = MeasuredCandidates(times={}, stats={}, skipped={})
    recorded = []
    for alg in candidates:
        trial = ConvPlan(
            spec=spec, dtype=dtype, algorithm=alg,
            solution=pick_solution(spec) if alg == "mec" else "auto",
            w_blk=_pallas_w_blk(spec, alg), precision=precision_name,
            backend=jax.default_backend())
        if alg in _PALLAS_ALGOS:
            # Static geometry gate (repro.analysis.pallas_check): a
            # candidate the checker rejects would fault or overrun VMEM
            # on a real TPU — never time it, never let it win.
            from repro.analysis.pallas_check import check_plan
            verdict = check_plan(trial)
            if not verdict.ok:
                reason = "pallas_check: " + \
                    verdict.render().replace("\n", "; ")
                out.skipped[alg] = reason
                warnings.warn(f"measured planning skips {alg}: {reason}")
                continue
        try:
            timing = _time_trial(trial, inp, ker, iters, warmup, interpret)
        except Exception as e:
            # A candidate that fails to compile or run must not crash
            # the race — but it must be *counted*, not silently absent.
            reason = f"{type(e).__name__}: {e}"[:300]
            out.skipped[alg] = reason
            warnings.warn(f"measured planning skips {alg}: {reason}")
            continue
        out.times[alg] = timing["us_median"]
        out.stats[alg] = dict(timing, solution=trial.solution,
                              w_blk=trial.w_blk)
        recorded.append((alg, trial.solution, trial.w_blk,
                         timing["us_median"]))
    if record and recorded:
        _record_time_trials(spec, dtype, recorded)
    return out


def measure_candidates(spec: ConvSpec, dtype: str = "float32",
                       candidates: Optional[Sequence[str]] = None,
                       iters: int = 3, warmup: int = 1,
                       interpret: Optional[bool] = None,
                       precision=None,
                       record: bool = True) -> Dict[str, float]:
    """``measure_candidates_detailed`` reduced to {algorithm: us_median}
    (the historical return shape)."""
    return measure_candidates_detailed(
        spec, dtype, candidates, iters=iters, warmup=warmup,
        interpret=interpret, precision=precision, record=record).times


def _stage2_trials(spec: ConvSpec, dtype: str, algorithm: str,
                   precision_name: Optional[str], backend: str):
    """The winner's knob grid for measured stage 2 (DESIGN.md §10).

    mec: both §3.2 solutions (A = h-direction Solution 1, B =
    w-direction Solution 2) — ``pick_solution``'s T=100 rule is exactly
    the kind of paper constant the measurements should audit.  Pallas
    variants: the planner's ``pick_w_blk`` default plus half and double
    (clamped to [8, o_w]), each re-checked by the geometry gate.  Other
    algorithms have no plan-level knob.  Returns (knob_name, {label:
    trial plan}) or (None, {}).
    """
    if algorithm == "mec":
        plans = {sol: ConvPlan(spec=spec, dtype=dtype, algorithm="mec",
                               solution=sol, precision=precision_name,
                               backend=backend)
                 for sol in ("A", "B")}
        return "solution", plans
    if algorithm in _PALLAS_ALGOS:
        from repro.analysis.pallas_check import check_plan
        default = _pallas_w_blk(spec, algorithm)
        grid = {default, max(8, default // 2), min(spec.o_w, default * 2)}
        plans = {}
        for blk in sorted(b for b in grid if 1 <= b <= spec.o_w):
            trial = ConvPlan(spec=spec, dtype=dtype, algorithm=algorithm,
                             w_blk=blk, precision=precision_name,
                             backend=backend)
            if check_plan(trial).ok:
                plans[str(blk)] = trial
        return "w_blk", plans
    return None, {}


def tune_measured(spec: ConvSpec, dtype: str = "float32",
                  backend: Optional[str] = None, precision=None,
                  candidates: Optional[Sequence[str]] = None,
                  iters: int = 3, warmup: int = 1,
                  interpret: Optional[bool] = None,
                  record: bool = True,
                  calibration="ambient") -> Tuple[ConvPlan, Dict]:
    """The full measured policy: stage-1 algorithm race, then a stage-2
    grid over the winner's knob (MEC solution / Pallas ``w_blk``), both
    through ``pick_measured``'s noise margin so a non-default knob needs
    evidence beyond jitter.  Every trial lands in the calibration store
    when ``record=True``.

    Returns ``(plan, detail)`` where ``plan`` is the partition-free
    measured :class:`ConvPlan` and ``detail`` is the JSON-able evidence
    record the autotune bench suite embeds per cell:
    ``{analytic_algorithm, candidate_us, candidate_stats, skipped,
    tuning}`` (``tuning`` is None when the winner has no knob).
    """
    import jax
    backend = backend or jax.default_backend()
    dtype = _dtype_name(dtype)
    precision_name = _precision_name(precision)
    mc = measure_candidates_detailed(
        spec, dtype, candidates, iters=iters, warmup=warmup,
        interpret=interpret, precision=precision_name, record=record)
    from repro.launch.costmodel import pick_conv2d_algorithm
    analytic = pick_conv2d_algorithm(spec, backend,
                                     calibration=calibration)
    if not mc.times:
        raise ValueError(
            f"measured planning has no timeable candidate for "
            f"{spec_key(spec)}: skipped={mc.skipped}")
    algorithm = pick_measured(mc.times, analytic, spreads={
        a: s.get("us_rel_spread") for a, s in mc.stats.items()})
    solution = pick_solution(spec) if algorithm == "mec" else "auto"
    w_blk = _pallas_w_blk(spec, algorithm)

    tuning = None
    knob, plans = _stage2_trials(spec, dtype, algorithm,
                                 precision_name, backend)
    if knob is not None and plans:
        from repro.bench.harness import make_arrays
        inp, ker = make_arrays(spec, dtype)
        default_label = solution if knob == "solution" else str(w_blk)
        trial_times: Dict[str, float] = {}
        trial_stats: Dict[str, Dict] = {}
        recorded = []
        for label, trial in plans.items():
            try:
                timing = _time_trial(trial, inp, ker, iters, warmup,
                                     interpret)
            except Exception as e:
                mc.skipped[f"{algorithm}[{knob}={label}]"] = \
                    f"{type(e).__name__}: {e}"[:300]
                continue
            trial_times[label] = timing["us_median"]
            trial_stats[label] = dict(timing, solution=trial.solution,
                                      w_blk=trial.w_blk)
            recorded.append((algorithm, trial.solution, trial.w_blk,
                             timing["us_median"]))
        if record and recorded:
            _record_time_trials(spec, dtype, recorded)
        if trial_times:
            # The analytic default keeps its noise-margin advantage; if
            # it could not be timed the fastest trial wins outright.
            # Deliberately the plain 5% floor (no spread widening):
            # both trials run the same algorithm, so their jitter is
            # common-mode, and the default here is a paper heuristic
            # under audit (pick_solution's T=100, pick_w_blk) — a lower
            # bar than overriding the calibrated costmodel.
            picked = pick_measured(trial_times, default_label) \
                if default_label in trial_times \
                else min(trial_times, key=lambda k: trial_times[k])
            if knob == "solution":
                solution = picked
            else:
                w_blk = int(picked)
            tuning = {"knob": knob, "algorithm": algorithm,
                      "default": default_label, "picked": picked,
                      "trials": trial_stats}

    plan = ConvPlan(spec=spec, dtype=dtype, algorithm=algorithm,
                    solution=solution, w_blk=w_blk,
                    precision=precision_name, backend=backend,
                    mode="measured")
    if plan.algorithm in _PALLAS_ALGOS:
        # Never return a Pallas plan the static checker rejects —
        # raising here beats faulting at execute.
        from repro.analysis.pallas_check import assert_plan
        assert_plan(plan)
    detail = {"analytic_algorithm": analytic,
              "candidate_us": dict(mc.times),
              "candidate_stats": mc.stats,
              "skipped": mc.skipped,
              "tuning": tuning}
    return plan, detail


def plan_conv2d(spec: ConvSpec, *, dtype="float32", mode: str = "analytic",
                backend: Optional[str] = None, precision=None,
                partition=None, partition_axis=None,
                candidates: Optional[Sequence[str]] = None,
                iters: int = 3, warmup: int = 1,
                interpret: Optional[bool] = None,
                cache=None, calibration="ambient") -> ConvPlan:
    """Produce the :class:`ConvPlan` for one post-padding ``spec``.

    mode: ``"analytic"`` (costmodel pick — today's ``auto`` rule),
    ``"measured"`` (time every candidate through the bench harness,
    keep the winner, then tune its knob — see :func:`tune_measured`),
    or ``"cached"`` (process LRU -> on-disk JSON -> analytic on miss;
    see ``repro.plan.cache``).

    calibration: the fitted-costmodel handle the analytic pick consults
    (DESIGN.md §10) — ``"ambient"`` (default: $REPRO_CALIBRATION or the
    fingerprinted store beside the plan cache, silently absent when
    unfitted), ``None`` (force the paper's uncalibrated constants), or
    an explicit ``repro.plan.calibrate.Calibration``.  Cached plans
    record whatever the calibration said at *plan* time; like any
    costmodel change, a new calibration takes effect on cache misses
    and environment-fingerprint rollover, not retroactively.

    partition follows the executor's rules-aware convention: ``None``
    consults the installed ``parallel.axes`` rules (no mesh -> no
    partition), ``"auto"``/explicit modes resolve against the mesh at
    *plan* time — the plan records both the components and the mesh
    axes, so executing it never re-enumerates.
    """
    import jax
    if mode not in PLAN_MODES:
        raise ValueError(f"unknown plan mode {mode!r}; expected one of "
                         f"{PLAN_MODES}")
    spec.validate()
    dtype = _dtype_name(dtype)
    backend = backend or jax.default_backend()
    precision_name = _precision_name(precision)

    if mode == "cached":
        from repro.plan.cache import global_plan_cache
        cache = cache if cache is not None else global_plan_cache()
        key = plan_cache_key(spec, dtype, backend)
        hit = cache.get(key)
        if hit is not None and _hit_satisfies(hit, precision_name,
                                              partition, partition_axis):
            return hit
        # Miss — or a hit whose precision/partition decision does not
        # satisfy THIS request (the key is only spec|dtype|backend, so
        # a conflicting hit must never be served silently): recompute
        # and overwrite — most recent decision wins.
        plan = plan_conv2d(spec, dtype=dtype, mode="analytic",
                           backend=backend, precision=precision_name,
                           partition=partition,
                           partition_axis=partition_axis,
                           calibration=calibration)
        if plan != hit:               # an agreeing recompute skips the
            cache.put(key, plan)      # disk rewrite entirely
        return plan

    import jax.numpy as jnp
    parts, axes = _resolve_partition(spec, partition, partition_axis,
                                     jnp.dtype(dtype).itemsize)

    if mode == "measured":
        base, _detail = tune_measured(
            spec, dtype, backend=backend, precision=precision_name,
            candidates=candidates, iters=iters, warmup=warmup,
            interpret=interpret, calibration=calibration)
        # tune_measured already ran the Pallas assert; replaying it
        # through replace() only re-runs __post_init__ validation.
        plan = dataclasses.replace(base, partition=parts,
                                   partition_axes=axes)
        if plan.partition:
            # Same rule as the Pallas hook: never return a partitioned
            # plan whose compiled collectives break the costmodel
            # contract (skips silently when no mesh is installed).
            from repro.analysis.shardcheck import assert_plan_contract
            assert_plan_contract(plan)
        # Every returned plan also passes the static numeric contract
        # (DESIGN.md §8.5): accumulation widths, cast structure,
        # in-kernel Pallas accumulators.  Trace-only and memoized, so
        # planning stays cheap; the measured error-budget probe runs in
        # the numcheck suite, not here.
        from repro.analysis.numcheck import assert_plan_numerics
        assert_plan_numerics(plan)
        return plan

    from repro.launch.costmodel import pick_conv2d_algorithm
    algorithm = pick_conv2d_algorithm(spec, backend,
                                      calibration=calibration)
    solution = pick_solution(spec) if algorithm == "mec" else "auto"
    plan = ConvPlan(spec=spec, dtype=dtype, algorithm=algorithm,
                    solution=solution,
                    w_blk=_pallas_w_blk(spec, algorithm),
                    precision=precision_name,
                    partition=parts, partition_axes=axes,
                    backend=backend, mode=mode)
    if plan.algorithm in _PALLAS_ALGOS:
        # Never return (or let the cached policy store) a Pallas plan the
        # static checker rejects — raising here beats faulting at execute.
        from repro.analysis.pallas_check import assert_plan
        assert_plan(plan)
    if plan.partition:
        # Partitioned plans additionally pass the collective contract
        # (halo/psum bytes vs. the costmodel, no accidental resharding;
        # DESIGN.md §8).  Skips silently when no mesh is installed.
        from repro.analysis.shardcheck import assert_plan_contract
        assert_plan_contract(plan)
    # Every returned plan passes the static numeric contract (DESIGN.md
    # §8.5) for its resolved backend x dtype — accumulation widths, cast
    # structure, in-kernel Pallas accumulators.  Trace-only + memoized.
    from repro.analysis.numcheck import assert_plan_numerics
    assert_plan_numerics(plan)
    return plan


def resolve_cached_plan(spec: ConvSpec, dtype="float32",
                        backend: Optional[str] = None) -> ConvPlan:
    """What ``conv2d(algorithm="auto")`` calls: the cached-policy plan
    for (spec, dtype, backend), partition-free (the executor's partition
    routing already happened upstream)."""
    return plan_conv2d(spec, dtype=dtype, mode="cached", backend=backend,
                       partition="none")
