"""Fitted costmodel calibration (DESIGN.md §10).

The paper's analytic model (Eqs. 2-4) ranks lowerings by memory
overhead, but the right pick is microarchitecture-dependent: the
committed ``BENCH_autotune.json`` shows ``direct`` beating the analytic
``mec`` pick 2.1x on the s5x5 smoke cell, and ``BENCH_memaudit.json``
shows XLA's measured mec temp bytes running 1.03-1.51x the Eq. 3
prediction while im2col lands at exactly 1.00x.  This module closes the
loop: it accumulates the planner's own measurements and turns them into
per-backend/per-device-kind correction coefficients the costmodel
consults.

Two kinds of evidence feed one :class:`Calibration`:

* **time samples** — every trial ``plan_conv2d(mode="measured")`` /
  ``repro.bench --suite autotune`` times (keyed
  ``spec|dtype|algorithm|solution|w_blk``), recorded by
  ``repro.plan.convplan.measure_candidates``: autotune runs ARE the
  training data;
* **memory samples** — measured/predicted temp-byte ratios from
  ``repro.analysis.memaudit`` (keyed ``spec|dtype|algorithm``).

Fitting produces three views (:meth:`Calibration.fit`):

* ``time_cells`` — per-cell measured us per algorithm; where a spec has
  direct evidence covering the analytic pick plus a rival, the pick is
  re-decided through ``pick_measured``'s noise margin (this is what
  flips s5x5 to ``direct``; cells without evidence keep the paper
  rule — a fit from three smoke cells must not rewrite Table 2);
* ``time_constants`` — per-algorithm least-squares constants of
  ``us ~ c0 + c_flops*flops + c_overhead*overhead_elems`` (the Eq. 2-4
  time model the paper leaves implicit), reported by
  ``python -m repro.plan calibrate --report``;
* ``mem_ratio`` — per-algorithm geometric-mean measured/Eq. 2-3 byte
  ratio (paper constant: 1.0), which scales the overhead comparison in
  ``pick_conv2d_algorithm`` and the per-device predictions of
  ``conv_partition_costs``.

Persistence mirrors ``repro.plan.cache.PlanCache`` exactly: one JSON
file per environment fingerprint beside the plan cache
(``calibration-<fingerprint>.json`` under ``plan_cache_dir()``), the
fingerprint change IS the invalidation rule, disk I/O is best-effort
(missing/corrupt/read-only degrades silently to the uncalibrated
analytic constants, counted in ``CalibrationStore.io_errors``), and
writes are atomic (tempfile + ``os.replace``).  ``$REPRO_CALIBRATION``
points the ambient lookup at an explicit file instead (CI uses the
committed ``benchmarks/baselines/calibration.json``); explicit files
are matched on backend + device kind rather than the full fingerprint,
so a committed CPU calibration survives a jax patch bump but never
leaks onto a TPU.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import pathlib
import tempfile
from typing import Dict, List, Optional, Tuple

from repro.core.convspec import ConvSpec
from repro.plan.convplan import spec_key

CALIBRATION_FILE_VERSION = 1
CALIBRATION_ENV = "REPRO_CALIBRATION"

# Keep the last N samples per (spec, dtype, algorithm, solution, w_blk)
# key: enough to median away scheduler noise, bounded so a long-running
# autotune loop cannot grow the file without limit.
MAX_SAMPLES_PER_KEY = 32

DEFAULT_BASELINE = "benchmarks/baselines/calibration.json"


def calibration_path() -> pathlib.Path:
    """The fingerprinted store file beside the plan cache."""
    from repro.plan.cache import environment_fingerprint, plan_cache_dir
    return plan_cache_dir() / f"calibration-{environment_fingerprint()}.json"


def time_sample_key(spec: ConvSpec, dtype: str, algorithm: str,
                    solution: str = "auto",
                    w_blk: Optional[int] = None) -> str:
    blk = "-" if w_blk is None else str(int(w_blk))
    return f"{spec_key(spec)}|{dtype}|{algorithm}|{solution}|{blk}"


def mem_sample_key(spec: ConvSpec, dtype: str, algorithm: str) -> str:
    return f"{spec_key(spec)}|{dtype}|{algorithm}"


def parse_spec_key(key: str) -> ConvSpec:
    """Inverse of ``repro.plan.spec_key`` (sample keys embed it)."""
    dims, kpart, spart = key.split("-")
    i_n, i_h, i_w, i_c = (int(v) for v in dims.split("x"))
    k_h, k_w, k_c = (int(v) for v in kpart[1:].split("x"))
    s_h, s_w = (int(v) for v in spart[1:].split("x"))
    return ConvSpec(i_n, i_h, i_w, i_c, k_h, k_w, k_c, s_h, s_w)


def _geomean(values: List[float]) -> float:
    return math.exp(sum(math.log(max(v, 1e-12)) for v in values)
                    / len(values))


def _features(spec: ConvSpec, algorithm: str) -> Tuple[float, float]:
    """(flops, overhead_elems) of the Eq. 2-4 time model for one trial.

    Overhead follows ``repro.core.memory.algorithm_overhead`` (variant
    names resolve through ``_DISPATCH_BASE``: the fused Pallas kernels
    predict the direct conv's zero HBM overhead); flops are the base
    algorithm's from ``conv2d_algorithm_costs`` (every MEC variant
    computes the same mult-adds).
    """
    from repro.core import memory
    from repro.launch.costmodel import conv2d_algorithm_costs
    overhead = float(memory.algorithm_overhead(spec, algorithm))
    costs = conv2d_algorithm_costs(spec)
    base = algorithm if algorithm in costs else \
        ("mec" if algorithm.startswith("mec") else algorithm)
    flops = float(costs[base]["flops"]) if base in costs \
        else float(memory.conv_flops(spec))
    return flops, overhead


def _current_env() -> Tuple[str, str]:
    import jax
    try:
        kind = jax.devices()[0].device_kind
    except Exception:
        kind = "unknown"
    return jax.default_backend(), kind


@dataclasses.dataclass
class Calibration:
    """Accumulated measurements + the fits derived from them, for one
    (backend, device kind).  Coefficients never cross backends: a
    calibration only applies to picks made for ``self.backend``."""

    backend: str
    device_kind: str
    fingerprint: str
    time_samples: Dict[str, List[float]] = dataclasses.field(
        default_factory=dict)
    mem_samples: Dict[str, List[float]] = dataclasses.field(
        default_factory=dict)

    @classmethod
    def for_current_env(cls) -> "Calibration":
        from repro.plan.cache import environment_fingerprint
        backend, kind = _current_env()
        return cls(backend=backend, device_kind=kind,
                   fingerprint=environment_fingerprint())

    def is_empty(self) -> bool:
        return not self.time_samples and not self.mem_samples

    # ------------------------------------------------------------ recording

    def add_time(self, spec: ConvSpec, dtype: str, algorithm: str,
                 us: float, solution: str = "auto",
                 w_blk: Optional[int] = None) -> None:
        key = time_sample_key(spec, dtype, algorithm, solution, w_blk)
        samples = self.time_samples.setdefault(key, [])
        samples.append(float(us))
        del samples[:-MAX_SAMPLES_PER_KEY]

    def add_memory(self, spec: ConvSpec, dtype: str, algorithm: str,
                   ratio: float) -> None:
        key = mem_sample_key(spec, dtype, algorithm)
        samples = self.mem_samples.setdefault(key, [])
        samples.append(float(ratio))
        del samples[:-MAX_SAMPLES_PER_KEY]

    def merge(self, other: "Calibration") -> None:
        for key, samples in other.time_samples.items():
            mine = self.time_samples.setdefault(key, [])
            mine.extend(samples)
            del mine[:-MAX_SAMPLES_PER_KEY]
        for key, samples in other.mem_samples.items():
            mine = self.mem_samples.setdefault(key, [])
            mine.extend(samples)
            del mine[:-MAX_SAMPLES_PER_KEY]

    # -------------------------------------------------------------- fitting

    def time_cells(self) -> Dict[str, Dict[str, float]]:
        """spec-key -> algorithm -> best (min over solution/w_blk/dtype
        variants) median us — the cell-level evidence picks consult."""
        cells: Dict[str, Dict[str, float]] = {}
        import numpy as np
        for key, samples in self.time_samples.items():
            if not samples:
                continue
            spec_part, _dtype, alg, _sol, _blk = key.split("|")
            med = float(np.median(samples))
            algs = cells.setdefault(spec_part, {})
            algs[alg] = min(algs.get(alg, med), med)
        return cells

    def cell_times(self, spec: ConvSpec) -> Dict[str, float]:
        return self.time_cells().get(spec_key(spec), {})

    def mem_ratios(self) -> Dict[str, Dict[str, float]]:
        """algorithm -> {ratio (geomean), n} measured/predicted bytes."""
        by_alg: Dict[str, List[float]] = {}
        for key, samples in self.mem_samples.items():
            alg = key.split("|")[2]
            by_alg.setdefault(alg, []).extend(samples)
        return {alg: {"ratio": _geomean(samples), "n": len(samples)}
                for alg, samples in sorted(by_alg.items()) if samples}

    def mem_ratio_for(self, algorithm: str) -> float:
        """Fitted byte ratio for one algorithm; 1.0 (the paper's
        implicit constant) when unfitted."""
        entry = self.mem_ratios().get(algorithm)
        return float(entry["ratio"]) if entry else 1.0

    def time_constants(self) -> Dict[str, Dict[str, float]]:
        """Per-algorithm least-squares constants of the Eq. 2-4 time
        model ``us ~ c0 + c_flops*flops + c_overhead*overhead_elems``.

        Reported (``calibrate --report``) and used for ``time_us_est``
        in ``conv2d_algorithm_costs``; picks never extrapolate through
        these — cell-level evidence gates every flip.
        """
        import numpy as np
        by_alg: Dict[str, List[Tuple[float, float, float]]] = {}
        for cell, algs in self.time_cells().items():
            spec = parse_spec_key(cell)
            for alg, us in algs.items():
                flops, overhead = _features(spec, alg)
                by_alg.setdefault(alg, []).append((flops, overhead, us))
        out: Dict[str, Dict[str, float]] = {}
        for alg, rows in sorted(by_alg.items()):
            a = np.array([[1.0, f, o] for f, o, _ in rows])
            b = np.array([us for _, _, us in rows])
            coef, *_ = np.linalg.lstsq(a, b, rcond=None)
            out[alg] = {"c0": float(coef[0]), "c_flops": float(coef[1]),
                        "c_overhead": float(coef[2]), "n": len(rows)}
        return out

    def time_estimate(self, spec: ConvSpec, algorithm: str,
                      constants: Optional[Dict] = None) -> Optional[float]:
        constants = self.time_constants() if constants is None else constants
        c = constants.get(algorithm)
        if c is None:
            return None
        flops, overhead = _features(spec, algorithm)
        return c["c0"] + c["c_flops"] * flops + c["c_overhead"] * overhead

    def decisions(self) -> Dict[str, Dict[str, str]]:
        """Per evidence cell: the paper-rule pick vs the calibrated pick
        — the decision fields ``calibrate --check`` gates exactly."""
        from repro.launch.costmodel import pick_conv2d_algorithm
        out: Dict[str, Dict[str, str]] = {}
        for cell in sorted(self.time_cells()):
            spec = parse_spec_key(cell)
            out[cell] = {
                "uncalibrated": pick_conv2d_algorithm(
                    spec, self.backend, calibration=None),
                "calibrated": pick_conv2d_algorithm(
                    spec, self.backend, calibration=self),
            }
        return out

    def fit(self) -> Dict:
        return {
            "time_cells": self.time_cells(),
            "time_constants": self.time_constants(),
            "mem_ratio": self.mem_ratios(),
            "decisions": self.decisions(),
        }

    # -------------------------------------------------------- serialization

    def to_dict(self, with_fit: bool = True) -> Dict:
        import jax
        doc = {
            "calibration_file_version": CALIBRATION_FILE_VERSION,
            "fingerprint": self.fingerprint,
            "backend": self.backend,
            "device_kind": self.device_kind,
            "jax": jax.__version__,
            "time_samples": {k: list(v) for k, v
                             in sorted(self.time_samples.items())},
            "mem_samples": {k: list(v) for k, v
                            in sorted(self.mem_samples.items())},
        }
        if with_fit:
            doc["fitted"] = self.fit()
        return doc

    @classmethod
    def from_dict(cls, doc: Dict) -> "Calibration":
        version = doc.get("calibration_file_version")
        if version != CALIBRATION_FILE_VERSION:
            raise ValueError(f"calibration_file_version {version!r} is not "
                             f"{CALIBRATION_FILE_VERSION}")
        return cls(
            backend=doc["backend"],
            device_kind=doc.get("device_kind", "unknown"),
            fingerprint=doc.get("fingerprint", ""),
            time_samples={str(k): [float(x) for x in v]
                          for k, v in doc.get("time_samples", {}).items()},
            mem_samples={str(k): [float(x) for x in v]
                         for k, v in doc.get("mem_samples", {}).items()},
        )


def resolve_calibration(calibration, backend: str) -> Optional[Calibration]:
    """``"ambient"`` | None | Calibration -> the Calibration a pick for
    ``backend`` may consult (None when absent or backend-mismatched:
    coefficients fitted on one backend never decide picks on another).
    """
    if calibration is None:
        return None
    if calibration == "ambient":
        calibration = current_calibration()
        if calibration is None:
            return None
    return calibration if calibration.backend == backend else None


# ---------------------------------------------------------------------------
# persistence
# ---------------------------------------------------------------------------

class CalibrationStore:
    """Best-effort accumulation into the fingerprinted store file.

    ``add_time``/``add_memory`` buffer in memory; ``flush()`` merges the
    buffer into whatever is on disk (load -> merge -> atomic rewrite),
    so concurrent autotune runs append rather than clobber.  All disk
    failure modes degrade silently and bump ``io_errors`` — the same
    stance (and counter name) as ``PlanCache``.
    """

    def __init__(self, path: Optional[pathlib.Path] = None):
        self._explicit_path = pathlib.Path(path) if path is not None else None
        self.pending = Calibration.for_current_env()
        self.io_errors = 0

    def path(self) -> pathlib.Path:
        if self._explicit_path is not None:
            return self._explicit_path
        return calibration_path()

    def add_time(self, spec: ConvSpec, dtype: str, algorithm: str,
                 us: float, solution: str = "auto",
                 w_blk: Optional[int] = None) -> None:
        self.pending.add_time(spec, dtype, algorithm, us, solution, w_blk)

    def add_memory(self, spec: ConvSpec, dtype: str, algorithm: str,
                   ratio: float) -> None:
        self.pending.add_memory(spec, dtype, algorithm, ratio)

    def load(self) -> Calibration:
        """The on-disk calibration, or a fresh empty one.  A file whose
        fingerprint does not match the current environment is ignored —
        the PlanCache invalidation rule."""
        fresh = Calibration.for_current_env()
        path = self.path()
        try:
            text = path.read_text()
        except FileNotFoundError:
            return fresh
        except OSError:
            self.io_errors += 1
            return fresh
        try:
            calib = Calibration.from_dict(json.loads(text))
        except (ValueError, KeyError, TypeError):
            self.io_errors += 1       # corrupt file: degrade, but count it
            return fresh
        if calib.fingerprint != fresh.fingerprint:
            return fresh
        return calib

    def flush(self) -> None:
        if self.pending.is_empty():
            return
        disk = self.load()
        disk.merge(self.pending)
        self.pending = Calibration.for_current_env()
        path = self.path()
        try:
            path.parent.mkdir(parents=True, exist_ok=True)
            fd, tmp = tempfile.mkstemp(dir=str(path.parent),
                                       prefix=path.name, suffix=".tmp")
            with os.fdopen(fd, "w") as f:
                json.dump(disk.to_dict(), f, indent=1, sort_keys=True)
            os.replace(tmp, path)
        except OSError:
            self.io_errors += 1       # read-only environment: drop silently
        _load_cache.pop(str(path), None)


# Ambient lookup cache: path -> (stat signature, Calibration or None).
# Keyed by path (not a process singleton) so tests that repoint
# REPRO_PLAN_CACHE_DIR / REPRO_CALIBRATION see the change immediately.
_load_cache: Dict[str, Tuple[Optional[Tuple[int, int]],
                             Optional[Calibration]]] = {}


def _load_file(path: pathlib.Path, strict_fingerprint: bool
               ) -> Optional[Calibration]:
    try:
        sig_stat = path.stat()
        sig = (sig_stat.st_mtime_ns, sig_stat.st_size)
    except OSError:
        sig = None
    cached = _load_cache.get(str(path))
    if cached is not None and cached[0] == sig:
        return cached[1]
    calib: Optional[Calibration] = None
    if sig is not None:
        try:
            calib = Calibration.from_dict(json.loads(path.read_text()))
        except (OSError, ValueError, KeyError, TypeError):
            calib = None              # silent degradation to uncalibrated
    if calib is not None:
        if strict_fingerprint:
            from repro.plan.cache import environment_fingerprint
            if calib.fingerprint != environment_fingerprint():
                calib = None
        else:
            backend, kind = _current_env()
            if calib.backend != backend or calib.device_kind != kind:
                calib = None          # committed file from another device
    _load_cache[str(path)] = (sig, calib)
    return calib


def reset_calibration_cache() -> None:
    """Forget memoized file loads (tests)."""
    _load_cache.clear()


def current_calibration() -> Optional[Calibration]:
    """The ambient calibration the planner consults by default:
    ``$REPRO_CALIBRATION`` (explicit file, backend/device-kind matched)
    if set, else the fingerprinted store beside the plan cache.  None —
    the uncalibrated analytic constants — when absent, corrupt, empty,
    or environment-mismatched."""
    env = os.environ.get(CALIBRATION_ENV)
    if env:
        calib = _load_file(pathlib.Path(env), strict_fingerprint=False)
    else:
        calib = _load_file(calibration_path(), strict_fingerprint=True)
    if calib is None or calib.is_empty():
        return None
    return calib


def calibration_info() -> Dict:
    """Provenance block for bench reports: is a calibration active, and
    where did it come from?"""
    env = os.environ.get(CALIBRATION_ENV)
    calib = current_calibration()
    return {
        "active": calib is not None,
        "source": (f"env:{env}" if env else
                   (f"store:{calibration_path()}" if calib is not None
                    else None)),
        "backend": None if calib is None else calib.backend,
        "cells": 0 if calib is None else len(calib.time_cells()),
    }


# ---------------------------------------------------------------------------
# report ingestion (building the committed baseline)
# ---------------------------------------------------------------------------

def ingest_autotune(calib: Calibration, doc: Dict) -> int:
    """Fold a BENCH_autotune.json (schema v1 or v2) into ``calib`` as
    time samples.  Returns the number of samples added."""
    n = 0
    for rec in doc.get("results", []):
        spec = ConvSpec(**rec["run_spec"])
        dtype = rec.get("dtype", "float32")
        stats = rec.get("candidate_stats") or {}
        for alg, us in (rec.get("candidate_us") or {}).items():
            meta = stats.get(alg) or {}
            calib.add_time(spec, dtype, alg, float(us),
                           solution=meta.get("solution", "auto"),
                           w_blk=meta.get("w_blk"))
            n += 1
        tuning = rec.get("tuning") or {}
        for label, trial in (tuning.get("trials") or {}).items():
            if tuning.get("knob") == "solution":
                calib.add_time(spec, dtype, tuning["algorithm"],
                               float(trial["us_median"]), solution=label)
            elif tuning.get("knob") == "w_blk":
                calib.add_time(spec, dtype, tuning["algorithm"],
                               float(trial["us_median"]),
                               w_blk=int(label))
            n += 1
    return n


def ingest_memaudit(calib: Calibration, doc: Dict) -> int:
    """Fold a BENCH_memaudit.json into ``calib`` as memory samples.
    Only tolerance-gated cells count: Pallas interpret-mode temps are
    XLA artifacts, not the kernel's memory story."""
    from repro.core.memory import _DISPATCH_BASE
    n = 0
    for rec in doc.get("results", []):
        if rec.get("policy") != "gated" or rec.get("ratio") is None:
            continue
        spec = ConvSpec(**rec["spec"])
        base = _DISPATCH_BASE.get(rec["algorithm"], rec["algorithm"])
        calib.add_memory(spec, rec.get("dtype", "float32"), base,
                         float(rec["ratio"]))
        n += 1
    return n


# ---------------------------------------------------------------------------
# CLI: python -m repro.plan calibrate ...
# ---------------------------------------------------------------------------

def _repo_root() -> pathlib.Path:
    return pathlib.Path(__file__).resolve().parents[3]


def _rel_close(a: float, b: float, rtol: float) -> bool:
    return abs(a - b) <= rtol * max(abs(a), abs(b), 1e-9)


def check_calibration(doc: Dict, rtol: float = 0.05) -> List[str]:
    """Gate a calibration document: the stored ``fitted`` block must be
    reproducible from the stored samples — decision fields exactly,
    coefficients within ``rtol`` (numpy lstsq may wobble across
    versions).  Returns the failure list (empty == pass)."""
    failures: List[str] = []
    try:
        calib = Calibration.from_dict(doc)
    except (ValueError, KeyError, TypeError) as e:
        return [f"unreadable calibration document: {e}"]
    stored = doc.get("fitted")
    if not isinstance(stored, dict):
        return ["no 'fitted' block: regenerate with "
                "python -m repro.plan calibrate --fit"]
    refit = calib.fit()
    # Decisions: exact, both directions.
    for cell in sorted(set(stored.get("decisions", {}))
                       | set(refit["decisions"])):
        a = stored.get("decisions", {}).get(cell)
        b = refit["decisions"].get(cell)
        if a != b:
            failures.append(f"decision drift on {cell}: stored {a!r} "
                            f"vs refit {b!r}")
    # Coefficients: tolerance.
    for alg in sorted(set(stored.get("time_constants", {}))
                      | set(refit["time_constants"])):
        a = stored.get("time_constants", {}).get(alg)
        b = refit["time_constants"].get(alg)
        if (a is None) != (b is None):
            failures.append(f"time_constants coverage drift on {alg}")
            continue
        for coef in ("c0", "c_flops", "c_overhead"):
            if not _rel_close(a[coef], b[coef], rtol):
                failures.append(f"time_constants[{alg}][{coef}] "
                                f"{a[coef]:.6g} vs refit {b[coef]:.6g} "
                                f"(rtol {rtol})")
    for alg in sorted(set(stored.get("mem_ratio", {}))
                      | set(refit["mem_ratio"])):
        a = stored.get("mem_ratio", {}).get(alg)
        b = refit["mem_ratio"].get(alg)
        if (a is None) != (b is None):
            failures.append(f"mem_ratio coverage drift on {alg}")
            continue
        if not _rel_close(a["ratio"], b["ratio"], rtol):
            failures.append(f"mem_ratio[{alg}] {a['ratio']:.6g} vs refit "
                            f"{b['ratio']:.6g} (rtol {rtol})")
    for cell in sorted(set(stored.get("time_cells", {}))
                       | set(refit["time_cells"])):
        a = stored.get("time_cells", {}).get(cell, {})
        b = refit["time_cells"].get(cell, {})
        for alg in sorted(set(a) | set(b)):
            if alg not in a or alg not in b:
                failures.append(f"time_cells coverage drift on "
                                f"{cell}/{alg}")
            elif not _rel_close(a[alg], b[alg], rtol):
                failures.append(f"time_cells[{cell}][{alg}] {a[alg]:.6g} "
                                f"vs refit {b[alg]:.6g} (rtol {rtol})")
    return failures


def render_report(calib: Calibration) -> List[str]:
    """Fitted-vs-paper constants, one block per evidence cell."""
    lines = [f"[calibrate] backend={calib.backend} "
             f"device_kind={calib.device_kind} "
             f"fingerprint={calib.fingerprint}"]
    constants = calib.time_constants()
    decisions = calib.decisions()
    for cell, algs in sorted(calib.time_cells().items()):
        spec = parse_spec_key(cell)
        lines.append(f"cell {cell}:")
        lines.append(f"  {'algorithm':12s} {'Eq.2-4 elems':>12s} "
                     f"{'flops':>12s} {'measured us':>12s} "
                     f"{'fitted us':>10s}")
        for alg in sorted(algs):
            flops, overhead = _features(spec, alg)
            est = calib.time_estimate(spec, alg, constants)
            lines.append(
                f"  {alg:12s} {overhead:12.3e} {flops:12.3e} "
                f"{algs[alg]:12.1f} "
                f"{'-' if est is None else format(est, '10.1f')}")
        d = decisions.get(cell, {})
        flip = "" if d.get("uncalibrated") == d.get("calibrated") \
            else "   <-- flip"
        lines.append(f"  pick: paper={d.get('uncalibrated')} "
                     f"calibrated={d.get('calibrated')}{flip}")
    lines.append("memory ratios (measured / Eq. 2-3 prediction; "
                 "paper constant 1.0):")
    for alg, entry in calib.mem_ratios().items():
        lines.append(f"  {alg:12s} {entry['ratio']:.4f}  "
                     f"(n={entry['n']})")
    lines.append("time constants "
                 "(us ~ c0 + c_flops*flops + c_overhead*overhead):")
    for alg, c in constants.items():
        lines.append(f"  {alg:12s} c0={c['c0']:+.4g} "
                     f"c_flops={c['c_flops']:+.4g} "
                     f"c_overhead={c['c_overhead']:+.4g} (n={c['n']})")
    return lines


def calibrate_main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        prog="repro.plan calibrate",
        description="Fitted-costmodel calibration: report, gate, or "
                    "(re)build the coefficient file (DESIGN.md §10)")
    ap.add_argument("--report", action="store_true",
                    help="print fitted-vs-paper constants per cell")
    ap.add_argument("--check", action="store_true",
                    help="gate a calibration file: stored fit must be "
                         "reproducible from its samples (decisions "
                         "exact, coefficients within --rtol)")
    ap.add_argument("--fit", action="store_true",
                    help="build a calibration from the ambient store "
                         "and/or report files; write it with --out")
    ap.add_argument("--baseline", default=None,
                    help=f"calibration JSON to report on / check "
                         f"(default: {DEFAULT_BASELINE})")
    ap.add_argument("--rtol", type=float, default=0.05,
                    help="coefficient tolerance for --check")
    ap.add_argument("--autotune", default=None,
                    help="BENCH_autotune.json to ingest for --fit")
    ap.add_argument("--memaudit", default=None,
                    help="BENCH_memaudit.json to ingest for --fit")
    ap.add_argument("--out", default=None,
                    help="where --fit writes the calibration JSON")
    args = ap.parse_args(argv)

    baseline = pathlib.Path(args.baseline) if args.baseline \
        else _repo_root() / DEFAULT_BASELINE

    if args.fit:
        calib = CalibrationStore().load()
        for path, ingest in ((args.autotune, ingest_autotune),
                             (args.memaudit, ingest_memaudit)):
            if path is None:
                continue
            try:
                doc = json.loads(pathlib.Path(path).read_text())
            except (OSError, ValueError) as e:
                print(f"[calibrate] cannot read {path}: {e}",
                      file=__import__("sys").stderr)
                return 2
            n = ingest(calib, doc)
            print(f"[calibrate] ingested {n} sample(s) from {path}")
        if calib.is_empty():
            print("[calibrate] nothing to fit: no samples in the store "
                  "or the given reports", file=__import__("sys").stderr)
            return 2
        out = pathlib.Path(args.out) if args.out else baseline
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(calib.to_dict(), indent=1,
                                  sort_keys=True) + "\n")
        flips = sum(1 for d in calib.decisions().values()
                    if d["uncalibrated"] != d["calibrated"])
        print(f"[calibrate] {len(calib.time_cells())} time cell(s), "
              f"{len(calib.mem_ratios())} memory-fitted algorithm(s), "
              f"{flips} calibrated flip(s) -> {out}")
        if args.report:
            for line in render_report(calib):
                print(line)
        return 0

    if args.check:
        try:
            doc = json.loads(baseline.read_text())
        except (OSError, ValueError) as e:
            print(f"[calibrate] cannot read {baseline}: {e}",
                  file=__import__("sys").stderr)
            return 2
        failures = check_calibration(doc, rtol=args.rtol)
        if failures:
            import sys
            for f in failures:
                print(f"[calibrate] FAIL: {f}", file=sys.stderr)
            print(f"[calibrate] {len(failures)} failure(s) in {baseline}",
                  file=sys.stderr)
            return 1
        n_cells = len(doc.get("fitted", {}).get("time_cells", {}))
        print(f"[calibrate] OK: {baseline} is self-consistent "
              f"({n_cells} cell(s), rtol {args.rtol})")
        if not args.report:
            return 0

    # --report (also the default action)
    calib = None
    if args.baseline:
        calib = _load_file(baseline, strict_fingerprint=False)
    if calib is None:
        calib = current_calibration()
    if calib is None and baseline.exists():
        calib = _load_file(baseline, strict_fingerprint=False)
    if calib is None or calib.is_empty():
        print("[calibrate] no calibration found (no ambient store, no "
              f"{baseline}); run the autotune suite or calibrate --fit",
              file=__import__("sys").stderr)
        return 2
    for line in render_report(calib):
        print(line)
    return 0
