"""Fault-tolerant checkpointing.

Semantics (what Orbax/tensorstore provide on a real pod, implemented here
self-contained):

* **Atomic**: leaves are written into ``step_N.tmp/`` and the directory is
  renamed to ``step_N/`` only after an fsync'd manifest — a crash mid-save
  can never corrupt the latest checkpoint.
* **Async**: ``save_async`` snapshots device arrays to host then writes on
  a background thread; training continues. ``wait()`` joins before the
  next save (bounded in-flight = 1).
* **Elastic restore**: leaves are stored as full logical arrays with a
  manifest of paths/shapes/dtypes; ``restore`` re-shards onto *any* mesh
  via device_put with the target NamedSharding — the restoring job may
  have a different device count than the saving job.
* **Exact resume**: the data-pipeline state dict rides along, so a
  restarted job continues from the same sample.
* Retention: keep the newest ``keep`` checkpoints.
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np

# dtypes numpy cannot serialize natively: store a same-width integer view
# and re-view on load
_EXOTIC = {
    "bfloat16": (np.uint16, ml_dtypes.bfloat16),
    "float8_e4m3fn": (np.uint8, ml_dtypes.float8_e4m3fn),
    "float8_e5m2": (np.uint8, ml_dtypes.float8_e5m2),
}


def _to_savable(arr: np.ndarray):
    name = arr.dtype.name
    if name in _EXOTIC:
        return np.ascontiguousarray(arr).view(_EXOTIC[name][0]), name
    return arr, name


def _from_saved(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _EXOTIC:
        return arr.view(_EXOTIC[dtype_name][1])
    return arr


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        flat[key] = leaf
    return flat


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------- save --
    def save(self, step: int, trees: Dict[str, Any]) -> None:
        """Synchronous atomic save. trees: name -> pytree."""
        host = {name: jax.tree.map(np.asarray, tree)
                for name, tree in trees.items()}
        self._write(step, host)

    def save_async(self, step: int, trees: Dict[str, Any]) -> None:
        self.wait()
        # snapshot to host memory before returning control to the step loop
        host = {name: jax.tree.map(np.asarray, tree)
                for name, tree in trees.items()}
        self._thread = threading.Thread(target=self._write,
                                        args=(step, host), daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host: Dict[str, Any]) -> None:
        final = self.dir / f"step_{step:08d}"
        tmp = self.dir / f"step_{step:08d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "trees": {}}
        for name, tree in host.items():
            flat = _flatten(tree)
            tdir = tmp / name
            tdir.mkdir()
            entries = {}
            for key, leaf in flat.items():
                arr = np.asarray(leaf)
                savable, dtype_name = _to_savable(arr)
                fname = key.replace("/", "__") + ".npy"
                np.save(tdir / fname, savable)
                entries[key] = {"file": fname, "shape": list(arr.shape),
                                "dtype": dtype_name}
            manifest["trees"][name] = entries
        mpath = tmp / "manifest.json"
        mpath.write_text(json.dumps(manifest))
        fd = os.open(mpath, os.O_RDONLY)
        os.fsync(fd)
        os.close(fd)
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self) -> None:
        ckpts = sorted(self.all_steps())
        for step in ckpts[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{step:08d}", ignore_errors=True)

    # ---------------------------------------------------------- restore --
    def all_steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not (p / "manifest.json").exists():
                continue
            out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Dict[str, Any],
                shardings: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
        """Restore trees with the structure of ``like``; re-shard onto the
        current mesh if ``shardings`` (matching pytrees of NamedSharding)
        is given — this is the elastic-scaling path."""
        cdir = self.dir / f"step_{step:08d}"
        manifest = json.loads((cdir / "manifest.json").read_text())
        out = {}
        for name, tree in like.items():
            entries = manifest["trees"][name]
            flat_like = _flatten(tree)
            loaded = {}
            for key in flat_like:
                arr = np.load(cdir / name / entries[key]["file"])
                loaded[key] = _from_saved(arr, entries[key]["dtype"])
            shard_tree = shardings.get(name) if shardings else None
            flat_shard = _flatten(shard_tree) if shard_tree is not None else None

            # reconstruct in tree order
            leaves_sorted = []
            for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
                key = "/".join(
                    str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
                arr = loaded[key]
                if flat_shard is not None and key in flat_shard:
                    leaves_sorted.append(jax.device_put(arr, flat_shard[key]))
                else:
                    leaves_sorted.append(jnp.asarray(arr))
            out[name] = jax.tree_util.tree_unflatten(
                jax.tree_util.tree_structure(tree), leaves_sorted)
        return out
