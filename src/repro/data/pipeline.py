"""Deterministic synthetic data pipeline with exact-resume state.

Batches are generated from (seed, step) only — any host can regenerate any
step, which gives:
* per-host sharding without communication (host h of H takes rows
  h::H of the global batch),
* exact resume after preemption (state = {"step": N} rides in the
  checkpoint),
* straggler-independent determinism (no host ever waits on a data server).

Real deployments swap `_synth_tokens` for a tokenized shard reader with
the same (seed, step) -> batch contract.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclasses.dataclass
class DataState:
    step: int = 0

    def to_dict(self):
        return {"step": np.asarray(self.step)}

    @staticmethod
    def from_dict(d):
        return DataState(step=int(np.asarray(d["step"])))


class SyntheticLMData:
    def __init__(self, cfg: ModelConfig, global_batch: int, seq_len: int,
                 seed: int = 0, host_id: int = 0, num_hosts: int = 1):
        assert global_batch % num_hosts == 0
        self.cfg = cfg
        self.global_batch = global_batch
        self.local_batch = global_batch // num_hosts
        self.seq_len = seq_len
        self.seed = seed
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.state = DataState()

    def _synth_tokens(self, step: int) -> np.ndarray:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        full = rng.integers(0, self.cfg.vocab,
                            size=(self.global_batch, self.seq_len + 1),
                            dtype=np.int32)
        # learnable structure: every token in a row shares a "topic"
        # residue mod 16, inferable from any earlier token -> achievable
        # NLL is ~ln(vocab) - ln(16) below the random floor
        topic = rng.integers(0, 16, size=(self.global_batch, 1),
                             dtype=np.int32)
        full = (full // 16) * 16 + topic
        full %= self.cfg.vocab
        return full[self.host_id::self.num_hosts]

    def next_batch(self) -> Dict[str, jnp.ndarray]:
        step = self.state.step
        full = self._synth_tokens(step)
        batch = {"tokens": jnp.asarray(full[:, :-1]),
                 "labels": jnp.asarray(full[:, 1:])}
        cfg = self.cfg
        if cfg.family == "vlm":
            rng = np.random.default_rng(step + 17)
            batch["vision"] = jnp.asarray(
                rng.standard_normal((self.local_batch, cfg.prefix_len,
                                     cfg.d_model)).astype(np.float32))
        if cfg.family == "audio":
            rng = np.random.default_rng(step + 31)
            batch["frames"] = jnp.asarray(
                rng.standard_normal((self.local_batch, cfg.encoder_len,
                                     cfg.d_model)).astype(np.float32))
        self.state.step += 1
        return batch
