"""Serving paths: prefill (build caches from a prompt) and single-token
decode for every architecture family.  Caches are pytrees with layer-stacked
leaves so the decode step scans over layers exactly like training does.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import mamba2, moe, xlstm
from repro.models.layers import (attention_block, attention_decode,
                                 decode_attention, linear, rms_norm, swiglu)
from repro.models.lm import LM, dense_block, gelu_mlp, moe_block
from repro.parallel.axes import constrain


def _cache_dtype(cfg):
    return jnp.dtype(cfg.dtype)


def _kv_into(max_len: int, k: jnp.ndarray, v: jnp.ndarray):
    """Embed prefill k/v (B,S,KV,D) into zero caches of length max_len."""
    b, s, kv, d = k.shape
    kc = jnp.zeros((b, max_len, kv, d), k.dtype).at[:, :s].set(k)
    vc = jnp.zeros((b, max_len, kv, d), v.dtype).at[:, :s].set(v)
    kc = constrain(kc, "batch", "seq_tp", "kv_heads", None)
    vc = constrain(vc, "batch", "seq_tp", "kv_heads", None)
    return kc, vc


def _logits_last(model: LM, params, h):
    """Last-position logits (B, V)."""
    w = model.head_weights(params)
    return jnp.einsum("bd,dv->bv", h[:, -1, :].astype(jnp.float32),
                      w.astype(jnp.float32),
                      preferred_element_type=jnp.float32)


def _logits_one(model: LM, params, h):
    return _logits_last(model, params, h)


# ---------------------------------------------------------------------------
# dense / vlm / moe
# ---------------------------------------------------------------------------

def _attn_families_prefill(model: LM, params, batch, max_len: int):
    cfg = model.cfg
    tokens = batch["tokens"]
    h = model.embed(params, tokens)
    if cfg.family == "vlm":
        vis = linear(batch["vision"].astype(h.dtype), params["vision_proj"])
        h = jnp.concatenate([vis, h], axis=1)
    s = h.shape[1]
    positions = jnp.arange(s)
    is_moe = cfg.family == "moe"

    def body(x, p):
        if is_moe:
            x2, kv, _ = moe_block(p, cfg, x, positions)
        else:
            x2, kv = dense_block(p, cfg, x, positions)
        return x2, _kv_into(max_len, *kv)

    h, (kc, vc) = lax.scan(body, h, params["blocks"])
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    cache = {"k": kc, "v": vc, "len": jnp.asarray(s, jnp.int32)}
    return _logits_last(model, params, h), cache


def _attn_families_decode(model: LM, params, cache, tokens):
    cfg = model.cfg
    h = model.embed(params, tokens)          # (B, 1, d)
    is_moe = cfg.family == "moe"
    int8 = "k_s" in cache
    ln = cache["len"]

    def body(x, inputs):
        if int8:
            p, kc, vc, ks, vs = inputs
            lcache = {"k": kc, "v": vc, "k_s": ks, "v_s": vs, "len": ln}
        else:
            p, kc, vc = inputs
            lcache = {"k": kc, "v": vc, "len": ln}
        xn = rms_norm(x, p["norm1"], cfg.norm_eps)
        a, new = attention_decode(p["attn"], cfg, xn, lcache)
        x = x + a
        xn2 = rms_norm(x, p["norm2"], cfg.norm_eps)
        if is_moe:
            f, _ = moe.moe_ffn(p["moe"], cfg, xn2)
        else:
            f = swiglu(xn2, p["mlp"])
        ys = ((new["k"], new["v"], new["k_s"], new["v_s"]) if int8
              else (new["k"], new["v"]))
        return x + f, ys

    if int8:
        xs = (params["blocks"], cache["k"], cache["v"], cache["k_s"],
              cache["v_s"])
        h, (kc, vc, ks, vs) = lax.scan(body, h, xs)
        new_cache = {"k": kc, "v": vc, "k_s": ks, "v_s": vs, "len": ln + 1}
    else:
        h, (kc, vc) = lax.scan(body, h,
                               (params["blocks"], cache["k"], cache["v"]))
        new_cache = {"k": kc, "v": vc, "len": ln + 1}
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    return _logits_one(model, params, h), new_cache


# ---------------------------------------------------------------------------
# hybrid (zamba2)
# ---------------------------------------------------------------------------

def _hybrid_prefill(model: LM, params, batch, max_len: int):
    cfg = model.cfg
    h = model.embed(params, batch["tokens"])
    s = h.shape[1]
    positions = jnp.arange(s)
    n_super, tail = divmod(cfg.n_layers, cfg.attn_every)
    norms = params["mamba_norms"][:n_super * cfg.attn_every].reshape(
        n_super, cfg.attn_every, -1)

    def mamba_step(x, pn):
        p, nrm = pn
        out, mc = mamba2.mamba_core(p, cfg, rms_norm(x, nrm, cfg.norm_eps))
        return x + out, mc

    def super_step(x, inputs):
        p_group, nrm_group = inputs
        x, mcaches = lax.scan(mamba_step, x, (p_group, nrm_group))
        x, kv = dense_block(params["shared"], cfg, x, positions)
        return x, (mcaches, _kv_into(max_len, *kv))

    h, (mcaches, (kc, vc)) = lax.scan(super_step, h, (params["mamba"], norms))
    tail_cache = None
    if tail:
        tail_norms = params["mamba_norms"][n_super * cfg.attn_every:]
        h, tail_cache = lax.scan(mamba_step, h,
                                 (params["mamba_tail"], tail_norms))
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    cache = {"mamba": mcaches, "attn_k": kc, "attn_v": vc,
             "tail": tail_cache, "len": jnp.asarray(s, jnp.int32)}
    return _logits_last(model, params, h), cache


def _hybrid_decode(model: LM, params, cache, tokens):
    cfg = model.cfg
    h = model.embed(params, tokens)
    n_super, tail = divmod(cfg.n_layers, cfg.attn_every)
    norms = params["mamba_norms"][:n_super * cfg.attn_every].reshape(
        n_super, cfg.attn_every, -1)
    ln = cache["len"]
    shared = params["shared"]

    def mamba_step(x, inputs):
        p, nrm, mc = inputs
        out, mc2 = mamba2.mamba_decode(p, cfg, rms_norm(x, nrm, cfg.norm_eps),
                                       mc)
        return x + out, mc2

    def super_step(x, inputs):
        p_group, nrm_group, mc_group, kc, vc = inputs
        x, mc_new = lax.scan(mamba_step, x, (p_group, nrm_group, mc_group))
        xn = rms_norm(x, shared["norm1"], cfg.norm_eps)
        a, new = attention_decode(shared["attn"], cfg, xn,
                                  {"k": kc, "v": vc, "len": ln})
        x = x + a
        x = x + swiglu(rms_norm(x, shared["norm2"], cfg.norm_eps),
                       shared["mlp"])
        return x, (mc_new, new["k"], new["v"])

    h, (mc_new, kc, vc) = lax.scan(
        super_step, h,
        (params["mamba"], norms, cache["mamba"], cache["attn_k"],
         cache["attn_v"]))
    tail_cache = cache["tail"]
    if tail:
        tail_norms = params["mamba_norms"][n_super * cfg.attn_every:]
        h, tail_cache = lax.scan(
            mamba_step, h, (params["mamba_tail"], tail_norms, cache["tail"]))
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    new_cache = {"mamba": mc_new, "attn_k": kc, "attn_v": vc,
                 "tail": tail_cache, "len": ln + 1}
    return _logits_one(model, params, h), new_cache


# ---------------------------------------------------------------------------
# ssm (xLSTM)
# ---------------------------------------------------------------------------

def _ssm_prefill(model: LM, params, batch, max_len: int):  # lint-ignore: accepted-kwarg-not-forwarded (prefill-dispatch signature; ssm caches are length-free)
    cfg = model.cfg
    h = model.embed(params, batch["tokens"])

    def m_step(x, p):
        out, c = xlstm.mlstm_prefill(p, cfg, x)
        return x + out, c

    def super_step(x, inputs):
        p_m, p_s = inputs
        x, mc = lax.scan(m_step, x, p_m)
        out, sc = xlstm.slstm_core(p_s, cfg, x)
        return x + out, (mc, sc)

    h, (mc, sc) = lax.scan(super_step, h, (params["mlstm"], params["slstm"]))
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    cache = {"mlstm": mc, "slstm": sc,
             "len": jnp.asarray(batch["tokens"].shape[1], jnp.int32)}
    return _logits_last(model, params, h), cache


def _ssm_decode(model: LM, params, cache, tokens):
    cfg = model.cfg
    h = model.embed(params, tokens)

    def m_step(x, inputs):
        p, c = inputs
        out, c2 = xlstm.mlstm_decode(p, cfg, x, c)
        return x + out, c2

    def super_step(x, inputs):
        p_m, p_s, mc, sc = inputs
        x, mc2 = lax.scan(m_step, x, (p_m, mc))
        out, sc2 = xlstm.slstm_decode(p_s, cfg, x, sc)
        return x + out, (mc2, sc2)

    h, (mc, sc) = lax.scan(
        super_step, h,
        (params["mlstm"], params["slstm"], cache["mlstm"], cache["slstm"]))
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    return (_logits_one(model, params, h),
            {"mlstm": mc, "slstm": sc, "len": cache["len"] + 1})


# ---------------------------------------------------------------------------
# audio (whisper enc-dec)
# ---------------------------------------------------------------------------

def _audio_prefill(model: LM, params, batch, max_len: int):
    cfg = model.cfg
    enc = model.encode(params, batch["frames"])
    h = model.embed(params, batch["tokens"])
    s = h.shape[1]
    positions = jnp.arange(s)

    def body(x, p):
        x2, kv = model._dec_block(p, x, positions, None, enc)
        ck, cv = model._cross_kv(p, enc)
        return x2, (_kv_into(max_len, *kv), (ck, cv))

    h, ((kc, vc), (ck, cv)) = lax.scan(body, h, params["dec_blocks"])
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    cache = {"k": kc, "v": vc, "cross_k": ck, "cross_v": cv,
             "len": jnp.asarray(s, jnp.int32)}
    return _logits_last(model, params, h), cache


def _audio_decode(model: LM, params, cache, tokens):
    cfg = model.cfg
    h = model.embed(params, tokens)
    ln = cache["len"]

    def body(x, inputs):
        p, kc, vc, ck, cv = inputs
        xn = rms_norm(x, p["norm1"], cfg.norm_eps)
        a, new = attention_decode(p["attn"], cfg, xn,
                                  {"k": kc, "v": vc, "len": ln})
        x = x + a
        # cross-attention against the static encoder cache
        xn = rms_norm(x, p["norm_x"], cfg.norm_eps)
        b = x.shape[0]
        q = linear(xn, p["xattn"]["wq"]).reshape(b, 1, cfg.n_heads,
                                                 cfg.head_dim)
        xa = decode_attention(q, ck, cv, ck.shape[1])
        xa = linear(xa.reshape(b, 1, cfg.n_heads * cfg.head_dim),
                    p["xattn"]["wo"])
        x = x + xa
        x = x + gelu_mlp(rms_norm(x, p["norm2"], cfg.norm_eps), p["mlp"])
        return x, (new["k"], new["v"])

    h, (kc, vc) = lax.scan(
        body, h, (params["dec_blocks"], cache["k"], cache["v"],
                  cache["cross_k"], cache["cross_v"]))
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    new_cache = dict(cache, k=kc, v=vc, len=ln + 1)
    return _logits_one(model, params, h), new_cache


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------

_PREFILL = {"dense": _attn_families_prefill, "vlm": _attn_families_prefill,
            "moe": _attn_families_prefill, "hybrid": _hybrid_prefill,
            "ssm": _ssm_prefill, "audio": _audio_prefill}
_DECODE = {"dense": _attn_families_decode, "vlm": _attn_families_decode,
           "moe": _attn_families_decode, "hybrid": _hybrid_decode,
           "ssm": _ssm_decode, "audio": _audio_decode}


def prefill(model: LM, params, batch, max_len: int):
    """-> (last-token logits (B, V), cache)."""
    return _PREFILL[model.cfg.family](model, params, batch, max_len)


def decode_step(model: LM, params, cache, tokens):
    """tokens (B, 1) -> (logits (B, V), new cache)."""
    return _DECODE[model.cfg.family](model, params, cache, tokens)


def init_decode_cache(model: LM, batch: int, max_len: int):
    """Zero caches for decode-only benchmarking (no prefill)."""
    cfg = model.cfg
    dt = _cache_dtype(cfg)
    hd = cfg.head_dim
    fam = cfg.family

    def kv(n_layers, length):
        return (jnp.zeros((n_layers, batch, length, cfg.n_kv_heads, hd), dt),
                jnp.zeros((n_layers, batch, length, cfg.n_kv_heads, hd), dt))

    if fam in ("dense", "vlm", "moe"):
        if getattr(cfg, "kv_cache_int8", False):
            shp = (cfg.n_layers, batch, max_len, cfg.n_kv_heads)
            return {"k": jnp.zeros(shp + (hd,), jnp.int8),
                    "v": jnp.zeros(shp + (hd,), jnp.int8),
                    "k_s": jnp.zeros(shp + (1,), jnp.bfloat16),
                    "v_s": jnp.zeros(shp + (1,), jnp.bfloat16),
                    "len": jnp.asarray(max_len - 1, jnp.int32)}
        k, v = kv(cfg.n_layers, max_len)
        return {"k": k, "v": v, "len": jnp.asarray(max_len - 1, jnp.int32)}
    if fam == "hybrid":
        n_super, tail = divmod(cfg.n_layers, cfg.attn_every)
        mc = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_super, cfg.attn_every) + x.shape),
            mamba2.init_mamba_cache(cfg, batch, dt))
        k, v = kv(n_super, max_len)
        tail_c = None
        if tail:
            tail_c = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (tail,) + x.shape),
                mamba2.init_mamba_cache(cfg, batch, dt))
        return {"mamba": mc, "attn_k": k, "attn_v": v, "tail": tail_c,
                "len": jnp.asarray(max_len - 1, jnp.int32)}
    if fam == "ssm":
        n_super = cfg.n_layers // cfg.slstm_every
        k_m = cfg.slstm_every - 1
        mc = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_super, k_m) + x.shape),
            xlstm.init_mlstm_cache(cfg, batch))
        sc = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_super,) + x.shape),
            xlstm.init_slstm_cache(cfg, batch))
        return {"mlstm": mc, "slstm": sc,
                "len": jnp.asarray(max_len - 1, jnp.int32)}
    if fam == "audio":
        k, v = kv(cfg.n_layers, max_len)
        ck = jnp.zeros((cfg.n_layers, batch, cfg.encoder_len,
                        cfg.n_kv_heads, hd), dt)
        return {"k": k, "v": v, "cross_k": ck, "cross_v": ck,
                "len": jnp.asarray(max_len - 1, jnp.int32)}
    raise ValueError(fam)
