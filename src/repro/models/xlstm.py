"""xLSTM blocks (Beck et al., 2024): mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory, sequential scan).

mLSTM training uses the stabilized parallel (quadratic) form, chunked over
queries; decode is the O(1) matrix-memory update.  sLSTM is an exponential-
gated recurrent scan with head-wise block-diagonal recurrence.  Both carry
a causal depthwise conv1d pre-activation — the MEC conv1d hot-spot.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.mec import mec_conv1d_depthwise
from repro.models.mamba2 import conv1d
from repro.models.layers import init_linear, linear, rms_norm

_NEG = -1e30


def _dims(cfg):
    d_in = 2 * cfg.d_model
    h = cfg.n_heads
    p = d_in // h
    return d_in, h, p


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm(key, cfg, dtype) -> dict:
    d = cfg.d_model
    d_in, h, p = _dims(cfg)
    ks = jax.random.split(key, 8)
    return {
        "up": init_linear(ks[0], d, 2 * d_in, dtype),        # x_in, z gate
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_width, d_in),
                                     jnp.float32) * 0.2).astype(dtype),
        "wq": init_linear(ks[2], d_in, d_in, dtype),
        "wk": init_linear(ks[3], d_in, d_in, dtype),
        "wv": init_linear(ks[4], d_in, d_in, dtype),
        "wif": init_linear(ks[5], d_in, 2 * h, dtype, bias=True),
        "norm": jnp.ones((d_in,), dtype),
        "down": init_linear(ks[6], d_in, d, dtype),
    }


def _mlstm_gates(p, xc, cfg):
    d_in, h, _ = _dims(cfg)
    g = linear(xc, p["wif"]).astype(jnp.float32)     # (B, S, 2H)
    log_i = g[..., :h]
    log_f = jax.nn.log_sigmoid(g[..., h:] + 3.0)     # bias toward remember
    return log_i, log_f


def mlstm_parallel(q, k, v, log_i, log_f, q_chunk: int = 256):
    """Stabilized parallel mLSTM.

    q,k,v: (B, S, H, P); log_i/log_f: (B, S, H).
    D[i,j] = F_i - F_j + I_j (j <= i), F = cumsum(log_f).
    h_t = (sum_j exp(D[t,j] - m_t) q_t.k_j v_j) / max(|den|, exp(-m_t)).
    """
    b, s, h, p = q.shape
    q_chunk = min(q_chunk, s)
    pad = (-s) % q_chunk
    f_cum = jnp.cumsum(log_f, axis=1)                       # (B, S, H)
    scale = p ** -0.5
    kt = k.astype(jnp.float32) * scale
    vt = v.astype(jnp.float32)
    bias_k = (log_i - f_cum)                                # I_j - F_j
    nq = (s + pad) // q_chunk

    def q_step(iq):
        sl = lambda t: lax.dynamic_slice_in_dim(t, iq * q_chunk, q_chunk, axis=1)
        q_i = sl(q).astype(jnp.float32)                     # (B, c, H, P)
        f_i = sl(f_cum)                                     # (B, c, H)
        scores = jnp.einsum("bthp,bshp->bhts", q_i, kt,
                            preferred_element_type=jnp.float32)  # (B,H,c,S)
        dmat = (f_i.transpose(0, 2, 1)[:, :, :, None]
                + bias_k.transpose(0, 2, 1)[:, :, None, :])  # (B,H,c,S)
        qpos = iq * q_chunk + jnp.arange(q_chunk)
        mask = jnp.arange(s)[None, :] <= qpos[:, None]
        dmat = jnp.where(mask[None, None], dmat, _NEG)
        m = jnp.maximum(dmat.max(axis=-1), -p * 10.0)       # (B, H, c)
        w = jnp.exp(dmat - m[..., None]) * scores
        den = jnp.maximum(jnp.abs(w.sum(-1)), jnp.exp(-m))  # (B, H, c)
        out = jnp.einsum("bhts,bshp->bthp", w, vt,
                         preferred_element_type=jnp.float32
                         ) / den.transpose(0, 2, 1)[..., None]
        return out                                          # (B, c, H, P)

    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        f_cum = jnp.pad(f_cum, ((0, 0), (0, pad), (0, 0)))
    out = lax.map(q_step, jnp.arange(nq))                   # (nq, B, c, H, P)
    out = jnp.moveaxis(out, 0, 1).reshape(b, s + pad, h, p)[:, :s]
    return out


def mlstm_forward(p: dict, cfg, x: jnp.ndarray) -> jnp.ndarray:
    d_in, h, pd = _dims(cfg)
    up = linear(x, p["up"])
    x_in, z = up[..., :d_in], up[..., d_in:]
    xc = conv1d(cfg, x_in, p["conv_w"].astype(x_in.dtype))
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(x.dtype)
    b, s, _ = x.shape
    q = linear(xc, p["wq"]).reshape(b, s, h, pd)
    k = linear(xc, p["wk"]).reshape(b, s, h, pd)
    v = linear(x_in, p["wv"]).reshape(b, s, h, pd)
    log_i, log_f = _mlstm_gates(p, xc, cfg)
    out = mlstm_parallel(q, k, v, log_i, log_f, q_chunk=cfg.q_chunk)
    out = out.reshape(b, s, d_in).astype(x.dtype)
    out = rms_norm(out, p["norm"], cfg.norm_eps)
    out = out * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    return linear(out, p["down"])


def mlstm_prefill(p: dict, cfg, x: jnp.ndarray):
    """Forward over a full sequence AND build the decode cache.

    The recurrent state after S tokens has the closed form
      m = max(F_S, max_j (F_S - F_j + I_j))
      C = sum_j exp(F_S - F_j + I_j - m) k_j v_j^T,   n likewise.
    """
    d_in, h, pd = _dims(cfg)
    b, s, _ = x.shape
    up = linear(x, p["up"])
    x_in, z = up[..., :d_in], up[..., d_in:]
    xc = conv1d(cfg, x_in, p["conv_w"].astype(x_in.dtype))
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(x.dtype)
    q = linear(xc, p["wq"]).reshape(b, s, h, pd)
    k = linear(xc, p["wk"]).reshape(b, s, h, pd)
    v = linear(x_in, p["wv"]).reshape(b, s, h, pd)
    log_i, log_f = _mlstm_gates(p, xc, cfg)
    out = mlstm_parallel(q, k, v, log_i, log_f, q_chunk=cfg.q_chunk)
    # closed-form final state
    f_cum = jnp.cumsum(log_f, axis=1)                       # (B, S, H)
    f_s = f_cum[:, -1, :]                                   # (B, H)
    bias = f_s[:, None, :] - f_cum + log_i                  # (B, S, H)
    m = jnp.maximum(f_s, bias.max(axis=1))                  # (B, H)
    w = jnp.exp(bias - m[:, None, :])                       # (B, S, H)
    kf = k.astype(jnp.float32) * pd ** -0.5
    c_state = jnp.einsum("bsh,bshp,bsho->bhpo", w, kf, v.astype(jnp.float32),
                         preferred_element_type=jnp.float32)
    n_state = jnp.einsum("bsh,bshp->bhp", w, kf,
                         preferred_element_type=jnp.float32)
    conv = x_in[:, s - (cfg.conv_width - 1):, :].astype(jnp.float32)
    cache = {"c": c_state, "n": n_state, "m": m, "conv": conv}
    out = out.reshape(b, s, d_in).astype(x.dtype)
    out = rms_norm(out, p["norm"], cfg.norm_eps)
    out = out * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    return linear(out, p["down"]), cache


def init_mlstm_cache(cfg, batch: int) -> dict:
    d_in, h, pd = _dims(cfg)
    return {
        "c": jnp.zeros((batch, h, pd, pd), jnp.float32),   # matrix memory
        "n": jnp.zeros((batch, h, pd), jnp.float32),
        "m": jnp.full((batch, h), 0.0, jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, d_in), jnp.float32),
    }


def mlstm_decode(p: dict, cfg, x: jnp.ndarray, cache: dict
                 ) -> Tuple[jnp.ndarray, dict]:
    d_in, h, pd = _dims(cfg)
    b = x.shape[0]
    up = linear(x[:, 0], p["up"])
    x_in, z = up[..., :d_in], up[..., d_in:]
    hist = jnp.concatenate(
        [cache["conv"], x_in[:, None, :].astype(jnp.float32)], axis=1)
    xc = jax.nn.silu(jnp.einsum("bkc,kc->bc", hist,
                                p["conv_w"].astype(jnp.float32),
                                preferred_element_type=jnp.float32))
    xc = xc.astype(x.dtype)
    q = linear(xc, p["wq"]).reshape(b, h, pd).astype(jnp.float32)
    k = linear(xc, p["wk"]).reshape(b, h, pd).astype(jnp.float32) * pd ** -0.5
    v = linear(x_in[:, None].astype(x.dtype), p["wv"])[:, 0].reshape(b, h, pd).astype(jnp.float32)
    g = linear(xc, p["wif"]).astype(jnp.float32)
    log_i = g[..., :h].reshape(b, h)
    log_f = jax.nn.log_sigmoid(g[..., h:].reshape(b, h) + 3.0)
    m_new = jnp.maximum(log_f + cache["m"], log_i)
    fw = jnp.exp(log_f + cache["m"] - m_new)[..., None]
    iw = jnp.exp(log_i - m_new)[..., None]
    c_new = cache["c"] * fw[..., None] + iw[..., None] * (k[..., :, None] * v[..., None, :])
    n_new = cache["n"] * fw + iw * k
    num = jnp.einsum("bhp,bhpo->bho", q, c_new,
                     preferred_element_type=jnp.float32)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhp,bhp->bh", q, n_new,
                                         preferred_element_type=jnp.float32)),
                      jnp.exp(-m_new))[..., None]
    out = (num / den).reshape(b, d_in).astype(x.dtype)
    out = rms_norm(out, p["norm"], cfg.norm_eps)
    out = out * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    new_cache = {"c": c_new, "n": n_new, "m": m_new, "conv": hist[:, 1:, :]}
    return linear(out, p["down"])[:, None, :], new_cache


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(key, cfg, dtype) -> dict:
    d = cfg.d_model
    d_in, h, pd = _dims(cfg)
    ks = jax.random.split(key, 5)
    return {
        "up": init_linear(ks[0], d, d_in, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_width, d_in),
                                     jnp.float32) * 0.2).astype(dtype),
        "w_gates": init_linear(ks[2], d_in, 4 * d_in, dtype, bias=True),
        # head-wise block-diagonal recurrence: h (H, P) -> gates (H, 4P)
        "r_gates": (jax.random.normal(ks[3], (h, 4 * pd, pd), jnp.float32)
                    * pd ** -0.5).astype(dtype),
        "norm": jnp.ones((d_in,), dtype),
        "down": init_linear(ks[4], d_in, d, dtype),
    }


def _slstm_cell(p, cfg, xg, state):
    """One sLSTM step. xg: (B, 4*d_in) pre-activations from the input path."""
    d_in, h, pd = _dims(cfg)
    c, n, m, h_prev = state
    rec = jnp.einsum("bhp,hqp->bhq", h_prev, p["r_gates"].astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    g = xg.reshape(-1, h, 4 * pd).astype(jnp.float32) + rec
    zi, ii, fi, oi = jnp.split(g, 4, axis=-1)            # (B, H, P) each
    z = jnp.tanh(zi)
    o = jax.nn.sigmoid(oi)
    log_i = ii
    log_f = jax.nn.log_sigmoid(fi + 3.0)
    m_new = jnp.maximum(log_f + m, log_i)
    c_new = jnp.exp(log_f + m - m_new) * c + jnp.exp(log_i - m_new) * z
    n_new = jnp.exp(log_f + m - m_new) * n + jnp.exp(log_i - m_new)
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, m_new, h_new), h_new


def slstm_core(p: dict, cfg, x: jnp.ndarray):
    d_in, h, pd = _dims(cfg)
    b, s, _ = x.shape
    x_in = linear(x, p["up"])
    xc = conv1d(cfg, x_in, p["conv_w"].astype(x_in.dtype))
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(x.dtype)
    xg = linear(xc, p["w_gates"])                         # (B, S, 4*d_in)
    state0 = tuple(jnp.zeros((b, h, pd), jnp.float32) for _ in range(4))

    def step(state, xg_t):
        return _slstm_cell(p, cfg, xg_t, state)

    state, hs = lax.scan(step, state0, jnp.moveaxis(xg, 1, 0))
    out = jnp.moveaxis(hs, 0, 1).reshape(b, s, d_in).astype(x.dtype)
    out = rms_norm(out, p["norm"], cfg.norm_eps)
    cache = {"c": state[0], "n": state[1], "m": state[2], "h": state[3],
             "conv": x_in[:, s - (cfg.conv_width - 1):, :].astype(jnp.float32)}
    return linear(out, p["down"]), cache


def slstm_forward(p: dict, cfg, x: jnp.ndarray) -> jnp.ndarray:
    return slstm_core(p, cfg, x)[0]


def init_slstm_cache(cfg, batch: int) -> dict:
    d_in, h, pd = _dims(cfg)
    return {
        "c": jnp.zeros((batch, h, pd), jnp.float32),
        "n": jnp.zeros((batch, h, pd), jnp.float32),
        "m": jnp.zeros((batch, h, pd), jnp.float32),
        "h": jnp.zeros((batch, h, pd), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, d_in), jnp.float32),
    }


def slstm_decode(p: dict, cfg, x: jnp.ndarray, cache: dict
                 ) -> Tuple[jnp.ndarray, dict]:
    d_in, h, pd = _dims(cfg)
    b = x.shape[0]
    x_in = linear(x[:, 0], p["up"])
    hist = jnp.concatenate(
        [cache["conv"], x_in[:, None, :].astype(jnp.float32)], axis=1)
    xc = jax.nn.silu(jnp.einsum("bkc,kc->bc", hist,
                                p["conv_w"].astype(jnp.float32),
                                preferred_element_type=jnp.float32)
                     ).astype(x.dtype)
    xg = linear(xc, p["w_gates"])
    state = (cache["c"], cache["n"], cache["m"], cache["h"])
    state_new, h_new = _slstm_cell(p, cfg, xg, state)
    out = h_new.reshape(b, d_in).astype(x.dtype)
    out = rms_norm(out, p["norm"], cfg.norm_eps)
    new_cache = {"c": state_new[0], "n": state_new[1], "m": state_new[2],
                 "h": state_new[3], "conv": hist[:, 1:, :]}
    return linear(out, p["down"])[:, None, :], new_cache
