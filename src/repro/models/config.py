"""Model configuration schema covering every assigned architecture family:
dense / moe / hybrid (Mamba2+shared-attn) / ssm (xLSTM) / vlm / audio.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    head_dim: int = 0                # 0 -> d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 1_000_000.0
    use_bias: bool = False
    tie_embeddings: bool = False
    norm_eps: float = 1e-6

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                # per-expert hidden (d_ff of each expert)
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.001

    # hybrid (zamba2-style): Mamba2 layers + one shared attention block
    attn_every: int = 0              # apply shared attn block after every k layers
    ssm_state: int = 0               # Mamba2 N
    ssm_head_dim: int = 64           # Mamba2 P
    ssm_expand: int = 2              # d_inner = expand * d_model
    conv_width: int = 4              # depthwise causal conv (MEC conv1d kernel)

    # ssm (xLSTM): mLSTM blocks with sLSTM every slstm_every layers
    slstm_every: int = 0

    # audio (whisper): encoder-decoder
    encoder_layers: int = 0
    encoder_len: int = 1500          # stub frame-embedding length

    # vlm (llava): patch-embedding prefix (stub)
    prefix_len: int = 0

    max_seq: int = 8192
    dtype: str = "bfloat16"
    remat: bool = True
    # remat policy: "full" recomputes everything; "dots" saves matmul
    # outputs (skips re-running dots AND their TP collectives in the
    # recompute pass, at the cost of saved-activation memory)
    remat_policy: str = "full"
    # Megatron-style sequence parallelism: residual stream is seq-sharded
    # over the model axis between attention and FFN/MoE (RS+AG replaces AR)
    seq_parallel: bool = False
    # MoE execution: 'ep' = shard_map expert parallel (needs mesh), 'local'
    moe_impl: str = "local"
    # int8-quantized EP all_to_all (2x fewer dispatch/combine bytes)
    moe_dispatch_int8: bool = False
    # conv1d dataflow inside SSM blocks: "lowered" materializes the MEC
    # compact L (paper-faithful Algorithm 2 data movement); "fused" is the
    # shift-add dataflow of the fused Pallas kernel (no L at all)
    conv_impl: str = "lowered"
    # int8 KV cache (per token x head scales): ~1.9x less decode HBM
    kv_cache_int8: bool = False
    # int8 error-feedback DP gradient reduction (partial-manual shard_map;
    # not yet composable with moe_impl='ep')
    grad_compress_int8: bool = False
    # causal attention visits only lower-triangle chunk pairs (half the
    # score FLOPs; exact)
    attn_skip_masked: bool = False

    # attention chunking (memory-efficient streaming attention)
    q_chunk: int = 512
    kv_chunk: int = 1024

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------
    # Parameter counts (for MODEL_FLOPS = 6*N*D roofline term)
    # ------------------------------------------------------------------
    def param_count(self, active_only: bool = False) -> int:
        d, h = self.d_model, self.head_dim
        n_q, n_kv = self.n_heads, self.n_kv_heads
        attn = d * h * (n_q + 2 * n_kv) + n_q * h * d
        dense_ffn = 3 * d * self.d_ff if self.d_ff else 0
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        if self.family in ("dense", "vlm"):
            return self.n_layers * (attn + dense_ffn) + emb
        if self.family == "moe":
            e = self.top_k if active_only else self.n_experts
            moe_ffn = 3 * d * self.moe_d_ff * e + d * self.n_experts  # + router
            shared = 3 * d * self.moe_d_ff * self.n_shared_experts
            return self.n_layers * (attn + moe_ffn + shared) + emb
        if self.family == "hybrid":
            d_in = self.ssm_expand * d
            n_h = d_in // self.ssm_head_dim
            mamba = (d * (2 * d_in + 2 * self.ssm_state + n_h)  # in_proj
                     + self.conv_width * (d_in + 2 * self.ssm_state)
                     + d_in * d)                                  # out_proj
            n_attn_apps = self.n_layers // max(1, self.attn_every)
            shared_blk = attn + dense_ffn                          # shared weights
            return self.n_layers * mamba + shared_blk + emb
        if self.family == "ssm":  # xLSTM
            d_in = 2 * d
            mlstm = d * 2 * d_in + 3 * d_in * h * n_q // max(n_q, 1) + d_in * d
            mlstm = 2 * d * d_in + 3 * d_in * d_in + d_in * d      # approx
            return self.n_layers * mlstm + emb
        if self.family == "audio":
            enc = self.encoder_layers * (attn + dense_ffn)
            dec = self.n_layers * (2 * attn + dense_ffn)           # self + cross
            return enc + dec + emb
        raise ValueError(self.family)
