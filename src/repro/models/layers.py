"""Model primitives: norms, linear, conv2d, RoPE, SwiGLU, GQA attention.

Attention comes in two forms:
* ``chunked_attention`` — streaming (flash-style) online-softmax attention
  for train/prefill: O(S^2) FLOPs, O(S * chunk) memory.
* ``decode_attention``  — one new query against a (possibly seq-sharded)
  KV cache; softmax reductions over the sharded seq axis are handled by
  GSPMD (partial max/sum + all-reduce).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.conv_api import conv2d
from repro.parallel.axes import constrain

_NEG = -1e30


# ---------------------------------------------------------------------------
# basics
# ---------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * lax.rsqrt(var + eps)).astype(x.dtype) * w


def linear(x: jnp.ndarray, p: dict) -> jnp.ndarray:
    y = jnp.einsum("...d,df->...f", x, p["w"].astype(x.dtype),
                   preferred_element_type=jnp.float32).astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def init_linear(key, d_in: int, d_out: int, dtype, bias: bool = False,
                scale: Optional[float] = None) -> dict:
    scale = scale if scale is not None else d_in ** -0.5
    p = {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32)
               * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def init_conv2d(key, k_h: int, k_w: int, c_in: int, c_out: int,
                dtype=jnp.float32, bias: bool = True) -> dict:
    p = {"w": (jax.random.normal(key, (k_h, k_w, c_in, c_out), jnp.float32)
               * (k_h * k_w * c_in) ** -0.5).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((c_out,), dtype)
    return p


def conv2d_layer(p: dict, x: jnp.ndarray, *, stride=1, padding="SAME",
                 algorithm: str = "auto",
                 partition: Optional[str | Tuple[str, ...]] = None,
                 plan=None) -> jnp.ndarray:
    """One conv block through the unified front-end (repro.core.conv_api):
    padding, geometry validation, algorithm dispatch AND mesh
    partitioning (DESIGN.md §6) all live there — models never hand-roll
    them.  partition=None is rules-aware: under ``parallel.axes``
    rules the conv shards itself; without a mesh it is single-device.
    plan (a resolved repro.plan.ConvPlan) wins over algorithm/partition
    — resolve it once at layer construction with
    :func:`plan_conv2d_layer` instead of re-deriving per step."""
    y = conv2d(x, p["w"].astype(x.dtype), stride=stride, padding=padding,
               algorithm=algorithm, partition=partition, plan=plan)
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


def plan_conv2d_layer(p: dict, x_shape: Tuple[int, ...], *, stride=1,
                      padding="SAME", dtype=jnp.float32,
                      mode: str = "cached", partition=None):
    """Resolve the layer's ConvPlan ONCE, at construction (DESIGN.md §7).

    x_shape/dtype describe the activations the layer will see (the
    kernel's dtype follows the activations, exactly as
    :func:`conv2d_layer` casts it).  Returns the frozen plan; pass it to
    every ``conv2d_layer(..., plan=)`` step so train/serve loops never
    re-derive — or re-measure — the decision per call.
    """
    import jax as _jax

    from repro.core.conv_api import conv2d_spec
    from repro.plan import plan_conv2d
    spec = conv2d_spec(_jax.ShapeDtypeStruct(tuple(x_shape), dtype),
                       p["w"], stride=stride, padding=padding)
    return plan_conv2d(spec, dtype=dtype, mode=mode, partition=partition)


def swiglu(x: jnp.ndarray, p: dict) -> jnp.ndarray:
    g = linear(x, p["gate"])
    u = linear(x, p["up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    h = constrain(h, "batch", "seq", "ffn")
    return linear(h, p["down"])


def init_swiglu(key, d: int, f: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {"gate": init_linear(k1, d, f, dtype),
            "up": init_linear(k2, d, f, dtype),
            "down": init_linear(k3, f, d, dtype)}


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_cos_sin(positions: jnp.ndarray, dim: int, theta: float):
    """positions (S,) -> cos/sin (S, dim//2) in f32."""
    freqs = theta ** (-jnp.arange(0, dim, 2, dtype=jnp.float32) / dim)
    ang = positions.astype(jnp.float32)[:, None] * freqs[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray):
    """x (..., S, H, D); cos/sin (S, D//2).  Split-half (llama) convention."""
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    c = cos[..., :, None, :].astype(jnp.float32)
    s = sin[..., :, None, :].astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [x1f * c - x2f * s, x2f * c + x1f * s], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# streaming GQA attention (train / prefill)
# ---------------------------------------------------------------------------

def chunked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                      causal: bool = True, q_chunk: int = 512,
                      kv_chunk: int = 1024) -> jnp.ndarray:
    """Online-softmax attention.

    q: (B, Sq, H, D); k, v: (B, Skv, KV, D) with H % KV == 0.
    Returns (B, Sq, H, D) in q.dtype.  Assumes Sq == Skv when causal.
    """
    b, sq, h, d = q.shape
    _, skv, kv, _ = k.shape
    g = h // kv
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    # pad seq to chunk multiples
    pq, pk = (-sq) % q_chunk, (-skv) % kv_chunk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    nq, nk = (sq + pq) // q_chunk, (skv + pk) // kv_chunk
    scale = d ** -0.5

    qc = q.reshape(b, nq, q_chunk, kv, g, d).transpose(1, 0, 3, 4, 2, 5)
    kc = k.reshape(b, nk, kv_chunk, kv, d).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(b, nk, kv_chunk, kv, d).transpose(1, 0, 3, 2, 4)
    # qc: (nq, B, KV, G, Tq, D); kc/vc: (nk, B, KV, Tk, D)

    def q_step(iq, q_i):
        def kv_step(carry, inputs):
            m, l, acc = carry
            ik, k_j, v_j = inputs
            s = jnp.einsum("bkgtd,bkcd->bkgtc", q_i, k_j,
                           preferred_element_type=jnp.float32) * scale
            qpos = iq * q_chunk + jnp.arange(q_chunk)
            kpos = ik * kv_chunk + jnp.arange(kv_chunk)
            mask = kpos[None, :] < skv                       # kv padding
            if causal:
                mask = mask & (kpos[None, :] <= qpos[:, None])
            s = jnp.where(mask[None, None, None], s, _NEG)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkgtc,bkcd->bkgtd", p.astype(v_j.dtype), v_j,
                preferred_element_type=jnp.float32)
            return (m_new, l, acc), None

        m0 = jnp.full((b, kv, g, q_chunk), _NEG, jnp.float32)
        l0 = jnp.zeros((b, kv, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, kv, g, q_chunk, d), jnp.float32)
        (m, l, acc), _ = lax.scan(kv_step, (m0, l0, a0),
                                  (jnp.arange(nk), kc, vc))
        return acc / jnp.maximum(l, 1e-30)[..., None]

    out = lax.map(lambda args: q_step(*args), (jnp.arange(nq), qc))
    # (nq, B, KV, G, Tq, D) -> (B, S, H, D)
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(b, sq + pq, h, d)
    return out[:, :sq].astype(q.dtype)


def chunked_attention_tri(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                          q_chunk: int = 512,
                          kv_chunk: int = 512) -> jnp.ndarray:
    """Causal attention that only visits lower-triangle chunk pairs.

    The plain streaming kernel computes every (q-chunk, kv-chunk) pair and
    masks — 2x the useful FLOPs.  Here the scan runs over the static list
    of non-fully-masked pairs (nq*(nq+1)/2-ish instead of nq*nk), carrying
    full-sequence (m, l, acc) accumulators and updating one q-chunk's rows
    per step.  Exactly the same math; half the score FLOPs at long S.
    """
    b, s, h, d = q.shape
    _, skv, kv, _ = k.shape
    g = h // kv
    q_chunk = min(q_chunk, s)
    kv_chunk = min(kv_chunk, skv)
    pq, pk = (-s) % q_chunk, (-skv) % kv_chunk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    sqp, skp = s + pq, skv + pk
    nq, nk = sqp // q_chunk, skp // kv_chunk
    scale = d ** -0.5
    qc = q.reshape(b, nq, q_chunk, kv, g, d).transpose(1, 0, 3, 4, 2, 5)
    kc = k.reshape(b, nk, kv_chunk, kv, d).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(b, nk, kv_chunk, kv, d).transpose(1, 0, 3, 2, 4)

    pairs = [(i, j) for i in range(nq) for j in range(nk)
             if j * kv_chunk <= (i + 1) * q_chunk - 1]
    iq_list = jnp.asarray([p[0] for p in pairs])
    jk_list = jnp.asarray([p[1] for p in pairs])

    def step(carry, idx):
        m, l, acc = carry                       # (B,KV,G,Sqp[,D])
        iq, jk = idx
        q_i = lax.dynamic_index_in_dim(qc, iq, 0, keepdims=False)
        k_j = lax.dynamic_index_in_dim(kc, jk, 0, keepdims=False)
        v_j = lax.dynamic_index_in_dim(vc, jk, 0, keepdims=False)
        sc = jnp.einsum("bkgtd,bkcd->bkgtc", q_i, k_j,
                        preferred_element_type=jnp.float32) * scale
        qpos = iq * q_chunk + jnp.arange(q_chunk)
        kpos = jk * kv_chunk + jnp.arange(kv_chunk)
        mask = (kpos[None, :] <= qpos[:, None]) & (kpos[None, :] < skv)
        sc = jnp.where(mask[None, None, None], sc, _NEG)
        start = iq * q_chunk
        m_rows = lax.dynamic_slice_in_dim(m, start, q_chunk, axis=3)
        l_rows = lax.dynamic_slice_in_dim(l, start, q_chunk, axis=3)
        a_rows = lax.dynamic_slice_in_dim(acc, start, q_chunk, axis=3)
        m_new = jnp.maximum(m_rows, sc.max(axis=-1))
        p = jnp.exp(sc - m_new[..., None])
        corr = jnp.exp(m_rows - m_new)
        l_new = l_rows * corr + p.sum(axis=-1)
        a_new = a_rows * corr[..., None] + jnp.einsum(
            "bkgtc,bkcd->bkgtd", p.astype(v_j.dtype), v_j,
            preferred_element_type=jnp.float32)
        m = lax.dynamic_update_slice_in_dim(m, m_new, start, axis=3)
        l = lax.dynamic_update_slice_in_dim(l, l_new, start, axis=3)
        acc = lax.dynamic_update_slice_in_dim(acc, a_new, start, axis=3)
        return (m, l, acc), None

    m0 = jnp.full((b, kv, g, sqp), _NEG, jnp.float32)
    l0 = jnp.zeros((b, kv, g, sqp), jnp.float32)
    a0 = jnp.zeros((b, kv, g, sqp, d), jnp.float32)
    (m, l, acc), _ = lax.scan(step, (m0, l0, a0), (iq_list, jk_list))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sqp, h, d)
    return out[:, :s].astype(q.dtype)


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, cache_len,
                     k_scale=None, v_scale=None) -> jnp.ndarray:
    """q: (B, 1, H, D); caches: (B, Smax, KV, D); entries < cache_len valid.

    The cache may be sequence-sharded ("seq_tp"); the max/sum reductions
    below then lower to partial reductions + all-reduce under GSPMD.
    With k_scale/v_scale (B, Smax, KV, 1) the caches are int8 and
    dequantized on the fly (beyond-paper: ~1.9x less decode HBM).
    """
    b, _, h, d = q.shape
    _, smax, kv, _ = k_cache.shape
    g = h // kv
    qg = q.reshape(b, 1, kv, g, d)
    kk = k_cache.astype(jnp.float32) if k_scale is not None else k_cache
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, kk,
                   preferred_element_type=jnp.float32) * d ** -0.5
    if k_scale is not None:
        s = s * k_scale[:, :, :, 0].transpose(0, 2, 1)[:, :, None, None, :]
    valid = jnp.arange(smax)[None, :] < cache_len  # (1 or B, Smax)
    s = jnp.where(valid[:, None, None, None, :], s, _NEG)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = p.sum(axis=-1, keepdims=True)
    p = p / l
    if v_scale is not None:
        p = p * v_scale[:, :, :, 0].transpose(0, 2, 1)[:, :, None, None, :]
        vv = v_cache.astype(jnp.float32)
    else:
        p = p.astype(v_cache.dtype)
        vv = v_cache
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, vv,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, d).astype(q.dtype)


def quantize_kv(x: jnp.ndarray):
    """x (B, S, KV, D) -> int8 values + (B, S, KV, 1) bf16 scales."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1,
                    keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.bfloat16)


# ---------------------------------------------------------------------------
# GQA attention block (projections + rope + qk-norm + cache handling)
# ---------------------------------------------------------------------------

def init_attention(key, cfg, dtype) -> dict:
    d, hd = cfg.d_model, cfg.head_dim
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    p = {
        "wq": init_linear(k1, d, cfg.n_heads * hd, dtype, cfg.use_bias),
        "wk": init_linear(k2, d, cfg.n_kv_heads * hd, dtype, cfg.use_bias),
        "wv": init_linear(k3, d, cfg.n_kv_heads * hd, dtype, cfg.use_bias),
        "wo": init_linear(k4, cfg.n_heads * hd, d, dtype,
                          scale=(cfg.n_heads * hd) ** -0.5 / (2 * cfg.n_layers) ** 0.5),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def attention_qkv(p: dict, cfg, x: jnp.ndarray, positions: jnp.ndarray,
                  use_rope: bool = True):
    """Project + (qk-norm) + RoPE.  x (B, S, D_model) -> q (B,S,H,Dh), k/v (B,S,KV,Dh)."""
    b, s, _ = x.shape
    hd = cfg.head_dim
    q = linear(x, p["wq"]).reshape(b, s, cfg.n_heads, hd)
    k = linear(x, p["wk"]).reshape(b, s, cfg.n_kv_heads, hd)
    v = linear(x, p["wv"]).reshape(b, s, cfg.n_kv_heads, hd)
    q = constrain(q, "batch", "seq", "heads", None)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if use_rope:
        cos, sin = rope_cos_sin(positions, hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    return q, k, v


def attention_block(p: dict, cfg, x: jnp.ndarray, positions: jnp.ndarray,
                    causal: bool = True, use_rope: bool = True,
                    kv_override: Optional[Tuple] = None) -> jnp.ndarray:
    """Full attention (train/prefill path).  Returns (out, (k, v))."""
    q, k, v = attention_qkv(p, cfg, x, positions, use_rope)
    if kv_override is not None:            # cross-attention
        k, v = kv_override
    if causal and getattr(cfg, "attn_skip_masked", False):
        out = chunked_attention_tri(q, k, v, q_chunk=cfg.q_chunk,
                                    kv_chunk=cfg.kv_chunk)
    else:
        out = chunked_attention(q, k, v, causal=causal,
                                q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
    b, s = x.shape[:2]
    out = out.reshape(b, s, cfg.n_heads * cfg.head_dim)
    return linear(out, p["wo"]), (k, v)


def attention_decode(p: dict, cfg, x: jnp.ndarray, cache: dict,
                     use_rope: bool = True) -> Tuple[jnp.ndarray, dict]:
    """One-token decode. x (B, 1, D). cache = {k: (B,Smax,KV,Dh), v: ...,
    len: ()} (+ k_s/v_s scale planes when the cache is int8)."""
    pos = cache["len"][None]               # scalar position
    q, k, v = attention_qkv(p, cfg, x, pos, use_rope)
    int8 = "k_s" in cache

    def upd(buf, val):
        return lax.dynamic_update_slice_in_dim(
            buf, val.astype(buf.dtype), cache["len"], axis=1)

    if int8:
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        k_cache, v_cache = upd(cache["k"], kq), upd(cache["v"], vq)
        k_s, v_s = upd(cache["k_s"], ks), upd(cache["v_s"], vs)
        out = decode_attention(q, k_cache, v_cache, cache["len"] + 1,
                               k_scale=k_s, v_scale=v_s)
        new_cache = {"k": k_cache, "v": v_cache, "k_s": k_s, "v_s": v_s,
                     "len": cache["len"] + 1}
    else:
        k_cache, v_cache = upd(cache["k"], k), upd(cache["v"], v)
        out = decode_attention(q, k_cache, v_cache, cache["len"] + 1)
        new_cache = {"k": k_cache, "v": v_cache, "len": cache["len"] + 1}
    b = x.shape[0]
    out = out.reshape(b, 1, cfg.n_heads * cfg.head_dim)
    return linear(out, p["wo"]), new_cache


def init_kv_cache(cfg, batch: int, max_len: int, dtype) -> dict:
    hd = cfg.head_dim
    if getattr(cfg, "kv_cache_int8", False):
        return {
            "k": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), jnp.int8),
            "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), jnp.int8),
            "k_s": jnp.zeros((batch, max_len, cfg.n_kv_heads, 1),
                             jnp.bfloat16),
            "v_s": jnp.zeros((batch, max_len, cfg.n_kv_heads, 1),
                             jnp.bfloat16),
            "len": jnp.zeros((), jnp.int32),
        }
    return {
        "k": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dtype),
        "len": jnp.zeros((), jnp.int32),
    }
