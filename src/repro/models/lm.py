"""Unified language-model assembly for all assigned architecture families.

Families (DESIGN.md §5):
  dense / vlm — GQA transformer (llava = dense backbone + stub vision prefix)
  moe         — GQA transformer with expert-parallel MoE FFN
  hybrid      — zamba2: Mamba2 layers + ONE shared attention+MLP block
                applied after every ``attn_every`` layers (weight sharing)
  ssm         — xLSTM: mLSTM blocks with an sLSTM every ``slstm_every``
  audio       — whisper: encoder (stub frame embeddings) + decoder with
                cross-attention

All stacks scan over layers (compile-time O(1) in depth); ``cfg.remat``
wraps each block in jax.checkpoint.  Three entry points per model:
``forward`` (train), ``prefill`` (build caches), ``decode_step`` (1 token).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import mamba2, moe, xlstm
from repro.models.config import ModelConfig
from repro.models.layers import (attention_block, attention_decode,
                                 init_attention, init_kv_cache, init_linear,
                                 init_swiglu, linear, rms_norm, swiglu)
from repro.parallel.axes import constrain


def _dtype(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


def _stack_init(init_fn, key, n: int):
    return jax.vmap(init_fn)(jax.random.split(key, n))


def _maybe_remat(fn, cfg):
    if not cfg.remat:
        return fn
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def init_dense_block(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "norm1": jnp.ones((cfg.d_model,), dtype),
        "attn": init_attention(k1, cfg, dtype),
        "norm2": jnp.ones((cfg.d_model,), dtype),
        "mlp": init_swiglu(k2, cfg.d_model, cfg.d_ff, dtype),
    }


def dense_block(p, cfg, x, positions):
    a, kv = attention_block(p["attn"], cfg, rms_norm(x, p["norm1"], cfg.norm_eps),
                            positions)
    # seq_parallel: the attn->mlp residual segment is sequence-sharded over
    # the model axis (Megatron-SP): the partitioner emits reduce-scatter
    # after the attn out-proj and all-gather before the next attention,
    # replacing a full-operand all-reduce (half the collective bytes) and
    # keeping norms/residual memory sharded.
    seg = "seq_tp" if cfg.seq_parallel else "seq"
    x = constrain(x + a, "batch", seg, "embed")
    f = swiglu(rms_norm(x, p["norm2"], cfg.norm_eps), p["mlp"])
    return constrain(x + f, "batch", "seq", "embed"), kv


def init_moe_block(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "norm1": jnp.ones((cfg.d_model,), dtype),
        "attn": init_attention(k1, cfg, dtype),
        "norm2": jnp.ones((cfg.d_model,), dtype),
        "moe": moe.init_moe(k2, cfg, dtype),
    }


def moe_block(p, cfg, x, positions):
    a, kv = attention_block(p["attn"], cfg, rms_norm(x, p["norm1"], cfg.norm_eps),
                            positions)
    # seq_parallel: seq-shard the residual segment feeding the MoE block so
    # the attn out-proj reduce-scatters directly into the layout the EP
    # shard_map wants (P(batch, model, None)) — no separate reshard.
    seg = "seq_tp" if cfg.seq_parallel else "seq"
    x = constrain(x + a, "batch", seg, "embed")
    f, aux = moe.moe_ffn(p["moe"], cfg, rms_norm(x, p["norm2"], cfg.norm_eps))
    return constrain(x + f, "batch", "seq", "embed"), kv, aux


def init_gelu_mlp(key, d, f, dtype):
    k1, k2 = jax.random.split(key)
    return {"up": init_linear(k1, d, f, dtype, bias=True),
            "down": init_linear(k2, f, d, dtype, bias=True)}


def gelu_mlp(x, p):
    h = jax.nn.gelu(linear(x, p["up"]).astype(jnp.float32)).astype(x.dtype)
    h = constrain(h, "batch", "seq", "ffn")
    return linear(h, p["down"])


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------

class LM:
    """Functional model: params are plain pytrees, methods are pure."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------------------------------------- init --
    def init(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        dt = _dtype(cfg)
        keys = jax.random.split(key, 8)
        params: Dict[str, Any] = {
            "emb": (jax.random.normal(keys[0], (cfg.vocab, cfg.d_model),
                                      jnp.float32) * 0.02).astype(dt),
            "final_norm": jnp.ones((cfg.d_model,), dt),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = init_linear(keys[1], cfg.d_model, cfg.vocab, dt)

        fam = cfg.family
        if fam in ("dense", "vlm"):
            params["blocks"] = _stack_init(
                lambda k: init_dense_block(k, cfg, dt), keys[2], cfg.n_layers)
            if fam == "vlm":
                params["vision_proj"] = init_linear(keys[3], cfg.d_model,
                                                    cfg.d_model, dt)
        elif fam == "moe":
            params["blocks"] = _stack_init(
                lambda k: init_moe_block(k, cfg, dt), keys[2], cfg.n_layers)
        elif fam == "hybrid":
            n_super, tail = divmod(cfg.n_layers, cfg.attn_every)
            params["mamba"] = jax.vmap(
                lambda k: _stack_init(lambda kk: mamba2.init_mamba(kk, cfg, dt),
                                      k, cfg.attn_every)
            )(jax.random.split(keys[2], n_super))
            if tail:
                params["mamba_tail"] = _stack_init(
                    lambda k: mamba2.init_mamba(k, cfg, dt), keys[3], tail)
            params["shared"] = init_dense_block(keys[4], cfg, dt)
            params["mamba_norms"] = jnp.ones((cfg.n_layers, cfg.d_model), dt)
        elif fam == "ssm":
            n_super = cfg.n_layers // cfg.slstm_every
            k_m = cfg.slstm_every - 1
            params["mlstm"] = jax.vmap(
                lambda k: _stack_init(lambda kk: xlstm.init_mlstm(kk, cfg, dt),
                                      k, k_m)
            )(jax.random.split(keys[2], n_super))
            params["slstm"] = _stack_init(
                lambda k: xlstm.init_slstm(k, cfg, dt), keys[3], n_super)
        elif fam == "audio":
            params["enc_blocks"] = _stack_init(
                lambda k: self._init_enc_block(k, dt), keys[2],
                cfg.encoder_layers)
            params["dec_blocks"] = _stack_init(
                lambda k: self._init_dec_block(k, dt), keys[3], cfg.n_layers)
            params["enc_norm"] = jnp.ones((cfg.d_model,), dt)
        else:
            raise ValueError(fam)
        return params

    def _init_enc_block(self, key, dt):
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        return {"norm1": jnp.ones((cfg.d_model,), dt),
                "attn": init_attention(k1, cfg, dt),
                "norm2": jnp.ones((cfg.d_model,), dt),
                "mlp": init_gelu_mlp(k2, cfg.d_model, cfg.d_ff, dt)}

    def _init_dec_block(self, key, dt):
        cfg = self.cfg
        k1, k2, k3 = jax.random.split(key, 3)
        return {"norm1": jnp.ones((cfg.d_model,), dt),
                "attn": init_attention(k1, cfg, dt),
                "norm_x": jnp.ones((cfg.d_model,), dt),
                "xattn": init_attention(k2, cfg, dt),
                "norm2": jnp.ones((cfg.d_model,), dt),
                "mlp": init_gelu_mlp(k3, cfg.d_model, cfg.d_ff, dt)}

    # --------------------------------------------------------- embedding --
    def embed(self, params, tokens):
        h = jnp.take(params["emb"], tokens, axis=0)
        return constrain(h, "batch", "seq", "embed")

    def head_weights(self, params):
        if self.cfg.tie_embeddings:
            return params["emb"].T
        return params["lm_head"]["w"]

    # ------------------------------------------------------------ train --
    def forward(self, params, batch) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Returns (final_hidden (B,S,d), aux_loss). Logits are produced by
        the (chunked) loss to avoid materializing (B,S,V)."""
        cfg = self.cfg
        fam = cfg.family
        if fam == "audio":
            return self._forward_audio(params, batch)
        tokens = batch["tokens"]
        h = self.embed(params, tokens)
        if fam == "vlm":
            vis = linear(batch["vision"].astype(h.dtype), params["vision_proj"])
            h = jnp.concatenate([vis, h], axis=1)
        s = h.shape[1]
        positions = jnp.arange(s)
        aux = jnp.zeros((), jnp.float32)

        if fam in ("dense", "vlm"):
            body = _maybe_remat(
                lambda p, x: dense_block(p, cfg, x, positions)[0], cfg)
            h, _ = lax.scan(lambda x, p: (body(p, x), None), h, params["blocks"])
        elif fam == "moe":
            def moe_body(p, x):
                x2, _, a = moe_block(p, cfg, x, positions)
                return x2, a
            body = _maybe_remat(moe_body, cfg)

            def f(carry, p):
                x, acc = carry
                x2, a = body(p, x)
                return (x2, acc + a), None
            (h, aux), _ = lax.scan(f, (h, aux), params["blocks"])
            aux = aux * cfg.router_aux_coef / cfg.n_layers
        elif fam == "hybrid":
            h = self._hybrid_stack(params, h, positions, mode="train")
        elif fam == "ssm":
            h = self._ssm_stack(params, h, mode="train")
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        if fam == "vlm":   # loss only over text positions
            h = h[:, batch["vision"].shape[1]:, :]
        return h, aux

    # hybrid: scan over super-blocks of (attn_every mamba) + shared attn+mlp
    def _hybrid_stack(self, params, h, positions, mode, caches=None):  # lint-ignore: accepted-kwarg-not-forwarded (stack-dispatch signature shared with decode)
        cfg = self.cfg
        n_super, tail = divmod(cfg.n_layers, cfg.attn_every)

        seg = "seq_tp" if cfg.seq_parallel else "seq"
        mamba_body = _maybe_remat(
            lambda p, nrm, x: constrain(
                x + mamba2.mamba_forward(p, cfg, rms_norm(x, nrm, cfg.norm_eps)),
                "batch", seg, "embed"), cfg)

        def super_step(x, inputs):
            p_group, norms = inputs
            x, _ = lax.scan(
                lambda xx, pn: (mamba_body(pn[0], pn[1], xx), None),
                x, (p_group, norms))
            x, _ = dense_block(params["shared"], cfg, x, positions)
            return x, None

        norms = params["mamba_norms"][:n_super * cfg.attn_every].reshape(
            n_super, cfg.attn_every, -1)
        h, _ = lax.scan(super_step, h, (params["mamba"], norms))
        if tail:
            tail_norms = params["mamba_norms"][n_super * cfg.attn_every:]
            h, _ = lax.scan(
                lambda xx, pn: (mamba_body(pn[0], pn[1], xx), None),
                h, (params["mamba_tail"], tail_norms))
        return h

    # ssm: supers of (slstm_every-1 mLSTM) + 1 sLSTM
    def _ssm_stack(self, params, h, mode):  # lint-ignore: accepted-kwarg-not-forwarded (stack-dispatch signature shared with decode)
        cfg = self.cfg
        m_body = _maybe_remat(
            lambda p, x: x + xlstm.mlstm_forward(p, cfg, x), cfg)
        s_body = _maybe_remat(
            lambda p, x: x + xlstm.slstm_forward(p, cfg, x), cfg)

        def super_step(x, inputs):
            p_m, p_s = inputs
            x, _ = lax.scan(lambda xx, p: (m_body(p, xx), None), x, p_m)
            return s_body(p_s, x), None

        h, _ = lax.scan(super_step, h, (params["mlstm"], params["slstm"]))
        return h

    def _forward_audio(self, params, batch):
        cfg = self.cfg
        enc = self.encode(params, batch["frames"])
        tokens = batch["tokens"]
        h = self.embed(params, tokens)
        positions = jnp.arange(h.shape[1])

        def dec_body(p, x):
            return self._dec_block(p, x, positions, (None, None), enc)[0]
        body = _maybe_remat(dec_body, cfg)
        h, _ = lax.scan(lambda x, p: (body(p, x), None), h,
                        params["dec_blocks"])
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        return h, jnp.zeros((), jnp.float32)

    def encode(self, params, frames):
        """Whisper encoder over stub frame embeddings (B, T, d)."""
        cfg = self.cfg
        h = frames.astype(_dtype(cfg))
        positions = jnp.arange(h.shape[1])

        def enc_body(p, x):
            a, _ = attention_block(p["attn"], cfg,
                                   rms_norm(x, p["norm1"], cfg.norm_eps),
                                   positions, causal=False)
            x = x + a
            return x + gelu_mlp(rms_norm(x, p["norm2"], cfg.norm_eps), p["mlp"])
        body = _maybe_remat(enc_body, cfg)
        h, _ = lax.scan(lambda x, p: (body(p, x), None), h,
                        params["enc_blocks"])
        return rms_norm(h, params["enc_norm"], cfg.norm_eps)

    def _dec_block(self, p, x, positions, self_kv, enc):  # lint-ignore: accepted-kwarg-not-forwarded (kv slot reserved for decode cache path)
        cfg = self.cfg
        a, kv = attention_block(p["attn"], cfg,
                                rms_norm(x, p["norm1"], cfg.norm_eps),
                                positions)
        x = x + a
        xa, xkv = attention_block(
            p["xattn"], cfg, rms_norm(x, p["norm_x"], cfg.norm_eps),
            positions, causal=False, use_rope=False,
            kv_override=self._cross_kv(p, enc))
        x = x + xa
        x = x + gelu_mlp(rms_norm(x, p["norm2"], cfg.norm_eps), p["mlp"])
        return x, kv

    def _cross_kv(self, p, enc):
        cfg = self.cfg
        b, t, _ = enc.shape
        k = linear(enc, p["xattn"]["wk"]).reshape(b, t, cfg.n_kv_heads,
                                                  cfg.head_dim)
        v = linear(enc, p["xattn"]["wv"]).reshape(b, t, cfg.n_kv_heads,
                                                  cfg.head_dim)
        return k, v
