"""Mamba2 block (SSD, chunked) for the zamba2 hybrid architecture.

Training/prefill uses the chunked state-space-duality form (scan over
sequence chunks, quadratic within a chunk, linear state hand-off across
chunks).  Decode is the O(1) recurrent update.  The depthwise causal
conv1d is the MEC conv hot-spot (repro.kernels.mec_conv1d on TPU;
pure-jnp reference here so the dry-run HLO stays backend-portable).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.mec import mec_conv1d_depthwise, mec_conv1d_shift
from repro.models.layers import init_linear, linear, rms_norm
from repro.parallel.axes import constrain


def conv1d(cfg, x, w):
    """MEC conv1d with the configured dataflow (DESIGN §2, §Perf)."""
    fn = (mec_conv1d_shift if getattr(cfg, "conv_impl", "lowered") == "fused"
          else mec_conv1d_depthwise)
    return fn(x, w)


def _dims(cfg):
    d_in = cfg.ssm_expand * cfg.d_model
    n_heads = d_in // cfg.ssm_head_dim
    return d_in, n_heads, cfg.ssm_head_dim, cfg.ssm_state


def init_mamba(key, cfg, dtype) -> dict:
    d = cfg.d_model
    d_in, h, p_dim, n = _dims(cfg)
    conv_ch = d_in + 2 * n
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        # order: [z (d_in), xBC (d_in + 2n), dt (h)]
        "in_proj": init_linear(k1, d, 2 * d_in + 2 * n + h, dtype),
        "conv_w": (jax.random.normal(k2, (cfg.conv_width, conv_ch),
                                     jnp.float32) * 0.2).astype(dtype),
        "a_log": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.full((h,), -2.0, jnp.float32),
        "norm": jnp.ones((d_in,), dtype),
        "out_proj": init_linear(k3, d_in, d, dtype),
    }


def _split_proj(zxbcdt, cfg):
    d_in, h, p_dim, n = _dims(cfg)
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in:2 * d_in + 2 * n]
    dt = zxbcdt[..., 2 * d_in + 2 * n:]
    return z, xbc, dt


def ssd_chunked(x, dt, a, b_mat, c_mat, chunk: int = 128):
    """Chunked SSD scan.

    x: (B, S, H, P); dt: (B, S, H) (post-softplus); a: (H,) negative;
    b_mat/c_mat: (B, S, N) (single group, broadcast over heads).
    Returns y (B, S, H, P) f32 and final state (B, H, P, N).
    """
    bsz, s, h, p_dim = x.shape
    n = b_mat.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    xc = x.reshape(bsz, nc, chunk, h, p_dim)
    dtc = dt.reshape(bsz, nc, chunk, h)
    bc = b_mat.reshape(bsz, nc, chunk, n)
    cc = c_mat.reshape(bsz, nc, chunk, n)
    da = dtc * a[None, None, None, :]                   # (B, nc, c, H)

    def step(state, inputs):
        x_k, dt_k, da_k, b_k, c_k = inputs               # chunk leading
        cs = jnp.cumsum(da_k, axis=1)                    # (B, c, H)
        # intra-chunk causal decay L[i,j] = exp(cs_i - cs_j), j <= i
        li = cs[:, :, None, :] - cs[:, None, :, :]       # (B, c, c, H)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        decay = jnp.where(tri[None, :, :, None], jnp.exp(li), 0.0)
        xdt = x_k * dt_k[..., None]                      # discrete input
        y_diag = jnp.einsum("bln,bsn,blsh,bshp->blhp", c_k, b_k, decay, xdt,
                            preferred_element_type=jnp.float32)
        # contribution of incoming state
        g = jnp.exp(cs)                                  # decay from chunk start
        y_off = jnp.einsum("bln,blh,bhpn->blhp", c_k, g, state,
                           preferred_element_type=jnp.float32)
        # state update
        tail = jnp.exp(cs[:, -1:, :] - cs)               # decay to chunk end
        new_state = (state * jnp.exp(cs[:, -1, :])[..., None, None]
                     + jnp.einsum("bsn,bsh,bshp->bhpn", b_k, tail, xdt,
                                  preferred_element_type=jnp.float32))
        return new_state, y_diag + y_off

    state0 = jnp.zeros((bsz, h, p_dim, n), jnp.float32)
    inputs = tuple(jnp.moveaxis(t, 1, 0).astype(jnp.float32)
                   for t in (xc, dtc, da, bc, cc))
    state, yc = lax.scan(step, state0, inputs)
    y = jnp.moveaxis(yc, 0, 1).reshape(bsz, s, h, p_dim)
    return y, state


def mamba_core(p: dict, cfg, x: jnp.ndarray, chunk: int = 128):
    """Full-sequence Mamba2 block. x (B, S, d) -> (out (B,S,d), cache)."""
    d_in, h, p_dim, n = _dims(cfg)
    zxbcdt = linear(x, p["in_proj"])
    z, xbc_raw, dt = _split_proj(zxbcdt, cfg)
    xbc = constrain(xbc_raw, "batch", "seq", "conv_ch")
    xbc = conv1d(cfg, xbc, p["conv_w"].astype(xbc.dtype))
    xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(x.dtype)
    xs = xbc[..., :d_in].reshape(*x.shape[:2], h, p_dim)
    b_mat = xbc[..., d_in:d_in + n]
    c_mat = xbc[..., d_in + n:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    y, state = ssd_chunked(xs.astype(jnp.float32), dt, a,
                           b_mat.astype(jnp.float32),
                           c_mat.astype(jnp.float32), chunk=chunk)
    y = y + xs.astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = y.reshape(*x.shape[:2], d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                 p["norm"], cfg.norm_eps)
    cache = {"state": state,
             "conv": xbc_raw[:, x.shape[1] - (cfg.conv_width - 1):, :]}
    return linear(y, p["out_proj"]), cache


def mamba_forward(p: dict, cfg, x: jnp.ndarray,
                  chunk: int = 128) -> jnp.ndarray:
    return mamba_core(p, cfg, x, chunk)[0]


def init_mamba_cache(cfg, batch: int, dtype) -> dict:
    d_in, h, p_dim, n = _dims(cfg)
    conv_ch = d_in + 2 * n
    return {
        "state": jnp.zeros((batch, h, p_dim, n), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_ch), dtype),
    }


def mamba_decode(p: dict, cfg, x: jnp.ndarray, cache: dict
                 ) -> Tuple[jnp.ndarray, dict]:
    """One-token recurrent step. x (B, 1, d)."""
    d_in, h, p_dim, n = _dims(cfg)
    zxbcdt = linear(x, p["in_proj"])
    z, xbc, dt = _split_proj(zxbcdt[:, 0], cfg)
    # depthwise conv over (k_w-1 history, current)
    hist = jnp.concatenate([cache["conv"], xbc[:, None, :].astype(cache["conv"].dtype)], axis=1)
    conv_out = jnp.einsum("bkc,kc->bc", hist.astype(jnp.float32),
                          p["conv_w"].astype(jnp.float32),
                          preferred_element_type=jnp.float32)
    xbc_c = jax.nn.silu(conv_out)
    xs = xbc_c[..., :d_in].reshape(-1, h, p_dim)
    b_vec = xbc_c[..., d_in:d_in + n]
    c_vec = xbc_c[..., d_in + n:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B, H)
    a = -jnp.exp(p["a_log"])
    da = jnp.exp(dt * a[None, :])                                  # (B, H)
    state = (cache["state"] * da[..., None, None]
             + jnp.einsum("bh,bhp,bn->bhpn", dt, xs, b_vec,
                          preferred_element_type=jnp.float32))
    y = jnp.einsum("bhpn,bn->bhp", state, c_vec,
                   preferred_element_type=jnp.float32)
    y = y + xs * p["d_skip"][None, :, None]
    y = y.reshape(-1, 1, d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)[:, None, :],
                 p["norm"], cfg.norm_eps)
    new_cache = {"state": state, "conv": hist[:, 1:, :]}
    return linear(y, p["out_proj"]), new_cache
