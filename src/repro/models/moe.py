"""Mixture-of-Experts FFN with expert parallelism.

Top-k token-choice routing with capacity buckets.  Two executors sharing
the same routing math (so CPU smoke tests validate the distributed path):

* ``_moe_local`` — all experts resident; pure jnp (unit tests / no mesh).
* ``_moe_ep``    — shard_map over the mesh: experts sharded over the
  ``model`` axis, tokens sequence-sharded over ``model`` inside the block
  (SP), dispatch/return via two ``all_to_all`` collectives (DESIGN.md §6).

Dropped tokens (over capacity) fall back to the residual path, standard
for capacity-based MoE.
"""
from __future__ import annotations

import functools
import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core.compat import shard_map
from repro.models.layers import init_linear, init_swiglu, swiglu
from repro.parallel.axes import current_rules


def init_moe(key, cfg, dtype) -> dict:
    e, d, f = cfg.n_experts, cfg.d_model, cfg.moe_d_ff
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    scale = d ** -0.5
    p = {
        "router": (jax.random.normal(k1, (d, e), jnp.float32) * scale),
        "wg": (jax.random.normal(k2, (e, d, f), jnp.float32) * scale).astype(dtype),
        "wu": (jax.random.normal(k3, (e, d, f), jnp.float32) * scale).astype(dtype),
        "wd": (jax.random.normal(k4, (e, f, d), jnp.float32) * f ** -0.5).astype(dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = init_swiglu(k5, d, f * cfg.n_shared_experts, dtype)
    return p


def _capacity(t: int, cfg) -> int:
    c = int(math.ceil(t * cfg.top_k / cfg.n_experts * cfg.capacity_factor))
    return max(4, -(-c // 4) * 4)


def _route(x_flat: jnp.ndarray, router_w: jnp.ndarray, cfg):
    """x_flat (T, d) -> gate weights (T, k), expert ids (T, k), aux loss."""
    logits = jnp.einsum("td,de->te", x_flat.astype(jnp.float32), router_w,
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gw, idx = lax.top_k(probs, cfg.top_k)
    gw = gw / jnp.maximum(gw.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance aux: E * sum_e f_e * p_e
    e = cfg.n_experts
    fracs = jnp.mean(
        (jax.nn.one_hot(idx, e, dtype=jnp.float32)).sum(1), axis=0)
    aux = e * jnp.sum(fracs * jnp.mean(probs, axis=0)) / cfg.top_k
    return gw, idx, aux


def _pack(x_flat, gw, idx, capacity: int, cfg):  # lint-ignore: accepted-kwarg-not-forwarded (gates applied at unpack; kept for dispatch symmetry)
    """Scatter tokens into (E, C, d) capacity buckets."""
    t, d = x_flat.shape
    k, e = cfg.top_k, cfg.n_experts
    e_idx = idx.reshape(-1)                                  # (T*k,)
    tok_idx = jnp.repeat(jnp.arange(t), k)                   # (T*k,)
    onehot = jax.nn.one_hot(e_idx, e, dtype=jnp.int32)       # (T*k, E)
    pos = jnp.take_along_axis(jnp.cumsum(onehot, axis=0) - 1,
                              e_idx[:, None], axis=1)[:, 0]  # (T*k,)
    buckets = jnp.zeros((e, capacity, d), x_flat.dtype)
    buckets = buckets.at[e_idx, pos].set(x_flat[tok_idx], mode="drop")
    return buckets, (e_idx, pos, tok_idx)


def _unpack(expert_out, routing, gw, t: int, d: int):
    e_idx, pos, tok_idx = routing
    vals = expert_out.at[e_idx, pos].get(mode="fill", fill_value=0.0)
    w = gw.reshape(-1)[:, None].astype(vals.dtype)
    return jnp.zeros((t, d), vals.dtype).at[tok_idx].add(w * vals)


def _expert_ffn(buckets, wg, wu, wd):
    """buckets (E, C, d) x per-expert SwiGLU -> (E, C, d); f32 accumulation."""
    g = jnp.einsum("ecd,edf->ecf", buckets, wg,
                   preferred_element_type=jnp.float32)
    u = jnp.einsum("ecd,edf->ecf", buckets, wu,
                   preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * u).astype(buckets.dtype)
    return jnp.einsum("ecf,efd->ecd", h, wd,
                      preferred_element_type=jnp.float32).astype(buckets.dtype)


def _moe_local(p, cfg, x):
    b, s, d = x.shape
    x_flat = x.reshape(b * s, d)
    gw, idx, aux = _route(x_flat, p["router"], cfg)
    cap = _capacity(b * s, cfg)
    buckets, routing = _pack(x_flat, gw, idx, cap, cfg)
    out = _expert_ffn(buckets, p["wg"], p["wu"], p["wd"])
    y = _unpack(out, routing, gw, b * s, d).reshape(b, s, d)
    return y, aux


# ---------------------------------------------------------------------------
# int8 all_to_all (beyond-paper, DESIGN §6): dispatch/combine activations are
# quantized per-row to int8 with a bf16 scale before crossing the ICI, in
# BOTH directions (the VJP quantizes the cotangents too) — 2x fewer
# collective bytes on the EP a2a at ~0.4% relative rounding error per hop.
# ---------------------------------------------------------------------------

def _q8(x):
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1,
                    keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.bfloat16)


def _a2a(v, ep, split_axis, concat_axis):
    return lax.all_to_all(v, ep, split_axis=split_axis,
                          concat_axis=concat_axis, tiled=True)


def _q8_a2a(x, ep, split_axis, concat_axis):
    q, s = _q8(x)
    qr = _a2a(q, ep, split_axis, concat_axis)
    sr = _a2a(s, ep, split_axis, concat_axis)
    return (qr.astype(jnp.float32) * sr.astype(jnp.float32)).astype(x.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def int8_all_to_all(x, ep, split_axis, concat_axis):
    return _q8_a2a(x, ep, split_axis, concat_axis)


def _int8_a2a_fwd(x, ep, split_axis, concat_axis):
    return _q8_a2a(x, ep, split_axis, concat_axis), None


def _int8_a2a_bwd(ep, split_axis, concat_axis, _, g):
    # reverse direction: swap split/concat; quantize the cotangents too
    return (_q8_a2a(g, ep, concat_axis, split_axis),)


int8_all_to_all.defvjp(_int8_a2a_fwd, _int8_a2a_bwd)


def _moe_ep(p, cfg, x, rules):
    mesh, ep = rules.mesh, rules.ep_axis
    dp = rules.dp_axes
    sizes = dict(mesh.shape)
    dp_prod = 1
    for a in dp:
        dp_prod *= sizes[a]
    if x.shape[0] % max(dp_prod, 1) or cfg.n_experts % sizes[ep]:
        return _moe_local(p, cfg, x)        # undistributable cell: replicate
    batch_ax = dp if len(dp) != 1 else dp[0]
    # tokens: batch over DP; seq over EP (sequence parallelism) when it
    # divides — decode steps (S=1) replicate over EP instead (the expert
    # compute is then 16x redundant but negligible at one token).
    seq_ax = ep if x.shape[1] % sizes[ep] == 0 else None
    x_spec = P(batch_ax, seq_ax, None)
    all_axes = tuple(mesh.axis_names)

    def fn(x_loc, router, wg, wu, wd):
        b, s, d = x_loc.shape
        t = b * s
        x_flat = x_loc.reshape(t, d)
        gw, idx, aux = _route(x_flat, router, cfg)
        cap = _capacity(t, cfg)
        buckets, routing = _pack(x_flat, gw, idx, cap, cfg)
        a2a = (int8_all_to_all
               if getattr(cfg, "moe_dispatch_int8", False)
               else lambda v, ax, s_, c_: lax.all_to_all(
                   v, ax, split_axis=s_, concat_axis=c_, tiled=True))
        # dispatch: (E, C, d) -> (E_loc, ep*C, d)
        recv = a2a(buckets, ep, 0, 1)
        out = _expert_ffn(recv, wg, wu, wd)
        # return: (E_loc, ep*C, d) -> (E, C, d)
        back = a2a(out, ep, 1, 0)
        y = _unpack(back, routing, gw, t, d).reshape(b, s, d)
        return y, lax.pmean(aux, all_axes)

    y, aux = shard_map(
        fn, mesh=mesh,
        in_specs=(x_spec, P(None, None), P(ep, None, None),
                  P(ep, None, None), P(ep, None, None)),
        out_specs=(x_spec, P()),
        check_vma=False,
    )(x, p["router"], p["wg"], p["wu"], p["wd"])
    return y, aux


def moe_ffn(p: dict, cfg, x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (B, S, d) -> (y, aux_loss).  Adds shared experts if configured."""
    rules = current_rules()
    if cfg.moe_impl == "ep" and rules is not None and rules.ep_axis:
        y, aux = _moe_ep(p, cfg, x, rules)
    else:
        y, aux = _moe_local(p, cfg, x)
    if cfg.n_shared_experts:
        y = y + swiglu(x, p["shared"])
    return y, aux
