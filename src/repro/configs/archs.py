"""The 10 assigned architectures (exact configs from the assignment) and
reduced smoke variants of each family.

Sources (verification tier in brackets, per assignment):
qwen3-4b [hf], phi3-medium-14b [arXiv:2404.14219], command-r-35b [hf],
yi-6b [arXiv:2403.04652], zamba2-7b [arXiv:2411.15242],
qwen3-moe-30b-a3b [hf], kimi-k2-1t-a32b [arXiv:2501.kimi2],
llava-next-34b [hf], xlstm-125m [arXiv:2405.04517],
whisper-tiny [arXiv:2212.04356].
"""
from __future__ import annotations

from repro.models.config import ModelConfig

MAX_SEQ = 32768 + 2048   # covers prefill_32k + decode headroom

ARCHS = {
    "qwen3-4b": ModelConfig(
        name="qwen3-4b", family="dense", n_layers=36, d_model=2560,
        n_heads=32, n_kv_heads=8, d_ff=9728, vocab=151936, head_dim=128,
        qk_norm=True, rope_theta=1e6, tie_embeddings=True, max_seq=MAX_SEQ),
    "phi3-medium-14b": ModelConfig(
        name="phi3-medium-14b", family="dense", n_layers=40, d_model=5120,
        n_heads=40, n_kv_heads=10, d_ff=17920, vocab=100352, head_dim=128,
        rope_theta=1e4, max_seq=MAX_SEQ),
    "command-r-35b": ModelConfig(
        name="command-r-35b", family="dense", n_layers=40, d_model=8192,
        n_heads=64, n_kv_heads=8, d_ff=22528, vocab=256000, head_dim=128,
        use_bias=False, tie_embeddings=True, rope_theta=8e6, max_seq=MAX_SEQ),
    "yi-6b": ModelConfig(
        name="yi-6b", family="dense", n_layers=32, d_model=4096,
        n_heads=32, n_kv_heads=4, d_ff=11008, vocab=64000, head_dim=128,
        rope_theta=5e6, max_seq=MAX_SEQ),
    "zamba2-7b": ModelConfig(
        name="zamba2-7b", family="hybrid", n_layers=81, d_model=3584,
        n_heads=32, n_kv_heads=32, d_ff=14336, vocab=32000, head_dim=112,
        attn_every=6, ssm_state=64, ssm_head_dim=64, ssm_expand=2,
        conv_width=4, max_seq=524288 + 64),
    "qwen3-moe-30b-a3b": ModelConfig(
        name="qwen3-moe-30b-a3b", family="moe", n_layers=48, d_model=2048,
        n_heads=32, n_kv_heads=4, d_ff=768, vocab=151936, head_dim=128,
        qk_norm=True, n_experts=128, top_k=8, moe_d_ff=768,
        rope_theta=1e6, max_seq=MAX_SEQ, moe_impl="ep"),
    "kimi-k2-1t-a32b": ModelConfig(
        name="kimi-k2-1t-a32b", family="moe", n_layers=61, d_model=7168,
        n_heads=64, n_kv_heads=8, d_ff=2048, vocab=163840, head_dim=112,
        n_experts=384, top_k=8, moe_d_ff=2048, n_shared_experts=1,
        rope_theta=5e7, max_seq=MAX_SEQ, moe_impl="ep"),
    "llava-next-34b": ModelConfig(
        name="llava-next-34b", family="vlm", n_layers=60, d_model=7168,
        n_heads=56, n_kv_heads=8, d_ff=20480, vocab=64000, head_dim=128,
        prefix_len=2880, rope_theta=1e6, max_seq=MAX_SEQ),
    "xlstm-125m": ModelConfig(
        name="xlstm-125m", family="ssm", n_layers=12, d_model=768,
        n_heads=4, n_kv_heads=4, d_ff=0, vocab=50304,
        slstm_every=4, conv_width=4, max_seq=524288 + 64),
    "whisper-tiny": ModelConfig(
        name="whisper-tiny", family="audio", n_layers=4, d_model=384,
        n_heads=6, n_kv_heads=6, d_ff=1536, vocab=51865, head_dim=64,
        encoder_layers=4, encoder_len=1500, max_seq=MAX_SEQ),
}


def smoke_config(arch: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests (one fwd/train step)."""
    cfg = ARCHS[arch]
    common = dict(n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
                  vocab=256, max_seq=128, dtype="float32", remat=False,
                  q_chunk=16, kv_chunk=16)
    if cfg.family == "moe":
        return cfg.with_(**common, d_ff=96, moe_d_ff=96, n_experts=8,
                         top_k=2, head_dim=16, moe_impl="local",
                         capacity_factor=8.0)
    if cfg.family == "hybrid":
        common = dict(common, n_layers=5, n_kv_heads=4)
        return cfg.with_(**common, d_ff=96, attn_every=2, head_dim=16,
                         ssm_state=8, ssm_head_dim=8)
    if cfg.family == "ssm":
        return cfg.with_(**common, slstm_every=2, d_ff=0, head_dim=32)
    if cfg.family == "audio":
        common = dict(common, n_layers=2)
        return cfg.with_(**common, encoder_layers=2, d_ff=96, head_dim=16,
                         encoder_len=12)
    if cfg.family == "vlm":
        return cfg.with_(**common, d_ff=96, prefix_len=8, head_dim=16)
    return cfg.with_(**common, d_ff=96, head_dim=16)
