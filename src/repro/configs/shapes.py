"""Assigned input-shape cells and ShapeDtypeStruct input specs.

LM shapes (per assignment): train_4k / prefill_32k lower ``train_step`` /
``prefill``; decode_32k / long_500k lower ``serve_step`` (one token against
a seq_len cache).  long_500k runs only for sub-quadratic archs
(zamba2-7b, xlstm-125m) — see DESIGN.md §5 for the recorded skips.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import serve
from repro.models.config import ModelConfig
from repro.models.lm import LM


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}

# archs with O(1)/sub-quadratic decode state — the only ones that run long_500k
LONG_CONTEXT_ARCHS = ("zamba2-7b", "xlstm-125m")


def cell_applicable(arch: str, shape: str) -> bool:
    if shape == "long_500k":
        return arch in LONG_CONTEXT_ARCHS
    return True


def smoke_shape(cell: ShapeCell) -> ShapeCell:
    return dataclasses.replace(cell, seq_len=32, global_batch=2)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_input_specs(cfg: ModelConfig, cell: ShapeCell) -> Dict:
    b, s = cell.global_batch, cell.seq_len
    dt = jnp.dtype(cfg.dtype)
    if cfg.family == "vlm":
        s_text = s - cfg.prefix_len
        return {"tokens": _sds((b, s_text), jnp.int32),
                "labels": _sds((b, s_text), jnp.int32),
                "vision": _sds((b, cfg.prefix_len, cfg.d_model), dt)}
    if cfg.family == "audio":
        return {"tokens": _sds((b, s), jnp.int32),
                "labels": _sds((b, s), jnp.int32),
                "frames": _sds((b, cfg.encoder_len, cfg.d_model), dt)}
    return {"tokens": _sds((b, s), jnp.int32),
            "labels": _sds((b, s), jnp.int32)}


def prefill_input_specs(cfg: ModelConfig, cell: ShapeCell) -> Dict:
    spec = train_input_specs(cfg, cell)
    spec.pop("labels")
    return spec


def decode_input_specs(cfg: ModelConfig, cell: ShapeCell) -> Dict:
    """Decode: one new token against a seq_len cache."""
    model = LM(cfg)
    cache = jax.eval_shape(
        lambda: serve.init_decode_cache(model, cell.global_batch,
                                        cell.seq_len))
    return {"cache": cache,
            "tokens": _sds((cell.global_batch, 1), jnp.int32)}


def input_specs(cfg: ModelConfig, cell: ShapeCell) -> Dict:
    if cell.kind == "train":
        return train_input_specs(cfg, cell)
    if cell.kind == "prefill":
        return prefill_input_specs(cfg, cell)
    return decode_input_specs(cfg, cell)


def make_batch(cfg: ModelConfig, cell: ShapeCell, seed: int = 0) -> Dict:
    """Concrete synthetic batch matching input_specs (for smokes/examples)."""
    if cell.kind == "decode":
        model = LM(cfg)
        cache = serve.init_decode_cache(model, cell.global_batch,
                                        cell.seq_len)
        tokens = jax.random.randint(jax.random.key(seed),
                                    (cell.global_batch, 1), 0,
                                    cfg.vocab, dtype=jnp.int32)
        return {"cache": cache, "tokens": tokens}
    specs = input_specs(cfg, cell)
    key = jax.random.key(seed)

    def gen(path, s):  # lint-ignore: accepted-kwarg-not-forwarded (tree_map_with_path callback signature)
        nonlocal key
        key, sub = jax.random.split(key)
        if jnp.issubdtype(s.dtype, jnp.integer):
            return jax.random.randint(sub, s.shape, 0, max(2, cfg.vocab - 1),
                                      dtype=s.dtype)
        return jnp.zeros(s.shape, s.dtype)

    return jax.tree_util.tree_map_with_path(gen, specs)
