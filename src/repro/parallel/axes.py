"""Logical-axis sharding: model code names activation/parameter axes
logically ("batch", "seq", "tp", "expert", ...) and the launcher installs a
rule set mapping them to mesh axes.  Outside any mesh (unit tests, CPU
smokes) every annotation is a no-op, so the same model code runs
everywhere.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    mesh: Mesh
    # logical name -> mesh axis (str), tuple of mesh axes, or None (replicate)
    rules: dict
    dp_axes: Tuple[str, ...] = ("data",)   # gradient/psum axes
    ep_axis: Optional[str] = "model"       # expert-parallel a2a axis
    tp_axis: Optional[str] = "model"

    def spec(self, logical_axes) -> P:
        return P(*(self.rules.get(a) if a is not None else None
                   for a in logical_axes))


_state = threading.local()


def current_rules() -> Optional[ShardingRules]:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Optional[ShardingRules]):
    prev = current_rules()
    _state.rules = rules
    try:
        yield
    finally:
        _state.rules = prev


def constrain(x, *logical_axes):
    """Annotate activation sharding; no-op when no rules are installed."""
    rules = current_rules()
    if rules is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, rules.spec(logical_axes)))


# Default logical->mesh mapping for the production mesh (DESIGN.md §6).
def default_rules(mesh: Mesh) -> ShardingRules:
    axis_names = mesh.axis_names
    dp = tuple(a for a in ("pod", "data") if a in axis_names)
    tp = "model" if "model" in axis_names else None
    return ShardingRules(
        mesh=mesh,
        rules={
            "batch": dp if len(dp) > 1 else (dp[0] if dp else None),
            "seq": None,
            "seq_tp": tp,       # sequence-parallel regions (MoE SP, KV cache)
            "embed": None,
            "heads": tp,
            "kv_heads": None,   # kv heads may not divide tp; replicate
            "head_dim": None,
            "ffn": tp,
            "expert": tp,
            "vocab": tp,
            "conv_ch": tp,
            "zero": dp if len(dp) > 1 else (dp[0] if dp else None),
        },
        dp_axes=dp,
        ep_axis=tp,
        tp_axis=tp,
    )
