"""Gradient compression: int8 quantized DP all-reduce with error feedback.

Owns the data-parallel gradient reduction (so it must run inside a
shard_map over the DP axes, where per-shard gradients are visible before
reduction).  Each leaf is quantized to int8 with a per-leaf scale; the
quantization error is carried in an error-feedback buffer folded into the
next step's gradient — the standard convergence-preserving trick.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax import lax


def quantize(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum(grads, ef, dp_axes: Tuple[str, ...]):
    """grads/ef: local f32 trees.  Returns (reduced grads, new ef).

    The wire carries int8 values (+ one f32 scale per leaf per shard):
    an all_gather of int8 moves 1 byte/element vs the 8 bytes/element a
    ring f32 all-reduce moves — the reduction itself happens locally as a
    scale-weighted sum of the gathered shards (each shard has its own
    quantization scale, so the sum is exact in the quantized domain).
    """
    n = 1
    for ax in dp_axes:
        n *= lax.psum(1, ax)

    def one(g, e):
        g = g.astype(jnp.float32) + e
        q, scale = quantize(g)
        gathered = lax.all_gather(q, dp_axes)               # (n, ...) int8
        scales = lax.all_gather(scale, dp_axes)             # (n,) f32
        summed = jnp.tensordot(scales, gathered.astype(jnp.float32),
                               axes=(0, 0))
        new_e = g - dequantize(q, scale)
        return summed / n, new_e

    out = jax.tree.map(one, grads, ef)
    reduced = jax.tree.map(lambda o: o[0], out,
                           is_leaf=lambda x: isinstance(x, tuple))
    new_ef = jax.tree.map(lambda o: o[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    return reduced, new_ef


def init_ef(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
