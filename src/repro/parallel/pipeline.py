"""GPipe-style pipeline parallelism as a shard_map primitive.

``pipeline_apply`` runs a layer-stacked block function over a mesh axis
holding pipeline stages: each stage owns ``n_layers/n_stages`` layers
(params sharded on their leading dim), microbatches flow stage-to-stage
via ``ppermute``.  The schedule is the classic GPipe fill/steady/drain
(n_micro + n_stages - 1 ticks); autodiff through ppermute gives the
reverse-order backward schedule for free, and jax.checkpoint on the
block keeps the per-stage activation footprint at
O(n_micro x microbatch) inputs rather than full activations.

This is the PP building block referenced in DESIGN.md §6.  The
production 2x16x16 mesh uses the pod axis for DP by default; a
pipeline deployment re-labels it ("pipe", 16, 16) and wires this
primitive around the layer stack — exercised on a 4-stage host mesh in
tests/test_pipeline.py, including gradient flow.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.compat import shard_map


def pipeline_apply(block_fn: Callable, stacked_params, x: jnp.ndarray,
                   mesh: Mesh, pp_axis: str, n_microbatches: int,
                   remat: bool = True) -> jnp.ndarray:
    """Run ``x`` through all layers, stage-sharded over ``pp_axis``.

    block_fn(params_one_layer, h) -> h;  stacked_params leaves are
    (n_layers, ...) with n_layers % n_stages == 0; x is (batch, ...) with
    batch % n_microbatches == 0.  Returns the full-batch output,
    replicated over ``pp_axis``.
    """
    n_stages = dict(mesh.shape)[pp_axis]
    n_layers = jax.tree.leaves(stacked_params)[0].shape[0]
    assert n_layers % n_stages == 0, (n_layers, n_stages)
    batch = x.shape[0]
    assert batch % n_microbatches == 0, (batch, n_microbatches)
    mb = batch // n_microbatches
    m = n_microbatches
    fn = jax.checkpoint(block_fn) if remat else block_fn

    def stage_stack(params_local, h):
        out, _ = lax.scan(lambda hh, p: (fn(p, hh), None), h, params_local)
        return out

    def pipelined(params_local, x_local):
        stage = lax.axis_index(pp_axis)
        xs = x_local.reshape((m, mb) + x_local.shape[1:])
        zero = jnp.zeros((mb,) + x_local.shape[1:], x_local.dtype)
        fwd = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        def tick(carry, t):
            recv, outs = carry
            mb_idx = t - stage
            live = (mb_idx >= 0) & (mb_idx < m)
            # stage 0 reads its own microbatch; others take the wire
            inp = jnp.where(stage == 0,
                            xs[jnp.clip(t, 0, m - 1)], recv)
            h = stage_stack(params_local, inp)
            h = jnp.where(live, h, jnp.zeros_like(h))
            # last stage banks its finished microbatch (read-modify-write
            # so non-banking ticks never clobber a stored slot)
            bank = (stage == n_stages - 1) & live
            idx = jnp.clip(mb_idx, 0, m - 1)
            prev = lax.dynamic_slice_in_dim(outs, idx, 1, axis=0)[0]
            outs = lax.dynamic_update_slice_in_dim(
                outs, jnp.where(bank, h, prev)[None], idx, axis=0)
            recv = lax.ppermute(h, pp_axis, fwd)
            return (recv, outs), None

        outs0 = jnp.zeros((m, mb) + x_local.shape[1:], x_local.dtype)
        (_, outs), _ = lax.scan(tick, (zero, outs0),
                                jnp.arange(m + n_stages - 1))
        # only the last stage holds real outputs; broadcast to all stages
        outs = lax.psum(outs, pp_axis)
        return outs.reshape((batch,) + x_local.shape[1:])

    p_spec = jax.tree.map(lambda _: P(pp_axis), stacked_params)
    return shard_map(
        pipelined, mesh=mesh,
        in_specs=(p_spec, P()), out_specs=P(),
        check_vma=False,
    )(stacked_params, x)
