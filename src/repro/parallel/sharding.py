"""Parameter/optimizer/cache sharding: name-based rules + divisibility
fallback, and ZeRO-1 sharding of the optimizer moments.

Rules map parameter *path names* to logical column/row roles; any mesh
axis that does not divide the corresponding dimension is dropped
(replicated), which transparently handles e.g. kv_heads=8 on a model=16
axis or layer-stacked leading dims.
"""
from __future__ import annotations

import re
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# (regex over '/'-joined path) -> spec for the LAST ndim dims of the leaf.
# Leading (layer-stack) dims are padded with None.
_PARAM_RULES = [
    (r"emb$",                         ("model", None)),       # (V, d) vocab-sharded
    (r"lm_head/w$",                   (None, "model")),
    (r"vision_proj/w$",               (None, "model")),
    (r"(wq|wk|wv)/w$",                (None, "model")),
    (r"wo/w$",                        ("model", None)),
    (r"(gate|up)/w$",                 (None, "model")),
    (r"down/w$",                      ("model", None)),
    (r"moe/router$",                  (None, None)),
    (r"moe/(wg|wu|wd)$",              ("model", None, None)),  # experts
    (r"shared/(wg|wu|wd)$",           ("model", None, None)),
    (r"in_proj/w$",                   (None, "model")),
    (r"out_proj/w$",                  ("model", None)),
    (r"conv_w$",                      (None, "model")),
    (r"w_gates/w$",                   (None, "model")),
    (r"r_gates$",                     ("model", None, None)),
    (r"(wif)/w$",                     (None, None)),
    (r"/b$",                          (None,)),                # biases replicated
]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _fit(spec_tail: Tuple, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Pad the rule to ndim and drop axes that don't divide the dim."""
    ndim = len(shape)
    tail = list(spec_tail)[-ndim:]
    full = [None] * (ndim - len(tail)) + tail
    sizes = dict(mesh.shape)
    out = []
    for dim, ax in zip(shape, full):
        if ax is None:
            out.append(None)
        else:
            axes = (ax,) if isinstance(ax, str) else tuple(ax)
            if any(a not in sizes for a in axes):   # axis absent on this mesh
                out.append(None)
                continue
            prod = int(np.prod([sizes[a] for a in axes]))
            out.append(ax if dim % prod == 0 else None)
    return P(*out)


def param_specs(params_shapes, mesh: Mesh):
    """Tree of PartitionSpec matching a tree of ShapeDtypeStruct/arrays."""

    def one(path, leaf):
        name = _path_str(path)
        for pat, tail in _PARAM_RULES:
            if re.search(pat, name):
                return _fit(tail, leaf.shape, mesh)
        return P(*([None] * len(leaf.shape)))

    return jax.tree_util.tree_map_with_path(one, params_shapes)


def zero1_specs(param_spec_tree, params_shapes, mesh: Mesh,
                zero_axes: Tuple[str, ...] = ("data",)):
    """ZeRO-1: shard optimizer moments over the DP axes too.

    For each leaf, find the first dimension that is unsharded in the param
    spec and divisible by the DP axis product; shard it over zero_axes.
    Leaves with no eligible dim keep the param spec (replicated moments).
    """
    sizes = dict(mesh.shape)
    dp = int(np.prod([sizes[a] for a in zero_axes])) if zero_axes else 1

    def one(spec: P, leaf):
        if dp <= 1:
            return spec
        parts = list(spec) + [None] * (len(leaf.shape) - len(spec))
        for i, (dim, ax) in enumerate(zip(leaf.shape, parts)):
            if ax is None and dim % dp == 0 and dim > 0:
                parts[i] = zero_axes if len(zero_axes) > 1 else zero_axes[0]
                return P(*parts)
        return spec

    return jax.tree.map(one, param_spec_tree, params_shapes,
                        is_leaf=lambda x: isinstance(x, P))


def opt_state_specs(param_spec_tree, params_shapes, mesh: Mesh,
                    zero_axes=("data",)):
    z = zero1_specs(param_spec_tree, params_shapes, mesh, zero_axes)
    return {"m": z, "v": z, "step": P()}


def cache_specs(cache_shapes, mesh: Mesh, rules) -> Any:
    """KV/state caches: batch over DP, seq over model where divisible."""
    sizes = dict(mesh.shape)

    def one(path, leaf):
        name = _path_str(path)
        nd = len(leaf.shape)
        if name.endswith("len") or nd == 0:
            return P()
        # layer-stacked KV caches: (..., B, S, KV, D)
        if re.search(r"(attn_k|attn_v|cross_k|cross_v|/k|/v|/k_s|/v_s)$", name) and nd >= 4:
            spec = [None] * nd
            b_dim, s_dim = nd - 4, nd - 3
            batch_ax = rules.rules.get("batch")
            seq_ax = rules.rules.get("seq_tp")
            if batch_ax is not None:
                axes = (batch_ax,) if isinstance(batch_ax, str) else tuple(batch_ax)
                if leaf.shape[b_dim] % int(np.prod([sizes[a] for a in axes])) == 0:
                    spec[b_dim] = batch_ax
            if seq_ax is not None and leaf.shape[s_dim] % sizes[seq_ax] == 0:
                spec[s_dim] = seq_ax
            return P(*spec)
        # recurrent states: (..., B, ...) — batch on the dim matching known B
        batch_ax = rules.rules.get("batch")
        if batch_ax is not None:
            axes = (batch_ax,) if isinstance(batch_ax, str) else tuple(batch_ax)
            prod = int(np.prod([sizes[a] for a in axes]))
            spec = [None] * nd
            for i, dim in enumerate(leaf.shape):
                if dim % prod == 0 and dim >= prod and i < nd - 1:
                    spec[i] = batch_ax
                    return P(*spec)
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(one, cache_shapes)
