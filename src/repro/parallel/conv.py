"""Distributed conv2d execution: shard_map MEC with spatial halo exchange
(DESIGN.md §6).

The paper's Solution B parallelizes the o_h shifted GEMMs across threads
on one device; this module is the same idea at mesh scale.  One entry
point, :func:`sharded_conv2d`, partitions a convolution over one mesh
axis — or, composite, over TWO — in one of three base modes:

``batch``    input sharded on ``i_n``; kernel replicated.  No forward
             communication; the kernel cotangent is psum'd by the
             shard_map transpose.
``channel``  kernel sharded on ``k_c`` (output channels); input
             replicated.  No forward communication; the *input*
             cotangent is psum'd in the backward pass.
``spatial``  input sharded on ``i_h`` rows.  Because MEC's compact L
             (Eq. 3) lowers whole input rows, a device only needs the
             first ``k_h - s_h`` rows of its lower neighbour — the same
             overlap the ``fused2`` kernel fetches as its halo — which
             are exchanged with one ``lax.ppermute`` before the local
             conv.  The backward pass routes the halo cotangent back
             through the transposed permute automatically.

Composite partitions (:data:`COMPOSITE_PARTITIONS`) pair two base modes
over two *distinct* mesh axes — ``("batch", "spatial")`` shards the
input on ``(i_n, i_h)`` simultaneously, ``("batch", "channel")`` shards
input rows and kernel columns, ``("spatial", "channel")`` shards input
rows and kernel columns — so a ``data x model`` mesh is filled even
when no single dimension divides by the full chip count.  The halo
``ppermute`` runs only along the *spatial sub-axis*; the other sub-axis
adds no forward communication, exactly as in its 1-D mode.

Each mode wraps ``repro.core.conv_api.conv2d`` as its per-device body,
so every ``algorithm=`` backend (direct/im2col/fft/winograd/mec/Pallas)
and the MEC custom VJP compose with the partitioning unchanged.  With no
mesh (or a 1-way axis under ``partition="auto"``) the call degrades to
the single-device ``conv2d`` — the same model code runs everywhere.

Axis resolution: ``batch`` prefers the rules' first data-parallel axis,
``channel``/``spatial`` prefer the tensor-parallel axis; on a 1-D mesh
any partition uses its only axis.  Composite components resolve in
order, each skipping axes already claimed by an earlier component; when
the preference list is exhausted and exactly one mesh axis remains
unclaimed, that axis is used (so ``("spatial", "channel")`` lands on
``(model, data)``).  ``partition="auto"`` asks
``repro.launch.costmodel.pick_conv_partition`` (per-device memory +
halo/collective bytes) which viable partition — 1-D or composite — is
cheapest.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple, Union

import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.compat import shard_map
from repro.core.conv_api import ALGORITHMS, apply_padding, conv2d
from repro.core.convspec import ConvSpec, normalize_stride, spec_of
from repro.core.mec import SOLUTIONS
from repro.parallel.axes import ShardingRules, current_rules

PARTITIONS = ("batch", "channel", "spatial")
# Canonical composite partitions: two base modes over two distinct mesh
# axes.  ("channel", "channel") etc. make no sense (one operand dimension
# cannot shard over two axes here), and order is fixed so cost-model
# keys, bench record names, and axis tuples all line up.
COMPOSITE_PARTITIONS = (("batch", "spatial"), ("batch", "channel"),
                        ("spatial", "channel"))

Partition = Union[str, Tuple[str, ...]]


def normalize_partition(partition: Partition) -> Tuple[str, ...]:
    """Canonical component tuple of a partition argument.

    Accepts a base-mode string (``"spatial"``), a component tuple/list
    (``("batch", "spatial")``), or the serialized composite form
    (``"batch+spatial"``, as emitted by :func:`partition_name`).
    Returns a 1- or 2-tuple of base modes; composites must be one of
    :data:`COMPOSITE_PARTITIONS` (canonical order).
    """
    if isinstance(partition, str):
        parts = tuple(partition.split("+")) if "+" in partition \
            else (partition,)
    elif isinstance(partition, Sequence):
        parts = tuple(partition)
    else:
        raise ValueError(f"unknown partition {partition!r}")
    for p in parts:
        if p not in PARTITIONS:
            raise ValueError(
                f"unknown partition {partition!r}; components must be "
                f"from {PARTITIONS} (composites: {COMPOSITE_PARTITIONS})")
    if len(parts) == 1:
        return parts
    if parts not in COMPOSITE_PARTITIONS:
        raise ValueError(
            f"unknown composite partition {partition!r}; expected one of "
            f"{COMPOSITE_PARTITIONS} (canonical component order)")
    return parts


def partition_name(partition: Partition) -> str:
    """Serialized form: ``"spatial"`` / ``"batch+spatial"`` (bench
    records, dry-run tags; round-trips through normalize_partition)."""
    return "+".join(normalize_partition(partition))


def spatial_halo_rows(k_h: int, s_h: int) -> int:
    """Input rows a device needs from its lower neighbour: the window of
    the last local output row overhangs by ``k_h - s_h`` rows (0 when
    stride covers the kernel)."""
    return max(0, k_h - s_h)


def _component_viable(spec: ConvSpec, mode: str, n_dev: int) -> bool:
    if n_dev < 1:
        return False
    if mode == "batch":
        return spec.i_n % n_dev == 0
    if mode == "channel":
        return spec.k_c % n_dev == 0
    # spatial
    if spec.i_h % n_dev:
        return False
    h_loc = spec.i_h // n_dev
    return h_loc % spec.s_h == 0 and \
        spatial_halo_rows(spec.k_h, spec.s_h) <= h_loc


def partition_viable(spec: ConvSpec, partition: Partition,
                     n_dev: Union[int, Tuple[int, ...]]) -> bool:
    """Can ``spec`` be split ``n_dev``-ways along ``partition``?

    ``spatial`` additionally needs the per-device row count to be a
    stride multiple (so every device emits the same number of output
    rows) and the halo to fit in the immediate neighbour (single-hop
    ``ppermute``).  Composite partitions take a matching tuple of
    sub-axis sizes; components split independent dimensions, so
    viability is componentwise on the *global* spec.
    """
    parts = normalize_partition(partition)
    sizes = (n_dev,) if isinstance(n_dev, int) else tuple(n_dev)
    if len(sizes) != len(parts):
        raise ValueError(
            f"partition {partition!r} has {len(parts)} component(s) but "
            f"n_dev {n_dev!r} has {len(sizes)}")
    return all(_component_viable(spec, p, n) for p, n in zip(parts, sizes))


def _component_axis(mode: str, mesh: Mesh, rules: Optional[ShardingRules],
                    used: Tuple[str, ...]) -> str:
    names = mesh.axis_names
    if mode == "batch":
        prefer = tuple(rules.dp_axes) if rules else ()
        prefer += ("data", "pod")
    else:  # channel / spatial live on the tensor-parallel axis
        prefer = (rules.tp_axis,) if rules and rules.tp_axis else ()
        prefer += ("model",)
    for a in prefer:
        if a in names and a not in used:
            return a
    free = tuple(a for a in names if a not in used)
    if len(free) == 1:
        return free[0]
    raise ValueError(
        f"cannot infer a mesh axis for partition component {mode!r} on "
        f"mesh axes {names} (already claimed: {used}); pass axis= "
        "explicitly")


def default_axis(partition: Partition, mesh: Mesh,
                 rules: Optional[ShardingRules] = None
                 ) -> Union[str, Tuple[str, ...]]:
    """Mesh axis (or axis tuple, for composites) a partition runs over
    when the caller names none.  Composite components resolve in order,
    each skipping axes already claimed by an earlier one."""
    parts = normalize_partition(partition)
    axes: Tuple[str, ...] = ()
    for mode in parts:
        axes += (_component_axis(mode, mesh, rules, axes),)
    return axes[0] if len(parts) == 1 else axes


def _resolve_axes(parts: Tuple[str, ...], axis, mesh: Mesh,
                  rules: Optional[ShardingRules]) -> Tuple[str, ...]:
    """Explicit-or-default mesh axes, one per component, validated."""
    if axis is None:
        resolved = default_axis(parts if len(parts) > 1 else parts[0],
                                mesh, rules)
        return resolved if isinstance(resolved, tuple) else (resolved,)
    axes = (axis,) if isinstance(axis, str) else tuple(axis)
    if len(axes) != len(parts):
        raise ValueError(
            f"partition {parts!r} needs {len(parts)} mesh axis(es), got "
            f"axis={axis!r}")
    for a in axes:
        if a not in mesh.axis_names:
            raise ValueError(f"axis {a!r} not in mesh axes "
                             f"{mesh.axis_names}")
    if len(set(axes)) != len(axes):
        raise ValueError(f"composite partition axes must be distinct, "
                         f"got {axes!r}")
    return axes


def _partition_specs(axis_of: dict) -> Tuple[P, P, P]:
    """(input, kernel, output) PartitionSpecs from a mode->axis map."""
    return (P(axis_of.get("batch"), axis_of.get("spatial")),
            P(None, None, None, axis_of.get("channel")),
            P(axis_of.get("batch"), axis_of.get("spatial"), None,
              axis_of.get("channel")))


def conv_partition_specs(partition: Partition,
                         axis: Union[str, Tuple[str, ...]]
                         ) -> Tuple[P, P, P]:
    """(input, kernel, output) PartitionSpecs of one partition mode —
    what ``jax.jit`` in_shardings should look like so GSPMD does not
    reshard on entry (used by launch.dryrun).  ``axis`` pairs with the
    partition components positionally (tuple for composites)."""
    parts = normalize_partition(partition)
    axes = (axis,) if isinstance(axis, str) else tuple(axis)
    if len(axes) != len(parts):
        raise ValueError(f"partition {partition!r} needs {len(parts)} "
                         f"axis(es), got {axis!r}")
    return _partition_specs(dict(zip(parts, axes)))


def enumerate_partition_candidates(
        mesh: Mesh, rules: Optional[ShardingRules] = None,
        axis: Union[str, Tuple[str, ...], None] = None):
    """Every partition mode that can resolve mesh axes here:
    ``{mode: (axes_tuple, n_dev)}`` with ``n_dev`` an int for 1-D modes
    and a per-sub-axis tuple for composites.  Geometry viability is NOT
    filtered here — ``pick_conv_partition`` ranks/filters on the spec.
    Shared by ``sharded_conv2d(partition="auto")`` and the planner
    (``repro.plan.plan_conv2d``), so a plan records exactly the
    candidate set the executor would have enumerated."""
    candidates = {}
    if axis is None or isinstance(axis, str):
        for part in PARTITIONS:
            try:
                axes = _resolve_axes((part,), axis, mesh, rules)
            except ValueError:
                continue  # no resolvable axis -> mode not a candidate
            candidates[part] = (axes, int(mesh.shape[axes[0]]))
    if axis is None or not isinstance(axis, str):
        for comp in COMPOSITE_PARTITIONS:
            try:
                axes = _resolve_axes(comp, axis, mesh, rules)
            except ValueError:
                continue
            candidates[comp] = (
                axes, tuple(int(mesh.shape[a]) for a in axes))
    return candidates


def _validate_call(algorithm: str, solution: str) -> None:
    # Hoisted to the call site so a typo raises a plain ValueError here,
    # not a traced failure inside the shard_map body.
    if algorithm.lower() not in ALGORITHMS:
        raise ValueError(
            f"unknown algorithm {algorithm!r}; expected one of "
            f"{ALGORITHMS}")
    if solution not in SOLUTIONS:
        raise ValueError(
            f"unknown MEC solution {solution!r}; expected one of "
            f"{SOLUTIONS}")


def _single_device(x, kernel, stride, algorithm, solution, interpret,
                   precision):
    # x is already padded; partition="none" keeps the call from
    # re-entering the sharded path under installed rules.
    return conv2d(x, kernel, stride=stride, padding="VALID",
                  algorithm=algorithm, solution=solution,
                  interpret=interpret, precision=precision,
                  partition="none")


def sharded_conv2d(inp: jnp.ndarray, kernel: jnp.ndarray, *, stride=1,
                   padding="VALID", algorithm: str = "auto",
                   solution: str = "auto", partition: Partition = "auto",
                   axis: Union[str, Tuple[str, ...], None] = None,
                   mesh: Optional[Mesh] = None,
                   rules: Optional[ShardingRules] = None,
                   interpret: Optional[bool] = None,
                   precision=None) -> jnp.ndarray:
    """Distributed 2-D convolution, NHWC x HWIO -> NHWC.

    partition: 'batch' | 'channel' | 'spatial' | a composite tuple from
    :data:`COMPOSITE_PARTITIONS` (e.g. ``("batch", "spatial")``) | 'auto'.
    'auto' asks the cost model for the cheapest viable split — 1-D and
    composite candidates both enumerated — and degrades to the
    single-device ``conv2d`` when none is, or when there is no mesh.
    An *explicit* partition that cannot split the geometry raises.
    axis names the mesh axis (a tuple, paired positionally, for
    composites).  mesh/rules default to the installed ``parallel.axes``
    rules.
    """
    _validate_call(algorithm, solution)
    if rules is None:
        rules = current_rules()
    if mesh is None and rules is not None:
        mesh = rules.mesh
    if isinstance(axis, (tuple, list)):
        axis = axis[0] if len(axis) == 1 else tuple(axis)
    if axis is not None and mesh is not None:
        # An explicit axis must be valid even under partition="auto" —
        # a typo should raise, not silently lose all parallelism when
        # every candidate fails to resolve.
        names = (axis,) if isinstance(axis, str) else axis
        for a in names:
            if a not in mesh.axis_names:
                raise ValueError(
                    f"axis {a!r} not in mesh axes {mesh.axis_names}")
        if len(set(names)) != len(names):
            raise ValueError(f"partition axes must be distinct, got "
                             f"{axis!r}")
        if len(names) > 2:
            raise ValueError(f"at most 2 partition axes supported, got "
                             f"{axis!r}")

    s_h, s_w = normalize_stride(stride)
    k_h, k_w = kernel.shape[0], kernel.shape[1]
    x = apply_padding(inp, k_h, k_w, s_h, s_w, padding)
    spec = spec_of(x, kernel, (s_h, s_w))

    if partition != "auto":
        # Validate the partition even when there is no mesh to run it on.
        parts = normalize_partition(partition)
    if mesh is None:
        return _single_device(x, kernel, (s_h, s_w), algorithm, solution,
                              interpret, precision)

    if partition == "auto":
        # Lazy import mirrors conv_api's costmodel use: the launch layer
        # is consulted at call time, never at core/parallel import time.
        from repro.launch.costmodel import pick_conv_partition
        candidates = enumerate_partition_candidates(mesh, rules, axis)
        picked = pick_conv_partition(
            spec, {p: n for p, (_, n) in candidates.items()},
            dtype_bytes=jnp.dtype(x.dtype).itemsize)
        if picked is None:
            return _single_device(x, kernel, (s_h, s_w), algorithm,
                                  solution, interpret, precision)
        parts = normalize_partition(picked)
        axes, n_dev = candidates[picked]
    else:
        axes = _resolve_axes(parts, axis, mesh, rules)
        n_dev = tuple(int(mesh.shape[a]) for a in axes)
        n_dev = n_dev[0] if len(parts) == 1 else n_dev
        if not partition_viable(spec, parts, n_dev):
            raise ValueError(
                f"partition {partition!r} cannot split {spec} over "
                f"{n_dev} devices (axes {axes!r}); see "
                "parallel.conv.partition_viable")

    axis_of = dict(zip(parts, axes))
    x_spec, k_spec, o_spec = _partition_specs(axis_of)
    spatial_axis = axis_of.get("spatial")
    halo = spatial_halo_rows(k_h, s_h)
    n_spatial = int(mesh.shape[spatial_axis]) if spatial_axis else 1
    h_loc = spec.i_h // n_spatial

    def body(xb, kb):
        if spatial_axis and halo:
            # Each device ships its first `halo` rows one step down the
            # spatial sub-axis; the last device receives zeros (non-ring
            # permute) and its overhanging output rows are sliced off
            # below.  Other sub-axes (batch/channel) exchange nothing.
            nxt = lax.ppermute(xb[:, :halo], spatial_axis,
                               [(d + 1, d) for d in range(n_spatial - 1)])
            xb = jnp.concatenate([xb, nxt], axis=1)
        out = _single_device(xb, kb, (s_h, s_w), algorithm, solution,
                             interpret, precision)
        if spatial_axis:
            assert out.shape[1] == h_loc // s_h, (out.shape, h_loc, s_h)
        return out

    f = shard_map(body, mesh=mesh, in_specs=(x_spec, k_spec),
                  out_specs=o_spec, check_vma=False)
    out = f(x, kernel)
    if spatial_axis:
        # n_spatial * (h_loc / s_h) rows were produced; the trailing ones
        # (windows that overran the input into the zero halo) are not
        # real outputs.
        out = out[:, :spec.o_h]
    return out
