"""Distributed conv2d execution: shard_map MEC with spatial halo exchange
(DESIGN.md §6).

The paper's Solution B parallelizes the o_h shifted GEMMs across threads
on one device; this module is the same idea at mesh scale.  One entry
point, :func:`sharded_conv2d`, partitions a convolution over ONE mesh
axis in one of three ways:

``batch``    input sharded on ``i_n``; kernel replicated.  No forward
             communication; the kernel cotangent is psum'd by the
             shard_map transpose.
``channel``  kernel sharded on ``k_c`` (output channels); input
             replicated.  No forward communication; the *input*
             cotangent is psum'd in the backward pass.
``spatial``  input sharded on ``i_h`` rows.  Because MEC's compact L
             (Eq. 3) lowers whole input rows, a device only needs the
             first ``k_h - s_h`` rows of its lower neighbour — the same
             overlap the ``fused2`` kernel fetches as its halo — which
             are exchanged with one ``lax.ppermute`` before the local
             conv.  The backward pass routes the halo cotangent back
             through the transposed permute automatically.

Each mode wraps ``repro.core.conv_api.conv2d`` as its per-device body,
so every ``algorithm=`` backend (direct/im2col/fft/winograd/mec/Pallas)
and the MEC custom VJP compose with the partitioning unchanged.  With no
mesh (or a 1-way axis under ``partition="auto"``) the call degrades to
the single-device ``conv2d`` — the same model code runs everywhere.

Axis resolution: ``batch`` prefers the rules' first data-parallel axis,
``channel``/``spatial`` prefer the tensor-parallel axis; on a 1-D mesh
any partition uses its only axis.  ``partition="auto"`` asks
``repro.launch.costmodel.pick_conv_partition`` (per-device memory +
halo/collective bytes) which viable partition is cheapest.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.compat import shard_map
from repro.core.conv_api import apply_padding, conv2d, _norm_stride
from repro.core.convspec import ConvSpec, spec_of
from repro.parallel.axes import ShardingRules, current_rules

PARTITIONS = ("batch", "channel", "spatial")


def spatial_halo_rows(k_h: int, s_h: int) -> int:
    """Input rows a device needs from its lower neighbour: the window of
    the last local output row overhangs by ``k_h - s_h`` rows (0 when
    stride covers the kernel)."""
    return max(0, k_h - s_h)


def partition_viable(spec: ConvSpec, partition: str, n_dev: int) -> bool:
    """Can ``spec`` be split ``n_dev``-ways along ``partition``?

    ``spatial`` additionally needs the per-device row count to be a
    stride multiple (so every device emits the same number of output
    rows) and the halo to fit in the immediate neighbour (single-hop
    ``ppermute``).
    """
    if n_dev < 1:
        return False
    if partition == "batch":
        return spec.i_n % n_dev == 0
    if partition == "channel":
        return spec.k_c % n_dev == 0
    if partition == "spatial":
        if spec.i_h % n_dev:
            return False
        h_loc = spec.i_h // n_dev
        return h_loc % spec.s_h == 0 and \
            spatial_halo_rows(spec.k_h, spec.s_h) <= h_loc
    raise ValueError(f"unknown partition {partition!r}; "
                     f"expected one of {PARTITIONS}")


def default_axis(partition: str, mesh: Mesh,
                 rules: Optional[ShardingRules] = None) -> str:
    """Mesh axis a partition runs over when the caller names none."""
    names = mesh.axis_names
    if partition == "batch":
        prefer = tuple(rules.dp_axes) if rules else ()
        prefer += ("data", "pod")
    else:  # channel / spatial live on the tensor-parallel axis
        prefer = (rules.tp_axis,) if rules and rules.tp_axis else ()
        prefer += ("model",)
    for a in prefer:
        if a in names:
            return a
    if len(names) == 1:
        return names[0]
    raise ValueError(
        f"cannot infer a mesh axis for partition={partition!r} on mesh "
        f"axes {names}; pass axis= explicitly")


def _single_device(x, kernel, stride, algorithm, solution, interpret,
                   precision):
    # x is already padded; partition="none" keeps the call from
    # re-entering the sharded path under installed rules.
    return conv2d(x, kernel, stride=stride, padding="VALID",
                  algorithm=algorithm, solution=solution,
                  interpret=interpret, precision=precision,
                  partition="none")


def sharded_conv2d(inp: jnp.ndarray, kernel: jnp.ndarray, *, stride=1,
                   padding="VALID", algorithm: str = "auto",
                   solution: str = "auto", partition: str = "auto",
                   axis: Optional[str] = None, mesh: Optional[Mesh] = None,
                   rules: Optional[ShardingRules] = None,
                   interpret: Optional[bool] = None,
                   precision=None) -> jnp.ndarray:
    """Distributed 2-D convolution, NHWC x HWIO -> NHWC.

    partition: 'batch' | 'channel' | 'spatial' | 'auto'.  'auto' asks the
    cost model for the cheapest viable split (and degrades to the
    single-device ``conv2d`` when none is, or when there is no mesh).
    An *explicit* partition that cannot split the geometry raises.
    mesh/rules default to the installed ``parallel.axes`` rules.
    """
    if rules is None:
        rules = current_rules()
    if mesh is None and rules is not None:
        mesh = rules.mesh

    s_h, s_w = _norm_stride(stride)
    k_h, k_w = kernel.shape[0], kernel.shape[1]
    x = apply_padding(inp, k_h, k_w, s_h, s_w, padding)
    spec = spec_of(x, kernel, (s_h, s_w))

    if mesh is None:
        if partition not in PARTITIONS + ("auto",):
            raise ValueError(f"unknown partition {partition!r}")
        return _single_device(x, kernel, (s_h, s_w), algorithm, solution,
                              interpret, precision)

    if partition == "auto":
        # Lazy import mirrors conv_api's costmodel use: the launch layer
        # is consulted at call time, never at core/parallel import time.
        from repro.launch.costmodel import pick_conv_partition
        sizes = {}
        for part in PARTITIONS:
            try:
                ax = axis or default_axis(part, mesh, rules)
            except ValueError:
                continue      # no resolvable axis -> mode not a candidate
            sizes[part] = (ax, int(mesh.shape[ax]))
        picked = pick_conv_partition(
            spec, {p: n for p, (_, n) in sizes.items()},
            dtype_bytes=jnp.dtype(x.dtype).itemsize)
        if picked is None:
            return _single_device(x, kernel, (s_h, s_w), algorithm,
                                  solution, interpret, precision)
        partition, (axis, n_dev) = picked, sizes[picked]
    else:
        if partition not in PARTITIONS:
            raise ValueError(f"unknown partition {partition!r}; expected "
                             f"one of {PARTITIONS + ('auto',)}")
        axis = axis or default_axis(partition, mesh, rules)
        n_dev = int(mesh.shape[axis])
        if not partition_viable(spec, partition, n_dev):
            raise ValueError(
                f"partition {partition!r} cannot split {spec} over "
                f"{n_dev} devices (axis {axis!r}); see "
                "parallel.conv.partition_viable")

    def body(xb, kb):
        return _single_device(xb, kb, (s_h, s_w), algorithm, solution,
                              interpret, precision)

    if partition == "batch":
        f = shard_map(body, mesh=mesh, in_specs=(P(axis), P()),
                      out_specs=P(axis), check_vma=False)
        return f(x, kernel)

    if partition == "channel":
        f = shard_map(body, mesh=mesh,
                      in_specs=(P(), P(None, None, None, axis)),
                      out_specs=P(None, None, None, axis), check_vma=False)
        return f(x, kernel)

    # spatial: halo exchange then a VALID conv per device.
    halo = spatial_halo_rows(k_h, s_h)
    h_loc = spec.i_h // n_dev

    def spatial_body(xb, kb):
        if halo:
            # Each device ships its first `halo` rows one step down the
            # axis; the last device receives zeros (non-ring permute) and
            # its overhanging output rows are sliced off below.
            nxt = lax.ppermute(xb[:, :halo], axis,
                               [(d + 1, d) for d in range(n_dev - 1)])
            xb = jnp.concatenate([xb, nxt], axis=1)
        out = body(xb, kb)
        assert out.shape[1] == h_loc // s_h, (out.shape, h_loc, s_h)
        return out

    f = shard_map(spatial_body, mesh=mesh,
                  in_specs=(P(None, axis), P()),
                  out_specs=P(None, axis), check_vma=False)
    out = f(x, kernel)
    # n_dev * (h_loc / s_h) rows were produced; the trailing ones (windows
    # that overran the input into the zero halo) are not real outputs.
    return out[:, :spec.o_h]


def conv_partition_specs(partition: str, axis: str) -> Tuple[P, P, P]:
    """(input, kernel, output) PartitionSpecs of one partition mode —
    what ``jax.jit`` in_shardings should look like so GSPMD does not
    reshard on entry (used by launch.dryrun)."""
    if partition == "batch":
        return P(axis), P(), P(axis)
    if partition == "channel":
        return P(), P(None, None, None, axis), P(None, None, None, axis)
    if partition == "spatial":
        return P(None, axis), P(), P(None, axis)
    raise ValueError(f"unknown partition {partition!r}")
